"""Paper Figs. 5-6 + Tabs. 2/5/7 analogue: bit-allocation strategy shootout.

Compares PPL (+ task probe) of the quantized smoke Mixtral under:
uniform 2/3-bit, random allocation, frequency-only, weight-only, Hessian
trace, F-norm(eps)-only, and full PMQ — at matched mean-bit budgets.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import Table, calib_tokens, trained_smoke_mixtral
from repro.config import CompressionConfig
from repro.core import allocation as alloc_lib
from repro.core import pipeline
from repro.core import pmq as pmq_lib
from repro.eval.perplexity import eval_tokens, perplexity
from repro.models.transformer import MCRuntime


def run(verbose: bool = True) -> Table:
    cfg, model, params = trained_smoke_mixtral()
    calib = calib_tokens(cfg)
    ev = eval_tokens(cfg, n_seq=6, seq_len=96)
    fp_ppl = perplexity(model, params, ev)

    table = Table("PMQ allocation shootout (smoke Mixtral, Fig5/6+Tab2)",
                  ["method", "target_bits", "avg_bits", "ppl",
                   "ppl_ratio_vs_fp16"])
    table.add("fp32 (reference)", 32, 32, fp_ppl, 1.0)

    def eval_artifact(artifact):
        rt = artifact.runtime
        return perplexity(model, artifact.params, ev,
                          mc=MCRuntime(odp=None, quant_meta=rt.quant_meta,
                                       layer_metas=rt.layer_metas))

    # staged API: one calibration, a cheap re-plan per bit target
    record = pipeline.calibrate(model, params, calib,
                                bit_choices=(1, 2, 3), group_size=32)
    for target in (2.5, 2.0, 1.6):
        ccfg = CompressionConfig(enabled=True, target_bits=target,
                                 group_size=32, odp_enabled=False)
        artifact = pipeline.apply(
            model, params, pipeline.plan(record, ccfg, layout="uniform"),
            record)
        ppl = eval_artifact(artifact)
        table.add("PMQ (ours)", target, round(artifact.report.avg_bits, 3),
                  ppl, ppl / fp_ppl)

    # uniform baselines (single-width bit_choices need their own probes)
    for bits in (3, 2):
        ccfg = CompressionConfig(enabled=True, target_bits=float(bits),
                                 bit_choices=(bits,), group_size=32,
                                 odp_enabled=False)
        record.ensure_eps(model, params, (bits,), 32)
        artifact = pipeline.apply(
            model, params, pipeline.plan(record, ccfg, layout="uniform"),
            record)
        ppl = eval_artifact(artifact)
        table.add(f"uniform {bits}-bit", bits, bits, ppl, ppl / fp_ppl)

    # single-metric greedy baselines at 2.5 bits via forced assignment
    moe_slots = [s for s in range(model.period)
                 if model.slot_kinds[s] == "moe"]
    eps_tables = record.eps[((1, 2, 3), 32)]

    def greedy_eval(metric_name):
        ccfg = CompressionConfig(enabled=True, target_bits=2.5,
                                 group_size=32, odp_enabled=False)
        q_layers, metas = [], []
        for li, lc in enumerate(record.layers):
            moe_p = pipeline._get_moe_params(params, model, moe_slots, li)
            eps = eps_tables[li]
            if metric_name == "random":
                rng = np.random.RandomState(li)
                bits = alloc_lib.allocate_random(cfg.num_experts, 2.5, rng)
            else:
                metric = {
                    "freq_only": lc.frequency,
                    "weight_only": lc.mean_weight,
                    "fnorm_only": eps[:, 1],
                    "hessian": eps[:, 1] / np.maximum(
                        lc.frequency, 1e-6),  # loss-only proxy
                }[metric_name]
                bits = alloc_lib.allocate_greedy_metric(metric, 2.5)
            counts = tuple(int((bits == b).sum()) for b in (1, 2, 3))
            qp_l, meta, _ = pmq_lib.compress_moe_layer(
                cfg, ccfg, moe_p, jnp.asarray(lc.x), lc.topk_idx,
                lc.topk_weights, layer_idx=li, forced_counts=counts)
            q_layers.append(qp_l)
            metas.append(meta)
        new_params = dict(params)
        new_params["moe_layers"] = q_layers
        ppl = perplexity(model, new_params, ev, metas=metas)
        avg = float(np.mean([np.dot(m.bit_classes, m.class_counts)
                             / cfg.num_experts for m in metas]))
        return ppl, avg

    for name in ("random", "freq_only", "weight_only", "fnorm_only"):
        ppl, avg = greedy_eval(name)
        table.add(name, 2.5, round(avg, 3), ppl, ppl / fp_ppl)

    if verbose:
        print(table.render())
    return table


if __name__ == "__main__":
    run()
