"""Per-host artifact loading: bytes read + wall time, full vs sharded.

The MC paper's deployment premise is that 2-3-bit experts make MoE weights
cheap to *move*; this bench measures the loading half of that claim. A
:class:`repro.core.pipeline.CompressedArtifact` is saved in the
expert-major shard layout (one fingerprinted shard group per (layer,
expert) + dense groups), then loaded three ways:

* full single-host restore (``CompressedArtifact.load``) — the baseline
  every host used to pay;
* per-host streaming restore (``CompressedArtifact.load_sharded`` with
  ``num_hosts``/``host``) — each host reads the dense groups plus only the
  expert block it owns;
* union check — the per-host subset trees are merged back
  (``checkpointer.merge_subset_trees``) and compared leaf-for-leaf against
  the full restore, so the streaming path is provably lossless.

Reported per host: bytes read, fraction of the artifact, shard-group/file
counts, and load seconds. ``tests/test_artifact_sharding.py`` pins the
headline: with 2 hosts each host reads < 60% of the artifact bytes.

    PYTHONPATH=src python -m benchmarks.bench_artifact_loading
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path
from typing import Dict, Optional

import jax
import numpy as np

from benchmarks.common import Table
from repro.checkpoint import checkpointer as ckpt_lib
from repro.config import CompressionConfig
from repro.configs import get_config
from repro.core import pipeline
from repro.models.transformer import DecoderModel


def build_artifact(directory, *, num_experts: int = 16, d_model: int = 64,
                   moe_d_ff: int = 1024, num_layers: int = 2,
                   vocab_size: int = 128, group_size: int = 32,
                   target_bits: float = 2.5, layout: str = "uniform",
                   seed: int = 0, bits_override=None,
                   capacity_factor: float = 4.0):
    """Compress a reduced expert-heavy Mixtral and save the artifact.

    Expert-heavy on purpose (wide ``moe_d_ff``, small attention): in real
    MoE LLMs experts are >96% of the weights, and the per-host savings of
    sharded loading scale with exactly that ratio.

    ``bits_override``: optional per-expert bit widths forced into every
    layer's plan — the distributed benches/tests use it to pin class
    counts that divide the expert-parallel axis.

    Returns ``(model, artifact, step_dir)``.
    """
    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        dtype="float32", num_layers=num_layers, d_model=d_model,
        d_ff=d_model, moe_d_ff=moe_d_ff, num_experts=num_experts,
        vocab_size=vocab_size, capacity_factor=capacity_factor,
        scan_layers=False)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    calib = jax.random.randint(jax.random.PRNGKey(seed + 1), (2, 48), 0,
                               cfg.vocab_size)
    record = pipeline.calibrate(model, params, calib,
                                bit_choices=(1, 2, 3),
                                group_size=group_size)
    ccfg = CompressionConfig(enabled=True, target_bits=target_bits,
                             group_size=group_size, odp_enabled=True)
    cplan = pipeline.plan(record, ccfg, layout=layout)
    if bits_override is not None:
        bits = np.asarray(bits_override)
        assert bits.shape == (num_experts,), bits.shape
        cplan.layers = [pipeline._make_layer_plan(lp.layer, bits,
                                                  lp.objective)
                        for lp in cplan.layers]
    artifact = pipeline.apply(model, params, cplan, record)
    step_dir = artifact.save(directory)
    return model, artifact, step_dir


def _tree_equal(a, b) -> bool:
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    pa = {jax.tree_util.keystr(kp): leaf for kp, leaf in fa}
    pb = {jax.tree_util.keystr(kp): leaf for kp, leaf in fb}
    if set(pa) != set(pb):
        return False
    return all(np.array_equal(np.asarray(pa[k]), np.asarray(pb[k]))
               for k in pa)


def distributed_placement_report(directory, built, n_procs: int = 2):
    """Per-process bytes of the **distributed boot path**: what each
    ``jax.distributed`` process streams — and holds resident after
    ``pipeline.distributed_params`` placement — when booting from its
    placement slice (one block per bit class,
    ``moe_parallel.ep_owned_ranges``) plus the replicated dense groups.

    Returns per-process rows, or ``{"skipped": reason}`` when the class
    layout cannot split over ``n_procs`` (counts must divide the axis).
    """
    from repro.sharding import moe_parallel as mp
    meta = built.metas[0]
    try:
        # only the layout question is skippable — a class layout that
        # cannot split over n_procs is a property of the artifact, while
        # a failing load below is a real error that must propagate
        shard_ranges = [mp.ep_owned_ranges(meta, n_procs, r)
                        for r in range(n_procs)]
    except ValueError as e:
        return {"skipped": str(e)}
    rows = []
    for ranges in shard_ranges:
        def keep(path, group, ranges=ranges):
            e = pipeline.expert_of_group(group)
            return e is None or any(a <= e < b for a, b in ranges)

        t0 = time.time()
        _, _, st = ckpt_lib.load_pytree_subset(directory, keep)
        rows.append({
            "ranges": list(ranges),
            "placed_bytes": st.bytes_read,
            "frac": st.read_fraction,
            "groups": f"{st.groups_read}/{st.total_groups}",
            "seconds": time.time() - t0,
        })
    return {"procs": rows, "max_proc_frac": max(r["frac"] for r in rows)}


def run(n_hosts: int = 2, verbose: bool = True,
        directory: Optional[str] = None, **build_kw) -> Dict:
    """Build + save an artifact, then measure full vs per-host loading.

    Returns a dict with ``total_bytes``, ``full_s``, per-``hosts`` entries
    (``experts``, ``bytes``, ``frac``, ``groups``, ``seconds``),
    ``max_host_frac``, ``union_exact``, and the ``distributed`` per-process
    placed-bytes report for the multi-process boot path.
    """
    tmp = None
    if directory is None:
        tmp = tempfile.TemporaryDirectory()
        directory = tmp.name
    directory = Path(directory) / "artifact"
    if "bits_override" not in build_kw and \
            build_kw.get("num_experts", 16) == 16:
        # pin class counts (6, 4, 6) so the distributed report can split
        # every class over the default 2-process axis
        build_kw["bits_override"] = [1] * 6 + [2] * 4 + [3] * 6
    try:
        t0 = time.time()
        _, built, _ = build_artifact(directory, **build_kw)
        build_s = time.time() - t0
        n_experts = built.num_experts

        t0 = time.time()
        full = pipeline.CompressedArtifact.load(directory)
        full_s = time.time() - t0
        total_bytes = full.load_stats.total_bytes

        hosts = []
        parts = []
        for h in range(n_hosts):
            t0 = time.time()
            art = pipeline.CompressedArtifact.load_sharded(
                directory, num_hosts=n_hosts, host=h)
            dt = time.time() - t0
            st = art.load_stats
            parts.append((art.params, st))
            hosts.append({
                "experts": art.expert_range,
                "bytes": st.bytes_read,
                "frac": st.read_fraction,
                "groups": f"{st.groups_read}/{st.total_groups}",
                "seconds": dt,
            })

        merged = ckpt_lib.merge_subset_trees(parts)
        union_exact = _tree_equal(merged, full.params)
        distributed = distributed_placement_report(directory, built,
                                                   n_procs=n_hosts)

        out = {
            "total_bytes": total_bytes,
            "build_s": build_s,
            "full_s": full_s,
            "n_hosts": n_hosts,
            "hosts": hosts,
            "max_host_frac": max(h["frac"] for h in hosts),
            "union_exact": union_exact,
            "distributed": distributed,
        }
        if verbose:
            print(f"artifact: {total_bytes / 1e6:.2f} MB, "
                  f"{n_experts} experts, built in {build_s:.1f}s; "
                  f"full load {full_s:.2f}s")
            tab = Table("sharded artifact loading (per host)",
                        ["host", "experts", "bytes", "frac", "groups",
                         "load_s"])
            for h, row in enumerate(hosts):
                k0, k1 = row["experts"]
                tab.add(f"{h}/{n_hosts}", f"[{k0}:{k1})",
                        f"{row['bytes'] / 1e6:.2f} MB",
                        f"{row['frac']:.0%}", row["groups"],
                        f"{row['seconds']:.2f}")
            print(tab.render())
            print(f"union of host subsets == full tree: {union_exact}")
            print(f"max per-host fraction: {out['max_host_frac']:.0%} "
                  "(acceptance: < 60% at 2 hosts)")
            if "skipped" in distributed:
                print("distributed boot report skipped: "
                      f"{distributed['skipped']}")
            else:
                tab = Table("distributed boot (per jax.distributed "
                            "process: placement slice + dense groups)",
                            ["proc", "expert ranges", "placed_bytes",
                             "frac", "groups", "load_s"])
                for r, row in enumerate(distributed["procs"]):
                    tab.add(f"{r}/{n_hosts}",
                            str([f"[{a}:{b})" for a, b in row["ranges"]]),
                            f"{row['placed_bytes'] / 1e6:.2f} MB",
                            f"{row['frac']:.0%}", row["groups"],
                            f"{row['seconds']:.2f}")
                print(tab.render())
                print("max per-process placed fraction: "
                      f"{distributed['max_proc_frac']:.0%}")
        return out
    finally:
        if tmp is not None:
            tmp.cleanup()


if __name__ == "__main__":
    run()
