"""Chaos benchmark: fleet correctness and tail latency under an
unreliable transport.

Drives real compressed-artifact replicas through the message-based
router (``serve.transport`` + hardened ``serve.router``) and reports the
numbers CI's tier1-slow gate checks (``BENCH_chaos.json``):

* ``baseline``  — fault-free run on the reliable transport: the token
  reference and the completion-tick floor;
* ``schedules`` — ≥ 3 seeded chaos schedules (drops, duplicates, delays,
  reorders, plus a scripted partition and a replica kill) asserting the
  chaos invariants per schedule: zero lost requests (every admitted one
  completes), zero duplicated decode work (per-replica dedup max 1),
  token identity with the fault-free run, balanced ``FleetReport``
  accounting; the dedup-hit counter proves duplicate deliveries really
  occurred and were absorbed;
* ``hedging``   — straggler A/B: one replica slows 8× mid-run; with
  hedging the straggler's outstanding requests are raced on the
  least-loaded survivor and p99 completion tick must drop.

Invariant violations raise — a chaos regression fails the benchmark
run itself, not just a downstream JSON gate.
"""
import tempfile
from pathlib import Path

import numpy as np

from benchmarks.bench_artifact_loading import build_artifact
from repro.runtime.supervisor import (FaultEvent, FaultInjector,
                                      KILL_REPLICA, PARTITION,
                                      SLOW_REPLICA)
from repro.serve.engine import GenerationOptions, Request
from repro.serve.fleet import ShardedReplica
from repro.serve.router import FleetRouter, RouterConfig
from repro.serve.transport import ChaosConfig, FaultyTransport


def _requests(vocab: int, n: int, max_new: int):
    return [Request(uid=i,
                    prompt=np.arange(1 + i, 9 + i, dtype=np.int32) % vocab,
                    options=GenerationOptions(max_new_tokens=max_new,
                                              odp="off"))
            for i in range(n)]


def _pool(model, directory, replicas):
    return [ShardedReplica(model, directory, replica_id=i, num_hosts=2,
                           blocks_per_host=2, batch_size=2, odp="off")
            for i in range(replicas)]


def _tokens(rpt):
    return {r.uid: [int(t) for t in r.tokens]
            for r in rpt.completed.values()}


def _p99(rpt):
    ticks = sorted(rpt.completion_ticks.values())
    return float(np.percentile(ticks, 99)) if ticks else float("nan")


def run(verbose: bool = True, n_requests: int = 6, max_new: int = 6,
        seeds=(1, 2, 3)):
    work = Path(tempfile.mkdtemp(prefix="bench_chaos_"))
    model, _, _ = build_artifact(
        work / "artifact", num_experts=16, d_model=32, moe_d_ff=384,
        vocab_size=64, group_size=32, capacity_factor=32.0)
    art_dir = work / "artifact"
    vocab = model.cfg.vocab_size
    out = {}

    # -- fault-free reference ----------------------------------------------
    router = FleetRouter(_pool(model, art_dir, 2), work / "hb_base",
                         config=RouterConfig())
    rpt = router.run(_requests(vocab, n_requests, max_new))
    reference = _tokens(rpt)
    out["baseline"] = {
        "admitted": rpt.admitted, "completed": len(rpt.completed),
        "ticks": rpt.ticks, "p99_completion_tick": _p99(rpt),
    }
    if verbose:
        print(f"[chaos] baseline: {len(rpt.completed)}/{rpt.admitted} "
              f"in {rpt.ticks} ticks")

    # -- seeded chaos schedules --------------------------------------------
    schedules = []
    for i, seed in enumerate(seeds):
        chaos = ChaosConfig(seed=seed, p_drop=0.12, p_dup=0.12,
                            p_delay=0.15, p_reorder=0.15, max_delay=2,
                            until=40)
        # compose message chaos with scripted process/network faults:
        # schedule 0 also kills a replica, schedule 1 also partitions one
        events = []
        if i == 0:
            events.append(FaultEvent(tick=6, kind=KILL_REPLICA,
                                     replica=0))
        elif i == 1:
            events.append(FaultEvent(tick=4, kind=PARTITION, replica=1,
                                     until=14))
        router = FleetRouter(
            _pool(model, art_dir, 2), work / f"hb_s{seed}",
            config=RouterConfig(seed=seed, max_retries=20,
                                max_redispatch=100),
            injector=FaultInjector(events),
            transport=FaultyTransport(chaos))
        rpt = router.run(_requests(vocab, n_requests, max_new))

        lost = sorted(set(reference) - set(rpt.completed))
        dup_decodes = max((max(n.decode_submissions.values(), default=0)
                           for n in router.nodes.values()), default=0)
        token_identical = _tokens(rpt) == reference
        row = {
            "seed": seed,
            "extra_fault": (events[0].kind if events else None),
            "admitted": rpt.admitted, "completed": len(rpt.completed),
            "lost": len(lost),
            "max_decodes_per_replica": dup_decodes,
            "duplicate_results": rpt.duplicate_results,
            "ghost_results": rpt.ghost_results,
            "dedup_hits": rpt.dedup_hits,
            "retries": rpt.retries, "redispatches": rpt.redispatches,
            "deaths": len(rpt.deaths),
            "token_identical": token_identical,
            "ticks": rpt.ticks,
            "transport": rpt.transport,
        }
        schedules.append(row)
        if verbose:
            print(f"[chaos] seed {seed}: completed {row['completed']}/"
                  f"{row['admitted']}, dedup_hits {row['dedup_hits']}, "
                  f"token_identical {token_identical}")
        if lost or not token_identical or dup_decodes > 1:
            raise AssertionError(
                f"chaos invariant violated at seed {seed}: lost={lost} "
                f"token_identical={token_identical} "
                f"max_decodes_per_replica={dup_decodes}")
    if not any(r["dedup_hits"] > 0 for r in schedules):
        raise AssertionError(
            "no schedule exercised replica-side dedup (dedup_hits == 0 "
            "everywhere) — the chaos probabilities are too tame to "
            "certify the exactly-once path")
    out["schedules"] = schedules

    # -- hedging A/B under a straggler -------------------------------------
    hedging = {}
    for mode, hedge in (("hedge_on", True), ("hedge_off", False)):
        inj = FaultInjector([FaultEvent(tick=12, kind=SLOW_REPLICA,
                                        replica=0, factor=8)])
        router = FleetRouter(
            _pool(model, art_dir, 2), work / f"hb_{mode}",
            config=RouterConfig(hedge=hedge),
            injector=inj, transport=FaultyTransport())
        rpt = router.run(_requests(vocab, n_requests, 12))
        hedging[mode] = {
            "completed": len(rpt.completed), "admitted": rpt.admitted,
            "hedges": rpt.hedges, "hedge_wins": rpt.hedge_wins,
            "p99_completion_tick": _p99(rpt), "ticks": rpt.ticks,
        }
        if verbose:
            print(f"[chaos] {mode}: p99 completion tick "
                  f"{hedging[mode]['p99_completion_tick']:.0f} "
                  f"({rpt.hedges} hedges, {rpt.hedge_wins} wins)")
    if hedging["hedge_on"]["p99_completion_tick"] >= \
            hedging["hedge_off"]["p99_completion_tick"]:
        raise AssertionError(
            "hedging did not help: p99 completion tick "
            f"{hedging['hedge_on']['p99_completion_tick']} (on) vs "
            f"{hedging['hedge_off']['p99_completion_tick']} (off)")
    out["hedging"] = hedging
    return out


if __name__ == "__main__":
    run()
