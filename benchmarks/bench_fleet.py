"""Fleet serving benchmark: availability, recovery, delta re-shard bytes.

Boots a small fleet of block-owning replicas from one expert-major
artifact and drives three scripted scenarios through the router:

* ``baseline``   — no faults: every admitted request completes;
* ``replica_kill`` — one replica dies mid-decode: the supervisor detects
  the silence, its requests retry on the survivor, availability stays 1;
* ``host_loss``  — one replica loses a host mid-decode: in-flight work is
  drained, only the orphaned expert blocks are re-streamed (delta bytes
  strictly below a full reload), and the drained requests resume
  token-identically.

The JSON (``BENCH_fleet.json`` via ``benchmarks.run --json``) carries the
numbers CI gates on: per-scenario completed/admitted counts, recovery
ticks, and delta vs full-reload bytes.
"""
import tempfile
from pathlib import Path

import numpy as np

from benchmarks.bench_artifact_loading import build_artifact
from repro.runtime.supervisor import (FaultEvent, FaultInjector, KILL_HOST,
                                      KILL_REPLICA)
from repro.serve.engine import GenerationOptions, Request
from repro.serve.fleet import ShardedReplica
from repro.serve.router import FleetRouter, RouterConfig


def _requests(vocab: int, n: int, max_new: int):
    return [Request(uid=i,
                    prompt=np.arange(1 + i, 9 + i, dtype=np.int32) % vocab,
                    options=GenerationOptions(max_new_tokens=max_new,
                                              odp="off"))
            for i in range(n)]


def _fleet(model, directory, hb, *, replicas, injector):
    pool = [ShardedReplica(model, directory, replica_id=i, num_hosts=2,
                           blocks_per_host=2, batch_size=2, odp="off")
            for i in range(replicas)]
    return FleetRouter(pool, hb, config=RouterConfig(),
                       injector=injector), pool


def run(verbose: bool = True, n_requests: int = 6, max_new: int = 6):
    work = Path(tempfile.mkdtemp(prefix="bench_fleet_"))
    model, _, _ = build_artifact(
        work / "artifact", num_experts=16, d_model=32, moe_d_ff=384,
        vocab_size=64, group_size=32, capacity_factor=32.0)
    art_dir = work / "artifact"
    vocab = model.cfg.vocab_size
    out = {}

    # -- baseline: no faults ------------------------------------------------
    router, _ = _fleet(model, art_dir, work / "hb0", replicas=2,
                       injector=FaultInjector([]))
    rpt = router.run(_requests(vocab, n_requests, max_new))
    baseline = {r.uid: [int(t) for t in r.tokens]
                for r in rpt.completed.values()}
    out["baseline"] = {
        "admitted": rpt.admitted, "completed": len(rpt.completed),
        "availability": rpt.availability, "ticks": rpt.ticks,
    }

    # -- replica kill mid-decode -------------------------------------------
    router, _ = _fleet(
        model, art_dir, work / "hb1", replicas=2,
        injector=FaultInjector([FaultEvent(tick=3, kind=KILL_REPLICA,
                                           replica=0)]))
    rpt = router.run(_requests(vocab, n_requests, max_new))
    got = {r.uid: [int(t) for t in r.tokens] for r in rpt.completed.values()}
    out["replica_kill"] = {
        "admitted": rpt.admitted, "completed": len(rpt.completed),
        "availability": rpt.availability, "ticks": rpt.ticks,
        "retries": rpt.retries, "deaths": rpt.deaths,
        "token_identical": got == baseline,
    }

    # -- host loss mid-decode: live delta re-shard --------------------------
    router, pool = _fleet(
        model, art_dir, work / "hb2", replicas=1,
        injector=FaultInjector([FaultEvent(tick=3, kind=KILL_HOST,
                                           replica=0, host=0)]))
    rpt = router.run(_requests(vocab, n_requests, max_new))
    got = {r.uid: [int(t) for t in r.tokens] for r in rpt.completed.values()}
    ev = rpt.reshards[0]
    st = pool[0].load_stats
    out["host_loss"] = {
        "admitted": rpt.admitted, "completed": len(rpt.completed),
        "availability": rpt.availability, "ticks": rpt.ticks,
        "requeued": ev.requeued, "blocks_moved": ev.blocks_moved,
        "delta_bytes": ev.delta_bytes,
        "full_reload_bytes": ev.full_reload_bytes,
        "delta_fraction": ev.delta_bytes / max(ev.full_reload_bytes, 1),
        "cumulative_bytes_read": st.bytes_read,
        "reads": st.reads,
        "token_identical": got == baseline,
    }

    if verbose:
        for name, row in out.items():
            print(f"[fleet] {name}: " + ", ".join(
                f"{k}={v}" for k, v in row.items() if k != "deaths"))
    return out


if __name__ == "__main__":
    run()
