"""Kernel benchmark: fused dequant GEMM vs references.

Correctness deltas (interpret mode vs jnp oracle), packed-size accounting
(the HBM-bandwidth claim of the kernel), and CPU wall-clock for the XLA
fallback path (relative across bit-widths; absolute numbers are CPU-bound
and labeled as such — the TPU target numbers come from §Roofline).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table
from repro.kernels.common import pack_kernel_layout
from repro.kernels.quant_matmul.ops import quant_matmul
from repro.kernels.quant_matmul.ref import quant_matmul_ref
from repro.quant import rtn_quantize


def run(verbose: bool = True):
    k, n, m = 512, 512, 64
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1

    t = Table("quant_matmul kernel: correctness + bytes",
              ["bits", "max_abs_err(interp_vs_ref)", "weight_bytes",
               "vs_bf16", "xla_path_ms"])
    bf16_bytes = k * n * 2
    for bits in (1, 2, 3, 4):
        res = rtn_quantize(w, bits=bits, group_size=128)
        planes = pack_kernel_layout(res.codes, bits, 128)
        ref = quant_matmul_ref(x, planes, res.scales, res.zeros, bits=bits,
                               group_size=128, pack_block=128)
        out = quant_matmul(x, planes, res.scales, res.zeros, bits=bits,
                           group_size=128, impl="interpret")
        err = float(jnp.abs(out - ref).max())
        pb = sum(int(np.prod(p.shape)) for p in planes)
        sb = res.scales.size * 2 + (res.zeros.size * 2 if bits > 1 else 0)

        fn = jax.jit(lambda xx: quant_matmul(
            xx, planes, res.scales, res.zeros, bits=bits, group_size=128,
            impl="auto"))
        fn(x).block_until_ready()
        t0 = time.time()
        for _ in range(10):
            fn(x).block_until_ready()
        ms = (time.time() - t0) / 10 * 1e3
        t.add(bits, f"{err:.2e}", pb + sb,
              f"{(pb + sb) / bf16_bytes:.3f}x", round(ms, 2))
    if verbose:
        print(t.render())
        print("(CPU wall-clock is the XLA fallback; TPU projections in "
              "EXPERIMENTS.md §Roofline)")
    return t


if __name__ == "__main__":
    run()
