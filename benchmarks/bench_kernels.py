"""Kernel benchmark: fused dequant GEMMs vs references.

Three sections:

* ``quant_matmul`` — correctness deltas (interpret mode vs jnp oracle),
  packed-size accounting (the HBM-bandwidth claim of the kernel), and CPU
  wall-clock for the XLA fallback path (relative across bit-widths;
  absolute numbers are CPU-bound and labeled as such — the TPU target
  numbers come from §Roofline);
* ``moe_ffn`` — the fused grouped expert-FFN kernel vs its oracle per
  bit-class mix;
* launch accounting — ``pallas_call`` sites per MoE layer on the fused
  single-launch path vs the staged per-class-launch baseline (before:
  ``3 x num_classes``; after: 1), the probe the serving gate builds on.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, pack_random_experts
from repro.kernels import common as kcommon
from repro.kernels.common import pack_kernel_layout
from repro.kernels.moe_ffn.ops import moe_ffn_quant
from repro.kernels.moe_ffn.ref import moe_ffn_ref
from repro.kernels.quant_matmul.ops import quant_matmul
from repro.kernels.quant_matmul.ref import quant_matmul_ref
from repro.quant import rtn_quantize


def _quant_matmul_table():
    k, n, m = 512, 512, 64
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1

    t = Table("quant_matmul kernel: correctness + bytes",
              ["bits", "max_abs_err(interp_vs_ref)", "weight_bytes",
               "vs_bf16", "xla_path_ms"])
    bf16_bytes = k * n * 2
    for bits in (1, 2, 3, 4):
        res = rtn_quantize(w, bits=bits, group_size=128)
        planes = pack_kernel_layout(res.codes, bits, 128)
        ref = quant_matmul_ref(x, planes, res.scales, res.zeros, bits=bits,
                               group_size=128, pack_block=128)
        out = quant_matmul(x, planes, res.scales, res.zeros, bits=bits,
                           group_size=128, impl="interpret")
        err = float(jnp.abs(out - ref).max())
        pb = sum(int(np.prod(p.shape)) for p in planes)
        sb = res.scales.size * 2 + (res.zeros.size * 2 if bits > 1 else 0)

        # quant_matmul is jitted internally — no outer jit wrapper needed
        def fn(xx):
            return quant_matmul(xx, planes, res.scales, res.zeros,
                                bits=bits, group_size=128, impl="auto")
        fn(x).block_until_ready()
        t0 = time.time()
        for _ in range(10):
            fn(x).block_until_ready()
        ms = (time.time() - t0) / 10 * 1e3
        t.add(bits, f"{err:.2e}", pb + sb,
              f"{(pb + sb) / bf16_bytes:.3f}x", round(ms, 2))
    return t


def _moe_ffn_table():
    d, f, gs, pb, m = 128, 256, 128, 128, 8
    t = Table("moe_ffn fused kernel: correctness + launch counts",
              ["bit_classes", "max_abs_err(interp_vs_ref)",
               "launches_fused", "launches_staged(before)"])
    launches = {}
    for bit_classes, counts in (((2,), (2,)), ((1, 2, 3), (1, 1, 1)),
                                ((3, 4), (1, 1))):
        experts_q, meta = pack_random_experts(bit_classes, counts, d=d,
                                              f=f, gs=gs, pb=pb)
        e = sum(counts)
        x = jax.random.normal(jax.random.PRNGKey(2), (e, m, d))
        cnts = jnp.asarray([m - 2 * (i % 2) for i in range(e)], jnp.int32)
        classes = [experts_q[f"cls{ci}"] for ci in range(len(bit_classes))]
        ref = moe_ffn_ref(x, classes, cnts, meta=meta, act="silu")
        out = moe_ffn_quant(x, experts_q, cnts, meta=meta, act="silu",
                            impl="interpret")
        err = float(jnp.abs(out - ref).max())
        with kcommon.override_impl("pallas"):
            fused = kcommon.count_pallas_calls(
                lambda xx: moe_ffn_quant(xx, experts_q, cnts, meta=meta,
                                         act="silu"), x)
        staged = 3 * len(bit_classes)
        key = "x".join(str(b) for b in bit_classes)
        launches[key] = {"fused": fused, "staged": staged}
        t.add(key, f"{err:.2e}", fused, staged)
    return t, launches


def run(verbose: bool = True):
    t_qmm = _quant_matmul_table()
    t_ffn, launches = _moe_ffn_table()
    if verbose:
        print(t_qmm.render())
        print()
        print(t_ffn.render())
        print("(CPU wall-clock is the XLA fallback; TPU projections in "
              "EXPERIMENTS.md §Roofline. launches_staged is the pre-fusion "
              "per-bit-class baseline: 3 quant_matmul launches per class.)")
    return {"quant_matmul": t_qmm.to_dict(), "moe_ffn": t_ffn.to_dict(),
            "launches_per_moe_layer": launches}


if __name__ == "__main__":
    run()
