"""KV memory layer benchmarks: paged + quantized cache vs contiguous.

Four sections, all smoke scale (CPU container):

* **bytes/token** — measured from real device-array ``nbytes`` (not the
  analytic formula, which is reported alongside): a bf16 contiguous
  cache (K + V rows plus the int32 ``pos`` bookkeeping) vs the paged
  pool at ``off`` / ``int8`` / ``int4``.  The headline ratio is
  int4/bf16; int8 with per-position scales lands at ~56% and is
  reported but not gated.
* **capacity at fixed bytes** — how many concurrent max-length slots a
  fixed pool byte budget holds, contiguous bf16 vs paged int4 (page
  granularity and the reserved trash page are charged to the paged
  side).
* **token identity** — the continuous engine on a mixed workload,
  contiguous vs paged (quant off): per-uid token sequences must be
  bit-identical.
* **prefill interleave** — the longest single scheduling round (the
  decode gap every active request observes) when a long prompt arrives
  mid-decode, chunked prefill vs monolithic.  Timing-based, reported
  only.

``gate=True`` asserts the CI contract: int4 bytes/token <= 50% of bf16
contiguous, paged tokens identical, and >= 2x concurrent slots at a
fixed pool budget.

    PYTHONPATH=src python -m benchmarks.bench_kv [--gate]
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Table
from repro.configs import get_config
from repro.models.model_registry import build_model
from repro.serve.engine import (EngineConfig, GenerationOptions, Request,
                                ServeEngine)
from repro.serve.kv_pool import (KVPoolConfig, contiguous_kv_bytes_per_token,
                                 paged_kv_bytes_per_token)


def _model(seed: int = 0):
    """The serving smoke MoE (same recipe as bench_serving)."""
    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        dtype="float32", num_layers=2, d_model=128, d_ff=256, moe_d_ff=256,
        num_experts=8, vocab_size=512, capacity_factor=8.0,
        scan_layers=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _paged_engine(model, params, batch=4, max_seq_len=96, **pool_kw):
    pool_kw.setdefault("num_pages", 33)
    pool_kw.setdefault("page_size", 16)
    return ServeEngine(model, params, config=EngineConfig(
        batch_size=batch, max_seq_len=max_seq_len,
        kv_pool=KVPoolConfig(**pool_kw)))


def _workload(cfg, n_requests=12, seed=0, max_seq_len=96):
    """Mixed lengths bounded so prompt + output fits ``max_seq_len``."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n_requests):
        pl = int(rng.choice([8, 16, 24, 40, 64]))
        mn = int(rng.choice([4, 8, 12, 16, 24]))
        assert pl + mn <= max_seq_len
        reqs.append(Request(
            uid=i, prompt=rng.randint(1, cfg.vocab_size, pl).astype(np.int32),
            options=GenerationOptions(max_new_tokens=mn)))
    return reqs


def _nbytes(tree) -> int:
    return sum(a.nbytes for a in jax.tree.leaves(tree))


def bytes_per_token(verbose: bool = True):
    """Measured KV bytes/token: bf16 contiguous vs paged off/int8/int4.

    Measured on a dense full-attention smoke model (internlm2) so the
    contiguous baseline is a plain ring-free cache; the per-layer
    analytic numbers from ``kv_pool`` are reported for cross-checking.
    """
    cfg = get_config("internlm2-1.8b", smoke=True).replace(dtype="bfloat16")
    model = build_model(cfg)
    num_pages, ps = 65, 16               # 64 usable pages = 1024 tokens
    tokens = (num_pages - 1) * ps

    contig = _nbytes(model.init_caches(1, tokens)) / tokens
    paged = {q: _nbytes(model.init_paged_caches(num_pages, ps, quant=q))
             / tokens for q in ("off", "int8", "int4")}

    t = Table(f"KV bytes/token ({cfg.num_layers} layers, "
              f"{cfg.num_kv_heads} KV heads x {cfg.head_dim}, "
              f"page_size {ps})",
              ["layout", "bytes_tok", "vs bf16 contiguous"])
    t.add("contiguous bf16 (+pos)", round(contig, 1), "1.00x")
    for q in ("off", "int8", "int4"):
        t.add(f"paged {q}", round(paged[q], 1),
              f"{paged[q] / contig:.2f}x")
    result = {
        "contiguous_bf16": contig,
        "paged": paged,
        "ratio_vs_bf16": {q: paged[q] / contig for q in paged},
        "analytic_per_layer": {
            "contiguous_bf16": contiguous_kv_bytes_per_token(
                cfg.num_kv_heads, cfg.head_dim),
            **{q: paged_kv_bytes_per_token(cfg.num_kv_heads, cfg.head_dim, q)
               for q in ("off", "int8", "int4")}},
    }
    if verbose:
        print(t.render())
    return result


def capacity_at_fixed_bytes(bpt: dict, max_len: int = 1024,
                            page_size: int = 16, base_slots: int = 4,
                            verbose: bool = True):
    """Concurrent max-length slots a fixed pool byte budget holds.

    The budget is what the contiguous engine allocates for
    ``base_slots`` slots of ``max_len``; the paged side is charged page
    granularity plus the reserved trash page.
    """
    budget = base_slots * max_len * bpt["contiguous_bf16"]
    pages_per_slot = -(-max_len // page_size)
    rows = []
    slots = {}
    for q in ("off", "int8", "int4"):
        page_bytes = bpt["paged"][q] * page_size
        slots[q] = int((budget - page_bytes)        # trash page
                       // (pages_per_slot * page_bytes))
        rows.append((q, slots[q], slots[q] / base_slots))
    t = Table(f"concurrent slots at fixed pool bytes "
              f"({base_slots} x {max_len}-token bf16 contiguous budget)",
              ["layout", "slots", "vs contiguous"])
    t.add("contiguous bf16", base_slots, "1.0x")
    for q, n, r in rows:
        t.add(f"paged {q}", n, f"{r:.1f}x")
    if verbose:
        print(t.render())
    return {"budget_bytes": budget, "contiguous_slots": base_slots,
            "paged_slots": slots,
            "slot_ratio": {q: slots[q] / base_slots for q in slots}}


def token_identity(verbose: bool = True):
    """Paged (quant off) tokens are bit-identical to the contiguous
    engine's on a mixed continuous-batching workload."""
    cfg, model, params = _model()
    reqs = _workload(cfg)

    contig = ServeEngine(model, params, batch_size=4)
    ref = {r.uid: list(r.tokens) for r in contig.run(
        [Request(r.uid, r.prompt, options=r.opts) for r in reqs])}
    paged = _paged_engine(model, params)
    out = {r.uid: list(r.tokens) for r in paged.run(
        [Request(r.uid, r.prompt, options=r.opts) for r in reqs])}
    identical = ref == out
    stats = paged._kv_mgr.stats
    if verbose:
        print(f"\npaged vs contiguous token identity: "
              f"{'IDENTICAL' if identical else 'MISMATCH'} "
              f"({len(reqs)} requests; prefix pages shared: "
              f"{stats.shared_pages}, admissions deferred: "
              f"{stats.failed_admits})")
    return {"identical": identical, "n_requests": len(reqs),
            "shared_pages": stats.shared_pages,
            "failed_admits": stats.failed_admits}


def prefill_interleave(verbose: bool = True, chunk: int = 8):
    """Longest scheduling round when a 64-token prompt lands mid-decode:
    monolithic prefill stalls every active slot for the whole prompt,
    chunked prefill bounds the gap at one chunk per round."""
    cfg, model, params = _model()
    rng = np.random.RandomState(5)

    def reqs():
        short = [Request(
            uid=i, prompt=rng.randint(1, cfg.vocab_size, 8).astype(np.int32),
            options=GenerationOptions(max_new_tokens=24)) for i in range(3)]
        long_req = Request(
            uid=99, prompt=rng.randint(1, cfg.vocab_size, 64).astype(np.int32),
            options=GenerationOptions(max_new_tokens=4))
        return short, long_req

    gaps = {}
    for name, pool_kw in (("monolithic", {}),
                          ("chunked", {"prefill_chunk": chunk})):
        eng = _paged_engine(model, params, **pool_kw)
        warm_s, warm_l = reqs()
        eng.run(warm_s + [warm_l])       # compile every prefill width
        short, long_req = reqs()
        eng.begin(short)
        for _ in range(3):
            eng.pump()
        eng.submit([long_req])
        worst = 0.0
        while eng.busy:
            t0 = time.time()
            eng.pump()
            worst = max(worst, time.time() - t0)
        eng.collect()
        gaps[name] = worst
    if verbose:
        print(f"\nworst decode gap with 64-token prompt arriving "
              f"mid-decode: monolithic {gaps['monolithic'] * 1e3:.1f}ms, "
              f"chunked({chunk}) {gaps['chunked'] * 1e3:.1f}ms")
    return {"worst_round_s": gaps, "chunk": chunk}


def run(verbose: bool = True, gate: bool = False):
    """Aggregate payload for ``benchmarks.run --json`` (BENCH_kv)."""
    bpt = bytes_per_token(verbose=verbose)
    cap = capacity_at_fixed_bytes(bpt, verbose=verbose)
    ident = token_identity(verbose=verbose)
    inter = prefill_interleave(verbose=verbose)
    result = {"bytes_per_token": bpt, "capacity_at_fixed_bytes": cap,
              "token_identity": ident, "prefill_interleave": inter}
    if gate:
        r4 = bpt["ratio_vs_bf16"]["int4"]
        assert r4 <= 0.5, (
            f"kv gate: int4 paged KV must be <= 50% of bf16 contiguous "
            f"bytes/token, got {r4:.1%}")
        assert ident["identical"], (
            "kv gate: paged (quant off) tokens must match contiguous")
        s4 = cap["slot_ratio"]["int4"]
        assert s4 >= 2.0, (
            f"kv gate: int4 paged pool must hold >= 2x concurrent slots "
            f"at fixed bytes, got {s4:.1f}x")
        if verbose:
            print(f"\nkv gate OK: int4 bytes/token {r4:.1%} <= 50%, "
                  f"tokens identical, {s4:.1f}x slots >= 2x")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--gate", action="store_true",
                    help="assert the CI contract (int4 <= 50% bytes/token, "
                         "token identity, >= 2x slots)")
    args = ap.parse_args()
    run(gate=args.gate)
