"""Paper Tab. 4 / Fig. 1(b) / Tab. 13-14 analogue.

1. analytic memory accounting for the FULL configs (mixtral 8x7b/8x22b +
   the assigned MoE archs): total / activated parameter bytes at 16-bit and
   at PMQ budgets, with the ODP activated-parameter reduction;
2. measured end-to-end serve throughput (smoke scale, CPU) for fp32 vs
   MC-compressed — the *relative* speed story of Tab. 13 (absolute numbers
   are CPU-bound and labeled as such).
"""
from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import Table, calib_tokens, trained_smoke_mixtral
from repro.config import CompressionConfig
from repro.configs import get_config
from repro.core import pipeline as pipeline_lib
from repro.launch.dryrun import synthetic_meta
from repro.core.pmq import dense_expert_bytes, packed_expert_bytes


def _gb(x):
    return x / 1e9


def analytic_table() -> Table:
    t = Table("memory accounting (Tab. 4 / Fig. 1b analogue, full configs)",
              ["model", "bits", "params_GB", "act_params_GB",
               "compression", "odp_act_GB"])
    for arch in ("mixtral-8x7b", "mixtral-8x22b", "arctic-480b",
                 "llama4-maverick-400b-a17b"):
        cfg = get_config(arch)
        n_moe = cfg.num_moe_layers()
        dense16 = dense_expert_bytes(cfg) * n_moe
        other = (cfg.param_count() * 2) - dense16   # non-expert bf16 bytes
        total16 = _gb(dense16 + other)
        act16 = _gb(cfg.active_param_count() * 2)
        t.add(arch, 16.0, round(total16, 1), round(act16, 1), "0%",
              round(act16, 1))
        for bits in (2.54, 2.05, 1.57):
            meta = synthetic_meta(cfg, bits)
            packed = packed_expert_bytes(cfg, meta) * n_moe
            other4 = other / 4   # non-expert weights at 4-bit (paper)
            total = _gb(packed + other4)
            act_expert_frac = cfg.top_k / cfg.num_experts
            act = _gb(packed * act_expert_frac
                      + (cfg.active_param_count() * 2 - dense16
                         * act_expert_frac) / 4)
            comp = 1 - total / total16
            # ODP: ~15% fewer expert activations (calibrated prune rate)
            odp_act = act * (1 - 0.15 * (cfg.top_k >= 2))
            t.add(arch, bits, round(total, 1), round(act, 2),
                  f"{comp:.1%}", round(odp_act, 2))
    return t


def measured_speed() -> Table:
    """Relative serve speed fp32 vs MC (smoke, CPU — relative only)."""
    from repro.models.transformer import MCRuntime
    from repro.serve.engine import Request, ServeEngine
    cfg, model, params = trained_smoke_mixtral()
    calib = calib_tokens(cfg)
    ccfg = CompressionConfig(enabled=True, target_bits=2.5, group_size=32,
                             odp_enabled=True)
    record = pipeline_lib.calibrate(model, params, calib,
                                    bit_choices=tuple(ccfg.bit_choices),
                                    group_size=ccfg.group_size)
    cplan = pipeline_lib.plan(record, ccfg, layout="uniform")
    art = pipeline_lib.apply(model, params, cplan, record)
    qparams, runtime, report = art.params, art.runtime, art.report
    t = Table("serve throughput (smoke Mixtral, CPU; relative — Tab. 13)",
              ["config", "decode_tok_s", "prefill_s", "act_param_reduction"])
    rng = np.random.RandomState(0)
    reqs = [Request(uid=i, prompt=rng.randint(
        1, cfg.vocab_size, 24).astype(np.int32), max_new_tokens=8)
        for i in range(4)]
    for name, p, mc in (
            ("fp32", params, None),
            ("MC 2.5-bit + ODP", qparams, runtime)):
        eng = ServeEngine(model, p, batch_size=4, mc=mc)
        eng.run(reqs)
        red = f"{report.odp_prune_rate:.1%}" if mc else "-"
        t.add(name, round(eng.stats.decode_tokens_per_s, 2),
              round(eng.stats.prefill_s, 2), red)
    return t


def run(verbose: bool = True):
    t1 = analytic_table()
    t2 = measured_speed()
    if verbose:
        print(t1.render())
        print(t2.render())
    return t1, t2


if __name__ == "__main__":
    run()
