"""Paper Figs. 7-8 + Tabs. 11-12 analogue: ODP ablations.

1. token-protection ratio sweep (Fig. 7): PPL + computation-compression
   ratio as protection grows 0 -> 20%;
2. pruning-threshold sweep (Tab. 12): PPL + pruned fraction per mu,
   including the calibrated median;
3. token-importance metric comparison (Tab. 11): Eq. 6 importance vs
   kurtosis / variance / mean magnitude ranking.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Table, calib_tokens, trained_smoke_mixtral
from repro.core import odp as odp_lib
from repro.eval.perplexity import eval_tokens, perplexity
from repro.models.layers.moe import OdpRuntime
from repro.models.transformer import MCRuntime


def _ppl_with_odp(model, params, ev, odp):
    return perplexity(model, params, ev,
                      mc=MCRuntime(odp=odp, quant_meta=None))


def _pruned_frac(model, params, calib, odp):
    _, _, aux = model.forward(params, calib, scan=False, collect_aux=True,
                              mc=MCRuntime(odp=odp, quant_meta=None))
    fr = [float(a["odp_pruned_frac"]) for a in aux["per_layer"]
          if "odp_pruned_frac" in a]
    return float(np.mean(fr)) if fr else 0.0


def run(verbose: bool = True):
    cfg, model, params = trained_smoke_mixtral()
    calib = calib_tokens(cfg)
    ev = eval_tokens(cfg, n_seq=6, seq_len=96)
    fp_ppl = perplexity(model, params, ev)

    # calibrate mu from router stats
    captured_mu = _calibrate_mu(model, params, calib)

    t1 = Table("ODP token-protection sweep (Fig. 7)",
               ["protect_ratio", "ppl", "pruned_frac", "ppl_vs_fp"])
    t1.add(0.0, fp_ppl, 0.0, 1.0)
    for ratio in (0.0, 0.02, 0.05, 0.1, 0.2):
        odp = OdpRuntime(threshold=captured_mu, protect_ratio=ratio,
                         capacity_scale=1.0)
        ppl = _ppl_with_odp(model, params, ev, odp)
        frac = _pruned_frac(model, params, calib, odp)
        t1.add(ratio, ppl, round(frac, 4), ppl / fp_ppl)

    t2 = Table("ODP threshold sweep (Tab. 12)",
               ["mu", "ppl", "pruned_frac"])
    for mu in (0.4, 0.5, 0.6, 0.7):
        odp = OdpRuntime(threshold=mu, protect_ratio=0.02,
                         capacity_scale=1.0)
        t2.add(mu, _ppl_with_odp(model, params, ev, odp),
               round(_pruned_frac(model, params, calib, odp), 4))
    odp = OdpRuntime(threshold=captured_mu, protect_ratio=0.0,
                     capacity_scale=1.0)
    t2.add(f"median={captured_mu:.3f}",
           _ppl_with_odp(model, params, ev, odp),
           round(_pruned_frac(model, params, calib, odp), 4))
    odp = OdpRuntime(threshold=captured_mu, protect_ratio=0.02,
                     capacity_scale=1.0)
    t2.add(f"ODP (median+protect)",
           _ppl_with_odp(model, params, ev, odp),
           round(_pruned_frac(model, params, calib, odp), 4))

    # metric comparison: prune bottom-30% tokens by each metric instead of
    # importance-aware protection (Tab. 11 style)
    t3 = Table("token-importance metric comparison (Tab. 11)",
               ["metric", "ppl"])
    for name in ("odp_importance", "token_kurtosis", "token_variance",
                 "token_mean"):
        ppl = _ppl_with_metric(model, params, ev, captured_mu, name)
        t3.add(name, ppl)

    if verbose:
        print(t1.render())
        print(t2.render())
        print(t3.render())
    return t1, t2, t3


def _calibrate_mu(model, params, calib):
    from repro.core.mc import calibrate_forward
    captured = calibrate_forward(model, params, calib)
    ratios = []
    for cap in captured:
        tw = np.asarray(cap["topk_weights"]).reshape(-1, 2)
        ratios.append(tw[:, 1] / np.maximum(tw[:, 0], 1e-9))
    return float(np.median(np.concatenate(ratios)))


def _ppl_with_metric(model, params, ev, mu, metric: str):
    """Protection driven by alternative token statistics (Tab. 11)."""
    name = {"odp_importance": "eq6", "token_kurtosis": "kurtosis",
            "token_variance": "variance", "token_mean": "mean"}[metric]
    odp = OdpRuntime(threshold=mu, protect_ratio=0.02, capacity_scale=1.0,
                     importance_metric=name)
    return _ppl_with_odp(model, params, ev, odp)


if __name__ == "__main__":
    run()
