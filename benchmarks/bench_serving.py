"""Serving benchmarks: engines, cold start, and the quant-decode path.

``run`` — static lockstep vs continuous batching on a mixed-length
workload. The regime where lockstep batching wastes the most: prompt and output
lengths vary widely per request, so in a static batch every short request
burns decode steps as padding until the batch-max ``max_new_tokens``
finishes, and no queued request can start until the whole batch retires.
The continuous engine admits queued requests into freed slots between
decode steps instead.

Reported per engine: decode throughput (useful tokens/s), slot occupancy
(useful slot-steps / total slot-steps), decode steps, and per-request
latency (admission -> finish) mean/p95. The headline number is the
continuous/static decode-throughput ratio.

``quant_decode`` — the PMQ decode hot path: fused single-launch grouped
kernel (`kernels.moe_ffn`) vs the per-class-launch staged baseline
(launch counts per MoE layer, the machine-independent probe) plus
quant-vs-dense decode throughput and per-bit packed weight bytes.
``--quant-gate`` asserts the fused path cuts launches by >= 1.5x — the
CI slow job runs it.

    PYTHONPATH=src python -m benchmarks.bench_serving [--quant-gate]
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Table
from repro.config import CompressionConfig
from repro.configs import get_config
from repro.core import pipeline
from repro.models.model_registry import build_model
from repro.serve.engine import (GenerationOptions, Request, ServeEngine,
                                StaticServeEngine)


def _model(seed: int = 0):
    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        dtype="float32", num_layers=2, d_model=128, d_ff=256, moe_d_ff=256,
        num_experts=8, vocab_size=512, capacity_factor=8.0,
        scan_layers=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def mixed_workload(cfg, n_requests: int = 16, seed: int = 0):
    """Mixed prompt (8..64) and output (4..48) lengths, arrival order
    shuffled so static batches mix short and long requests."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n_requests):
        pl = int(rng.choice([8, 12, 16, 24, 32, 48, 64]))
        mn = int(rng.choice([4, 6, 8, 12, 16, 24, 32, 48]))
        reqs.append(Request(
            uid=i, prompt=rng.randint(1, cfg.vocab_size, pl).astype(np.int32),
            options=GenerationOptions(max_new_tokens=mn)))
    return reqs


def _run(engine, reqs):
    # warmup pass compiles prefill/decode so timing measures steady state
    warm = [Request(uid=-1 - i, prompt=r.prompt.copy(), options=r.opts)
            for i, r in enumerate(reqs)]
    engine.run(warm)
    engine.stats.__init__()
    t0 = time.time()
    results = engine.run(reqs)
    wall = time.time() - t0
    lat = np.asarray([r.prefill_s + r.decode_s for r in results])
    return results, wall, lat


def run(verbose: bool = True, n_requests: int = 16, batch_size: int = 4):
    cfg, model, params = _model()
    reqs = mixed_workload(cfg, n_requests)

    static = StaticServeEngine(model, params, batch_size=batch_size)
    _, wall_s, lat_s = _run(
        static, [Request(r.uid, r.prompt, options=r.opts) for r in reqs])

    cont = ServeEngine(model, params, batch_size=batch_size)
    _, wall_c, lat_c = _run(
        cont, [Request(r.uid, r.prompt, options=r.opts) for r in reqs])

    t = Table("serving: static lockstep vs continuous batching "
              f"({n_requests} reqs, pool {batch_size}, mixed lengths)",
              ["engine", "decode_tok_s", "occupancy", "decode_steps",
               "lat_mean_s", "lat_p95_s", "wall_s"])
    for name, eng, wall, lat in (("static", static, wall_s, lat_s),
                                 ("continuous", cont, wall_c, lat_c)):
        s = eng.stats
        t.add(name, round(s.decode_tokens_per_s, 1), round(s.occupancy, 3),
              s.decode_steps, round(float(lat.mean()), 3),
              round(float(np.percentile(lat, 95)), 3), round(wall, 2))
    speedup = (cont.stats.decode_tokens_per_s
               / max(static.stats.decode_tokens_per_s, 1e-9))
    if verbose:
        print(t.render())
        print(f"\ncontinuous/static decode throughput: {speedup:.2f}x "
              f"(occupancy {static.stats.occupancy:.0%} -> "
              f"{cont.stats.occupancy:.0%})")
    return speedup


def cold_start(verbose: bool = True, out_dir=None):
    """Deployment cold-start: compress-inline vs load-artifact, time to
    first token.

    The staged API's premise is that compression runs once offline and
    serving just loads the artifact — this measures what that buys at boot:
    ``inline`` pays calibrate+plan+GPTQ on the serving node before the
    first request; ``artifact`` pays only ``CompressedArtifact.load``.
    """
    import tempfile

    cfg, model, params = _model()
    ccfg = CompressionConfig(enabled=True, target_bits=2.5, group_size=32,
                             odp_enabled=True)
    rng = np.random.RandomState(8)
    req = Request(uid=0,
                  prompt=rng.randint(1, cfg.vocab_size, 16).astype(np.int32),
                  options=GenerationOptions(max_new_tokens=1))

    def first_token(artifact):
        eng = ServeEngine.from_artifact(model, artifact, batch_size=1)
        return eng.run([Request(req.uid, req.prompt, options=req.opts)])

    # inline: everything between "node boots" and "first token out"
    t0 = time.time()
    artifact = _compress_smoke(cfg, model, params, ccfg)
    t_compress = time.time() - t0
    first_token(artifact)
    ttft_inline = time.time() - t0

    with tempfile.TemporaryDirectory() as tmp:
        directory = out_dir or tmp
        artifact.save(directory)
        t0 = time.time()
        loaded = pipeline.CompressedArtifact.load(directory)
        t_load = time.time() - t0
        first_token(loaded)
        ttft_artifact = time.time() - t0

    t = Table("serving cold start: compress-inline vs load-artifact",
              ["path", "setup_s", "ttft_s"])
    t.add("inline (calibrate+plan+GPTQ)", round(t_compress, 2),
          round(ttft_inline, 2))
    t.add("artifact (load only)", round(t_load, 2), round(ttft_artifact, 2))
    speedup = ttft_inline / max(ttft_artifact, 1e-9)
    if verbose:
        print(t.render())
        print(f"\nartifact boot is {speedup:.1f}x faster to first token")
    return speedup


def _compress_smoke(cfg, model, params, ccfg):
    """The shared smoke-scale inline-compression recipe (calibrate ->
    plan uniform -> apply); cold_start and quant_decode must measure the
    same artifact pipeline."""
    rng = np.random.RandomState(7)
    calib = jax.numpy.asarray(
        rng.randint(1, cfg.vocab_size, size=(4, 48)).astype(np.int32))
    record = pipeline.calibrate(model, params, calib,
                                bit_choices=ccfg.bit_choices,
                                group_size=ccfg.group_size)
    plan = pipeline.plan(record, ccfg, layout="uniform")
    return pipeline.apply(model, params, plan, record)


def quant_decode(verbose: bool = True, gate: bool = False,
                 n_requests: int = 8, batch_size: int = 4):
    """PMQ decode hot path: single-launch fused kernel vs baselines.

    Reports (a) ``pallas_call`` launch sites per MoE layer for the fused
    grouped path vs the staged per-class path — a trace-time probe, so
    the number is machine-independent; (b) decode tokens/s of the dense
    vs quantized continuous engines on the same workload (CPU ref path:
    relative only); (c) per-bit packed weight bytes per expert. With
    ``gate=True`` asserts launch reduction >= 1.5x (the CI gate).
    """
    from repro.core import pmq as pmq_lib
    from repro.kernels import common as kcommon
    from repro.models.layers import moe as moe_lib
    from repro.models.layers.moe import MoEQuantMeta

    cfg, model, params = _model()
    artifact = _compress_smoke(
        cfg, model, params,
        CompressionConfig(enabled=True, target_bits=2.5, group_size=32,
                          odp_enabled=False))
    meta = artifact.metas[0]

    # (a) launch counts per MoE layer, decode-shaped batch
    moe_slots = [s for s in range(model.period)
                 if model.slot_kinds[s] == "moe"]
    ffn = jax.tree.map(lambda a: a[0],
                       artifact.params[f"layers{moe_slots[0]}"]["ffn"])
    xd = jax.random.normal(jax.random.PRNGKey(0),
                           (batch_size, 1, cfg.d_model))
    with kcommon.override_impl("pallas"):
        fused = kcommon.count_pallas_calls(
            lambda xx: moe_lib.apply_moe(
                ffn, xx, cfg, quant_meta=meta, quant_path="fused")[0], xd)
        staged = kcommon.count_pallas_calls(
            lambda xx: moe_lib.apply_moe(
                ffn, xx, cfg, quant_meta=meta, quant_path="staged")[0], xd)
    launch_ratio = staged / max(fused, 1)

    # (b) decode throughput, dense vs quantized engines, same workload
    reqs = mixed_workload(cfg, n_requests)
    dense_eng = ServeEngine(model, params, batch_size=batch_size)
    _, _, _ = _run(dense_eng,
                   [Request(r.uid, r.prompt, options=r.opts)
                    for r in reqs])
    quant_eng = ServeEngine.from_artifact(model, artifact,
                                          batch_size=batch_size)
    _, _, _ = _run(quant_eng,
                   [Request(r.uid, r.prompt, options=r.opts)
                    for r in reqs])
    tok_dense = dense_eng.stats.decode_tokens_per_s
    tok_quant = quant_eng.stats.decode_tokens_per_s

    # (c) per-bit packed weight bytes (one expert, this model's dims)
    per_bit_bytes = {}
    for bits in sorted(set(meta.bit_classes)):
        one = MoEQuantMeta(bit_classes=(bits,), class_counts=(1,),
                           group_size=meta.group_size,
                           pack_block=meta.pack_block)
        per_bit_bytes[str(bits)] = pmq_lib.packed_expert_bytes_dims(
            cfg.d_model, cfg.moe_d_ff, one)

    t = Table("quant decode: fused single-launch vs per-class launches "
              f"(classes {meta.bit_classes}, counts {meta.class_counts})",
              ["metric", "value"])
    t.add("launches/MoE-layer fused", fused)
    t.add("launches/MoE-layer staged (before)", staged)
    t.add("launch reduction", f"{launch_ratio:.1f}x")
    t.add("decode tok/s dense", round(tok_dense, 1))
    t.add("decode tok/s quant (CPU ref path)", round(tok_quant, 1))
    if verbose:
        print(t.render())
        print(f"\nper-bit packed bytes/expert: {per_bit_bytes} "
              f"(dense bf16: "
              f"{pmq_lib.dense_expert_bytes_dims(1, cfg.d_model, cfg.moe_d_ff)})")
    result = {
        "launches_per_moe_layer": {"fused": fused, "staged": staged},
        "launch_reduction": launch_ratio,
        "decode_tok_s": {"dense": tok_dense, "quant": tok_quant},
        "per_bit_weight_bytes": per_bit_bytes,
        "bit_classes": list(meta.bit_classes),
        "class_counts": list(meta.class_counts),
    }
    if gate:
        assert launch_ratio >= 1.5, (
            f"quant-decode gate: fused path must cut kernel launches by "
            f">= 1.5x over the per-class baseline, got {launch_ratio:.2f}x "
            f"({staged} -> {fused})")
        if verbose:
            print(f"quant-decode gate OK: {launch_ratio:.1f}x >= 1.5x")
    return result


def odp_decode(verbose: bool = True, gate: bool = False,
               n_requests: int = 8, batch_size: int = 4):
    """Online Dynamic Pruning on the decode hot path: ``odp='off'`` vs the
    artifact-default threshold on the same engine.

    Reports (a) activated expert-params per token — counted from the MoE
    dispatch's live capacity rows (``aux['active_rows']``), so the number
    is machine-independent: pruned slots become dead rows the fused kernel
    skips; (b) decode tokens/s of the continuous engine at each knob
    setting (CPU ref path: relative only). With ``gate=True`` asserts the
    default threshold cuts activated expert-params/token by >= 10%.
    """
    import jax.numpy as jnp

    cfg, model, params = _model()
    artifact = _compress_smoke(
        cfg, model, params,
        CompressionConfig(enabled=True, target_bits=2.5, group_size=32,
                          odp_enabled=True))
    odp = artifact.runtime.odp

    # (a) live dispatch rows per MoE layer, off vs calibrated threshold
    rng = np.random.RandomState(3)
    toks = jax.numpy.asarray(
        rng.randint(1, cfg.vocab_size, (4, 48)).astype(np.int32))
    per_expert_row = 3 * cfg.d_model * cfg.moe_d_ff      # w1, w3, w2

    def act_params_per_token(thr: float) -> float:
        _, _, aux = model.forward(
            artifact.params, toks, scan=False, collect_aux=True,
            mc=artifact.runtime,
            odp_threshold=jnp.full((toks.shape[0],), thr, jnp.float32))
        rows = sum(int(np.asarray(a["active_rows"]).sum())
                   for a in aux["per_layer"] if "active_rows" in a)
        return rows * per_expert_row / toks.size

    act_off = act_params_per_token(0.0)
    act_on = act_params_per_token(float(odp.threshold))
    reduction = 1.0 - act_on / max(act_off, 1e-9)

    # (b) decode throughput at each knob setting, same mixed workload
    def reqs(knob):
        return [Request(uid=r.uid, prompt=r.prompt.copy(),
                        options=GenerationOptions(
                            max_new_tokens=r.opts.max_new_tokens, odp=knob))
                for r in mixed_workload(cfg, n_requests)]

    eng = ServeEngine.from_artifact(model, artifact, batch_size=batch_size)
    _run(eng, reqs("off"))
    tok_off = eng.stats.decode_tokens_per_s
    _run(eng, reqs("default"))
    tok_on = eng.stats.decode_tokens_per_s

    t = Table("ODP decode: off vs artifact-default threshold "
              f"(mu={odp.threshold:.3f}, plan prune rate "
              f"{artifact.report.odp_prune_rate:.1%})",
              ["metric", "odp=off", "odp=default"])
    t.add("activated params/token", f"{act_off / 1e6:.2f}M",
          f"{act_on / 1e6:.2f}M")
    t.add("decode tok/s (CPU ref path)", round(tok_off, 1),
          round(tok_on, 1))
    if verbose:
        print(t.render())
        print(f"\nactivated expert-param reduction: {reduction:.1%}")
    result = {
        "activated_params_per_token": {"off": act_off, "default": act_on},
        "activated_param_reduction": reduction,
        "decode_tok_s": {"off": tok_off, "default": tok_on},
        "odp_threshold": float(odp.threshold),
        "plan_prune_rate": artifact.report.odp_prune_rate,
    }
    if gate:
        assert reduction >= 0.10, (
            f"odp-decode gate: the artifact-default threshold must cut "
            f"activated expert-params/token by >= 10% vs odp='off', got "
            f"{reduction:.1%}")
        if verbose:
            print(f"odp-decode gate OK: {reduction:.1%} >= 10%")
    return result


FAMILY_SWEEP_ARCHS = ("mixtral-8x7b", "zamba2-1.2b", "whisper-medium",
                      "paligemma-3b", "falcon-mamba-7b")


def family_sweep(verbose: bool = True, n_requests: int = 4,
                 batch_size: int = 2, max_new: int = 6):
    """Every model family through the continuous engine's per-slot state
    layer: decode throughput plus the analytic state bytes/slot broken
    down by state kind (``slot_state.state_bytes_per_slot``). The sweep
    is a smoke-scale regression canary — the numbers matter relative to
    each other and across commits, not absolutely."""
    from repro.serve.slot_state import SlotStateSpec, state_bytes_per_slot

    t = Table(f"serving: family sweep ({n_requests} reqs, pool "
              f"{batch_size}, {max_new} new tokens)",
              ["arch", "family", "state_kinds", "decode_tok_s",
               "state_bytes_per_slot"])
    out = {}
    for arch in FAMILY_SWEEP_ARCHS:
        cfg = get_config(arch, smoke=True).replace(dtype="float32")
        if cfg.family == "moe":
            cfg = cfg.replace(capacity_factor=8.0)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.RandomState(1)
        plen = cfg.num_prefix_tokens if cfg.family == "vlm" else 0
        reqs = []
        for i in range(n_requests):
            pl = int(rng.randint(4, 13))
            enc = None
            if cfg.family == "encdec":
                enc = rng.randn(cfg.encoder_seq,
                                cfg.d_model).astype(np.float32)
            elif cfg.family == "vlm":
                enc = rng.randn(plen, cfg.d_model).astype(np.float32)
            reqs.append(Request(
                uid=i,
                prompt=rng.randint(1, cfg.vocab_size, pl).astype(np.int32),
                enc_input=enc,
                options=GenerationOptions(max_new_tokens=max_new)))
        eng = ServeEngine(model, params, batch_size=batch_size)
        # _run's warmup copies drop enc_input; build family-aware copies
        warm = [Request(uid=-1 - i, prompt=r.prompt.copy(),
                        enc_input=r.enc_input, options=r.opts)
                for i, r in enumerate(reqs)]
        eng.run(warm)
        eng.stats.__init__()
        eng.run(reqs)
        spec = SlotStateSpec.from_config(cfg)
        capacity = plen + 12 + max_new          # the workload's max span
        sizes = state_bytes_per_slot(cfg, capacity)
        tok_s = eng.stats.decode_tokens_per_s
        t.add(arch, cfg.family, "+".join(k.name for k in spec.kinds),
              round(tok_s, 1), round(sum(sizes.values())))
        out[cfg.family] = {
            "arch": arch,
            "state_kinds": [k.name for k in spec.kinds],
            "decode_tok_s": round(tok_s, 2),
            "state_bytes_per_slot": {k: round(v) for k, v in sizes.items()},
            "scratch_reuses": eng.stats.scratch_reuses,
        }
    if verbose:
        print(t.render())
    return out


def bench_all(verbose: bool = True):
    """Aggregate payload for ``benchmarks.run --json`` (BENCH_serving)."""
    speedup = run(verbose=verbose)
    ttft = cold_start(verbose=verbose)
    qd = quant_decode(verbose=verbose, gate=True)
    od = odp_decode(verbose=verbose)
    fs = family_sweep(verbose=verbose)
    return {"continuous_vs_static_decode_speedup": speedup,
            "artifact_cold_start_speedup": ttft,
            "quant_decode": qd,
            "odp_decode": od,
            "family_sweep": fs}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quant-gate", action="store_true",
                    help="run only the quant-decode section and assert "
                         "the >= 1.5x launch-reduction gate")
    args = ap.parse_args()
    if args.quant_gate:
        quant_decode(gate=True)
    else:
        run()
        cold_start()
        quant_decode(gate=True)
