"""Shared bench fixtures: a trained-ish smoke Mixtral and calibration data.

Benches that mirror paper tables need a model whose router has structure
(untrained routers are near-uniform). We quick-train a reduced Mixtral for a
few dozen steps so expert frequencies/weights diverge, then reuse it across
benchmark modules (cached in-process).
"""
from __future__ import annotations

import functools
import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CompressionConfig, TrainConfig
from repro.configs import get_config
from repro.data.pipeline import SyntheticTextConfig, SyntheticTokenDataset
from repro.models.transformer import DecoderModel
from repro.train.train_step import init_train_state, make_train_step


@functools.lru_cache(maxsize=1)
def trained_smoke_mixtral(steps: int = 300) -> Tuple:
    """A reduced Mixtral trained long enough to develop non-uniform expert
    routing and sub-random PPL — otherwise the compression comparisons the
    paper makes (PMQ vs uniform vs single-metric) cannot differentiate.
    Low aux-loss weight deliberately lets experts specialize/imbalance
    (the phenomenon Fig. 3 is about)."""
    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        dtype="float32", d_model=128, d_ff=256, moe_d_ff=256,
        num_experts=8, num_layers=4, capacity_factor=4.0,
        scan_layers=False)
    model = DecoderModel(cfg)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=20,
                       total_steps=steps, optimizer="adamw",
                       aux_loss_weight=0.003)
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    step = jax.jit(make_train_step(model, cfg, tcfg))
    ds = SyntheticTokenDataset(SyntheticTextConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, seed=3))
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        state, metrics = step(state, batch)
    return cfg, model, state.params


def calib_tokens(cfg, n=6, seq=96, seed=1234):
    ds = SyntheticTokenDataset(SyntheticTextConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=n, seed=seed))
    return jnp.asarray(ds.batch(0)["tokens"])


def pack_random_experts(bit_classes, class_counts, d=128, f=256, gs=32,
                        pb=128, seed=0):
    """Random RTN-quantized per-class expert stacks in the artifact layout
    (``experts_q`` dict + matching ``MoEQuantMeta``) — the fixture the
    fused moe_ffn kernel benchmarks and tests share."""
    from repro.kernels.common import pack_kernel_layout
    from repro.models.layers.moe import MoEQuantMeta
    from repro.quant import rtn_quantize
    key = jax.random.PRNGKey(seed)
    experts_q = {}
    for ci, (bits, cnt) in enumerate(zip(bit_classes, class_counts)):
        w = {}
        for tag, din, dout in (("in", d, f), ("gate", d, f), ("out", f, d)):
            planes_all, s_all, z_all = [], [], []
            for _ in range(cnt):
                key, k2 = jax.random.split(key)
                mat = jax.random.normal(k2, (din, dout)) * 0.1
                res = rtn_quantize(mat, bits=bits, group_size=gs)
                planes_all.append(pack_kernel_layout(res.codes, bits, pb))
                s_all.append(res.scales)
                z_all.append(res.zeros)
            for pi in range(len(planes_all[0])):
                w[f"{tag}_p{pi}"] = jnp.stack([p[pi] for p in planes_all])
            w[f"{tag}_s"] = jnp.stack(s_all)
            if bits > 1:
                w[f"{tag}_z"] = jnp.stack(z_all)
        experts_q[f"cls{ci}"] = w
    meta = MoEQuantMeta(bit_classes=tuple(bit_classes),
                        class_counts=tuple(class_counts),
                        group_size=gs, pack_block=pb)
    return experts_q, meta


class Table:
    """Minimal aligned-column table printer for bench output."""

    def __init__(self, title, cols):
        self.title = title
        self.cols = cols
        self.rows = []

    def add(self, *vals):
        self.rows.append(vals)

    def render(self) -> str:
        widths = [max(len(str(c)), *(len(_fmt(r[i])) for r in self.rows))
                  if self.rows else len(str(c))
                  for i, c in enumerate(self.cols)]
        out = [f"== {self.title} =="]
        out.append("  ".join(str(c).ljust(w) for c, w in
                             zip(self.cols, widths)))
        for r in self.rows:
            out.append("  ".join(_fmt(v).ljust(w) for v, w in
                                 zip(r, widths)))
        return "\n".join(out)

    def to_dict(self) -> dict:
        """Machine-readable form for ``benchmarks.run --json``."""
        return {"title": self.title,
                "rows": [dict(zip([str(c) for c in self.cols], r))
                         for r in self.rows]}


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)
