"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.roofline_report [--write]
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "experiments" / "dryrun"

ARCH_ORDER = [
    "arctic-480b", "llama4-maverick-400b-a17b", "whisper-medium",
    "zamba2-1.2b", "command-r-plus-104b", "h2o-danube-3-4b", "gemma2-27b",
    "internlm2-1.8b", "falcon-mamba-7b", "paligemma-3b", "mixtral-8x7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load():
    recs = {}
    for f in glob.glob(str(DRYRUN / "*.json")):
        r = json.loads(Path(f).read_text())
        recs[r["cell"]] = r
    return recs


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_table(recs, mesh="single", mc=False):
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL_FLOPs/HLO | bottleneck note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            tag = f"{arch}__{shape}__{mesh}" + ("__mc" if mc else "")
            r = recs.get(tag)
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | SKIP | — | "
                             f"{r['note'][:60]} |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | — | — | — | ERROR | — | "
                             f"{r.get('error', '')[:60]} |")
                continue
            t = r["roofline"]
            note = _note(r)
            lines.append(
                f"| {arch} | {shape} | {fmt_s(t['compute_s'])} | "
                f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
                f"**{t['dominant']}** | {t['useful_ratio']:.2f} | {note} |")
    return "\n".join(lines)


def _note(r):
    t = r["roofline"]
    d = t["dominant"]
    coll = r["hlo_analysis"]["collective_by_kind"]
    if d == "collective" and coll:
        top = max(coll, key=coll.get)
        return f"{top} dominates ICI ({coll[top]/1e9:.1f} GB/chip)"
    if d == "memory":
        if r["shape"].startswith("decode") or r["shape"].startswith("long"):
            return "weight+KV streaming bound"
        return "materialized attention + activations"
    return "MXU-bound"


def dryrun_table(recs, mesh):
    lines = [
        "| arch | shape | status | compile_s | args/chip | peak-ish/chip | "
        "collectives |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            tag = f"{arch}__{shape}__{mesh}"
            r = recs.get(tag)
            if r is None:
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | {r['status'].upper()} | "
                             f"— | — | — | — |")
                continue
            mem = r["memory_analysis"]
            args = (mem.get("argument_size_in_bytes") or 0) / 1e9
            temp = (mem.get("temp_size_in_bytes") or 0) / 1e9
            cc = r["hlo_analysis"]["collective_counts"]
            cstr = ",".join(f"{k.split('-')[-1]}:{v}"
                            for k, v in sorted(cc.items()))
            lines.append(
                f"| {arch} | {shape} | ok | {r['compile_s']} | "
                f"{args:.2f} GB | {temp:.2f} GB | {cstr} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--mc", action="store_true")
    args = ap.parse_args()
    recs = load()
    print("### Dry-run (mesh:", args.mesh, ")\n")
    print(dryrun_table(recs, args.mesh))
    print("\n### Roofline (mesh:", args.mesh, ", mc:", args.mc, ")\n")
    print(roofline_table(recs, args.mesh, args.mc))


if __name__ == "__main__":
    main()
