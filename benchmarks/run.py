"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Runs one benchmark per paper table/figure at smoke scale (CPU container):

* bench_allocation — Figs. 5-6, Tabs. 2/5/7 (PMQ vs baselines)
* bench_odp        — Figs. 7-8, Tabs. 11-12 (pruning + protection)
* bench_memory     — Tab. 4 / Fig. 1b / Tab. 13 (memory + speed)
* bench_kernels    — kernel correctness/bytes/launch counts (Tab. 13-14)
* bench_artifact_loading — per-host bytes/latency of sharded artifact
  streaming (the deployment half of the paper's pre-loading premise)
* bench_serving    — engines + the quant-decode launch gate
* bench_kv         — paged + quantized KV pool: bytes/token, capacity
  at fixed pool bytes, paged-vs-contiguous token identity
* bench_fleet      — elastic fleet: availability under replica/host
  faults + delta re-shard bytes vs full reload
* bench_chaos      — unreliable transport: exactly-once + token
  identity under seeded message chaos, hedging p99 A/B

``--json [DIR]`` additionally writes one machine-readable
``BENCH_<suite>.json`` per executed suite (kernel launch counts, decode
tokens/s quant-vs-dense, per-bit weight bytes, ...) — the repo's perf
trajectory; the CI slow job uploads them as artifacts.

The multi-pod roofline tables (EXPERIMENTS.md §Roofline) are produced by
``repro.launch.dryrun`` + ``benchmarks.roofline_report``.
"""
import argparse
import json
import time
from pathlib import Path


def _jsonable(v):
    """Best-effort conversion of bench returns to JSON-serializable data."""
    import numpy as np
    from benchmarks.common import Table
    if isinstance(v, Table):
        return v.to_dict()
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    return str(v)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="allocation|odp|memory|kernels|loading|serving|"
                         "kv|fleet|chaos")
    ap.add_argument("--json", nargs="?", const=".", default=None,
                    metavar="DIR",
                    help="write BENCH_<suite>.json per suite into DIR "
                         "(default: cwd)")
    args = ap.parse_args()
    t0 = time.time()
    from benchmarks import (bench_allocation, bench_artifact_loading,
                            bench_chaos, bench_fleet, bench_kernels,
                            bench_kv, bench_memory, bench_odp,
                            bench_serving)
    benches = {
        "kernels": bench_kernels.run,
        "memory": bench_memory.run,
        "odp": bench_odp.run,
        "allocation": bench_allocation.run,
        "loading": bench_artifact_loading.run,
        "serving": bench_serving.bench_all,
        "kv": bench_kv.run,
        "fleet": bench_fleet.run,
        "chaos": bench_chaos.run,
    }
    if args.only and args.only not in benches:
        ap.error(f"unknown suite {args.only!r} "
                 f"(choose from: {', '.join(benches)})")
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        print(f"\n#### benchmark: {name} " + "#" * 40)
        result = fn(verbose=True)
        if args.json is not None:
            out = Path(args.json) / f"BENCH_{name}.json"
            out.parent.mkdir(parents=True, exist_ok=True)
            out.write_text(json.dumps(_jsonable(result), indent=2))
            print(f"[benchmarks] wrote {out}")
    print(f"\n[benchmarks] total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
