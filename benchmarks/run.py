"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Runs one benchmark per paper table/figure at smoke scale (CPU container):

* bench_allocation — Figs. 5-6, Tabs. 2/5/7 (PMQ vs baselines)
* bench_odp        — Figs. 7-8, Tabs. 11-12 (pruning + protection)
* bench_memory     — Tab. 4 / Fig. 1b / Tab. 13 (memory + speed)
* bench_kernels    — kernel correctness/bytes (Tab. 13-14 kernel side)
* bench_artifact_loading — per-host bytes/latency of sharded artifact
  streaming (the deployment half of the paper's pre-loading premise)

The multi-pod roofline tables (EXPERIMENTS.md §Roofline) are produced by
``repro.launch.dryrun`` + ``benchmarks.roofline_report``.
"""
import argparse
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="allocation|odp|memory|kernels|loading")
    args = ap.parse_args()
    t0 = time.time()
    from benchmarks import (bench_allocation, bench_artifact_loading,
                            bench_kernels, bench_memory, bench_odp)
    benches = {
        "kernels": bench_kernels.run,
        "memory": bench_memory.run,
        "odp": bench_odp.run,
        "allocation": bench_allocation.run,
        "loading": bench_artifact_loading.run,
    }
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        print(f"\n#### benchmark: {name} " + "#" * 40)
        fn(verbose=True)
    print(f"\n[benchmarks] total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
