"""The paper's full pipeline on one model: calibrate -> analyze expert
significance (Fig. 3) -> IP bit allocation (Eq. 4, Fig. 10 bit map) ->
GPTQ quantization -> ODP calibration -> evaluate PPL vs baselines.

    PYTHONPATH=src python examples/compress_and_eval.py
"""
import numpy as np
import jax

from benchmarks.common import calib_tokens, trained_smoke_mixtral
from repro.config import CompressionConfig
from repro.core import mc as mc_lib
from repro.eval.perplexity import eval_tokens, perplexity
from repro.models.transformer import MCRuntime


def bitmap_ascii(reports):
    """Fig. 10-style bit-allocation map: rows = layers, cols = experts."""
    lines = ["bit map (rows=MoE layers, cols=experts; chars = bit-width):"]
    for rep in reports:
        lines.append(f"  L{rep.layer:02d} " +
                     "".join(str(int(b)) for b in rep.bits))
    return "\n".join(lines)


def main():
    cfg, model, params = trained_smoke_mixtral()
    calib = calib_tokens(cfg)
    ev = eval_tokens(cfg, n_seq=6, seq_len=96)
    fp_ppl = perplexity(model, params, ev)
    print(f"fp32 PPL: {fp_ppl:.3f}")

    for target in (2.54, 2.05, 1.57):
        ccfg = CompressionConfig(enabled=True, target_bits=target,
                                 group_size=32, odp_enabled=True)
        qp, runtime, report = mc_lib.compress(model, params, ccfg, calib,
                                              layout="uniform")
        # significance analysis printout (Fig. 3 channels)
        rep0 = report.pmq.reports[0]
        print(f"\n=== target {target} bits ===")
        print(f"layer0 expert frequency:  "
              f"{np.round(rep0.frequency, 3).tolist()}")
        print(f"layer0 expert weight:     "
              f"{np.round(rep0.mean_weight, 3).tolist()}")
        print(f"layer0 eps(2bit):         "
              f"{np.round(rep0.eps[:, 1], 2).tolist()}")
        print(bitmap_ascii(report.pmq.reports))
        ppl_pmq = perplexity(model, qp, ev,
                             mc=MCRuntime(odp=None,
                                          quant_meta=runtime.quant_meta))
        ppl_mc = perplexity(model, qp, ev, mc=runtime)
        print(f"avg bits {report.avg_bits:.2f} | compression "
              f"{report.pmq.compression_ratio:.1%} | "
              f"PPL PMQ {ppl_pmq:.3f} | PPL PMQ+ODP {ppl_mc:.3f} "
              f"(fp {fp_ppl:.3f})")


if __name__ == "__main__":
    main()
