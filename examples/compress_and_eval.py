"""The paper's full pipeline on one model: calibrate -> analyze expert
significance (Fig. 3) -> IP bit allocation (Eq. 4, Fig. 10 bit map) ->
GPTQ quantization -> ODP calibration -> evaluate PPL vs baselines.

Staged-API showcase: the calibration pass (and its eps probe tables) runs
**once**; each bit target below is just a cheap re-``plan`` plus the GPTQ
``apply`` — no recalibration between targets.

    PYTHONPATH=src python examples/compress_and_eval.py
"""
import numpy as np

from benchmarks.common import calib_tokens, trained_smoke_mixtral
from repro.config import CompressionConfig
from repro.core import pipeline
from repro.eval.perplexity import eval_tokens, perplexity
from repro.models.transformer import MCRuntime


def bitmap_ascii(reports):
    """Fig. 10-style bit-allocation map: rows = layers, cols = experts."""
    lines = ["bit map (rows=MoE layers, cols=experts; chars = bit-width):"]
    for rep in reports:
        lines.append(f"  L{rep.layer:02d} " +
                     "".join(str(int(b)) for b in rep.bits))
    return "\n".join(lines)


def main():
    cfg, model, params = trained_smoke_mixtral()
    calib = calib_tokens(cfg)
    ev = eval_tokens(cfg, n_seq=6, seq_len=96)
    fp_ppl = perplexity(model, params, ev)
    print(f"fp32 PPL: {fp_ppl:.3f}")

    record = pipeline.calibrate(model, params, calib,
                                bit_choices=(1, 2, 3), group_size=32)
    for target in (2.54, 2.05, 1.57):
        ccfg = CompressionConfig(enabled=True, target_bits=target,
                                 group_size=32, odp_enabled=True)
        cplan = pipeline.plan(record, ccfg, layout="uniform")
        artifact = pipeline.apply(model, params, cplan, record)
        report = artifact.report
        # significance analysis printout (Fig. 3 channels)
        rep0 = report.pmq.reports[0]
        print(f"\n=== target {target} bits "
              f"(probe sweeps: {record.eps_probe_runs}) ===")
        print(f"layer0 expert frequency:  "
              f"{np.round(rep0.frequency, 3).tolist()}")
        print(f"layer0 expert weight:     "
              f"{np.round(rep0.mean_weight, 3).tolist()}")
        print(f"layer0 eps(2bit):         "
              f"{np.round(rep0.eps[:, 1], 2).tolist()}")
        print(bitmap_ascii(report.pmq.reports))
        ppl_pmq = perplexity(
            model, artifact.params, ev,
            mc=MCRuntime(odp=None,
                         quant_meta=artifact.runtime.quant_meta,
                         layer_metas=artifact.runtime.layer_metas))
        ppl_mc = perplexity(model, artifact.params, ev, mc=artifact.runtime)
        print(f"avg bits {report.avg_bits:.2f} | compression "
              f"{report.pmq.compression_ratio:.1%} | "
              f"PPL PMQ {ppl_pmq:.3f} | PPL PMQ+ODP {ppl_mc:.3f} "
              f"(fp {fp_ppl:.3f})")


if __name__ == "__main__":
    main()
