"""Compress once, offline: calibrate -> plan -> apply -> saved artifact.

Demonstrates the staged API's two payoffs over a one-shot pipeline:

* **re-planning is free** — a second ``plan()`` at a different bit budget
  reuses the record's cached eps probe tables (no forward pass, no RTN
  probes, no GPTQ);
* **the artifact is the deployable unit** — ``apply()``'s output saves to
  disk and serving boots from it with no calibration data in sight
  (see ``examples/serve_compressed.py``).

    PYTHONPATH=src python examples/compress_offline.py [out_dir]
"""
import sys
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.config import CompressionConfig
from repro.configs import get_config
from repro.core import pipeline
from repro.data.pipeline import calibration_batch
from repro.models.model_registry import build_model


def main():
    out = (sys.argv[1] if len(sys.argv) > 1
           else tempfile.mkdtemp(prefix="mc_artifact_"))
    cfg = get_config("mixtral-8x7b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ccfg = CompressionConfig(enabled=True, target_bits=2.54, group_size=32,
                             odp_enabled=True)
    calib = jnp.asarray(calibration_batch(cfg, 4, 64))

    # stage 1 — one calibration pass + eps probes (the only expensive
    # weight-touching step before GPTQ)
    t0 = time.time()
    record = pipeline.calibrate(model, params, calib,
                                bit_choices=ccfg.bit_choices,
                                group_size=ccfg.group_size)
    print(f"calibrate: {time.time() - t0:.1f}s "
          f"({len(record.layers)} MoE layers, "
          f"{record.layers[0].x.shape[0]} tokens)")

    # stage 2 — plan at the paper's headline budget, then RE-plan at a
    # second budget: same record, cached probes, milliseconds
    t0 = time.time()
    plan = pipeline.plan(record, ccfg, layout="uniform")
    t_plan = time.time() - t0
    t0 = time.time()
    replan = pipeline.plan(record, ccfg.replace(target_bits=2.0),
                           layout="uniform")
    t_replan = time.time() - t0
    print(f"plan @2.54 bits: {t_plan * 1e3:.0f}ms -> "
          f"achieved {plan.achieved_bits:.2f}, counts {plan.uniform_counts}")
    print(f"re-plan @2.0 bits: {t_replan * 1e3:.0f}ms -> "
          f"achieved {replan.achieved_bits:.2f}, "
          f"counts {replan.uniform_counts} "
          f"(eps probe sweeps so far: {record.eps_probe_runs})")

    # stage 3 — GPTQ + pack at the planned widths, bundle the artifact
    t0 = time.time()
    artifact = pipeline.apply(model, params, plan, record)
    print(f"apply (GPTQ+pack): {time.time() - t0:.1f}s")

    path = artifact.save(out)
    print(f"artifact saved to {path} "
          f"({artifact.plan.predicted_bytes / 1024:.0f} KiB experts vs "
          f"{artifact.plan.original_bytes / 1024:.0f} KiB dense; "
          f"scan_safe={artifact.scan_safe})")
    print(f"\nserve it with:\n  PYTHONPATH=src python -m repro.launch.serve "
          f"--arch mixtral-8x7b --artifact {out}")


if __name__ == "__main__":
    main()
