"""Quickstart: build a (reduced) Mixtral, compress it with MC, compare.

    PYTHONPATH=src python examples/quickstart.py

Shows the staged public API: build model -> ``calibrate`` -> ``plan`` ->
``apply`` -> forward with the artifact's MCRuntime. (The same surface is
re-exported at the package root: ``repro.calibrate`` / ``repro.plan`` /
``repro.apply`` / ``repro.CompressedArtifact``.)
"""
import jax
import jax.numpy as jnp

from repro.config import CompressionConfig
from repro.configs import get_config
from repro.core import pipeline
from repro.data.pipeline import calibration_batch
from repro.models.model_registry import build_model


def main():
    # 1. a Mixtral-family model (reduced config for the CPU container;
    #    drop smoke=True on a real pod)
    cfg = get_config("mixtral-8x7b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {cfg.name}  ({cfg.num_experts} experts, "
          f"{cfg.param_count()/1e6:.1f}M params at this scale)")

    # 2. training-free mixture compression (PMQ + ODP), staged:
    #    one calibration pass, a cheap bit-allocation plan, then GPTQ+pack
    ccfg = CompressionConfig(enabled=True, target_bits=2.54, group_size=32,
                             odp_enabled=True)
    calib = jnp.asarray(calibration_batch(cfg, n_sequences=4, seq_len=64))
    record = pipeline.calibrate(model, params, calib,
                                bit_choices=ccfg.bit_choices,
                                group_size=ccfg.group_size)
    cplan = pipeline.plan(record, ccfg, layout="uniform")
    artifact = pipeline.apply(model, params, cplan, record)
    report = artifact.report
    print(f"PMQ: avg {report.avg_bits:.2f} bits/expert-weight, "
          f"{report.pmq.compression_ratio:.1%} of expert bytes removed")
    print(f"ODP: mu={report.odp_threshold:.3f}, "
          f"prune rate {report.odp_prune_rate:.1%}, "
          f"capacity scale {report.capacity_scale:.2f}")
    for rep in report.pmq.reports[:2]:
        print(f"  layer {rep.layer}: bits per expert = {rep.bits.tolist()}")

    # 3. run it (artifact.save(dir) would persist it for serving instead)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab_size)
    ref, _, _ = model.forward(params, tokens)
    out, _, _ = model.forward(artifact.params, tokens, mc=artifact.runtime)
    drift = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    print(f"logit drift vs fp: {drift:.3f} (finite: "
          f"{bool(jnp.isfinite(out).all())})")


if __name__ == "__main__":
    main()
