"""Serve a MC-compressed MoE with continuous batching (paper's deployment
scenario: one GPU/TPU slice hosting a 2.5-bit Mixtral under live traffic).

Requests arrive with mixed prompt/output lengths; the engine admits each
one into a freed decode slot as soon as one opens — no request waits for a
lockstep batch to finish.

    PYTHONPATH=src python examples/serve_compressed.py
"""
from repro.launch.serve import serve


def main():
    results, stats, report = serve(
        "mixtral-8x7b", smoke=True, mc=True, target_bits=2.54,
        n_requests=6, max_new=12, batch_size=3, mixed_lengths=True)
    print("\nsample generations (token ids):")
    for r in results[:3]:
        print(f"  req {r.uid}: {r.tokens.tolist()} ({r.finish_reason})")
    print(f"\nthroughput: {stats.decode_tokens_per_s:.1f} tok/s decode, "
          f"slot occupancy {stats.occupancy:.0%} "
          f"(CPU container; see EXPERIMENTS.md §Roofline for TPU "
          f"projections)")


if __name__ == "__main__":
    main()
