"""Compress once -> save artifact -> serve from the artifact.

The paper's deployment scenario (one GPU/TPU slice hosting a 2.5-bit
Mixtral under live traffic), now split the way production splits it: the
staged pipeline runs **offline** and persists a
:class:`~repro.core.pipeline.CompressedArtifact`; the serving side loads
that artifact with **no calibration data present** and generates
token-for-token identically to the in-memory compression it came from.

    PYTHONPATH=src python examples/serve_compressed.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CompressionConfig
from repro.configs import get_config
from repro.core import pipeline
from repro.data.pipeline import calibration_batch
from repro.models.model_registry import build_model
from repro.serve.engine import (EngineConfig, GenerationOptions, Request,
                                ServeEngine)


def _requests(cfg, n=6, seed=0, odp="default"):
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):   # mixed lengths: continuous batching's home turf
        pl = int(rng.randint(8, 33))
        mn = int(rng.randint(3, 13))
        reqs.append(Request(
            uid=i, prompt=rng.randint(1, cfg.vocab_size, pl).astype(np.int32),
            options=GenerationOptions(max_new_tokens=mn, odp=odp)))
    return reqs


def main():
    cfg = get_config("mixtral-8x7b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # ---- offline: calibrate -> plan -> apply -> save -------------------
    ccfg = CompressionConfig(enabled=True, target_bits=2.54, group_size=32,
                             odp_enabled=True)
    calib = jnp.asarray(calibration_batch(cfg, 4, 64))
    record = pipeline.calibrate(model, params, calib,
                                bit_choices=ccfg.bit_choices,
                                group_size=ccfg.group_size)
    artifact = pipeline.apply(
        model, params, pipeline.plan(record, ccfg, layout="uniform"), record)

    with tempfile.TemporaryDirectory() as tmp:
        artifact.save(tmp)
        # ---- online: load + serve (no calibration data in scope) -------
        del record, calib
        loaded = pipeline.CompressedArtifact.load(tmp)
        print(f"loaded artifact: avg_bits={loaded.report.avg_bits:.2f}, "
              f"odp_mu={loaded.runtime.odp.threshold:.3f}, "
              f"scan_safe={loaded.scan_safe}")

        reqs = _requests(cfg)
        engine = ServeEngine.from_artifact(
            model, loaded, config=EngineConfig(batch_size=3))
        results = engine.run(reqs)

        # the loaded artifact must match the in-memory one token-for-token
        ref_engine = ServeEngine.from_artifact(model, artifact, batch_size=3)
        ref = ref_engine.run(reqs)
        for r, rr in zip(results, ref):
            np.testing.assert_array_equal(r.tokens, rr.tokens)
        print("token-for-token identical to the inline compression path ✓")

        # the per-request ODP knob: 'off' disables pruning for a request,
        # an explicit ratio prunes harder — all inside ONE compiled decode
        # step (the knob is a jit input, not a retrace)
        mixed = _requests(cfg, odp="off")[:2] + _requests(cfg, odp=0.5)[2:]
        engine.run(mixed)
        print("mixed per-request odp knobs served without retracing ✓")

        print("\nsample generations (token ids):")
        for r in results[:3]:
            print(f"  req {r.uid}: {r.tokens.tolist()} ({r.finish_reason})")
        s = engine.stats
        print(f"\nthroughput: {s.decode_tokens_per_s:.1f} tok/s decode, "
              f"slot occupancy {s.occupancy:.0%} "
              f"(CPU container; see EXPERIMENTS.md §Roofline for TPU "
              f"projections)")


if __name__ == "__main__":
    main()
