"""Serve a MC-compressed MoE with batched requests (paper's deployment
scenario: one GPU/TPU slice hosting a 2.5-bit Mixtral).

    PYTHONPATH=src python examples/serve_compressed.py
"""
from repro.launch.serve import serve


def main():
    results, stats, report = serve(
        "mixtral-8x7b", smoke=True, mc=True, target_bits=2.54,
        n_requests=6, max_new=12, batch_size=3)
    print("\nsample generations (token ids):")
    for r in results[:3]:
        print(f"  req {r.uid}: {r.tokens.tolist()}")
    print(f"\nthroughput: {stats.decode_tokens_per_s:.1f} tok/s decode "
          f"(CPU container; see EXPERIMENTS.md §Roofline for TPU "
          f"projections)")


if __name__ == "__main__":
    main()
