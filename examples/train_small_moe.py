"""End-to-end training driver: train a small Mixtral-family MoE LM for a few
hundred steps with the full production loop — sharded train state, 8-bit
Adam, deterministic data pipeline, checkpoint/resume, straggler detection.

    PYTHONPATH=src python examples/train_small_moe.py            # ~8M CPU
    PYTHONPATH=src python examples/train_small_moe.py --m100     # ~100M

The 100M variant is the assignment's reference workload; the default is
sized so a few hundred steps finish on this 1-core CPU container. Both run
the identical code path (`repro.launch.train` drives the same loop).
"""
import argparse
import shutil

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.configs import get_config
from repro.checkpoint.checkpointer import CheckpointManager
from repro.data.pipeline import SyntheticTextConfig, SyntheticTokenDataset
from repro.models.model_registry import build_model
from repro.runtime.fault_tolerance import (StragglerDetector,
                                           run_with_fault_tolerance)
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--m100", action="store_true",
                    help="~100M-param config (slow on CPU)")
    ap.add_argument("--ckpt", default="/tmp/repro_small_moe")
    args = ap.parse_args()

    base = get_config("mixtral-8x7b", smoke=True)
    if args.m100:
        cfg = base.replace(num_layers=8, d_model=512, d_ff=1024,
                           moe_d_ff=1024, num_experts=8, num_heads=8,
                           num_kv_heads=4, head_dim=64, vocab_size=8192,
                           scan_layers=True, remat_policy="minimal")
    else:
        cfg = base.replace(num_layers=4, d_model=192, d_ff=384,
                           moe_d_ff=384, num_experts=8, vocab_size=2048)
    print(f"training {cfg.param_count()/1e6:.1f}M-param MoE "
          f"({cfg.num_experts} experts top-{cfg.top_k}) "
          f"for {args.steps} steps")

    tcfg = TrainConfig(learning_rate=1.5e-3, warmup_steps=20,
                       total_steps=args.steps, optimizer="adamw8bit",
                       aux_loss_weight=0.02)
    model = build_model(cfg)
    ds = SyntheticTokenDataset(SyntheticTextConfig(
        vocab_size=cfg.vocab_size, seq_len=128, global_batch=8, seed=0))
    step_fn = jax.jit(make_train_step(model, cfg, tcfg))
    shutil.rmtree(args.ckpt, ignore_errors=True)
    mgr = CheckpointManager(args.ckpt, keep=2)
    det = StragglerDetector()
    losses = []

    def one_step(state, step):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(step).items()}
        state, metrics = step_fn(state, batch)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"  step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"lb {float(metrics.get('load_balance', 0)):.3f}")
        losses.append(float(metrics["ce_loss"]))
        return state

    report = run_with_fault_tolerance(
        total_steps=args.steps,
        make_state=lambda: init_train_state(model,
                                            jax.random.PRNGKey(0), tcfg),
        step_fn=one_step, ckpt_manager=mgr,
        checkpoint_every=max(args.steps // 4, 10), detector=det)
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
          f"{report.restarts} restarts; checkpoint at {args.ckpt}")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
