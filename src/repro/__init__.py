"""repro: MC-MoE — Mixture Compressor for Mixture-of-Experts LLMs (ICLR 2025).

A production-grade JAX framework implementing the paper's training-free
mixture compression (PMQ mixed-precision expert quantization + ODP online
dynamic pruning) as first-class features of a multi-pod training/serving
stack, together with the substrate (model zoo, distribution, checkpointing,
fault tolerance, data, serving) required to run it at scale.
"""

__version__ = "1.0.0"
