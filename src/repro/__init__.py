"""repro: MC-MoE — Mixture Compressor for Mixture-of-Experts LLMs (ICLR 2025).

A production-grade JAX framework implementing the paper's training-free
mixture compression (PMQ mixed-precision expert quantization + ODP online
dynamic pruning) as first-class features of a multi-pod training/serving
stack, together with the substrate (model zoo, distribution, checkpointing,
fault tolerance, data, serving) required to run it at scale.

The package root re-exports the staged compression API and the serving
engines (lazily — importing ``repro`` stays cheap)::

    import repro

    record = repro.calibrate(model, params, calib_tokens, ...)
    plan = repro.plan(record, ccfg)
    artifact = repro.apply(model, params, plan, record)
    artifact.save(path)

    eng = repro.ServeEngine.from_artifact(
        model, repro.CompressedArtifact.load(path))
    results = eng.run([repro.Request(uid=0, prompt=toks,
                                     options=repro.GenerationOptions(
                                         max_new_tokens=32, odp=0.3))])
"""

__version__ = "1.0.0"

# name -> defining module, resolved lazily (PEP 562) so that importing the
# package root does not pull in jax/the model stack until first use
_EXPORTS = {
    "calibrate": "repro.core.pipeline",
    "plan": "repro.core.pipeline",
    "apply": "repro.core.pipeline",
    "CalibrationRecord": "repro.core.pipeline",
    "CompressionPlan": "repro.core.pipeline",
    "CompressedArtifact": "repro.core.pipeline",
    "MCReport": "repro.core.pipeline",
    "ServeEngine": "repro.serve.engine",
    "StaticServeEngine": "repro.serve.engine",
    "EngineConfig": "repro.serve.engine",
    "KVPoolConfig": "repro.serve.kv_pool",
    "SharedStatePool": "repro.serve.kv_pool",
    "SlotStateSpec": "repro.serve.slot_state",
    "StateKind": "repro.serve.slot_state",
    "state_kinds": "repro.serve.slot_state",
    "Request": "repro.serve.engine",
    "GenerationOptions": "repro.serve.engine",
    "Result": "repro.serve.engine",
    "FleetRouter": "repro.serve.router",
    "RouterConfig": "repro.serve.router",
    "FleetReport": "repro.serve.router",
    "ShardedReplica": "repro.serve.fleet",
    "ReplicaNode": "repro.serve.fleet",
    "LocalTransport": "repro.serve.transport",
    "FaultyTransport": "repro.serve.transport",
    "ChaosConfig": "repro.serve.transport",
}

__all__ = ["__version__", *_EXPORTS]


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro' has no attribute {name!r}")
    import importlib
    value = getattr(importlib.import_module(mod), name)
    globals()[name] = value          # cache: next access skips __getattr__
    return value


def __dir__():
    return sorted(__all__)
