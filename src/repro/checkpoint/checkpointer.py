"""Sharded, atomic, restart-safe checkpointing (no orbax dependency).

Layout per step (format v2, see ``docs/artifact_format.md`` for the
normative schema)::

    <dir>/step_000123/
        manifest.json     # tree paths, shapes, dtypes, shard groups, step
        shard_00000.npz   # one file per shard-group chunk (~512MB max)
        shard_00001.npz
    <dir>/LATEST          # atomic pointer file

Every leaf belongs to a named **shard group**; a group maps to one or more
npz files, each carrying a sha256 fingerprint in the manifest. Callers can
restore the full tree (:func:`load_pytree` / :func:`restore_pytree`) or
only the groups a host needs (:func:`load_pytree_subset`) — the subset
path reads strictly the files of the selected groups, which is what lets
an expert-parallel host stream only its slice of a
:class:`repro.core.pipeline.CompressedArtifact`.

Groups are assigned two ways:

* default — leaves are packed into rolling ``part*`` groups chunked at
  ~512MB (the v1 behavior, just named);
* ``split_fn`` — a leaf is cut into per-index slices along one axis, each
  slice assigned its own group (expert-major artifact layout: one group
  per (layer, expert)). The manifest records ``split`` metadata so loads
  reassemble the original array (or a contiguous partial stack).

Writes go to ``step_X.tmp-<pid>`` then ``os.rename`` (atomic on POSIX), so
a preempted writer never corrupts the latest checkpoint — the
fault-tolerance loop (runtime.fault_tolerance) relies on this. On
multi-host deployments each host writes the shards it owns (addressable
arrays); this container is single-host so every leaf is local.

Manifests written before the group format (no ``format_version`` field)
are still readable; manifests from a *newer* format fail loudly with an
upgrade message.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

_SHARD_BYTES = 512 * 1024 * 1024

#: Manifest schema version this module writes. v1 (implicit, pre-group
#: manifests with a per-leaf ``shard`` index) is still read; anything newer
#: than FORMAT_VERSION is rejected with an upgrade message.
FORMAT_VERSION = 2

#: Group name used for all leaves the ``split_fn`` does not claim.
DENSE_GROUP_PREFIX = "part"

LeafFilter = Callable[[str, str], bool]      # (key path, group name) -> keep?
SplitFn = Callable[[str, np.ndarray], Optional[Tuple[int, Sequence[str]]]]


@dataclass
class LoadStats:
    """Byte/file accounting for one (possibly partial) checkpoint read.

    A fleet host that re-shards accumulates several reads over its
    lifetime (boot stream + every delta block it takes over); fold them
    with :meth:`accumulate` so ``bytes_read``/``read_fraction`` report
    the host's *cumulative* streaming cost against the one artifact —
    the number ``benchmarks/bench_fleet.py`` compares to a full reload.
    """

    bytes_read: int = 0
    total_bytes: int = 0
    files_read: int = 0
    total_files: int = 0
    groups_read: int = 0
    total_groups: int = 0
    #: how many separate subset reads this accounting covers (1 for a
    #: plain load; boot + each re-shard delta for a fleet host)
    reads: int = 1
    #: fingerprint mismatches that recovered on the one re-read retry —
    #: transient torn reads (a writer racing the reader), not corruption
    fingerprint_retries: int = 0
    #: key path -> stacking axis, for every split leaf that was loaded
    split_axes: Dict[str, int] = field(default_factory=dict)
    #: key path -> (start, stop, count) when only a contiguous sub-range of
    #: a split leaf's slices was loaded (stop - start < count)
    partial: Dict[str, Tuple[int, int, int]] = field(default_factory=dict)

    @property
    def read_fraction(self) -> float:
        return self.bytes_read / max(self.total_bytes, 1)

    def accumulate(self, other: "LoadStats") -> "LoadStats":
        """Fold another read of the *same* checkpoint into this one (in
        place): read counters add, totals take the max (identical when
        both reads saw the same manifest). Split-leaf bookkeeping is
        deliberately NOT merged — disjoint ranges only compose at the
        part level (:func:`merge_subset_trees`), not inside one stats
        record. Returns ``self`` for chaining."""
        self.bytes_read += other.bytes_read
        self.files_read += other.files_read
        self.groups_read += other.groups_read
        self.reads += other.reads
        self.fingerprint_retries += other.fingerprint_retries
        self.total_bytes = max(self.total_bytes, other.total_bytes)
        self.total_files = max(self.total_files, other.total_files)
        self.total_groups = max(self.total_groups, other.total_groups)
        return self


def _path_str(kp) -> str:
    return jax.tree_util.keystr(kp)


def _npz_safe(arr: np.ndarray) -> np.ndarray:
    """npz can't hold extension dtypes (bfloat16); store the raw bits as
    uint16 (lossless, same size) and let the manifest's recorded dtype
    drive the reinterpretation on restore."""
    if str(arr.dtype) == "bfloat16":
        return arr.view(np.uint16)
    return arr


def _cast_back(arr: np.ndarray, dtype: str):
    import jax.numpy as jnp
    if dtype == "bfloat16" and arr.dtype == np.uint16:
        import ml_dtypes                     # jax dependency
        arr = arr.view(ml_dtypes.bfloat16)
    out = jnp.asarray(arr)
    if str(out.dtype) != dtype:
        out = out.astype(dtype)
    return out


def _sha256_file(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


# ------------------------------------------------------------------- saving
def save_pytree(directory: Path, step: int, tree: Any,
                meta: Optional[Dict] = None,
                split_fn: Optional[SplitFn] = None,
                fingerprint: bool = True) -> Path:
    """Write ``tree`` as an atomic checkpoint step.

    Args:
        directory: checkpoint root (``<directory>/step_XXXXXXXX`` is made).
        step: step number for the directory / ``LATEST`` pointer.
        meta: JSON-serializable extras stored under ``manifest['meta']``.
        split_fn: optional ``(key_path, array) -> None | (axis, names)``.
            When it returns ``(axis, names)`` (with ``len(names) ==
            array.shape[axis]``), the leaf is stored as per-index slices
            along ``axis``, slice ``i`` in shard group ``names[i]`` —
            this is how :class:`~repro.core.pipeline.CompressedArtifact`
            realizes the expert-major layout. Returning ``None`` places
            the leaf in the default size-chunked ``part*`` groups.
        fingerprint: record a sha256 per shard file (one extra page-cache
            read + hash per file at save, verified on load). Artifacts
            keep it on; rotating training checkpoints pass ``False``.

    Returns the finalized step directory.
    """
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest: Dict = {"format_version": FORMAT_VERSION, "step": step,
                      "meta": meta or {}, "leaves": [], "time": time.time()}

    # ---- assign every record (whole leaf or slice) to a group ----
    groups: Dict[str, List[Tuple[str, np.ndarray]]] = {}
    part_idx, part_bytes = 0, 0
    for i, (kp, leaf) in enumerate(leaves_with_paths):
        arr = np.asarray(leaf)
        path = _path_str(kp)
        sp = split_fn(path, arr) if split_fn is not None else None
        if sp is None:
            if part_bytes >= _SHARD_BYTES:
                part_idx, part_bytes = part_idx + 1, 0
            group = f"{DENSE_GROUP_PREFIX}{part_idx:05d}"
            part_bytes += arr.nbytes
            key = f"leaf_{i:06d}"
            manifest["leaves"].append({
                "path": path, "key": key, "group": group,
                "shape": list(arr.shape), "dtype": str(arr.dtype)})
            groups.setdefault(group, []).append((key, _npz_safe(arr)))
        else:
            axis, names = sp
            if len(names) != arr.shape[axis]:
                raise ValueError(
                    f"split_fn for {path} returned {len(names)} group names "
                    f"for axis {axis} of size {arr.shape[axis]}")
            for j, group in enumerate(names):
                # basic indexing: a view, not a copy — buffered groups
                # reference the original leaves, so peak save memory stays
                # O(params), not O(2x params); npz makes the transient
                # contiguous copy one slice at a time while writing
                sl = arr[(slice(None),) * axis + (j,)]
                key = f"leaf_{i:06d}_{j:04d}"
                manifest["leaves"].append({
                    "path": path, "key": key, "group": group,
                    "shape": list(sl.shape), "dtype": str(arr.dtype),
                    "split": {"axis": axis, "index": j,
                              "count": int(arr.shape[axis])}})
                groups.setdefault(group, []).append((key, _npz_safe(sl)))

    # ---- write each group as one or more fingerprinted npz chunks ----
    manifest["groups"] = {}
    file_seq = 0
    for group in sorted(groups):
        chunks: List[List[Tuple[str, np.ndarray]]] = [[]]
        nbytes = 0
        for key, arr in groups[group]:
            if nbytes >= _SHARD_BYTES and chunks[-1]:
                chunks.append([])
                nbytes = 0
            chunks[-1].append((key, arr))
            nbytes += arr.nbytes
        files = []
        for chunk in chunks:
            name = f"shard_{file_seq:05d}.npz"
            file_seq += 1
            np.savez(tmp / name, **dict(chunk))
            files.append({"name": name,
                          "bytes": (tmp / name).stat().st_size,
                          "sha256": (_sha256_file(tmp / name)
                                     if fingerprint else None)})
        manifest["groups"][group] = {
            "files": files, "bytes": sum(f["bytes"] for f in files)}

    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    latest_tmp = directory / f".LATEST.tmp-{os.getpid()}"
    latest_tmp.write_text(final.name)
    os.rename(latest_tmp, directory / "LATEST")
    return final


# ------------------------------------------------------------------ reading
def read_manifest(directory: Path, step: Optional[int] = None
                  ) -> Tuple[Dict, Path]:
    """Resolve ``step`` (``LATEST`` when None), validate the format version
    and return ``(manifest, step_dir)`` without reading any shard data —
    the cheap first half of a streaming load."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    ckpt = directory / f"step_{step:08d}"
    manifest = json.loads((ckpt / "manifest.json").read_text())
    fv = manifest.get("format_version", 1)
    if fv > FORMAT_VERSION:
        raise ValueError(
            f"checkpoint {ckpt} has manifest format_version {fv}, newer "
            f"than this build supports ({FORMAT_VERSION}); upgrade repro "
            "to read it (older formats are always readable)")
    return manifest, ckpt


def _v1_records(manifest: Dict) -> List[Dict]:
    """Normalize pre-group (v1) manifests: the per-leaf ``shard`` index
    becomes group ``part<idx>`` backed by the legacy shard file."""
    recs = []
    for rec in manifest["leaves"]:
        r = dict(rec)
        r["group"] = f"{DENSE_GROUP_PREFIX}{rec['shard']:05d}"
        r["_file"] = f"shard_{rec['shard']:05d}.npz"
        recs.append(r)
    return recs


def _group_files(manifest: Dict, ckpt: Path) -> Dict[str, List[Dict]]:
    fv = manifest.get("format_version", 1)
    if fv >= 2:
        return {g: info["files"] for g, info in manifest["groups"].items()}
    files: Dict[str, List[Dict]] = {}
    for rec in _v1_records(manifest):
        fn = rec["_file"]
        if rec["group"] not in files:
            size = (ckpt / fn).stat().st_size if (ckpt / fn).exists() else 0
            files[rec["group"]] = [{"name": fn, "bytes": size,
                                    "sha256": None}]
    return files


def _load_values(ckpt: Path, manifest: Dict,
                 leaf_filter: Optional[LeafFilter] = None,
                 verify: bool = True
                 ) -> Tuple[Dict[str, Tuple[np.ndarray, str]], LoadStats]:
    """Read (a subset of) the checkpoint's leaves.

    Returns ``(values, stats)`` where ``values`` maps key paths to
    ``(array, dtype)`` with split leaves reassembled — fully, or as the
    contiguous partial stack the filter selected (recorded in
    ``stats.partial``).
    """
    fv = manifest.get("format_version", 1)
    records = manifest["leaves"] if fv >= 2 else _v1_records(manifest)
    group_files = _group_files(manifest, ckpt)

    stats = LoadStats(total_groups=len(group_files))
    for files in group_files.values():
        stats.total_files += len(files)
        stats.total_bytes += sum(f["bytes"] for f in files)

    selected = [r for r in records
                if leaf_filter is None or leaf_filter(r["path"], r["group"])]
    needed_groups = sorted({r["group"] for r in selected})

    # read + fingerprint-check every file of every needed group
    arrays: Dict[str, np.ndarray] = {}
    for group in needed_groups:
        for f in group_files[group]:
            fpath = ckpt / f["name"]
            if not fpath.exists():
                raise FileNotFoundError(
                    f"shard group {group!r}: file {f['name']} missing "
                    f"from {ckpt}")
            if verify and f.get("sha256"):
                digest = _sha256_file(fpath)
                if digest != f["sha256"]:
                    # a re-shard delta read can race a writer mid-rename
                    # (torn read); one re-read distinguishes that
                    # transient from genuine corruption
                    digest = _sha256_file(fpath)
                    if digest == f["sha256"]:
                        stats.fingerprint_retries += 1
                    else:
                        raise ValueError(
                            f"shard group {group!r} failed its "
                            f"fingerprint check (twice): {f['name']} "
                            f"hashes to {digest[:12]}… but the manifest "
                            f"records {f['sha256'][:12]}… — the file is "
                            "corrupt or was tampered with; re-fetch the "
                            "artifact")
            with np.load(fpath) as z:
                arrays.update({k: z[k] for k in z.files})
            stats.files_read += 1
            stats.bytes_read += f["bytes"]
        stats.groups_read += 1

    # assemble leaves (stacking split slices back together)
    by_path: Dict[str, List[Dict]] = {}
    for rec in selected:
        by_path.setdefault(rec["path"], []).append(rec)
    values: Dict[str, Tuple[np.ndarray, str]] = {}
    for path, recs in by_path.items():
        for rec in recs:
            if rec["key"] not in arrays:
                raise KeyError(
                    f"checkpoint payload is missing leaf {path!r} "
                    f"(key {rec['key']}, shard group {rec['group']!r}) — "
                    "the npz shards do not match the manifest")
        if "split" not in recs[0]:
            assert len(recs) == 1, path
            values[path] = (arrays[recs[0]["key"]], recs[0]["dtype"])
            continue
        recs = sorted(recs, key=lambda r: r["split"]["index"])
        idx = [r["split"]["index"] for r in recs]
        count = recs[0]["split"]["count"]
        if idx != list(range(idx[0], idx[0] + len(idx))):
            raise ValueError(
                f"subset of split leaf {path!r} selects non-contiguous "
                f"slice indices {idx}; expert subsets must be contiguous")
        axis = recs[0]["split"]["axis"]
        stacked = np.stack([arrays[r["key"]] for r in recs], axis=axis)
        values[path] = (stacked, recs[0]["dtype"])
        stats.split_axes[path] = axis
        if len(idx) != count:
            stats.partial[path] = (idx[0], idx[0] + len(idx), count)
    return values, stats


def restore_pytree(directory: Path, target: Any,
                   step: Optional[int] = None,
                   verify: bool = True) -> Tuple[Any, int]:
    """Restore into the structure of ``target`` (arrays or structs).
    ``verify=False`` skips per-file fingerprint checks (when recorded)."""
    manifest, ckpt = read_manifest(directory, step)
    values, _ = _load_values(ckpt, manifest, verify=verify)

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    out = []
    for kp, leaf in leaves_with_paths:
        p = _path_str(kp)
        if p not in values:
            raise KeyError(f"checkpoint missing leaf {p}")
        arr, dtype = values[p]
        want_shape = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"shape mismatch at {p}: "
                             f"{arr.shape} vs {want_shape}")
        out.append(_cast_back(arr, dtype))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]


# --------------------------------------------------- structure-free restore
_KEY_TOKEN = re.compile(r"\['([^']*)'\]|\[(\d+)\]")


def _parse_keystr(path: str) -> List[Any]:
    """``['a'][0]['b']`` -> ``['a', 0, 'b']`` (dict keys / sequence idx)."""
    keys: List[Any] = []
    pos = 0
    for m in _KEY_TOKEN.finditer(path):
        if m.start() != pos:
            raise ValueError(f"unsupported key path {path!r}")
        keys.append(m.group(1) if m.group(1) is not None
                    else int(m.group(2)))
        pos = m.end()
    if pos != len(path) or not keys:
        raise ValueError(f"unsupported key path {path!r}")
    return keys


def _listify(node):
    """Convert int-keyed dict nodes (sequence entries) back into lists."""
    if not isinstance(node, dict):
        return node
    out = {k: _listify(v) for k, v in node.items()}
    if out and all(isinstance(k, int) for k in out):
        idx = sorted(out)
        if idx != list(range(len(idx))):
            raise ValueError(f"non-contiguous sequence indices {idx}")
        return [out[i] for i in idx]
    return out


def _build_tree(values: Dict[str, Tuple[np.ndarray, str]],
                order: List[str]) -> Any:
    root: Dict = {}
    seen = set()
    for path in order:
        if path in seen or path not in values:
            continue
        seen.add(path)
        keys = _parse_keystr(path)
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        arr, dtype = values[path]
        node[keys[-1]] = _cast_back(arr, dtype)
    return _listify(root)


def load_pytree(directory: Path, step: Optional[int] = None,
                verify: bool = True) -> Tuple[Any, Dict]:
    """Restore a checkpoint *without* a target structure.

    Rebuilds nested dicts/lists from the manifest key paths — this is what
    lets a :class:`repro.core.pipeline.CompressedArtifact` load with no
    model, plan, or calibration data in hand (quantized param trees aren't
    derivable from ``model.init``). Reads every shard group; use
    :func:`load_pytree_subset` to stream only some. ``verify=False`` skips
    the per-file sha256 fingerprint check. Returns ``(tree, manifest)``.
    """
    tree, manifest, _ = load_pytree_subset(directory, None, step=step,
                                           verify=verify)
    return tree, manifest


def load_pytree_subset(directory: Path,
                       leaf_filter: Optional[LeafFilter],
                       step: Optional[int] = None,
                       verify: bool = True) -> Tuple[Any, Dict, LoadStats]:
    """Restore only the leaves whose ``(key_path, group)`` the filter keeps.

    Only the npz files of the selected shard groups are opened — the whole
    point: a host that owns experts ``[k0:k1)`` of an expert-major
    :class:`~repro.core.pipeline.CompressedArtifact` passes a filter for
    its groups and reads strictly fewer bytes than a full load. Split
    leaves come back as a contiguous partial stack when only some of their
    slices are selected (``stats.partial`` records the range).

    Args:
        leaf_filter: ``(key_path, group_name) -> bool``; ``None`` keeps
            everything (= :func:`load_pytree`).
        verify: check each read file against its manifest sha256
            fingerprint (mismatch raises ``ValueError``).

    Returns ``(tree, manifest, stats)`` with byte/file accounting in
    ``stats`` (:class:`LoadStats`).
    """
    manifest, ckpt = read_manifest(directory, step)
    values, stats = _load_values(ckpt, manifest, leaf_filter, verify=verify)
    tree = _build_tree(values, [r["path"] for r in manifest["leaves"]])
    return tree, manifest, stats


def merge_subset_trees(parts: List[Tuple[Any, LoadStats]]) -> Any:
    """Reassemble a full pytree from per-host subset loads.

    ``parts`` is a list of ``(tree, stats)`` pairs as returned by
    :func:`load_pytree_subset`. Split leaves are concatenated along their
    recorded axis in slice order (the per-host ranges must tile
    ``[0, count)`` exactly); leaves present in several parts unsplit are
    taken from the first. The union of all hosts' subsets therefore
    reconstructs the original tree bit-for-bit — the invariant
    ``tests/test_artifact_sharding.py`` pins down.
    """
    pieces: Dict[str, List[Tuple[int, int, np.ndarray]]] = {}
    axes: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    dense: Dict[str, np.ndarray] = {}
    for tree, stats in parts:
        for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            path = _path_str(kp)
            arr = np.asarray(leaf)
            if path in stats.split_axes:
                axis = stats.split_axes[path]
                start, stop, count = stats.partial.get(
                    path, (0, arr.shape[axis], arr.shape[axis]))
                axes[path] = axis
                counts[path] = max(counts.get(path, 0), count)
                pieces.setdefault(path, []).append((start, stop, arr))
            else:
                dense.setdefault(path, arr)

    values: Dict[str, Tuple[np.ndarray, str]] = {}
    for path, arr in dense.items():
        values[path] = (arr, str(arr.dtype))
    for path, chunks in pieces.items():
        chunks = sorted(chunks, key=lambda c: c[0])
        pos = 0
        for start, stop, _ in chunks:
            if start != pos:
                raise ValueError(
                    f"subset ranges for {path!r} do not tile: gap/overlap "
                    f"at index {pos} (next chunk starts at {start})")
            pos = stop
        if pos != counts[path]:
            raise ValueError(
                f"subset ranges for {path!r} do not tile: slices cover "
                f"[0, {pos}) of {counts[path]} — a host's subset is "
                "missing from `parts`")
        merged = np.concatenate([c[2] for c in chunks], axis=axes[path])
        values[path] = (merged, str(merged.dtype))
    return _build_tree(values, sorted(values))


def latest_step(directory: Path) -> Optional[int]:
    directory = Path(directory)
    ptr = directory / "LATEST"
    if ptr.exists():
        name = ptr.read_text().strip()
        if (directory / name / "manifest.json").exists():
            return int(name.split("_")[1])
    steps = sorted(int(p.name.split("_")[1]) for p in directory.glob("step_*")
                   if p.is_dir() and (p / "manifest.json").exists())
    return steps[-1] if steps else None


class CheckpointManager:
    """Rotation + async save + resume discovery."""

    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, meta: Optional[Dict] = None,
             block: bool = False):
        self.wait()
        # snapshot to host memory before going async
        host_tree = jax.tree.map(np.asarray, tree)

        def _do():
            # rotating training checkpoints skip fingerprints: they are
            # transient, and hashing every shard on the hot save path
            # (and again at restore) buys nothing the rotation keeps
            save_pytree(self.dir, step, host_tree, meta,
                        fingerprint=False)
            self._rotate()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, target: Any, step: Optional[int] = None,
                verify: bool = True):
        self.wait()
        return restore_pytree(self.dir, target, step, verify=verify)

    def latest_step(self) -> Optional[int]:
        self.wait()
        return latest_step(self.dir)

    def _rotate(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.dir.glob("step_*") if p.is_dir())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
