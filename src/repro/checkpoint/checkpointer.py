"""Sharded, atomic, restart-safe checkpointing (no orbax dependency).

Layout per step::

    <dir>/step_000123/
        manifest.json     # tree paths, shapes, dtypes, step, config hash
        shard_00000.npz   # leaves, chunked ~512MB per file
    <dir>/LATEST          # atomic pointer file

Writes go to ``step_X.tmp-<pid>`` then ``os.rename`` (atomic on POSIX), so a
preempted writer never corrupts the latest checkpoint — the fault-tolerance
loop (runtime.fault_tolerance) relies on this. On multi-host deployments
each host writes the shards it owns (addressable arrays); this container is
single-host so every leaf is local.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_SHARD_BYTES = 512 * 1024 * 1024


def _path_str(kp) -> str:
    return jax.tree_util.keystr(kp)


def save_pytree(directory: Path, step: int, tree: Any,
                meta: Optional[Dict] = None) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "meta": meta or {}, "leaves": [],
                "time": time.time()}
    shard_idx, shard_bytes, shard_data = 0, 0, {}

    def flush():
        nonlocal shard_idx, shard_bytes, shard_data
        if shard_data:
            np.savez(tmp / f"shard_{shard_idx:05d}.npz", **shard_data)
            shard_idx += 1
            shard_bytes, shard_data = 0, {}

    for i, (kp, leaf) in enumerate(leaves_with_paths):
        arr = np.asarray(leaf)
        key = f"leaf_{i:06d}"
        manifest["leaves"].append({
            "path": _path_str(kp), "key": key, "shard": shard_idx,
            "shape": list(arr.shape), "dtype": str(arr.dtype)})
        shard_data[key] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= _SHARD_BYTES:
            flush()
    flush()
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    latest_tmp = directory / f".LATEST.tmp-{os.getpid()}"
    latest_tmp.write_text(final.name)
    os.rename(latest_tmp, directory / "LATEST")
    return final


def restore_pytree(directory: Path, target: Any,
                   step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``target`` (arrays or structs)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    ckpt = directory / f"step_{step:08d}"
    manifest = json.loads((ckpt / "manifest.json").read_text())
    by_shard: Dict[int, List[Dict]] = {}
    for rec in manifest["leaves"]:
        by_shard.setdefault(rec["shard"], []).append(rec)
    values: Dict[str, np.ndarray] = {}
    for shard, recs in by_shard.items():
        with np.load(ckpt / f"shard_{shard:05d}.npz") as z:
            for rec in recs:
                values[rec["path"]] = z[rec["key"]]

    import jax.numpy as jnp
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    out = []
    for kp, leaf in leaves_with_paths:
        p = _path_str(kp)
        if p not in values:
            raise KeyError(f"checkpoint missing leaf {p}")
        arr = values[p]
        want_shape = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"shape mismatch at {p}: "
                             f"{arr.shape} vs {want_shape}")
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]


def latest_step(directory: Path) -> Optional[int]:
    directory = Path(directory)
    ptr = directory / "LATEST"
    if ptr.exists():
        name = ptr.read_text().strip()
        if (directory / name / "manifest.json").exists():
            return int(name.split("_")[1])
    steps = sorted(int(p.name.split("_")[1]) for p in directory.glob("step_*")
                   if p.is_dir() and (p / "manifest.json").exists())
    return steps[-1] if steps else None


class CheckpointManager:
    """Rotation + async save + resume discovery."""

    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, meta: Optional[Dict] = None,
             block: bool = False):
        self.wait()
        # snapshot to host memory before going async
        host_tree = jax.tree.map(np.asarray, tree)

        def _do():
            save_pytree(self.dir, step, host_tree, meta)
            self._rotate()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, target: Any, step: Optional[int] = None):
        self.wait()
        return restore_pytree(self.dir, target, step)

    def latest_step(self) -> Optional[int]:
        self.wait()
        return latest_step(self.dir)

    def _rotate(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.dir.glob("step_*") if p.is_dir())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
