"""Sharded, atomic, restart-safe checkpointing (no orbax dependency).

Layout per step::

    <dir>/step_000123/
        manifest.json     # tree paths, shapes, dtypes, step, config hash
        shard_00000.npz   # leaves, chunked ~512MB per file
    <dir>/LATEST          # atomic pointer file

Writes go to ``step_X.tmp-<pid>`` then ``os.rename`` (atomic on POSIX), so a
preempted writer never corrupts the latest checkpoint — the fault-tolerance
loop (runtime.fault_tolerance) relies on this. On multi-host deployments
each host writes the shards it owns (addressable arrays); this container is
single-host so every leaf is local.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_SHARD_BYTES = 512 * 1024 * 1024


def _path_str(kp) -> str:
    return jax.tree_util.keystr(kp)


def _npz_safe(arr: np.ndarray) -> np.ndarray:
    """npz can't hold extension dtypes (bfloat16); store the raw bits as
    uint16 (lossless, same size) and let the manifest's recorded dtype
    drive the reinterpretation on restore."""
    if str(arr.dtype) == "bfloat16":
        return arr.view(np.uint16)
    return arr


def _cast_back(arr: np.ndarray, dtype: str):
    import jax.numpy as jnp
    if dtype == "bfloat16" and arr.dtype == np.uint16:
        import ml_dtypes                     # jax dependency
        arr = arr.view(ml_dtypes.bfloat16)
    out = jnp.asarray(arr)
    if str(out.dtype) != dtype:
        out = out.astype(dtype)
    return out


def save_pytree(directory: Path, step: int, tree: Any,
                meta: Optional[Dict] = None) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "meta": meta or {}, "leaves": [],
                "time": time.time()}
    shard_idx, shard_bytes, shard_data = 0, 0, {}

    def flush():
        nonlocal shard_idx, shard_bytes, shard_data
        if shard_data:
            np.savez(tmp / f"shard_{shard_idx:05d}.npz", **shard_data)
            shard_idx += 1
            shard_bytes, shard_data = 0, {}

    for i, (kp, leaf) in enumerate(leaves_with_paths):
        arr = np.asarray(leaf)
        key = f"leaf_{i:06d}"
        manifest["leaves"].append({
            "path": _path_str(kp), "key": key, "shard": shard_idx,
            "shape": list(arr.shape), "dtype": str(arr.dtype)})
        shard_data[key] = _npz_safe(arr)
        shard_bytes += shard_data[key].nbytes
        if shard_bytes >= _SHARD_BYTES:
            flush()
    flush()
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    latest_tmp = directory / f".LATEST.tmp-{os.getpid()}"
    latest_tmp.write_text(final.name)
    os.rename(latest_tmp, directory / "LATEST")
    return final


def restore_pytree(directory: Path, target: Any,
                   step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``target`` (arrays or structs)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    ckpt = directory / f"step_{step:08d}"
    manifest = json.loads((ckpt / "manifest.json").read_text())
    values = _load_shard_values(ckpt, manifest)

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    out = []
    for kp, leaf in leaves_with_paths:
        p = _path_str(kp)
        if p not in values:
            raise KeyError(f"checkpoint missing leaf {p}")
        arr, dtype = values[p]
        want_shape = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"shape mismatch at {p}: "
                             f"{arr.shape} vs {want_shape}")
        out.append(_cast_back(arr, dtype))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]


def _load_shard_values(ckpt: Path, manifest: Dict
                       ) -> Dict[str, Tuple[np.ndarray, str]]:
    by_shard: Dict[int, List[Dict]] = {}
    for rec in manifest["leaves"]:
        by_shard.setdefault(rec["shard"], []).append(rec)
    values: Dict[str, Tuple[np.ndarray, str]] = {}
    for shard, recs in by_shard.items():
        with np.load(ckpt / f"shard_{shard:05d}.npz") as z:
            for rec in recs:
                values[rec["path"]] = (z[rec["key"]], rec["dtype"])
    return values


# --------------------------------------------------- structure-free restore
_KEY_TOKEN = re.compile(r"\['([^']*)'\]|\[(\d+)\]")


def _parse_keystr(path: str) -> List[Any]:
    """``['a'][0]['b']`` -> ``['a', 0, 'b']`` (dict keys / sequence idx)."""
    keys: List[Any] = []
    pos = 0
    for m in _KEY_TOKEN.finditer(path):
        if m.start() != pos:
            raise ValueError(f"unsupported key path {path!r}")
        keys.append(m.group(1) if m.group(1) is not None
                    else int(m.group(2)))
        pos = m.end()
    if pos != len(path) or not keys:
        raise ValueError(f"unsupported key path {path!r}")
    return keys


def _listify(node):
    """Convert int-keyed dict nodes (sequence entries) back into lists."""
    if not isinstance(node, dict):
        return node
    out = {k: _listify(v) for k, v in node.items()}
    if out and all(isinstance(k, int) for k in out):
        idx = sorted(out)
        if idx != list(range(len(idx))):
            raise ValueError(f"non-contiguous sequence indices {idx}")
        return [out[i] for i in idx]
    return out


def load_pytree(directory: Path, step: Optional[int] = None
                ) -> Tuple[Any, Dict]:
    """Restore a checkpoint *without* a target structure.

    Rebuilds nested dicts/lists from the manifest key paths — this is what
    lets a :class:`repro.core.pipeline.CompressedArtifact` load with no
    model, plan, or calibration data in hand (quantized param trees aren't
    derivable from ``model.init``). Returns ``(tree, manifest)``.
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    ckpt = directory / f"step_{step:08d}"
    manifest = json.loads((ckpt / "manifest.json").read_text())
    values = _load_shard_values(ckpt, manifest)

    root: Dict = {}
    for rec in manifest["leaves"]:
        keys = _parse_keystr(rec["path"])
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        arr, dtype = values[rec["path"]]
        node[keys[-1]] = _cast_back(arr, dtype)
    return _listify(root), manifest


def latest_step(directory: Path) -> Optional[int]:
    directory = Path(directory)
    ptr = directory / "LATEST"
    if ptr.exists():
        name = ptr.read_text().strip()
        if (directory / name / "manifest.json").exists():
            return int(name.split("_")[1])
    steps = sorted(int(p.name.split("_")[1]) for p in directory.glob("step_*")
                   if p.is_dir() and (p / "manifest.json").exists())
    return steps[-1] if steps else None


class CheckpointManager:
    """Rotation + async save + resume discovery."""

    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, meta: Optional[Dict] = None,
             block: bool = False):
        self.wait()
        # snapshot to host memory before going async
        host_tree = jax.tree.map(np.asarray, tree)

        def _do():
            save_pytree(self.dir, step, host_tree, meta)
            self._rotate()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, target: Any, step: Optional[int] = None):
        self.wait()
        return restore_pytree(self.dir, target, step)

    def latest_step(self) -> Optional[int]:
        self.wait()
        return latest_step(self.dir)

    def _rotate(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.dir.glob("step_*") if p.is_dir())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
