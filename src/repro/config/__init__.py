from repro.config.base import (  # noqa: F401
    SHAPES,
    CompressionConfig,
    MeshConfig,
    ModelConfig,
    RunConfig,
    ShapeConfig,
    TrainConfig,
    apply_overrides,
)
