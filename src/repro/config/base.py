"""Config system: typed dataclasses with dict round-tripping and overrides.

Every architecture in ``repro.configs`` builds a :class:`ModelConfig`;
launchers combine it with a :class:`ShapeConfig` (one of the assigned
input-shape cells), a :class:`MeshConfig`, and (for PMQ/ODP) a
:class:`CompressionConfig`.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


def _asdict(obj) -> Dict[str, Any]:
    return dataclasses.asdict(obj)


class _Base:
    """Shared helpers for all config dataclasses."""

    def to_dict(self) -> Dict[str, Any]:
        return _asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]):
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)

    def fingerprint(self) -> str:
        """Stable content hash — used for checkpoint compatibility checks."""
        return hashlib.sha256(self.to_json().encode()).hexdigest()[:16]


@dataclass(frozen=True)
class ModelConfig(_Base):
    """Architecture definition. Covers dense / MoE / SSM / hybrid / enc-dec / VLM."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # 0 -> d_ff
    moe_layer_period: int = 1        # MoE every `period` layers (llama4: 2)
    first_moe_layer: int = 0
    shared_expert: bool = False      # llama4-style always-on shared expert
    dense_residual: bool = False     # arctic-style parallel dense FFN branch
    dense_residual_ff: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    moe_impl: str = "gather"         # gather | shard_map (EP all_to_all)

    # --- attention ---
    attn_type: str = "full"          # full | sliding | local_global | chunked
    window_size: int = 0             # sliding / local layers
    local_global_period: int = 2     # gemma2: every other layer global
    chunk_size: int = 0              # llama4 chunked-local layers
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    use_parallel_residual: bool = False   # command-r style attn || mlp
    use_qk_norm: bool = False
    attn_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    max_pos: int = 32768             # learned-position table size (use_rope=False)
    kv_quant: bool = False           # int8 KV cache (beyond-paper, KIVI-style)

    # --- FFN ---
    mlp_act: str = "silu"            # silu | gelu | gelu_tanh
    mlp_gated: bool = True           # SwiGLU/GeGLU vs plain

    # --- SSM (mamba) ---
    ssm_type: str = ""               # "" | mamba1 | mamba2
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64           # mamba2
    ssm_chunk: int = 256             # chunked scan length
    ssm_scan: str = "assoc"          # assoc | fused_seq (see ssm.py §Perf)
    ssm_dt_rank: int = 0             # 0 -> d_model // 16 (mamba1)

    # --- hybrid (zamba2) ---
    shared_attn_period: int = 0      # insert shared attn block every N ssm layers

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed frame embeddings length

    # --- VLM (paligemma) ---
    num_prefix_tokens: int = 0       # precomputed patch embeddings length

    # --- misc ---
    norm_eps: float = 1e-6
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    pre_post_norm: bool = False      # gemma2 double-norm
    tie_embeddings: bool = True
    embedding_scale: bool = False    # gemma-style sqrt(d) embed scaling
    dtype: str = "bfloat16"
    remat_policy: str = "minimal"    # none | minimal | full
    scan_layers: bool = True
    logit_dtype: str = "float32"

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.ssm_dt_rank == 0 and self.ssm_type == "mamba1":
            object.__setattr__(self, "ssm_dt_rank", max(1, self.d_model // 16))

    # ---- derived quantities -------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def moe_layer_ids(self) -> List[int]:
        if not self.is_moe:
            return []
        return [
            i for i in range(self.num_layers)
            if i >= self.first_moe_layer
            and (i - self.first_moe_layer) % self.moe_layer_period == 0
        ]

    def num_moe_layers(self) -> int:
        return len(self.moe_layer_ids())

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, h = self.d_model, self.head_dim
        n_q = self.num_heads * h
        n_kv = self.num_kv_heads * h
        attn = d * n_q + 2 * d * n_kv + n_q * d
        mlp_mats = 3 if self.mlp_gated else 2
        dense_ffn = mlp_mats * d * self.d_ff
        expert_ffn = mlp_mats * d * self.moe_d_ff

        total = 0
        if self.family == "ssm":
            inner = self.d_model * self.ssm_expand
            if self.ssm_type == "mamba1":
                per = (d * inner * 2 + inner * self.ssm_conv
                       + inner * (self.ssm_dt_rank + 2 * self.ssm_state)
                       + self.ssm_dt_rank * inner + inner * self.ssm_state
                       + inner * d)
            else:
                nheads = inner // self.ssm_head_dim
                per = (d * (2 * inner + 2 * self.ssm_state + nheads)
                       + inner * self.ssm_conv + inner * d)
            total += self.num_layers * per
        elif self.family == "hybrid":
            inner = self.d_model * self.ssm_expand
            nheads = max(1, inner // self.ssm_head_dim)
            per = (d * (2 * inner + 2 * self.ssm_state + nheads)
                   + inner * self.ssm_conv + inner * d)
            total += self.num_layers * per
            if self.shared_attn_period:
                total += attn + dense_ffn  # one shared block
        else:
            n_moe = self.num_moe_layers()
            n_dense = self.num_layers - n_moe
            per_moe = attn + self.num_experts * expert_ffn + d * self.num_experts
            if self.shared_expert:
                per_moe += expert_ffn
            if self.dense_residual:
                per_moe += mlp_mats * d * (self.dense_residual_ff or self.d_ff)
            total += n_moe * per_moe + n_dense * (attn + dense_ffn)
            if self.family == "encdec":
                enc_per = attn + dense_ffn + (d * n_q + n_q * d + 2 * d * n_kv)  # + cross-attn in dec
                total += self.encoder_layers * (attn + dense_ffn) + self.num_layers * (d * n_q + n_q * d + 2 * d * n_kv)
                _ = enc_per
        total += self.vocab_size * d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        return int(total)

    def active_param_count(self) -> int:
        """Per-token activated parameters (MoE: only top-k experts count)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        mlp_mats = 3 if self.mlp_gated else 2
        expert_ffn = mlp_mats * self.d_model * self.moe_d_ff
        n_moe = self.num_moe_layers()
        inactive = n_moe * (self.num_experts - self.top_k) * expert_ffn
        return int(full - inactive)


@dataclass(frozen=True)
class ShapeConfig(_Base):
    """One assigned input-shape cell."""

    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    mode: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class MeshConfig(_Base):
    """Logical device mesh. Axis order: (pod?, data, model)."""

    shape: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axis_names

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    def axis_size(self, name: str) -> int:
        return self.shape[self.axis_names.index(name)] if name in self.axis_names else 1


@dataclass(frozen=True)
class CompressionConfig(_Base):
    """MC settings: PMQ bit allocation + ODP pruning."""

    enabled: bool = False
    # PMQ
    target_bits: float = 2.54        # mean expert bit-width k in Eq. 4
    bit_choices: Tuple[int, ...] = (1, 2, 3)
    alpha: float = 1.0               # frequency exponent
    beta: float = 1.0                # routing-weight exponent
    gamma: float = 2.0               # quant-error exponent
    group_size: int = 128            # quantizer group size
    attn_bits: int = 4               # non-expert weights
    gptq_blocksize: int = 128
    gptq_percdamp: float = 0.01
    calib_sequences: int = 128
    calib_seq_len: int = 2048
    # ODP
    odp_enabled: bool = False
    prune_threshold: float = -1.0    # <0 -> use calibration median of w1/w0
    protect_ratio: float = 0.02      # fraction of tokens protected
    odp_capacity_scale: float = 0.85 # static capacity shrink from calibrated prune rate


@dataclass(frozen=True)
class TrainConfig(_Base):
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    optimizer: str = "adamw"         # adamw | adamw8bit
    grad_compression: str = "none"   # none | int8_ef
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    seed: int = 0
    z_loss: float = 1e-4
    aux_loss_weight: float = 0.01    # MoE load-balance loss


@dataclass(frozen=True)
class RunConfig(_Base):
    """Bundle handed to launchers."""

    model: Dict[str, Any] = field(default_factory=dict)
    shape: Dict[str, Any] = field(default_factory=dict)
    mesh: Dict[str, Any] = field(default_factory=dict)
    compression: Dict[str, Any] = field(default_factory=dict)
    train: Dict[str, Any] = field(default_factory=dict)

    @classmethod
    def build(cls, model: ModelConfig, shape: ShapeConfig,
              mesh: MeshConfig = MeshConfig(),
              compression: CompressionConfig = CompressionConfig(),
              train: TrainConfig = TrainConfig()) -> "RunConfig":
        return cls(model=model.to_dict(), shape=shape.to_dict(),
                   mesh=mesh.to_dict(), compression=compression.to_dict(),
                   train=train.to_dict())

    def model_config(self) -> ModelConfig:
        return ModelConfig.from_dict(self.model)

    def shape_config(self) -> ShapeConfig:
        return ShapeConfig.from_dict(self.shape)

    def mesh_config(self) -> MeshConfig:
        return MeshConfig.from_dict(dict(self.mesh))

    def compression_config(self) -> CompressionConfig:
        return CompressionConfig.from_dict(self.compression)

    def train_config(self) -> TrainConfig:
        return TrainConfig.from_dict(self.train)


def apply_overrides(cfg: ModelConfig, overrides: Optional[Dict[str, Any]]) -> ModelConfig:
    if not overrides:
        return cfg
    return cfg.replace(**overrides)
