from repro.configs.registry import (  # noqa: F401
    ALL_ARCHS,
    ASSIGNED_ARCHS,
    get_config,
    shrink,
)
