"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000; parallel attention+FFN residual, no biases.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.config import ModelConfig
from repro.configs import registry


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b",
        family="dense",
        num_layers=64,
        d_model=12288,
        num_heads=96,
        num_kv_heads=8,
        head_dim=128,
        d_ff=33792,
        vocab_size=256000,
        attn_type="full",
        use_parallel_residual=True,
        norm_type="layernorm",
        mlp_act="silu",
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return registry.shrink(config())
