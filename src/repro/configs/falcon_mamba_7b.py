"""falcon-mamba-7b [ssm]: 64L d_model=4096, attention-free Mamba-1
architecture, ssm_state=16, vocab=65024. [arXiv:2410.05355; unverified]
"""
from repro.config import ModelConfig
from repro.configs import registry


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        num_layers=64,
        d_model=4096,
        num_heads=0,
        num_kv_heads=0,
        head_dim=1,
        d_ff=0,
        vocab_size=65024,
        ssm_type="mamba1",
        ssm_state=16,
        ssm_expand=2,
        ssm_conv=4,
        ssm_scan="fused_seq",   # Perf cell A: 3.3x memory-term win vs assoc
        use_rope=False,
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return registry.shrink(config(), num_heads=0, num_kv_heads=0, head_dim=1,
                           d_ff=0)
