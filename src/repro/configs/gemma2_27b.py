"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000; alternating local(4096)/global attention, logit softcapping,
pre+post norms, embedding scaling. [arXiv:2408.00118; hf]
"""
from repro.config import ModelConfig
from repro.configs import registry


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b",
        family="dense",
        num_layers=46,
        d_model=4608,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab_size=256000,
        attn_type="local_global",
        window_size=4096,
        local_global_period=2,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        pre_post_norm=True,
        embedding_scale=True,
        mlp_act="gelu_tanh",
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return registry.shrink(config())
