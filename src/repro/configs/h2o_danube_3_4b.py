"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000; llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]
"""
from repro.config import ModelConfig
from repro.configs import registry


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b",
        family="dense",
        num_layers=24,
        d_model=3840,
        num_heads=32,
        num_kv_heads=8,
        head_dim=120,
        d_ff=10240,
        vocab_size=32000,
        attn_type="sliding",
        window_size=4096,
        mlp_act="silu",
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return registry.shrink(config(), head_dim=32)
