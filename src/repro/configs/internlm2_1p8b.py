"""internlm2-1.8b [dense]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544; llama-style GQA. [arXiv:2403.17297; hf]
"""
from repro.config import ModelConfig
from repro.configs import registry


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b",
        family="dense",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92544,
        attn_type="full",
        mlp_act="silu",
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return registry.shrink(config())
