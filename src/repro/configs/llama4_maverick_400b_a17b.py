"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 experts top-1 + shared expert, alternating dense/MoE
layers, interleaved chunked-local attention (iRoPE: every 4th layer global).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.config import ModelConfig
from repro.configs import registry


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        num_experts=128,
        top_k=1,
        moe_d_ff=8192,
        moe_layer_period=2,      # alternate dense / MoE
        first_moe_layer=1,
        shared_expert=True,
        attn_type="chunked",
        chunk_size=8192,
        local_global_period=4,   # every 4th layer full attention (NoPE)
        use_qk_norm=True,
        mlp_act="silu",
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return registry.shrink(config())
