"""mixtral-8x22b [moe] — the paper's second target: 56L d_model=6144 48H
(GQA kv=8) d_ff=16384 vocab=32768, MoE 8 experts top-2. [arXiv:2401.04088]
"""
from repro.config import ModelConfig
from repro.configs import registry


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        num_layers=56,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=32768,
        num_experts=8,
        top_k=2,
        moe_d_ff=16384,
        attn_type="full",
        mlp_act="silu",
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return registry.shrink(config())
