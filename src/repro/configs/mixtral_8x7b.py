"""mixtral-8x7b [moe] — the paper's primary target: 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000, MoE 8 experts top-2. [arXiv:2401.04088]
"""
from repro.config import ModelConfig
from repro.configs import registry


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        num_experts=8,
        top_k=2,
        moe_d_ff=14336,
        attn_type="full",
        mlp_act="silu",
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return registry.shrink(config())
