"""paligemma-3b [vlm]: 18L gemma decoder d_model=2048 8H (GQA kv=1, MQA)
d_ff=16384 vocab=257216; SigLIP vision tower is a STUB per assignment:
input_specs() supplies precomputed patch embeddings (256 x d_model).
[arXiv:2407.07726; hf]
"""
from repro.config import ModelConfig
from repro.configs import registry


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b",
        family="vlm",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=257216,
        num_prefix_tokens=256,
        attn_type="full",
        embedding_scale=True,
        mlp_act="gelu_tanh",
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return registry.shrink(config())
