"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Each ``repro/configs/<id>.py`` exposes ``config()`` (full, exact public
config) and ``smoke_config()`` (reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig

# arch id -> module name
_ARCH_MODULES: Dict[str, str] = {
    "arctic-480b": "arctic_480b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "whisper-medium": "whisper_medium",
    "zamba2-1.2b": "zamba2_1p2b",
    "command-r-plus-104b": "command_r_plus_104b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "gemma2-27b": "gemma2_27b",
    "internlm2-1.8b": "internlm2_1p8b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "paligemma-3b": "paligemma_3b",
    # the paper's own targets
    "mixtral-8x7b": "mixtral_8x7b",
    "mixtral-8x22b": "mixtral_8x22b",
}

ASSIGNED_ARCHS: List[str] = [
    "arctic-480b",
    "llama4-maverick-400b-a17b",
    "whisper-medium",
    "zamba2-1.2b",
    "command-r-plus-104b",
    "h2o-danube-3-4b",
    "gemma2-27b",
    "internlm2-1.8b",
    "falcon-mamba-7b",
    "paligemma-3b",
]

ALL_ARCHS: List[str] = list(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = _module(arch)
    return mod.smoke_config() if smoke else mod.config()


def shrink(cfg: ModelConfig, **extra) -> ModelConfig:
    """Generic family-preserving reduction for smoke tests."""
    kw = dict(
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        scan_layers=False,
        remat_policy="none",
    )
    if cfg.is_moe:
        kw.update(num_experts=min(cfg.num_experts, 8), moe_d_ff=256,
                  capacity_factor=2.0)
        if cfg.dense_residual:
            kw.update(dense_residual_ff=256)
    if cfg.ssm_type:
        kw.update(ssm_state=min(cfg.ssm_state, 16), ssm_chunk=32,
                  ssm_head_dim=32, ssm_dt_rank=8)
    if cfg.family == "encdec":
        kw.update(encoder_layers=2, encoder_seq=16)
    if cfg.family == "vlm":
        kw.update(num_prefix_tokens=8)
    if cfg.shared_attn_period:
        kw.update(shared_attn_period=2)
    if cfg.window_size:
        kw.update(window_size=64)
    if cfg.chunk_size:
        kw.update(chunk_size=64)
    kw.update(extra)
    return cfg.replace(**kw)
