"""whisper-medium [audio]: enc-dec transformer backbone, 24L decoder (+24L
encoder) d_model=1024 16H (kv=16) d_ff=4096 vocab=51865. The conv audio
frontend is a STUB per assignment: input_specs() supplies precomputed frame
embeddings (1500 x d_model). [arXiv:2212.04356; unverified]
"""
from repro.config import ModelConfig
from repro.configs import registry


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="encdec",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=51865,
        encoder_layers=24,
        encoder_seq=1500,
        attn_type="full",
        use_rope=False,          # learned absolute positions
        norm_type="layernorm",
        mlp_gated=False,
        mlp_act="gelu",
        attn_bias=True,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return registry.shrink(config())
