"""zamba2-1.2b [hybrid]: 38 Mamba2 layers d_model=2048 (GQA kv=32 in the
shared attention block, 32H) d_ff=8192 vocab=32000, ssm_state=64; a single
weight-shared attention+FFN block is interleaved periodically.
[arXiv:2411.15242; hf]
"""
from repro.config import ModelConfig
from repro.configs import registry


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=32000,
        ssm_type="mamba2",
        ssm_state=64,
        ssm_expand=2,
        ssm_conv=4,
        ssm_head_dim=64,
        shared_attn_period=6,    # shared block every 6 ssm layers
        attn_type="sliding",     # shared blocks use a window at long context
        window_size=4096,
        mlp_act="gelu",
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return registry.shrink(config())
