"""Optimal expert bit-width allocation (paper Eq. 4).

    MINIMIZE    sum_i sum_j  phi_i^alpha * w_i^beta * (eps_ij)^gamma * x_ij
    subject to  sum_ij j*x_ij = floor(n*k),   sum_j x_ij = 1  (one width each),
                sum_i x_i3 >= 1,  sum_i x_i2 >= 1,  x_ij in {0,1}.

The objective is linear in ``x`` (coefficients precomputed), and the
constraint structure is a small knapsack — we solve it **exactly** with
dynamic programming over (expert, bit-budget, has-a-3bit, has-a-2bit) states:
O(n * B * 4 * |bits|) with n <= a few hundred experts and B <= 3n. The paper
uses an off-the-shelf IP solver ("takes a second"); the DP is equivalent and
dependency-free, and `tests/test_allocation.py` cross-checks optimality
against scipy's MILP on random instances.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class AllocationResult:
    bits: np.ndarray          # (E,) chosen bit-width per expert
    objective: float          # optimal objective value
    target_bits: float        # requested mean width k
    achieved_bits: float      # sum(bits)/E after rounding
    cost_matrix: np.ndarray   # (E, |choices|) the c_ij used


def build_costs(frequency: np.ndarray, mean_weight: np.ndarray,
                eps: np.ndarray, *, alpha: float = 1.0, beta: float = 1.0,
                gamma: float = 2.0) -> np.ndarray:
    """c_ij = phi_i^alpha * w_i^beta * eps_ij^gamma (Eq. 4 coefficients)."""
    phi = np.maximum(np.asarray(frequency, np.float64), 1e-6)
    w = np.maximum(np.asarray(mean_weight, np.float64), 1e-8)
    sig = (phi ** alpha) * (w ** beta)
    return sig[:, None] * (np.asarray(eps, np.float64) ** gamma)


def solve_allocation(costs: np.ndarray, target_bits: float,
                     bit_choices: Sequence[int] = (1, 2, 3),
                     require_presence: bool = True) -> AllocationResult:
    """Exact DP solve of Eq. 4.

    Args:
      costs: (E, len(bit_choices)) — c_ij, lower is better.
      target_bits: mean bit-width k; the budget is floor(E * k).
      bit_choices: ascending candidate widths.
      require_presence: enforce >=1 expert at the top width and >=1 at the
        second width (paper's accuracy-preservation constraints). Skipped
        when E < 2.

    Returns AllocationResult; raises ValueError if infeasible.
    """
    costs = np.asarray(costs, np.float64)
    n, m = costs.shape
    bits = list(bit_choices)
    assert m == len(bits)
    budget = int(np.floor(n * target_bits))
    budget = max(budget, n * min(bits))
    budget = min(budget, n * max(bits))
    require_presence = require_presence and n >= 2 and m >= 3
    return _solve_exact(costs, budget, bits, require_presence, target_bits)


def _solve_exact(costs: np.ndarray, budget: int, bits: Sequence[int],
                 require_presence: bool, target_bits: float
                 ) -> AllocationResult:
    """Reference-clarity exact DP with parent pointers."""
    n, m = costs.shape
    nf = 4 if require_presence else 1
    inf = float("inf")
    dp = [[[inf] * nf for _ in range(budget + 1)] for _ in range(n + 1)]
    parent = {}
    dp[0][0][0] = 0.0
    for i in range(n):
        for b in range(budget + 1):
            for f in range(nf):
                cur = dp[i][b][f]
                if cur == inf:
                    continue
                for j, bj in enumerate(bits):
                    nb = b + bj
                    if nb > budget:
                        continue
                    if require_presence:
                        fadd = (1 if j == m - 1 else 0) | (
                            2 if j == m - 2 else 0)
                    else:
                        fadd = 0
                    nfed = f | fadd
                    cand = cur + costs[i, j]
                    if cand < dp[i + 1][nb][nfed]:
                        dp[i + 1][nb][nfed] = cand
                        parent[(i + 1, nb, nfed)] = (b, f, j)

    # Prefer full presence (flag 3); if the budget is too tight for
    # "one 3-bit + one 2-bit + rest at min" (budget < n*lo + 3), degrade
    # gracefully through weaker flag states rather than failing — small-n /
    # ultra-low-k corners the paper never hits but a framework must survive.
    flag_preference = [3, 1, 2, 0] if require_presence else [0]
    best = None
    for want_f in flag_preference:
        for b in range(budget, n * min(bits) - 1, -1):
            if dp[n][b][want_f] < inf:
                best = (b, dp[n][b][want_f], want_f)
                break
        if best is not None:
            break
    if best is None:
        raise ValueError(
            f"infeasible allocation: no assignment of {n} experts over bit "
            f"choices {tuple(bits)} fits budget {budget} total bits "
            f"(target {target_bits} bits/expert"
            f"{', with presence constraints' if require_presence else ''})")
    b, obj, f = best
    alloc = np.zeros(n, np.int64)
    for i in range(n, 0, -1):
        pb, pf, j = parent[(i, b, f)]
        alloc[i - 1] = bits[j]
        b, f = pb, pf
    return AllocationResult(bits=alloc, objective=float(obj),
                            target_bits=target_bits,
                            achieved_bits=float(alloc.sum()) / n,
                            cost_matrix=costs)


def allocate_layer(frequency: np.ndarray, mean_weight: np.ndarray,
                   eps: np.ndarray, *, target_bits: float,
                   bit_choices: Sequence[int] = (1, 2, 3), alpha: float = 1.0,
                   beta: float = 1.0, gamma: float = 2.0) -> AllocationResult:
    """Convenience: stats + eps -> optimal per-expert widths for one layer."""
    costs = build_costs(frequency, mean_weight, eps, alpha=alpha, beta=beta,
                        gamma=gamma)
    return solve_allocation(costs, target_bits, bit_choices)


# ------------------------------------------------------------------ baselines
def allocate_uniform(n: int, bits: int) -> np.ndarray:
    return np.full(n, bits, np.int64)


def allocate_random(n: int, target_bits: float, rng: np.random.RandomState,
                    bit_choices: Sequence[int] = (1, 2, 3)) -> np.ndarray:
    """Random allocation at the same budget (paper Fig. 5 baseline)."""
    budget = int(np.floor(n * target_bits))
    alloc = np.full(n, min(bit_choices), np.int64)
    budget -= alloc.sum()
    order = rng.permutation(n)
    hi = max(bit_choices)
    for i in order:
        room = hi - alloc[i]
        add = min(room, budget, rng.randint(0, hi - min(bit_choices) + 1))
        alloc[i] += add
        budget -= add
        if budget <= 0:
            break
    return alloc


def allocate_greedy_metric(metric: np.ndarray, target_bits: float,
                           bit_choices: Sequence[int] = (1, 2, 3)
                           ) -> np.ndarray:
    """Single-metric greedy (freq-only / weight-only / Hessian / F-norm
    baselines of Figs. 5-6): rank experts by `metric` descending and pour
    bits top-down within the budget."""
    n = len(metric)
    lo, hi = min(bit_choices), max(bit_choices)
    budget = int(np.floor(n * target_bits)) - n * lo
    alloc = np.full(n, lo, np.int64)
    order = np.argsort(-np.asarray(metric, np.float64))
    for level in range(hi - lo):
        for i in order:
            if budget <= 0:
                return alloc
            if alloc[i] == lo + level:
                alloc[i] += 1
                budget -= 1
    return alloc
