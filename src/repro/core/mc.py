"""MC — the Mixture Compressor facade (PMQ + ODP, paper Sec. 3).

``compress(model, params, calib_tokens)`` runs the single calibration pass,
compresses every MoE layer (PMQ), calibrates the ODP threshold/prune-rate,
and returns compressed params + the static `MCRuntime` handed to the model
at inference.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CompressionConfig, ModelConfig
from repro.core import odp as odp_lib
from repro.core import pmq as pmq_lib
from repro.core.significance import ExpertStats
from repro.models.layers.moe import MoEQuantMeta, OdpRuntime
from repro.models.transformer import DecoderModel, MCRuntime


@dataclass
class MCReport:
    pmq: pmq_lib.PMQResult
    odp_threshold: float
    odp_prune_rate: float
    capacity_scale: float
    avg_bits: float


def calibrate_forward(model: DecoderModel, params: Dict,
                      calib_tokens: jax.Array, **fw_kwargs):
    """One instrumented forward pass: per-MoE-layer FFN inputs + routing."""
    _, _, aux = model.forward(params, calib_tokens, scan=False,
                              collect_aux=True, capture=True, **fw_kwargs)
    captured = []
    for layer_aux in aux["per_layer"]:
        if "topk_idx" in layer_aux:
            captured.append({
                "x": layer_aux["ffn_input"],
                "topk_idx": layer_aux["topk_idx"],
                "topk_weights": layer_aux["topk_weights"],
            })
    return captured


def compress(model: DecoderModel, params: Dict, ccfg: CompressionConfig,
             calib_tokens: jax.Array, *, layout: str = "per_layer",
             **fw_kwargs) -> Tuple[Dict, MCRuntime, MCReport]:
    """Full MC pipeline on a DecoderModel with MoE layers."""
    cfg = model.cfg
    assert cfg.is_moe, "MC's PMQ applies to MoE experts (DESIGN.md §4)"
    captured = calibrate_forward(model, params, calib_tokens, **fw_kwargs)
    moe_ids = cfg.moe_layer_ids()
    assert len(captured) == len(moe_ids), (len(captured), len(moe_ids))

    # locate MoE blocks in the stacked param tree
    period = model.period
    moe_slots = [s for s in range(period) if model.slot_kinds[s] == "moe"]

    def flat(v):
        return v.reshape(-1, v.shape[-1])

    # pass 1 (uniform layout): per-layer optima -> median counts
    forced = None
    if layout == "uniform":
        per_layer_bits = []
        for li, cap in enumerate(captured):
            stats = ExpertStats(num_experts=cfg.num_experts)
            stats.update(cap["topk_idx"], cap["topk_weights"])
            moe_p = _get_moe_params(params, model, moe_slots, li)
            eps = pmq_lib.compute_eps(
                cfg, moe_p, flat(cap["x"]), flat(cap["topk_idx"]),
                flat(cap["topk_weights"]), tuple(ccfg.bit_choices),
                ccfg.group_size)
            from repro.core import allocation as alloc_lib
            costs = alloc_lib.build_costs(stats.frequency, stats.mean_weight,
                                          eps, alpha=ccfg.alpha,
                                          beta=ccfg.beta, gamma=ccfg.gamma)
            per_layer_bits.append(alloc_lib.solve_allocation(
                costs, ccfg.target_bits, tuple(ccfg.bit_choices)).bits)
        forced = pmq_lib.uniform_counts(per_layer_bits, tuple(ccfg.bit_choices))

    metas: List[Optional[MoEQuantMeta]] = []
    reports = []
    ratio_samples = []
    q_layers = []
    for li, cap in enumerate(captured):
        moe_p = _get_moe_params(params, model, moe_slots, li)
        q_params, meta, rep = pmq_lib.compress_moe_layer(
            cfg, ccfg, moe_p, flat(cap["x"]), flat(cap["topk_idx"]),
            flat(cap["topk_weights"]), layer_idx=moe_ids[li],
            forced_counts=forced)
        q_layers.append(q_params)
        metas.append(meta)
        reports.append(rep)
        tw = np.asarray(cap["topk_weights"]).reshape(-1,
                                                     cfg.top_k)
        if cfg.top_k >= 2:
            ratio_samples.append(tw[:, 1] / np.maximum(tw[:, 0], 1e-9))

    meta0 = metas[0]
    scan_safe = all(m == meta0 for m in metas)
    new_params = dict(params)
    if scan_safe:
        # identical metas (uniform layout / lucky per-layer): stack the
        # quantized layers back into the scanned stacks
        for slot in moe_slots:
            key = f"layers{slot}"
            per_step = [q_layers[i] for i in range(len(q_layers))
                        if moe_slots[i % len(moe_slots)] == slot]
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_step)
            layer = dict(new_params[key])
            layer["ffn"] = {**{k: v for k, v in layer["ffn"].items()
                               if k not in ("w_in", "w_gate", "w_out",
                                            "router")},
                            **stacked}
            new_params[key] = layer
    else:
        # heterogeneous metas: per-layer MoE params; serve with scan=False
        new_params["moe_layers"] = q_layers

    avg_bits = float(np.mean([r.achieved_bits for r in reports]))
    comp_bytes = sum(pmq_lib.packed_expert_bytes(cfg, m) for m in metas)
    orig_bytes = pmq_lib.dense_expert_bytes(cfg) * len(metas)
    pmq_res = pmq_lib.PMQResult(
        params=new_params, metas=metas, reports=reports, avg_bits=avg_bits,
        compressed_bytes=comp_bytes, original_bytes=orig_bytes)

    # ODP calibration
    odp_rt = None
    mu, rate, cap_scale = 0.0, 0.0, 1.0
    if ccfg.odp_enabled and cfg.top_k >= 2 and ratio_samples:
        ratios = np.concatenate(ratio_samples)
        mu = (float(np.median(ratios)) if ccfg.prune_threshold < 0
              else ccfg.prune_threshold)
        rate = float(np.mean(ratios < mu)) / cfg.top_k
        cap_scale = odp_lib.capacity_scale_from_prune_rate(
            rate, cfg.top_k, ccfg.protect_ratio)
        odp_rt = OdpRuntime(threshold=mu, protect_ratio=ccfg.protect_ratio,
                            capacity_scale=cap_scale)

    # quantized serving requires one static meta per scanned stack; uniform
    # layout guarantees it — otherwise serve via `quantized_forward`
    runtime = MCRuntime(odp=odp_rt,
                        quant_meta=meta0 if scan_safe else None)
    report = MCReport(pmq=pmq_res, odp_threshold=mu, odp_prune_rate=rate,
                      capacity_scale=cap_scale, avg_bits=avg_bits)
    return new_params, runtime, report


def _get_moe_params(params, model, moe_slots, li):
    period = model.period
    n_moe_per_step = len(moe_slots)
    step = li // n_moe_per_step
    slot = moe_slots[li % n_moe_per_step]
    stack = params[f"layers{slot}"]["ffn"]
    return jax.tree.map(lambda a: a[step], stack)


def quantized_forward(model: DecoderModel, params: Dict,
                      metas: List[MoEQuantMeta], tokens: jax.Array, *,
                      odp: Optional[OdpRuntime] = None, **fw_kwargs):
    """Loop-mode forward for heterogeneous per-layer metas
    (``layout='per_layer'``): MoE params come from ``params['moe_layers']``
    and each layer gets its own static MoEQuantMeta."""
    if "moe_layers" not in params:
        # metas turned out identical -> compress() stacked them; plain path
        return model.forward(params, tokens, scan=False,
                             mc=MCRuntime(odp=odp, quant_meta=metas[0]),
                             **fw_kwargs)
    return model.forward(params, tokens, scan=False,
                         mc=MCRuntime(odp=odp, quant_meta=None),
                         moe_layer_params=params.get("moe_layers"),
                         moe_layer_metas=metas, **fw_kwargs)
