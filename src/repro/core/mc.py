"""MC — the Mixture Compressor facade (PMQ + ODP, paper Sec. 3).

.. deprecated::
    The monolithic ``compress()`` is a thin shim over the staged API in
    :mod:`repro.core.pipeline` — ``calibrate -> plan -> apply`` — which
    separates the one-time calibration pass from cheap re-planning and the
    heavy GPTQ stage, and yields a serializable
    :class:`~repro.core.pipeline.CompressedArtifact` that serving loads
    directly (no calibration data at deploy time). New code should call the
    stages; ``compress()`` remains for existing callers and composes them.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax

from repro.config import CompressionConfig
from repro.core import pipeline as pipeline_lib
# Re-exported for backwards compatibility — these now live in pipeline.py.
from repro.core.pipeline import (  # noqa: F401
    CalibrationRecord, CompressedArtifact, CompressionPlan, MCReport,
    _get_moe_params, capture_forward as calibrate_forward)
from repro.models.layers.moe import MoEQuantMeta, OdpRuntime
from repro.models.transformer import DecoderModel, MCRuntime


def compress(model: DecoderModel, params: Dict, ccfg: CompressionConfig,
             calib_tokens: jax.Array, *, layout: str = "per_layer",
             **fw_kwargs) -> Tuple[Dict, MCRuntime, MCReport]:
    """Full MC pipeline in one call (deprecated shim).

    Equivalent to::

        record = pipeline.calibrate(model, params, calib_tokens,
                                    bit_choices=ccfg.bit_choices,
                                    group_size=ccfg.group_size)
        plan = pipeline.plan(record, ccfg, layout=layout)
        artifact = pipeline.apply(model, params, plan, record)

    but discards the record (so every call re-calibrates) and the artifact
    wrapper (so nothing can be saved). Prefer the staged API.
    """
    record = pipeline_lib.calibrate(
        model, params, calib_tokens, bit_choices=tuple(ccfg.bit_choices),
        group_size=ccfg.group_size, **fw_kwargs)
    plan = pipeline_lib.plan(record, ccfg, layout=layout)
    artifact = pipeline_lib.apply(model, params, plan, record)
    return artifact.params, artifact.runtime, artifact.report


def quantized_forward(model: DecoderModel, params: Dict,
                      metas: List[MoEQuantMeta], tokens: jax.Array, *,
                      odp: Optional[OdpRuntime] = None, **fw_kwargs):
    """Deprecated: heterogeneous per-layer metas now ride on
    ``MCRuntime.layer_metas`` and ``model.forward`` consumes both layouts
    uniformly — call ``model.forward(params, tokens, mc=artifact.runtime)``.
    """
    if "moe_layers" not in params:
        # metas turned out identical -> apply() stacked them; plain path
        return model.forward(params, tokens, scan=False,
                             mc=MCRuntime(odp=odp, quant_meta=metas[0]),
                             **fw_kwargs)
    return model.forward(params, tokens,
                         mc=MCRuntime(odp=odp, layer_metas=tuple(metas)),
                         **fw_kwargs)
