"""MC — the Mixture Compressor facade (PMQ + ODP, paper Sec. 3).

The monolithic ``compress()`` / ``quantized_forward()`` shims are **gone**
(they were deprecated for a full release): use the staged API in
:mod:`repro.core.pipeline` — ``calibrate -> plan -> apply`` — which
separates the one-time calibration pass from cheap re-planning and the
heavy GPTQ stage, and yields a serializable
:class:`~repro.core.pipeline.CompressedArtifact` that serving loads
directly (no calibration data at deploy time)::

    record = pipeline.calibrate(model, params, calib_tokens,
                                bit_choices=ccfg.bit_choices,
                                group_size=ccfg.group_size)
    plan = pipeline.plan(record, ccfg)
    artifact = pipeline.apply(model, params, plan, record)
    logits, _, _ = model.forward(params, tokens, mc=artifact.runtime)

The names below remain importable from here for existing callers; the same
surface is also re-exported at the package root (``repro.calibrate`` etc.).
"""
from __future__ import annotations

# Re-exported for backwards compatibility — these live in pipeline.py.
from repro.core.pipeline import (  # noqa: F401
    CalibrationRecord, CompressedArtifact, CompressionPlan, MCReport,
    _get_moe_params, apply, calibrate, plan,
    capture_forward as calibrate_forward)
