"""Online Dynamic Pruning (paper Sec. 3.3) — routing-level expert pruning
with significance-aware token protection.

Pure array-level logic, consumed by the MoE layer (training-free; applied at
inference).  The two rules:

1. **Weight-guided pruning** (Eq. 5): a token routed to top-2 experts with
   scores (w0, w1) drops the secondary expert when ``w1 / w0 < mu``; ``mu``
   is the calibration-set median of the ratio.
2. **Token protection** (Eq. 6): the top ``protect_ratio`` tokens by
   ``I_j = ||t_j||_1 * mean attention received`` keep all their experts —
   this is what prevents the "attention decay" failure (Fig. 4).

TPU adaptation (DESIGN.md §3): pruning is expressed as zeroing the routing
weight of pruned slots, and the calibrated prune rate feeds a *static*
capacity reduction in the dispatcher, so the saving appears as smaller
all-to-all buffers and grouped-GEMM shapes rather than dynamic control flow.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class OdpConfig:
    threshold: float = 0.5        # mu; calibrated median of w1/w0
    protect_ratio: float = 0.02   # fraction of tokens protected
    enabled: bool = True


def prune_mask(topk_weights: jax.Array, threshold,
               protected: Optional[jax.Array] = None) -> jax.Array:
    """Which (token, slot) routing assignments survive ODP.

    Args:
      topk_weights: (..., k) routing weights, slot 0 = primary (descending).
      threshold: mu of Eq. 5 — a Python float (static), or a traced array
        broadcastable against the token axes (e.g. per-token ``(...,)`` or
        per-row) for the serving engines' per-request knob. A threshold of
        0.0 keeps every slot (``ratio >= 0`` always), which is how
        ``odp='off'`` rides through the jitted decode without retracing.
      protected: (...,) bool — protected tokens keep every slot.

    Returns (..., k) bool keep-mask. Slot 0 is always kept; slots >= 1 are
    kept iff w_s / w_0 >= mu or the token is protected. (k=1 models pass
    through untouched; see DESIGN.md §4 for the llama4 deviation.)
    """
    k = topk_weights.shape[-1]
    if k == 1:
        return jnp.ones_like(topk_weights, dtype=bool)
    w0 = jnp.maximum(topk_weights[..., :1], 1e-9)
    ratio = topk_weights / w0
    if isinstance(threshold, jax.Array) and threshold.ndim == ratio.ndim - 1:
        threshold = threshold[..., None]
    keep = ratio >= threshold
    keep = keep.at[..., 0].set(True)
    if protected is not None:
        keep = keep | protected[..., None]
    return keep


def apply_pruning(topk_weights: jax.Array, keep: jax.Array,
                  renormalize: bool = True) -> jax.Array:
    """Zero pruned slots; optionally renormalize the survivors to sum 1.

    Tokens whose slots all survive pass through **bit-exactly** — the
    renormalizing division is bypassed for them, so an all-keep mask (the
    per-request ``odp='off'`` path) cannot introduce float drift against a
    run with ODP absent entirely.
    """
    w = jnp.where(keep, topk_weights, 0.0)
    if renormalize:
        denom = jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        w = jnp.where(keep.all(-1, keepdims=True), topk_weights, w / denom)
    return w


def protect_tokens(importance: jax.Array, protect_ratio: float,
                   valid: Optional[jax.Array] = None) -> jax.Array:
    """Top-``ceil(ratio * L)`` tokens by importance -> bool mask (per row).

    importance: (..., L); valid: optional (..., L) bool for padding.
    """
    l = importance.shape[-1]
    n_protect = max(1, int(np.ceil(protect_ratio * l))) if protect_ratio > 0 else 0
    if n_protect == 0:
        return jnp.zeros(importance.shape, bool)
    if valid is None:
        thresh = jax.lax.top_k(importance, n_protect)[0][..., -1:]
        return importance >= thresh
    # with padding/inactive tokens the quota is ceil(ratio * n_valid) —
    # computed over the *valid* tokens, so pad rows neither steal quota
    # nor inflate it (keeps masked pools equivalent to unpadded ones)
    imp = jnp.where(valid, importance, -jnp.inf)
    n_valid = valid.sum(-1, keepdims=True)
    k_eff = jnp.clip(jnp.ceil(protect_ratio * n_valid).astype(jnp.int32),
                     1, n_protect)
    sorted_vals = jax.lax.top_k(imp, n_protect)[0]
    thresh = jnp.take_along_axis(sorted_vals, k_eff - 1, axis=-1)
    return (imp >= thresh) & valid


def token_importance_from_running(tl1: jax.Array, attn_recv: jax.Array,
                                  counts: jax.Array) -> jax.Array:
    """Decode-time Eq. 6 with *running* column statistics.

    tl1: (..., L) l1 magnitudes of cached tokens; attn_recv: (..., L) sum of
    attention each cached token has received from decoded queries so far;
    counts: (..., L) number of queries that could have attended (denominator).
    """
    return tl1 * attn_recv / jnp.maximum(counts, 1.0)


def pruned_fraction(keep: jax.Array, topk: int,
                    valid: Optional[jax.Array] = None) -> jax.Array:
    """Fraction of expert activations removed (the paper's ~15% metric).

    valid: optional (...,) bool — restrict the accounting to live tokens
    (serving pools carry idle-slot / pad rows whose keep-masks are
    meaningless and would dilute the metric).
    """
    if valid is None:
        return 1.0 - keep.sum() / (np.prod(keep.shape[:-1]) * topk)
    v = valid.astype(keep.dtype)
    kept = (keep & valid[..., None]).sum()
    return 1.0 - kept / jnp.maximum(v.sum() * topk, 1)


def calibrate(ratio_samples: np.ndarray, protect_ratio: float = 0.02
              ) -> Tuple[OdpConfig, float]:
    """Median-threshold calibration; returns config + predicted prune rate."""
    mu = float(np.median(ratio_samples))
    rate = float(np.mean(ratio_samples < mu)) / 2.0  # half the slots are w1
    return OdpConfig(threshold=mu, protect_ratio=protect_ratio), rate


def plan_odp(ratio_samples: np.ndarray, top_k: int, *,
             protect_ratio: float = 0.02,
             prune_threshold: float = -1.0) -> Optional[dict]:
    """ODP portion of a CompressionPlan: threshold mu, predicted prune rate
    and the implied static capacity scale, from calibration w1/w0 samples.

    Returns None when ODP cannot apply (top-1 routing / no samples) —
    matching the paper's restriction of Eq. 5 to multi-expert routing.
    """
    ratios = np.asarray(ratio_samples)
    if top_k < 2 or ratios.size == 0:
        return None
    mu = (float(np.median(ratios)) if prune_threshold < 0
          else float(prune_threshold))
    rate = float(np.mean(ratios < mu)) / top_k
    return {
        "threshold": mu,
        "prune_rate": rate,
        "capacity_scale": capacity_scale_from_prune_rate(
            rate, top_k, protect_ratio),
        "protect_ratio": float(protect_ratio),
        "ratio_quantiles": ratio_quantiles(ratios),
    }


#: quantile grid resolution for the calibration ratio table (33 points at
#: levels 0, 1/32, ..., 1) — enough for per-request prune-ratio -> threshold
#: interpolation to land within a couple percent of the requested rate.
QUANTILE_POINTS = 33


def ratio_quantiles(ratio_samples: np.ndarray,
                    points: int = QUANTILE_POINTS) -> list:
    """Evenly-spaced quantiles of the calibration w_s/w_0 ratio samples.

    The table rides in the plan / artifact (``OdpRuntime.ratio_quantiles``)
    so serving can map a requested prune *ratio* to a threshold mu without
    the calibration set: pruning slot s of a token iff w_s/w_0 < mu removes
    a ``P(ratio < mu)`` fraction of secondary slots, so the quantile
    function **is** the ratio->threshold map.
    """
    levels = np.linspace(0.0, 1.0, points)
    return [float(v) for v in np.quantile(np.asarray(ratio_samples), levels)]


def threshold_for_prune_ratio(quantiles, prune_ratio: float,
                              top_k: int) -> float:
    """Invert the calibration ratio distribution: the threshold mu at which
    ODP prunes ``prune_ratio`` of all routed expert slots.

    ``prune_ratio`` counts pruned slots among **all** top-k slots (the
    paper's ~15% metric); only the k-1 secondary slots are prunable, so the
    quantile level is ``prune_ratio * k / (k - 1)``, clipped to [0, 1].
    """
    if not quantiles:
        raise ValueError(
            "no calibration ratio_quantiles available — the artifact "
            "predates the quantile table (re-plan with odp_enabled=True) "
            "so an explicit prune ratio cannot be mapped to a threshold; "
            "use odp='default' or odp='off'")
    if not 0.0 <= prune_ratio <= 1.0:
        raise ValueError(f"prune ratio must be in [0, 1], got {prune_ratio}")
    if top_k < 2:
        return 0.0
    q = np.asarray(quantiles, np.float64)
    levels = np.linspace(0.0, 1.0, q.size)
    level = min(prune_ratio * top_k / (top_k - 1), 1.0)
    return float(np.interp(level, levels, q))


def capacity_scale_from_prune_rate(prune_rate: float, top_k: int,
                                   protect_ratio: float) -> float:
    """Static capacity-factor multiplier implied by calibrated ODP.

    A prune removes one of top_k slots for non-protected tokens; protected
    tokens keep everything, so the expected kept fraction is
        1 - prune_rate * (1 - protect_ratio)
    where prune_rate counts pruned slots among all slots.
    """
    if top_k <= 1:
        return 1.0
    return float(1.0 - prune_rate * (1.0 - protect_ratio))
