"""Staged MC compression pipeline: calibrate -> plan -> apply -> artifact.

The paper's pipeline is naturally staged — one calibration pass yields expert
significance stats, an LP/DP bit allocation, then GPTQ + packing (Sec. 3.2).
This module exposes each stage as a first-class step so compression runs
*once offline* and deployment just loads a small artifact (the paper's
"pre-loading" premise):

1. :func:`calibrate` — one instrumented forward pass capturing per-MoE-layer
   FFN inputs, routing decisions, and the RTN eps_{i,j} probe table
   (Eq. 3). Returns a :class:`CalibrationRecord`; the expensive probes are
   cached per ``(bit_choices, group_size)`` so re-planning never re-runs
   them.
2. :func:`plan` — cheap, record-only: per-layer DP bit allocation (Eq. 4),
   class sorting, ODP threshold/capacity calibration, predicted sizes.
   Returns a small JSON-serializable :class:`CompressionPlan`; planning the
   same record at a different ``target_bits`` costs milliseconds.
3. :func:`apply` — the heavy stage: GPTQ each expert at its planned width,
   pack kernel-layout planes, assemble quantized params. Returns a
   :class:`CompressedArtifact` bundling params + metas + the static
   :class:`MCRuntime` + report.
4. :meth:`CompressedArtifact.save` / :meth:`CompressedArtifact.load` —
   persist through ``checkpoint.checkpointer`` so serving boots straight
   from the artifact with no calibration data present. Saving uses the
   expert-major shard layout (one fingerprinted shard group per (layer,
   expert) — ``docs/artifact_format.md``), so
   :meth:`CompressedArtifact.load_sharded` can stream each deployment
   host only the dense groups plus the expert block it owns and place
   packed planes expert-parallel on a device mesh.

These stages (plus the serving engines) are re-exported at the package
root — ``repro.calibrate`` / ``repro.plan`` / ``repro.apply`` /
``repro.CompressedArtifact``. The legacy one-shot ``repro.core.mc``
shims are gone; that module is now re-exports only.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CompressionConfig
from repro.core import allocation as alloc_lib
from repro.core import odp as odp_lib
from repro.core import pmq as pmq_lib
from repro.core.significance import ExpertStats
from repro.checkpoint import checkpointer as ckpt_lib
from repro.models.layers.moe import MoEQuantMeta, OdpRuntime
from repro.models.transformer import DecoderModel, MCRuntime
from repro.sharding import partitioning as part_lib
from repro.sharding.partitioning import meshes_equal  # re-export

#: Artifact metadata version. v1 artifacts (size-chunked shards, no
#: expert-major groups) are still loadable; v2 adds the expert-major shard
#: layout (one shard group per (layer, expert) + a dense group) that
#: :meth:`CompressedArtifact.load_sharded` streams per host.
ARTIFACT_VERSION = 2


# -------------------------------------------------- expert-major shard layout
# Key paths of packed expert planes inside an artifact param tree:
#   scan-safe   ['layers<slot>']['ffn']['experts_q']['cls<ci>'][...]
#               (leading layer-stack dim, expert axis = 1)
#   per-layer   ['moe_layers'][<li>]['experts_q']['cls<ci>'][...]
#               (expert axis = 0)
_SCAN_Q = re.compile(
    r"^\['layers(\d+)'\]\['ffn'\]\['experts_q'\]\['cls(\d+)'\]\[")
_HET_Q = re.compile(
    r"^\['moe_layers'\]\[(\d+)\]\['experts_q'\]\['cls(\d+)'\]\[")
_GROUP_EXPERT = re.compile(r"\.expert(\d+)$")


def expert_of_group(group: str) -> Optional[int]:
    """Global (class-sorted) expert index encoded in a shard-group name,
    or None for non-expert groups (the dense ``part*`` groups)."""
    m = _GROUP_EXPERT.search(group)
    return int(m.group(1)) if m else None


def byte_balanced_ranges(weights, num_hosts: int) -> List[Tuple[int, int]]:
    """Partition experts ``[0, len(weights))`` into ``num_hosts`` contiguous
    non-empty blocks minimizing the max per-block byte sum (exact DP).
    Byte- rather than count-balanced because mixed-precision classes make
    experts byte-heterogeneous (a 3-bit expert is ~3x a 1-bit one).

    Contiguity is load-bearing: the checkpointer reassembles split leaves
    only from contiguous slice ranges, and the class-sorted expert layout
    keeps each bit-class contiguous on a minimal number of hosts."""
    w = [int(v) for v in weights]
    e = len(w)
    if not 1 <= num_hosts <= e:
        raise ValueError(f"cannot split {e} experts over {num_hosts} hosts")
    prefix = np.concatenate([[0], np.cumsum(w)])

    # best[h][i] = minimal max-block-sum splitting w[:i] into h blocks
    best = np.full((num_hosts + 1, e + 1), np.inf)
    cut = np.zeros((num_hosts + 1, e + 1), np.int64)
    best[0][0] = 0.0
    for h in range(1, num_hosts + 1):
        for i in range(h, e - (num_hosts - h) + 1):
            for j in range(h - 1, i):
                cand = max(best[h - 1][j], prefix[i] - prefix[j])
                if cand < best[h][i]:
                    best[h][i], cut[h][i] = cand, j
    bounds = [e]
    for h in range(num_hosts, 0, -1):
        bounds.append(int(cut[h][bounds[-1]]))
    bounds = bounds[::-1]
    return [(bounds[i], bounds[i + 1]) for i in range(num_hosts)]


def _expert_bytes_from_manifest(manifest: Dict,
                                num_experts: int) -> Optional[List[int]]:
    groups = manifest.get("groups")
    if not groups:
        return None
    out = [0] * num_experts
    for name, info in groups.items():
        e = expert_of_group(name)
        if e is not None and e < num_experts:
            out[e] += int(info["bytes"])
    return out if any(out) else None


def _expert_split_fn(plan: "CompressionPlan"):
    """Build the checkpointer ``split_fn`` realizing the expert-major
    layout: each packed expert plane is cut along its expert axis, slice
    ``j`` of class ``ci`` going to group ``slot<k>.expert<g>`` (scan-safe;
    layers ride stacked inside the slice) or ``layer<li>.expert<g>``
    (per-layer), where ``g = class_start + j`` is the global class-sorted
    expert index. Everything else (router, attention, norms, embeddings)
    stays in the default dense ``part*`` groups."""
    metas = plan.metas()

    def names(meta: MoEQuantMeta, ci: int, tag: str) -> List[str]:
        _, e0, cnt = meta.class_slices()[ci]
        return [f"{tag}.expert{e0 + j:04d}" for j in range(cnt)]

    def split(path: str, arr) -> Optional[Tuple[int, List[str]]]:
        m = _SCAN_Q.match(path)
        if m:
            slot, ci = int(m.group(1)), int(m.group(2))
            return 1, names(metas[0], ci, f"slot{slot}")
        m = _HET_Q.match(path)
        if m:
            li, ci = int(m.group(1)), int(m.group(2))
            return 0, names(metas[li], ci, f"layer{li:02d}")
        return None

    return split


def _expert_axes(params: Dict) -> Dict[str, int]:
    """Key path -> expert axis, for every packed expert plane in ``params``
    (the placement dual of :func:`_expert_split_fn`)."""
    out = {}
    for kp, _ in jax.tree_util.tree_flatten_with_path(params)[0]:
        path = jax.tree_util.keystr(kp)
        if _SCAN_Q.match(path):
            out[path] = 1
        elif _HET_Q.match(path):
            out[path] = 0
    return out


def place_params(params: Dict, mesh, axis: str = "expert") -> Dict:
    """Device-put an artifact param tree onto ``mesh``: packed expert
    planes are sharded along their expert axis over the mesh axis carrying
    expert parallelism (``axis``; ``"expert"`` resolves to ``"data"`` on
    the standard (data, model) mesh), everything else replicated. Class
    slices whose expert count does not divide the axis are demoted to
    replicated (`sharding.partitioning` divisibility rule)."""
    from repro.sharding import partitioning as part_lib
    axis = _resolve_ep_axis(mesh, axis)
    shardings = part_lib.expert_placement_shardings(
        mesh, params, _expert_axes(params), axis=axis)
    return jax.device_put(params, shardings)


def _resolve_ep_axis(mesh, axis: str) -> str:
    if axis in mesh.shape:
        return axis
    if axis == "expert" and "data" in mesh.shape:
        # standard meshes name no literal 'expert' axis: EP rides the
        # 'data' axis (DESIGN.md §5), so accept the logical name
        return "data"
    raise ValueError(f"mesh {tuple(mesh.shape)} has no axis {axis!r} "
                     "to carry expert parallelism")


# --------------------------------------------- multi-process distribution
def expert_shard_expectation(mesh, segments, axis: str = "expert",
                             process_index: Optional[int] = None
                             ) -> Tuple[Tuple[int, int], ...]:
    """Which global experts one process must hold to serve on ``mesh``.

    Under the standard expert-parallel placement every class segment of
    ``segments`` (``(start, count)`` per bit class; a dense stack is the
    single segment ``(0, E)``) is split evenly along the mesh axis
    carrying expert parallelism. A process's expectation is the union of
    the blocks owned by its *addressable* devices — exactly the slice
    its per-host artifact stream must contain, no more (overlap) and no
    less (gap). ``process_index`` defaults to ``jax.process_index()``.

    Returns sorted disjoint merged ``((k0, k1), ...)`` global ranges.
    Raises when a class count does not divide the EP axis (the placement
    would demote to replicated, which a partial stream cannot satisfy)
    or when the process owns no devices of the mesh.
    """
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    from repro.sharding import moe_parallel as mp
    eax = _resolve_ep_axis(mesh, axis)
    dp = dict(mesh.shape)[eax]
    pidx = jax.process_index() if process_index is None else process_index
    probe = NamedSharding(mesh, P(eax))
    imap = probe.devices_indices_map((dp,))
    shards = sorted({idx[0].indices(dp)[0] for d, idx in imap.items()
                     if d.process_index == pidx})
    if not shards:
        raise ValueError(
            f"process {pidx} owns no devices of the mesh "
            f"(processes {part_lib.mesh_process_indices(mesh)})")
    ranges = []
    for r in shards:
        ranges.extend(mp.ep_owned_ranges(tuple(segments), dp, r))
    return mp.merge_ranges(ranges)


def distributed_params(params: Dict, mesh, stats: ckpt_lib.LoadStats,
                       axis: str = "expert") -> Dict:
    """Map one process's (possibly partial) param tree onto its
    addressable shard of the globally-placed tree.

    The dual of :func:`place_params` for multi-process meshes: split
    expert planes (recorded in ``stats.split_axes`` by the subset load)
    become global arrays sharded along their expert axis over the EP
    mesh axis, each addressable device receiving its rows out of the
    process-local block recorded in ``stats.partial`` — the union of all
    processes' slices *is* the placed global tree and no process ever
    materializes foreign experts. Every other leaf is replicated onto
    the process's addressable devices. Built on
    ``jax.make_array_from_single_device_arrays``, so the same code path
    serves real ``jax.distributed`` processes and single-process meshes
    (where it coincides with :func:`place_params`).
    """
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    eax = _resolve_ep_axis(mesh, axis)
    dp = dict(mesh.shape)[eax]
    pidx = jax.process_index()
    local = [d for d in mesh.devices.flat if d.process_index == pidx]
    if not local:
        raise ValueError(f"process {pidx} owns no devices of the mesh")

    def build(shape, sharding, bufs):
        return jax.make_array_from_single_device_arrays(
            shape, sharding, bufs)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        arr = np.asarray(leaf)
        ax = stats.split_axes.get(path)
        if ax is None:                         # dense leaf -> replicated
            out.append(build(arr.shape, NamedSharding(mesh, P()),
                             [jax.device_put(arr, d) for d in local]))
            continue
        start, stop, count = stats.partial.get(
            path, (0, arr.shape[ax], arr.shape[ax]))
        gshape = arr.shape[:ax] + (count,) + arr.shape[ax + 1:]
        if count % dp:
            # the placement demotes this plane to replicated
            # (divisibility rule) — only a full load can satisfy that
            if (start, stop) != (0, count):
                raise ValueError(
                    f"cannot place partial plane {path}: its expert axis "
                    f"({count}) does not divide the EP mesh axis ({dp}), "
                    "so placement demotes it to replicated — which needs "
                    f"every expert, not rows [{start}:{stop})")
            out.append(build(gshape, NamedSharding(mesh, P()),
                             [jax.device_put(arr, d) for d in local]))
            continue
        spec = [None] * arr.ndim
        spec[ax] = eax
        sharding = NamedSharding(mesh, P(*spec))
        imap = sharding.devices_indices_map(gshape)
        bufs = []
        for d in local:
            g0, g1, _ = imap[d][ax].indices(count)
            if not (start <= g0 and g1 <= stop):
                raise ValueError(
                    f"plane {path}: device {d} expects global expert "
                    f"rows [{g0}:{g1}) but this process holds "
                    f"[{start}:{stop}) — the artifact slice does not "
                    "match the mesh's placement expectation")
            sl = (slice(None),) * ax + (slice(g0 - start, g1 - start),)
            bufs.append(jax.device_put(arr[sl], d))
        out.append(build(gshape, sharding, bufs))
    return jax.tree_util.tree_unflatten(treedef, out)


def expert_range_delta(old_ranges, new_ranges
                       ) -> Tuple[Tuple[int, int], ...]:
    """Expert ranges in ``new_ranges`` but not ``old_ranges`` — the
    **delta** a host must stream after a re-shard changes its ownership
    from one ``expert_ranges`` plan to another (already-resident experts
    are never re-read). Both inputs are ``(start, stop)`` iterables;
    returns sorted disjoint merged ranges (empty tuple = nothing to
    stream)."""
    from repro.sharding.moe_parallel import merge_ranges
    old = merge_ranges(old_ranges) if old_ranges else ()
    out = []
    for a, b in (merge_ranges(new_ranges) if new_ranges else ()):
        cur = a
        for oa, ob in old:
            if ob <= cur or oa >= b:
                continue
            if oa > cur:
                out.append((cur, min(oa, b)))
            cur = max(cur, ob)
            if cur >= b:
                break
        if cur < b:
            out.append((cur, b))
    return tuple(out)


def load_expert_blocks(directory, ranges, *, include_dense: bool = False,
                       verify: bool = True):
    """Stream selected expert blocks of an expert-major artifact.

    The low-level read behind fleet re-sharding (``serve.fleet``): each
    contiguous ``(k0, k1)`` of ``ranges`` is loaded as its own subset
    part via the range-filtered :func:`checkpoint.load_pytree_subset`
    read — only that block's shard groups are opened — and
    ``include_dense=True`` additionally loads the dense (non-expert)
    groups once as a leading part. The returned ``(tree, stats)`` parts
    compose with ``checkpointer.merge_subset_trees`` whenever the union
    of everyone's blocks tiles ``[0, E)``.

    Unlike :meth:`CompressedArtifact.load_sharded` this returns raw
    parts, not an artifact: a re-shard folds new blocks into holdings
    that already exist, and the delta bytes are exactly
    ``sum(p.bytes_read for _, p in parts)``.
    """
    directory = Path(directory)
    parts = []
    if include_dense:
        tree, _, stats = ckpt_lib.load_pytree_subset(
            directory, lambda p, g: expert_of_group(g) is None,
            verify=verify)
        parts.append((tree, stats))
    for k0, k1 in ranges:
        if k1 <= k0:
            raise ValueError(f"empty expert block ({k0}, {k1})")

        def keep(path, group, k0=k0, k1=k1):
            e = expert_of_group(group)
            return e is not None and k0 <= e < k1

        tree, _, stats = ckpt_lib.load_pytree_subset(directory, keep,
                                                     verify=verify)
        parts.append((tree, stats))
    return parts


def artifact_expert_bytes(directory) -> Tuple[int, List[int]]:
    """``(num_experts, per-expert on-disk bytes)`` of an expert-major
    artifact, from the manifest alone (no tensor data read). The byte
    weights feed the fleet's block planner
    (:func:`repro.runtime.elastic.initial_assignment`)."""
    directory = Path(directory)
    manifest, _ = ckpt_lib.read_manifest(directory)
    art = _artifact_meta(directory, manifest)
    num_experts = art.get("num_experts",
                          len(art["plan"]["layers"][0]["bits"]))
    ebytes = _expert_bytes_from_manifest(manifest, num_experts)
    if ebytes is None:
        raise ValueError(
            f"{directory} has no expert-major shard groups (artifact "
            "saved by a pre-v2 version); block planning needs them — "
            "load() it fully once and re-save() to upgrade")
    return num_experts, ebytes


def _owned_expert_ranges(num_experts: int, segments, ebytes, *,
                         mesh=None, axis: str = "expert",
                         expert_range=None, num_hosts=None, host=None,
                         process_index=None):
    """Resolve which global experts this caller owns, in priority order:
    explicit ``expert_range`` > byte-balanced ``(num_hosts, host)`` >
    the multi-process mesh placement expectation > all experts. Explicit
    and byte-balanced selections against a multi-process mesh must equal
    the expectation exactly — overlap/gap/misalignment fails loudly.
    Returns ``(ranges, multiprocess)``.
    """
    multiproc = part_lib.mesh_spans_processes(mesh)
    ranges = None
    if expert_range is not None:
        k0, k1 = expert_range
        if not 0 <= k0 < k1 <= num_experts:
            raise ValueError(f"expert_range {tuple(expert_range)} invalid "
                             f"for {num_experts} experts")
        ranges = ((int(k0), int(k1)),)
    elif num_hosts is not None:
        h = jax.process_index() if host is None else host
        if not 0 <= h < num_hosts:
            raise ValueError(f"host {h} out of range for {num_hosts} hosts")
        ranges = (byte_balanced_ranges(ebytes, num_hosts)[h],)
    if multiproc:
        from repro.sharding.moe_parallel import merge_ranges
        expected = expert_shard_expectation(mesh, segments, axis=axis,
                                            process_index=process_index)
        if ranges is not None and merge_ranges(ranges) != expected:
            pidx = (jax.process_index() if process_index is None
                    else process_index)
            raise ValueError(
                f"requested expert ranges {tuple(sorted(ranges))} do not "
                f"match the mesh placement expectation {expected} for "
                f"process {pidx} — omit expert_range/num_hosts to stream "
                "exactly the expected slice")
        ranges = expected
    elif ranges is None:
        ranges = ((0, num_experts),)
    return ranges, multiproc


@dataclass
class MCReport:
    """Summary of one full compression run (also rebuilt on artifact load)."""

    pmq: pmq_lib.PMQResult
    odp_threshold: float
    odp_prune_rate: float
    capacity_scale: float
    avg_bits: float


# ------------------------------------------------------------- calibration
def capture_forward(model: DecoderModel, params: Dict,
                    calib_tokens: jax.Array, **fw_kwargs) -> List[Dict]:
    """One instrumented forward pass: per-MoE-layer FFN inputs + routing."""
    _, _, aux = model.forward(params, calib_tokens, scan=False,
                              collect_aux=True, capture=True, **fw_kwargs)
    captured = []
    for layer_aux in aux["per_layer"]:
        if "topk_idx" in layer_aux:
            captured.append({
                "x": layer_aux["ffn_input"],
                "topk_idx": layer_aux["topk_idx"],
                "topk_weights": layer_aux["topk_weights"],
            })
    return captured


@dataclass
class LayerCalibration:
    """Flattened calibration capture + router stats for one MoE layer."""

    x: np.ndarray             # (T, D) FFN inputs
    topk_idx: np.ndarray      # (T, k) routed expert ids
    topk_weights: np.ndarray  # (T, k) routing weights
    frequency: np.ndarray     # (E,) phi_i
    mean_weight: np.ndarray   # (E,) w_i


@dataclass
class CalibrationRecord:
    """Everything :func:`plan` and :func:`apply` need, computed once.

    ``eps`` caches the RTN probe tables keyed by ``(bit_choices,
    group_size)`` — re-planning at a new ``target_bits`` with the same
    quantizer settings reuses them without touching the model weights.
    """

    model_fingerprint: str
    num_experts: int
    top_k: int
    d_model: int
    moe_d_ff: int
    moe_layer_ids: List[int]
    layers: List[LayerCalibration]
    ratio_samples: np.ndarray                  # concatenated w1/w0 samples
    eps: Dict[Tuple[Tuple[int, ...], int], List[np.ndarray]] = \
        field(default_factory=dict)
    eps_probe_runs: int = 0                    # how many probe sweeps ran

    def ensure_eps(self, model: DecoderModel, params: Dict,
                   bit_choices, group_size: int) -> List[np.ndarray]:
        """Compute (or fetch cached) eps_{i,j} tables for one quantizer
        setting. Only this method re-touches the model weights."""
        key = (tuple(int(b) for b in bit_choices), int(group_size))
        if key in self.eps:
            return self.eps[key]
        moe_slots = _moe_slots(model)
        tables = []
        for li, lc in enumerate(self.layers):
            moe_p = _get_moe_params(params, model, moe_slots, li)
            tables.append(pmq_lib.compute_eps(
                model.cfg, moe_p, jnp.asarray(lc.x), lc.topk_idx,
                lc.topk_weights, key[0], key[1]))
        self.eps[key] = tables
        self.eps_probe_runs += 1
        return tables


def calibrate(model: DecoderModel, params: Dict, calib_tokens: jax.Array, *,
              bit_choices=(1, 2, 3), group_size: int = 128,
              **fw_kwargs) -> CalibrationRecord:
    """Stage 1: one instrumented forward pass -> :class:`CalibrationRecord`.

    Captures per-MoE-layer FFN inputs, routing decisions and expert
    significance stats, then runs the eps_{i,j} RTN probes for
    ``(bit_choices, group_size)``. The record is the only stage output
    that holds calibration arrays; :func:`plan` re-runs for free against
    it, and probes for further quantizer settings can be added later via
    :meth:`CalibrationRecord.ensure_eps`.

    Args:
        model: a MoE :class:`DecoderModel` (asserts ``cfg.is_moe``).
        params: its dense (uncompressed) parameters.
        calib_tokens: (B, S) int32 calibration batch.
        bit_choices: candidate expert widths to probe.
        group_size: quantization group size the probes assume.
        **fw_kwargs: forwarded to ``model.forward`` (e.g. VLM prefixes).
    """
    cfg = model.cfg
    assert cfg.is_moe, "MC's PMQ applies to MoE experts (DESIGN.md §4)"
    captured = capture_forward(model, params, calib_tokens, **fw_kwargs)
    moe_ids = cfg.moe_layer_ids()
    assert len(captured) == len(moe_ids), (len(captured), len(moe_ids))

    layers = []
    ratio_samples = []
    for cap in captured:
        x = np.asarray(cap["x"], np.float32)
        x = x.reshape(-1, x.shape[-1])
        idx = np.asarray(cap["topk_idx"]).reshape(-1, cfg.top_k)
        w = np.asarray(cap["topk_weights"], np.float32).reshape(-1, cfg.top_k)
        stats = ExpertStats(num_experts=cfg.num_experts)
        stats.update(idx, w)
        layers.append(LayerCalibration(
            x=x, topk_idx=idx, topk_weights=w,
            frequency=stats.frequency, mean_weight=stats.mean_weight))
        if cfg.top_k >= 2:
            ratio_samples.append(w[:, 1] / np.maximum(w[:, 0], 1e-9))

    record = CalibrationRecord(
        model_fingerprint=cfg.fingerprint(),
        num_experts=cfg.num_experts, top_k=cfg.top_k,
        d_model=cfg.d_model, moe_d_ff=cfg.moe_d_ff,
        moe_layer_ids=list(moe_ids), layers=layers,
        ratio_samples=(np.concatenate(ratio_samples) if ratio_samples
                       else np.zeros(0, np.float32)))
    record.ensure_eps(model, params, bit_choices, group_size)
    return record


# ------------------------------------------------------------------- plan
@dataclass
class LayerPlan:
    """Planned allocation for one MoE layer (all original expert order)."""

    layer: int                       # model layer id
    bits: Tuple[int, ...]            # (E,) allocated widths
    permutation: Tuple[int, ...]     # class-sorted expert order
    bit_classes: Tuple[int, ...]
    class_counts: Tuple[int, ...]
    objective: float
    achieved_bits: float

    def to_dict(self) -> Dict:
        return {"layer": self.layer, "bits": list(self.bits),
                "permutation": list(self.permutation),
                "bit_classes": list(self.bit_classes),
                "class_counts": list(self.class_counts),
                "objective": self.objective,
                "achieved_bits": self.achieved_bits}

    @classmethod
    def from_dict(cls, d: Dict) -> "LayerPlan":
        return cls(layer=int(d["layer"]),
                   bits=tuple(int(b) for b in d["bits"]),
                   permutation=tuple(int(p) for p in d["permutation"]),
                   bit_classes=tuple(int(b) for b in d["bit_classes"]),
                   class_counts=tuple(int(c) for c in d["class_counts"]),
                   objective=float(d["objective"]),
                   achieved_bits=float(d["achieved_bits"]))


@dataclass
class CompressionPlan:
    """Small, serializable output of :func:`plan` — everything :func:`apply`
    needs besides the weights and the calibration record."""

    layout: str                      # per_layer | uniform
    target_bits: float
    bit_choices: Tuple[int, ...]
    group_size: int
    pack_block: int
    gptq_percdamp: float
    achieved_bits: float             # mean over layers
    predicted_bytes: int
    original_bytes: int
    layers: List[LayerPlan]
    model_fingerprint: str
    uniform_counts: Optional[Tuple[int, ...]] = None
    uniform_achieved_bits: Optional[float] = None
    odp: Optional[Dict] = None       # threshold/prune_rate/capacity_scale/...

    @property
    def scan_safe(self) -> bool:
        """One static expert layout across layers -> scan-compatible."""
        first = (self.layers[0].bit_classes, self.layers[0].class_counts)
        return all((lp.bit_classes, lp.class_counts) == first
                   for lp in self.layers)

    def metas(self) -> List[MoEQuantMeta]:
        # MoEQuantMeta derives plane_suffixes at construction — the fused
        # moe_ffn kernel and the expert-major shard layout both index
        # packed planes through that precomputed field, never key scans
        return [MoEQuantMeta(bit_classes=lp.bit_classes,
                             class_counts=lp.class_counts,
                             group_size=self.group_size,
                             pack_block=self.pack_block)
                for lp in self.layers]

    def to_dict(self) -> Dict:
        return {
            "layout": self.layout, "target_bits": self.target_bits,
            "bit_choices": list(self.bit_choices),
            "group_size": self.group_size, "pack_block": self.pack_block,
            "gptq_percdamp": self.gptq_percdamp,
            "achieved_bits": self.achieved_bits,
            "predicted_bytes": self.predicted_bytes,
            "original_bytes": self.original_bytes,
            "layers": [lp.to_dict() for lp in self.layers],
            "model_fingerprint": self.model_fingerprint,
            "uniform_counts": (list(self.uniform_counts)
                               if self.uniform_counts is not None else None),
            "uniform_achieved_bits": self.uniform_achieved_bits,
            "odp": self.odp,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "CompressionPlan":
        return cls(
            layout=d["layout"], target_bits=float(d["target_bits"]),
            bit_choices=tuple(int(b) for b in d["bit_choices"]),
            group_size=int(d["group_size"]),
            pack_block=int(d["pack_block"]),
            gptq_percdamp=float(d["gptq_percdamp"]),
            achieved_bits=float(d["achieved_bits"]),
            predicted_bytes=int(d["predicted_bytes"]),
            original_bytes=int(d["original_bytes"]),
            layers=[LayerPlan.from_dict(lp) for lp in d["layers"]],
            model_fingerprint=d["model_fingerprint"],
            uniform_counts=(tuple(int(c) for c in d["uniform_counts"])
                            if d.get("uniform_counts") is not None else None),
            uniform_achieved_bits=d.get("uniform_achieved_bits"),
            odp=d.get("odp"))

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=2))
        return path

    @classmethod
    def load(cls, path) -> "CompressionPlan":
        return cls.from_dict(json.loads(Path(path).read_text()))


def _make_layer_plan(layer_id: int, bits: np.ndarray,
                     objective: float) -> LayerPlan:
    order = np.argsort(bits, kind="stable")
    classes, counts = np.unique(bits[order], return_counts=True)
    return LayerPlan(
        layer=int(layer_id),
        bits=tuple(int(b) for b in bits),
        permutation=tuple(int(i) for i in order),
        bit_classes=tuple(int(b) for b in classes),
        class_counts=tuple(int(c) for c in counts),
        objective=float(objective),
        achieved_bits=float(np.mean(bits)))


def plan(record: CalibrationRecord, ccfg: CompressionConfig, *,
         layout: str = "per_layer") -> CompressionPlan:
    """Stage 2: record -> :class:`CompressionPlan`. Cheap and weight-free.

    Solves the per-layer DP bit allocation (Eq. 4), class-sorts experts,
    calibrates the ODP threshold/capacity, and predicts compressed bytes.
    Re-planning the same record at a new ``ccfg.target_bits`` reuses the
    cached eps tables — milliseconds, no model access.

    Args:
        record: output of :func:`calibrate` (must hold an eps table for
            ``(ccfg.bit_choices, ccfg.group_size)``).
        ccfg: compression settings (target bits, choices, GPTQ params).
        layout: ``"per_layer"`` (paper formulation, independent optimum
            per layer) or ``"uniform"`` (one class layout across layers —
            scan-compatible, the production default for serving).

    Returns a small JSON-serializable plan (``save``/``load``) consumed
    by :func:`apply`.
    """
    if layout not in ("per_layer", "uniform"):
        raise ValueError(f"unknown layout {layout!r} "
                         "(expected 'per_layer' or 'uniform')")
    choices = tuple(int(b) for b in ccfg.bit_choices)
    key = (choices, int(ccfg.group_size))
    if key not in record.eps:
        raise ValueError(
            f"CalibrationRecord holds no eps table for bit_choices={choices}"
            f", group_size={ccfg.group_size} (available: "
            f"{sorted(record.eps)}); calibrate() with matching settings or "
            "call record.ensure_eps(model, params, bit_choices, group_size)")
    eps_tables = record.eps[key]

    per_layer = []
    for li, lc in enumerate(record.layers):
        costs = alloc_lib.build_costs(
            lc.frequency, lc.mean_weight, eps_tables[li],
            alpha=ccfg.alpha, beta=ccfg.beta, gamma=ccfg.gamma)
        res = alloc_lib.solve_allocation(costs, ccfg.target_bits, choices)
        per_layer.append((costs, res))

    layer_plans: List[LayerPlan] = []
    counts = None
    uni_achieved = None
    if layout == "uniform":
        counts, uni_achieved = pmq_lib.uniform_counts(
            [res.bits for _, res in per_layer], choices)
        for li, (costs, _) in enumerate(per_layer):
            bits, obj = pmq_lib.assign_with_counts(costs, choices, counts)
            layer_plans.append(_make_layer_plan(
                record.moe_layer_ids[li], bits, obj))
    else:
        for li, (_, res) in enumerate(per_layer):
            layer_plans.append(_make_layer_plan(
                record.moe_layer_ids[li], res.bits, res.objective))

    pack_block = (128 if (record.d_model % 128 == 0
                          and record.moe_d_ff % 128 == 0)
                  else int(ccfg.group_size))
    predicted = sum(pmq_lib.packed_expert_bytes_dims(
        record.d_model, record.moe_d_ff,
        MoEQuantMeta(lp.bit_classes, lp.class_counts,
                     int(ccfg.group_size), pack_block))
        for lp in layer_plans)
    original = (pmq_lib.dense_expert_bytes_dims(
        record.num_experts, record.d_model, record.moe_d_ff)
        * len(layer_plans))

    odp = None
    if ccfg.odp_enabled:
        odp = odp_lib.plan_odp(record.ratio_samples, record.top_k,
                               protect_ratio=ccfg.protect_ratio,
                               prune_threshold=ccfg.prune_threshold)

    return CompressionPlan(
        layout=layout, target_bits=float(ccfg.target_bits),
        bit_choices=choices, group_size=int(ccfg.group_size),
        pack_block=pack_block, gptq_percdamp=float(ccfg.gptq_percdamp),
        achieved_bits=float(np.mean([lp.achieved_bits
                                     for lp in layer_plans])),
        predicted_bytes=int(predicted), original_bytes=int(original),
        layers=layer_plans, model_fingerprint=record.model_fingerprint,
        uniform_counts=counts, uniform_achieved_bits=uni_achieved, odp=odp)


# ------------------------------------------------------------------ apply
@dataclass
class CompressedArtifact:
    """Quantized params + static metadata, the deployable unit.

    ``params`` is the full model tree with quantized experts — stacked back
    into the scanned layer stacks when the plan is scan-safe, or carried as
    the per-layer ``params['moe_layers']`` list otherwise. ``runtime`` is
    the :class:`MCRuntime` consumed uniformly by ``model.forward`` and the
    serving engines for both layouts.

    On disk the artifact uses the **expert-major shard layout** (artifact
    v2): one fingerprinted shard group per (layer, expert) holding that
    expert's packed planes, plus dense ``part*`` groups for everything
    else — so a host owning experts ``[k0:k1)`` streams only its slice
    (:meth:`load_sharded`). ``expert_range``/``load_stats`` are populated
    on artifacts produced by a subset load: ``expert_range`` is the
    class-sorted expert block this host holds (None = all experts) and
    ``load_stats`` the byte/file accounting of the read.
    """

    params: Dict
    metas: List[MoEQuantMeta]
    runtime: MCRuntime
    plan: CompressionPlan
    report: MCReport
    #: hull of the owned experts (min start, max stop); kept for messages
    #: and back-compat — ``expert_ranges`` is authoritative
    expert_range: Optional[Tuple[int, int]] = None
    #: sorted disjoint global ranges of the experts this artifact holds.
    #: A contiguous per-host stream is one range; a multi-process mesh
    #: slice is one block per bit class (``expert_shard_expectation``).
    #: None = everything (a full load).
    expert_ranges: Optional[Tuple[Tuple[int, int], ...]] = None
    load_stats: Optional[ckpt_lib.LoadStats] = None
    #: mesh the params were already place_params'd on (load_sharded sets
    #: it so engine boot skips a redundant device_put)
    placed_mesh: Optional[object] = None

    @property
    def scan_safe(self) -> bool:
        return self.runtime.quant_meta is not None

    @property
    def model_fingerprint(self) -> str:
        return self.plan.model_fingerprint

    @property
    def num_experts(self) -> int:
        return len(self.plan.layers[0].bits)

    @property
    def is_partial(self) -> bool:
        """True when this artifact holds only one host's expert slice."""
        if self.expert_ranges is not None:
            owned = sum(b - a for a, b in self.expert_ranges)
            return owned < self.num_experts
        return (self.expert_range is not None
                and self.expert_range != (0, self.num_experts))

    @property
    def owned_ranges(self) -> Tuple[Tuple[int, int], ...]:
        """The owned global expert ranges (full artifacts own all)."""
        if self.expert_ranges is not None:
            return self.expert_ranges
        return (self.expert_range if self.expert_range is not None
                else (0, self.num_experts),)

    def class_segments(self) -> Tuple[Tuple[int, int], ...]:
        """(start, count) per bit class — the segmentation the
        expert-parallel placement splits over the EP axis. Requires a
        scan-safe plan (per-layer layouts have no single segmentation
        and cannot boot a multi-process engine)."""
        if not self.plan.scan_safe:
            raise ValueError(
                "per-layer artifacts have no layer-invariant class "
                "segmentation; multi-process distributed serving needs a "
                "scan-safe artifact — re-plan with layout='uniform'")
        return self.metas[0].class_segments()

    def save(self, directory) -> Path:
        """Persist through the sharded checkpointer in the expert-major
        layout; the plan/metas/runtime ride in the manifest so
        :meth:`load` / :meth:`load_sharded` need no model or record."""
        meta = {"artifact": {
            "version": ARTIFACT_VERSION,
            "plan": self.plan.to_dict(),
            "odp": _odp_to_dict(self.runtime.odp),
            "scan_safe": self.scan_safe,
            "shard_layout": "expert_major",
            "num_experts": self.num_experts,
        }}
        return ckpt_lib.save_pytree(Path(directory), 0, self.params,
                                    meta=meta,
                                    split_fn=_expert_split_fn(self.plan))

    @classmethod
    def load(cls, directory, verify: bool = True) -> "CompressedArtifact":
        """Full (single-host) restore: reads every shard group. Accepts
        artifacts saved by this or any older artifact version; newer
        versions fail with an upgrade message. ``verify=False`` skips the
        per-file sha256 fingerprint checks."""
        params, manifest, stats = ckpt_lib.load_pytree_subset(
            Path(directory), None, verify=verify)
        art = _artifact_meta(directory, manifest)
        return cls._assemble(params, art, stats=stats)

    @classmethod
    def load_sharded(cls, directory, mesh=None, axis: str = "expert", *,
                     expert_range: Optional[Tuple[int, int]] = None,
                     num_hosts: Optional[int] = None,
                     host: Optional[int] = None,
                     verify: bool = True,
                     process_index: Optional[int] = None
                     ) -> "CompressedArtifact":
        """Streaming restore for expert-parallel deployment.

        Reads the dense shard groups plus only the (layer, expert) groups
        of the class-sorted experts this host owns, so per-host bytes
        scale with its expert share instead of the artifact size
        (``benchmarks/bench_artifact_loading.py`` measures this).

        The owned experts are, in priority order: ``expert_range=(k0,
        k1)`` explicitly; ``(num_hosts, host)`` — contiguous blocks
        byte-balanced over the manifest's shard-group sizes
        (:func:`byte_balanced_ranges`), ``host`` defaulting to
        ``jax.process_index()``; else, on a mesh spanning several
        processes, the **placement expectation** for this process
        (:func:`expert_shard_expectation`: one block per bit class);
        else all experts — the single-process case, where every device
        is addressable and parallelism comes purely from placement.
        Subset loading needs the expert-major layout; pre-v2 artifacts
        are refused with a re-save hint.

        When ``mesh`` is single-process and the artifact is complete,
        params are placed via :func:`place_params`: packed expert planes
        sharded along their expert axis over the mesh axis carrying
        expert parallelism (``axis``; the logical name ``"expert"``
        resolves to ``"data"`` on the standard mesh), the rest
        replicated. When ``mesh`` spans processes, the loaded slice is
        assembled straight into this process's addressable shard of the
        globally-placed tree (:func:`distributed_params`) — the partial
        stream *is* the local arguments of the expert-parallel schedule,
        and an explicit ``expert_range``/``num_hosts`` that disagrees
        with the expectation fails loudly. A partial artifact loaded
        without a mesh cannot boot a single-host engine.

        ``verify=False`` skips sha256 fingerprint checks. Returns the
        artifact with ``expert_ranges`` and ``load_stats`` populated.
        """
        directory = Path(directory)
        manifest, _ = ckpt_lib.read_manifest(directory)
        art = _artifact_meta(directory, manifest)
        num_experts = art.get("num_experts",
                              len(art["plan"]["layers"][0]["bits"]))
        ebytes = _expert_bytes_from_manifest(manifest, num_experts)
        multiproc = part_lib.mesh_spans_processes(mesh)
        if ebytes is None and (expert_range is not None
                               or num_hosts is not None or multiproc):
            raise ValueError(
                f"{directory} has no expert-major shard groups (artifact "
                "saved by a pre-v2 version); per-host subset loading needs "
                "them — load() it fully once and re-save() to upgrade")
        segments = _plan_segments(art) if multiproc else None
        ranges, _ = _owned_expert_ranges(
            num_experts, segments, ebytes, mesh=mesh, axis=axis,
            expert_range=expert_range, num_hosts=num_hosts, host=host,
            process_index=process_index)

        def keep(path: str, group: str) -> bool:
            e = expert_of_group(group)
            return e is None or any(a <= e < b for a, b in ranges)

        params, manifest, stats = ckpt_lib.load_pytree_subset(
            directory, keep, verify=verify)
        artifact = cls._assemble(params, art, stats=stats,
                                 expert_ranges=ranges)
        if mesh is not None:
            if multiproc:
                artifact.params = distributed_params(
                    artifact.params, mesh, stats, axis=axis)
                artifact.placed_mesh = mesh
            elif not artifact.is_partial:
                artifact.params = place_params(artifact.params, mesh,
                                               axis=axis)
                artifact.placed_mesh = mesh
        return artifact

    @classmethod
    def merge(cls, parts: List["CompressedArtifact"]
              ) -> "CompressedArtifact":
        """Reassemble a full artifact from per-host partial loads whose
        ranges tile ``[0, num_experts)`` exactly (the simulated
        multi-host path of ``launch.serve --num-hosts``); split planes
        are concatenated via ``checkpointer.merge_subset_trees``."""
        if not parts:
            raise ValueError("no artifact parts to merge")
        base = parts[0]
        params = ckpt_lib.merge_subset_trees(
            [(p.params, p.load_stats) for p in parts])
        report = _report_from_plan(base.plan, params, base.metas)
        return cls(params=params, metas=base.metas, runtime=base.runtime,
                   plan=base.plan, report=report)

    @classmethod
    def from_parts(cls, directory, parts) -> "CompressedArtifact":
        """Assemble a full artifact from raw ``(tree, stats)`` parts as
        returned by :func:`load_expert_blocks` — one dense part plus
        expert blocks whose union tiles ``[0, num_experts)`` exactly.
        Metadata (plan/runtime) comes from the artifact manifest; the
        fleet's block-owning replicas (``serve.fleet``) boot through
        this."""
        directory = Path(directory)
        manifest, _ = ckpt_lib.read_manifest(directory)
        art = _artifact_meta(directory, manifest)
        params = ckpt_lib.merge_subset_trees(list(parts))
        return cls._assemble(params, art)

    @classmethod
    def _assemble(cls, params: Dict, art: Dict, stats=None,
                  expert_ranges=None) -> "CompressedArtifact":
        cplan = CompressionPlan.from_dict(art["plan"])
        metas = cplan.metas()
        odp_rt = _odp_from_dict(art["odp"])
        scan_safe = bool(art["scan_safe"])
        runtime = MCRuntime(
            odp=odp_rt,
            quant_meta=metas[0] if scan_safe else None,
            layer_metas=None if scan_safe else tuple(metas))
        report = _report_from_plan(cplan, params, metas)
        hull = ((expert_ranges[0][0], expert_ranges[-1][1])
                if expert_ranges else None)
        return cls(params=params, metas=metas, runtime=runtime, plan=cplan,
                   report=report, expert_range=hull,
                   expert_ranges=(tuple(expert_ranges)
                                  if expert_ranges else None),
                   load_stats=stats)


def _plan_segments(art: Dict) -> Tuple[Tuple[int, int], ...]:
    """Layer-invariant (start, count) class segments from a manifest's
    plan block; per-layer (non-scan-safe) layouts are refused — they
    have no single segmentation a multi-process placement could split."""
    cplan = CompressionPlan.from_dict(art["plan"])
    if not cplan.scan_safe:
        raise ValueError(
            "multi-process distributed serving needs a scan-safe artifact "
            "(one class layout across layers); this artifact is per-layer "
            "— re-plan with layout='uniform'")
    return cplan.metas()[0].class_segments()


def _artifact_meta(directory, manifest: Dict) -> Dict:
    """Extract + version-check the ``artifact`` manifest block."""
    art = manifest.get("meta", {}).get("artifact")
    if art is None:
        raise ValueError(
            f"{directory} is a plain checkpoint, not a CompressedArtifact"
            " (manifest carries no 'artifact' metadata)")
    if art["version"] > ARTIFACT_VERSION:
        raise ValueError(
            f"artifact version {art['version']} is newer than this build "
            f"supports ({ARTIFACT_VERSION}); upgrade repro to load it "
            "(artifacts written by older versions always load)")
    return art


def apply(model: DecoderModel, params: Dict, cplan: CompressionPlan,
          record: CalibrationRecord) -> CompressedArtifact:
    """Stage 3 (the heavy one): GPTQ + pack every expert at its planned
    width and assemble the deployable :class:`CompressedArtifact`.

    Validates plan/record/model agreement (fingerprint, layer and expert
    counts), GPTQs each expert on the tokens actually routed to it, packs
    kernel-layout planes per bit class, and places the quantized layers
    back into the model tree (scan-stacked when the plan is scan-safe,
    as the ``params['moe_layers']`` list otherwise). The returned
    artifact serves directly (``ServeEngine.from_artifact``) or persists
    via :meth:`CompressedArtifact.save` in the expert-major shard layout
    for sharded deployment loading.
    """
    cfg = model.cfg
    if cplan.model_fingerprint != record.model_fingerprint:
        raise ValueError(
            "plan/record model mismatch: plan was made for "
            f"{cplan.model_fingerprint}, record for "
            f"{record.model_fingerprint}")
    if len(cplan.layers) != len(record.layers):
        raise ValueError(f"plan covers {len(cplan.layers)} MoE layers but "
                         f"record captured {len(record.layers)}")
    for lp in cplan.layers:
        if len(lp.bits) != record.num_experts:
            raise ValueError(
                f"plan layer {lp.layer} allocates {len(lp.bits)} experts "
                f"but the model has {record.num_experts}")
    ccfg = CompressionConfig(
        enabled=True, target_bits=cplan.target_bits,
        bit_choices=cplan.bit_choices, group_size=cplan.group_size,
        gptq_percdamp=cplan.gptq_percdamp)
    eps_tables = record.eps.get((cplan.bit_choices, cplan.group_size))
    moe_slots = _moe_slots(model)

    metas: List[MoEQuantMeta] = []
    reports: List[pmq_lib.PMQLayerReport] = []
    q_layers: List[Dict] = []
    for li, (lc, lp) in enumerate(zip(record.layers, cplan.layers)):
        moe_p = _get_moe_params(params, model, moe_slots, li)
        bits = np.asarray(lp.bits, np.int64)
        order = np.asarray(lp.permutation, np.int64)
        meta = MoEQuantMeta(bit_classes=lp.bit_classes,
                            class_counts=lp.class_counts,
                            group_size=cplan.group_size,
                            pack_block=cplan.pack_block)
        q_params = pmq_lib.quantize_moe_layer(
            cfg, ccfg, moe_p, jnp.asarray(lc.x), lc.topk_idx,
            bits_per_expert=bits, order=order, meta=meta)
        q_layers.append(q_params)
        metas.append(meta)
        reports.append(pmq_lib.PMQLayerReport(
            layer=lp.layer, bits=bits, permutation=order,
            achieved_bits=lp.achieved_bits, objective=lp.objective,
            eps=(eps_tables[li] if eps_tables is not None else None),
            frequency=lc.frequency, mean_weight=lc.mean_weight))

    # single source of truth: group_size/pack_block are plan-global, so
    # meta equality reduces to the plan's class-layout comparison
    scan_safe = cplan.scan_safe
    new_params = _assemble_params(params, q_layers, moe_slots, scan_safe)

    odp_rt = _odp_from_dict(cplan.odp)
    runtime = MCRuntime(
        odp=odp_rt,
        quant_meta=metas[0] if scan_safe else None,
        layer_metas=None if scan_safe else tuple(metas))

    avg_bits = float(np.mean([r.achieved_bits for r in reports]))
    pmq_res = pmq_lib.PMQResult(
        params=new_params, metas=metas, reports=reports, avg_bits=avg_bits,
        compressed_bytes=cplan.predicted_bytes,
        original_bytes=cplan.original_bytes)
    report = MCReport(
        pmq=pmq_res,
        odp_threshold=(cplan.odp or {}).get("threshold", 0.0),
        odp_prune_rate=(cplan.odp or {}).get("prune_rate", 0.0),
        capacity_scale=(cplan.odp or {}).get("capacity_scale", 1.0),
        avg_bits=avg_bits)
    return CompressedArtifact(params=new_params, metas=metas,
                              runtime=runtime, plan=cplan, report=report)


# --------------------------------------------- dense expert checkpoints
# Dense (uncompressed) expert stacks under the slot-stacked layer trees:
#   ['layers<slot>']['ffn']['w_in'|'w_gate'|'w_out']  (steps, E, D|F, F|D)
_DENSE_W = re.compile(
    r"^\['layers(\d+)'\]\['ffn'\]\['w_(in|gate|out)'\]$")


def save_dense_expert_params(directory, params: Dict) -> Path:
    """Persist an *uncompressed* MoE param tree in the expert-major
    shard layout.

    Each dense expert stack (``w_in``/``w_gate``/``w_out``, expert axis
    1 under the slot-stacked layers) is split one fingerprinted shard
    group per (slot, expert), exactly like a quantized artifact's packed
    planes — so :func:`load_dense_expert_params` can stream per-host
    expert slices with the same byte accounting and drive the dense
    expert-parallel serving path (``ServeEngine(..., ep_dispatch=True)``)
    from partial per-host checkpoints.
    """
    num_experts = None
    for kp, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if _DENSE_W.match(jax.tree_util.keystr(kp)):
            num_experts = int(np.shape(leaf)[1])
            break
    if num_experts is None:
        raise ValueError(
            "params hold no dense expert stacks "
            "(['layers<k>']['ffn']['w_in'|'w_gate'|'w_out']) — "
            "quantized params persist via CompressedArtifact.save")

    def split(path: str, arr) -> Optional[Tuple[int, List[str]]]:
        m = _DENSE_W.match(path)
        if m is None:
            return None
        slot = int(m.group(1))
        return 1, [f"slot{slot}.expert{j:04d}"
                   for j in range(arr.shape[1])]

    meta = {"dense_moe": {"num_experts": num_experts}}
    return ckpt_lib.save_pytree(Path(directory), 0, params, meta=meta,
                                split_fn=split)


def load_dense_expert_params(directory, mesh=None, axis: str = "expert", *,
                             expert_range: Optional[Tuple[int, int]] = None,
                             num_hosts: Optional[int] = None,
                             host: Optional[int] = None,
                             verify: bool = True,
                             process_index: Optional[int] = None):
    """Streaming restore of a :func:`save_dense_expert_params` checkpoint.

    Same owned-expert resolution as
    :meth:`CompressedArtifact.load_sharded` (explicit range >
    byte-balanced ``(num_hosts, host)`` > multi-process mesh placement
    expectation > everything) with the dense stacks forming one class
    segment ``(0, E)`` — so byte-balanced contiguous host blocks *are*
    the placement expectation whenever ``E`` divides the EP axis. On a
    mesh the loaded slice is assembled into the placed global tree
    (:func:`distributed_params`; partial slices require a multi-process
    mesh whose expectation they match).

    Returns ``(params, stats, ranges)``.
    """
    directory = Path(directory)
    manifest, _ = ckpt_lib.read_manifest(directory)
    dm = manifest.get("meta", {}).get("dense_moe")
    if dm is None:
        raise ValueError(
            f"{directory} was not written by save_dense_expert_params "
            "(manifest carries no 'dense_moe' metadata)")
    num_experts = int(dm["num_experts"])
    ebytes = _expert_bytes_from_manifest(manifest, num_experts)
    ranges, multiproc = _owned_expert_ranges(
        num_experts, ((0, num_experts),), ebytes, mesh=mesh, axis=axis,
        expert_range=expert_range, num_hosts=num_hosts, host=host,
        process_index=process_index)

    def keep(path: str, group: str) -> bool:
        e = expert_of_group(group)
        return e is None or any(a <= e < b for a, b in ranges)

    params, manifest, stats = ckpt_lib.load_pytree_subset(
        directory, keep, verify=verify)
    owned = sum(b - a for a, b in ranges)
    if mesh is not None:
        if multiproc or owned == num_experts:
            params = distributed_params(params, mesh, stats, axis=axis)
        else:
            raise ValueError(
                f"partial dense checkpoint (experts {ranges} of "
                f"{num_experts}) cannot be placed on a single-process "
                "mesh — every device is addressable, so the full stack "
                "is required; load without num_hosts/expert_range")
    return params, stats, ranges


# ---------------------------------------------------------------- helpers
def _moe_slots(model: DecoderModel) -> List[int]:
    return [s for s in range(model.period) if model.slot_kinds[s] == "moe"]


def _get_moe_params(params, model, moe_slots, li):
    n_moe_per_step = len(moe_slots)
    step = li // n_moe_per_step
    slot = moe_slots[li % n_moe_per_step]
    stack = params[f"layers{slot}"]["ffn"]
    return jax.tree.map(lambda a: a[step], stack)


_EXPERT_KEYS = ("w_in", "w_gate", "w_out", "router")


def _assemble_params(params, q_layers, moe_slots, scan_safe):
    """Place quantized MoE layers back into the model tree.

    Scan-safe (identical metas): stack the quantized layers into the
    scanned stacks. Heterogeneous: carry them as the per-layer
    ``moe_layers`` list (loop-mode forward) and strip the dense expert
    stacks and the stale unpermuted router — the artifact must not ship a
    second copy of anything the quantized layers already carry.
    """
    new_params = dict(params)
    if scan_safe:
        for slot in moe_slots:
            key = f"layers{slot}"
            per_step = [q_layers[i] for i in range(len(q_layers))
                        if moe_slots[i % len(moe_slots)] == slot]
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_step)
            layer = dict(new_params[key])
            layer["ffn"] = {**{k: v for k, v in layer["ffn"].items()
                               if k not in _EXPERT_KEYS},
                            **stacked}
            new_params[key] = layer
    else:
        for slot in moe_slots:
            key = f"layers{slot}"
            layer = dict(new_params[key])
            layer["ffn"] = {k: v for k, v in layer["ffn"].items()
                            if k not in _EXPERT_KEYS}
            new_params[key] = layer
        new_params["moe_layers"] = q_layers
    return new_params


def _odp_to_dict(odp: Optional[OdpRuntime]) -> Optional[Dict]:
    if odp is None:
        return None
    return {"threshold": odp.threshold, "protect_ratio": odp.protect_ratio,
            "capacity_scale": odp.capacity_scale, "enabled": odp.enabled,
            "importance_metric": odp.importance_metric,
            "ratio_quantiles": list(odp.ratio_quantiles)}


def _odp_from_dict(d: Optional[Dict]) -> Optional[OdpRuntime]:
    if d is None:
        return None
    return OdpRuntime(
        threshold=float(d["threshold"]),
        protect_ratio=float(d["protect_ratio"]),
        capacity_scale=float(d.get("capacity_scale", 1.0)),
        enabled=bool(d.get("enabled", True)),
        importance_metric=d.get("importance_metric", "eq6"),
        ratio_quantiles=tuple(d.get("ratio_quantiles") or ()))


def _report_from_plan(cplan: CompressionPlan, params: Dict,
                      metas: List[MoEQuantMeta]) -> MCReport:
    """Light report rebuilt at load time (no calibration arrays on disk)."""
    reports = [pmq_lib.PMQLayerReport(
        layer=lp.layer, bits=np.asarray(lp.bits, np.int64),
        permutation=np.asarray(lp.permutation, np.int64),
        achieved_bits=lp.achieved_bits, objective=lp.objective,
        eps=None, frequency=None, mean_weight=None)
        for lp in cplan.layers]
    pmq_res = pmq_lib.PMQResult(
        params=params, metas=metas, reports=reports,
        avg_bits=cplan.achieved_bits,
        compressed_bytes=cplan.predicted_bytes,
        original_bytes=cplan.original_bytes)
    odp = cplan.odp or {}
    return MCReport(pmq=pmq_res,
                    odp_threshold=odp.get("threshold", 0.0),
                    odp_prune_rate=odp.get("prune_rate", 0.0),
                    capacity_scale=odp.get("capacity_scale", 1.0),
                    avg_bits=cplan.achieved_bits)
