"""Staged MC compression pipeline: calibrate -> plan -> apply -> artifact.

The paper's pipeline is naturally staged — one calibration pass yields expert
significance stats, an LP/DP bit allocation, then GPTQ + packing (Sec. 3.2).
This module exposes each stage as a first-class step so compression runs
*once offline* and deployment just loads a small artifact (the paper's
"pre-loading" premise):

1. :func:`calibrate` — one instrumented forward pass capturing per-MoE-layer
   FFN inputs, routing decisions, and the RTN eps_{i,j} probe table
   (Eq. 3). Returns a :class:`CalibrationRecord`; the expensive probes are
   cached per ``(bit_choices, group_size)`` so re-planning never re-runs
   them.
2. :func:`plan` — cheap, record-only: per-layer DP bit allocation (Eq. 4),
   class sorting, ODP threshold/capacity calibration, predicted sizes.
   Returns a small JSON-serializable :class:`CompressionPlan`; planning the
   same record at a different ``target_bits`` costs milliseconds.
3. :func:`apply` — the heavy stage: GPTQ each expert at its planned width,
   pack kernel-layout planes, assemble quantized params. Returns a
   :class:`CompressedArtifact` bundling params + metas + the static
   :class:`MCRuntime` + report.
4. :meth:`CompressedArtifact.save` / :meth:`CompressedArtifact.load` —
   persist through ``checkpoint.checkpointer`` so serving boots straight
   from the artifact with no calibration data present.

The legacy one-shot ``repro.core.mc.compress`` remains as a thin shim that
composes these stages.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CompressionConfig
from repro.core import allocation as alloc_lib
from repro.core import odp as odp_lib
from repro.core import pmq as pmq_lib
from repro.core.significance import ExpertStats
from repro.checkpoint import checkpointer as ckpt_lib
from repro.models.layers.moe import MoEQuantMeta, OdpRuntime
from repro.models.transformer import DecoderModel, MCRuntime

ARTIFACT_VERSION = 1


@dataclass
class MCReport:
    """Summary of one full compression run (also rebuilt on artifact load)."""

    pmq: pmq_lib.PMQResult
    odp_threshold: float
    odp_prune_rate: float
    capacity_scale: float
    avg_bits: float


# ------------------------------------------------------------- calibration
def capture_forward(model: DecoderModel, params: Dict,
                    calib_tokens: jax.Array, **fw_kwargs) -> List[Dict]:
    """One instrumented forward pass: per-MoE-layer FFN inputs + routing."""
    _, _, aux = model.forward(params, calib_tokens, scan=False,
                              collect_aux=True, capture=True, **fw_kwargs)
    captured = []
    for layer_aux in aux["per_layer"]:
        if "topk_idx" in layer_aux:
            captured.append({
                "x": layer_aux["ffn_input"],
                "topk_idx": layer_aux["topk_idx"],
                "topk_weights": layer_aux["topk_weights"],
            })
    return captured


@dataclass
class LayerCalibration:
    """Flattened calibration capture + router stats for one MoE layer."""

    x: np.ndarray             # (T, D) FFN inputs
    topk_idx: np.ndarray      # (T, k) routed expert ids
    topk_weights: np.ndarray  # (T, k) routing weights
    frequency: np.ndarray     # (E,) phi_i
    mean_weight: np.ndarray   # (E,) w_i


@dataclass
class CalibrationRecord:
    """Everything :func:`plan` and :func:`apply` need, computed once.

    ``eps`` caches the RTN probe tables keyed by ``(bit_choices,
    group_size)`` — re-planning at a new ``target_bits`` with the same
    quantizer settings reuses them without touching the model weights.
    """

    model_fingerprint: str
    num_experts: int
    top_k: int
    d_model: int
    moe_d_ff: int
    moe_layer_ids: List[int]
    layers: List[LayerCalibration]
    ratio_samples: np.ndarray                  # concatenated w1/w0 samples
    eps: Dict[Tuple[Tuple[int, ...], int], List[np.ndarray]] = \
        field(default_factory=dict)
    eps_probe_runs: int = 0                    # how many probe sweeps ran

    def ensure_eps(self, model: DecoderModel, params: Dict,
                   bit_choices, group_size: int) -> List[np.ndarray]:
        """Compute (or fetch cached) eps_{i,j} tables for one quantizer
        setting. Only this method re-touches the model weights."""
        key = (tuple(int(b) for b in bit_choices), int(group_size))
        if key in self.eps:
            return self.eps[key]
        moe_slots = _moe_slots(model)
        tables = []
        for li, lc in enumerate(self.layers):
            moe_p = _get_moe_params(params, model, moe_slots, li)
            tables.append(pmq_lib.compute_eps(
                model.cfg, moe_p, jnp.asarray(lc.x), lc.topk_idx,
                lc.topk_weights, key[0], key[1]))
        self.eps[key] = tables
        self.eps_probe_runs += 1
        return tables


def calibrate(model: DecoderModel, params: Dict, calib_tokens: jax.Array, *,
              bit_choices=(1, 2, 3), group_size: int = 128,
              **fw_kwargs) -> CalibrationRecord:
    """Stage 1: one calibration pass + eps probes -> CalibrationRecord."""
    cfg = model.cfg
    assert cfg.is_moe, "MC's PMQ applies to MoE experts (DESIGN.md §4)"
    captured = capture_forward(model, params, calib_tokens, **fw_kwargs)
    moe_ids = cfg.moe_layer_ids()
    assert len(captured) == len(moe_ids), (len(captured), len(moe_ids))

    layers = []
    ratio_samples = []
    for cap in captured:
        x = np.asarray(cap["x"], np.float32)
        x = x.reshape(-1, x.shape[-1])
        idx = np.asarray(cap["topk_idx"]).reshape(-1, cfg.top_k)
        w = np.asarray(cap["topk_weights"], np.float32).reshape(-1, cfg.top_k)
        stats = ExpertStats(num_experts=cfg.num_experts)
        stats.update(idx, w)
        layers.append(LayerCalibration(
            x=x, topk_idx=idx, topk_weights=w,
            frequency=stats.frequency, mean_weight=stats.mean_weight))
        if cfg.top_k >= 2:
            ratio_samples.append(w[:, 1] / np.maximum(w[:, 0], 1e-9))

    record = CalibrationRecord(
        model_fingerprint=cfg.fingerprint(),
        num_experts=cfg.num_experts, top_k=cfg.top_k,
        d_model=cfg.d_model, moe_d_ff=cfg.moe_d_ff,
        moe_layer_ids=list(moe_ids), layers=layers,
        ratio_samples=(np.concatenate(ratio_samples) if ratio_samples
                       else np.zeros(0, np.float32)))
    record.ensure_eps(model, params, bit_choices, group_size)
    return record


# ------------------------------------------------------------------- plan
@dataclass
class LayerPlan:
    """Planned allocation for one MoE layer (all original expert order)."""

    layer: int                       # model layer id
    bits: Tuple[int, ...]            # (E,) allocated widths
    permutation: Tuple[int, ...]     # class-sorted expert order
    bit_classes: Tuple[int, ...]
    class_counts: Tuple[int, ...]
    objective: float
    achieved_bits: float

    def to_dict(self) -> Dict:
        return {"layer": self.layer, "bits": list(self.bits),
                "permutation": list(self.permutation),
                "bit_classes": list(self.bit_classes),
                "class_counts": list(self.class_counts),
                "objective": self.objective,
                "achieved_bits": self.achieved_bits}

    @classmethod
    def from_dict(cls, d: Dict) -> "LayerPlan":
        return cls(layer=int(d["layer"]),
                   bits=tuple(int(b) for b in d["bits"]),
                   permutation=tuple(int(p) for p in d["permutation"]),
                   bit_classes=tuple(int(b) for b in d["bit_classes"]),
                   class_counts=tuple(int(c) for c in d["class_counts"]),
                   objective=float(d["objective"]),
                   achieved_bits=float(d["achieved_bits"]))


@dataclass
class CompressionPlan:
    """Small, serializable output of :func:`plan` — everything :func:`apply`
    needs besides the weights and the calibration record."""

    layout: str                      # per_layer | uniform
    target_bits: float
    bit_choices: Tuple[int, ...]
    group_size: int
    pack_block: int
    gptq_percdamp: float
    achieved_bits: float             # mean over layers
    predicted_bytes: int
    original_bytes: int
    layers: List[LayerPlan]
    model_fingerprint: str
    uniform_counts: Optional[Tuple[int, ...]] = None
    uniform_achieved_bits: Optional[float] = None
    odp: Optional[Dict] = None       # threshold/prune_rate/capacity_scale/...

    @property
    def scan_safe(self) -> bool:
        """One static expert layout across layers -> scan-compatible."""
        first = (self.layers[0].bit_classes, self.layers[0].class_counts)
        return all((lp.bit_classes, lp.class_counts) == first
                   for lp in self.layers)

    def metas(self) -> List[MoEQuantMeta]:
        return [MoEQuantMeta(bit_classes=lp.bit_classes,
                             class_counts=lp.class_counts,
                             group_size=self.group_size,
                             pack_block=self.pack_block)
                for lp in self.layers]

    def to_dict(self) -> Dict:
        return {
            "layout": self.layout, "target_bits": self.target_bits,
            "bit_choices": list(self.bit_choices),
            "group_size": self.group_size, "pack_block": self.pack_block,
            "gptq_percdamp": self.gptq_percdamp,
            "achieved_bits": self.achieved_bits,
            "predicted_bytes": self.predicted_bytes,
            "original_bytes": self.original_bytes,
            "layers": [lp.to_dict() for lp in self.layers],
            "model_fingerprint": self.model_fingerprint,
            "uniform_counts": (list(self.uniform_counts)
                               if self.uniform_counts is not None else None),
            "uniform_achieved_bits": self.uniform_achieved_bits,
            "odp": self.odp,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "CompressionPlan":
        return cls(
            layout=d["layout"], target_bits=float(d["target_bits"]),
            bit_choices=tuple(int(b) for b in d["bit_choices"]),
            group_size=int(d["group_size"]),
            pack_block=int(d["pack_block"]),
            gptq_percdamp=float(d["gptq_percdamp"]),
            achieved_bits=float(d["achieved_bits"]),
            predicted_bytes=int(d["predicted_bytes"]),
            original_bytes=int(d["original_bytes"]),
            layers=[LayerPlan.from_dict(lp) for lp in d["layers"]],
            model_fingerprint=d["model_fingerprint"],
            uniform_counts=(tuple(int(c) for c in d["uniform_counts"])
                            if d.get("uniform_counts") is not None else None),
            uniform_achieved_bits=d.get("uniform_achieved_bits"),
            odp=d.get("odp"))

    def save(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=2))
        return path

    @classmethod
    def load(cls, path) -> "CompressionPlan":
        return cls.from_dict(json.loads(Path(path).read_text()))


def _make_layer_plan(layer_id: int, bits: np.ndarray,
                     objective: float) -> LayerPlan:
    order = np.argsort(bits, kind="stable")
    classes, counts = np.unique(bits[order], return_counts=True)
    return LayerPlan(
        layer=int(layer_id),
        bits=tuple(int(b) for b in bits),
        permutation=tuple(int(i) for i in order),
        bit_classes=tuple(int(b) for b in classes),
        class_counts=tuple(int(c) for c in counts),
        objective=float(objective),
        achieved_bits=float(np.mean(bits)))


def plan(record: CalibrationRecord, ccfg: CompressionConfig, *,
         layout: str = "per_layer") -> CompressionPlan:
    """Stage 2: record -> CompressionPlan. Cheap, weight-free; re-planning
    at a new ``target_bits`` reuses the record's cached eps tables."""
    if layout not in ("per_layer", "uniform"):
        raise ValueError(f"unknown layout {layout!r} "
                         "(expected 'per_layer' or 'uniform')")
    choices = tuple(int(b) for b in ccfg.bit_choices)
    key = (choices, int(ccfg.group_size))
    if key not in record.eps:
        raise ValueError(
            f"CalibrationRecord holds no eps table for bit_choices={choices}"
            f", group_size={ccfg.group_size} (available: "
            f"{sorted(record.eps)}); calibrate() with matching settings or "
            "call record.ensure_eps(model, params, bit_choices, group_size)")
    eps_tables = record.eps[key]

    per_layer = []
    for li, lc in enumerate(record.layers):
        costs = alloc_lib.build_costs(
            lc.frequency, lc.mean_weight, eps_tables[li],
            alpha=ccfg.alpha, beta=ccfg.beta, gamma=ccfg.gamma)
        res = alloc_lib.solve_allocation(costs, ccfg.target_bits, choices)
        per_layer.append((costs, res))

    layer_plans: List[LayerPlan] = []
    counts = None
    uni_achieved = None
    if layout == "uniform":
        counts, uni_achieved = pmq_lib.uniform_counts(
            [res.bits for _, res in per_layer], choices)
        for li, (costs, _) in enumerate(per_layer):
            bits, obj = pmq_lib.assign_with_counts(costs, choices, counts)
            layer_plans.append(_make_layer_plan(
                record.moe_layer_ids[li], bits, obj))
    else:
        for li, (_, res) in enumerate(per_layer):
            layer_plans.append(_make_layer_plan(
                record.moe_layer_ids[li], res.bits, res.objective))

    pack_block = (128 if (record.d_model % 128 == 0
                          and record.moe_d_ff % 128 == 0)
                  else int(ccfg.group_size))
    predicted = sum(pmq_lib.packed_expert_bytes_dims(
        record.d_model, record.moe_d_ff,
        MoEQuantMeta(lp.bit_classes, lp.class_counts,
                     int(ccfg.group_size), pack_block))
        for lp in layer_plans)
    original = (pmq_lib.dense_expert_bytes_dims(
        record.num_experts, record.d_model, record.moe_d_ff)
        * len(layer_plans))

    odp = None
    if ccfg.odp_enabled:
        odp = odp_lib.plan_odp(record.ratio_samples, record.top_k,
                               protect_ratio=ccfg.protect_ratio,
                               prune_threshold=ccfg.prune_threshold)

    return CompressionPlan(
        layout=layout, target_bits=float(ccfg.target_bits),
        bit_choices=choices, group_size=int(ccfg.group_size),
        pack_block=pack_block, gptq_percdamp=float(ccfg.gptq_percdamp),
        achieved_bits=float(np.mean([lp.achieved_bits
                                     for lp in layer_plans])),
        predicted_bytes=int(predicted), original_bytes=int(original),
        layers=layer_plans, model_fingerprint=record.model_fingerprint,
        uniform_counts=counts, uniform_achieved_bits=uni_achieved, odp=odp)


# ------------------------------------------------------------------ apply
@dataclass
class CompressedArtifact:
    """Quantized params + static metadata, the deployable unit.

    ``params`` is the full model tree with quantized experts — stacked back
    into the scanned layer stacks when the plan is scan-safe, or carried as
    the per-layer ``params['moe_layers']`` list otherwise. ``runtime`` is
    the :class:`MCRuntime` consumed uniformly by ``model.forward`` and the
    serving engines for both layouts.
    """

    params: Dict
    metas: List[MoEQuantMeta]
    runtime: MCRuntime
    plan: CompressionPlan
    report: MCReport

    @property
    def scan_safe(self) -> bool:
        return self.runtime.quant_meta is not None

    @property
    def model_fingerprint(self) -> str:
        return self.plan.model_fingerprint

    def save(self, directory) -> Path:
        """Persist through the sharded checkpointer; the plan/metas/runtime
        ride in the manifest so :meth:`load` needs no model or record."""
        meta = {"artifact": {
            "version": ARTIFACT_VERSION,
            "plan": self.plan.to_dict(),
            "odp": _odp_to_dict(self.runtime.odp),
            "scan_safe": self.scan_safe,
        }}
        return ckpt_lib.save_pytree(Path(directory), 0, self.params,
                                    meta=meta)

    @classmethod
    def load(cls, directory) -> "CompressedArtifact":
        params, manifest = ckpt_lib.load_pytree(Path(directory))
        art = manifest.get("meta", {}).get("artifact")
        if art is None:
            raise ValueError(
                f"{directory} is a plain checkpoint, not a CompressedArtifact"
                " (manifest carries no 'artifact' metadata)")
        if art["version"] > ARTIFACT_VERSION:
            raise ValueError(f"artifact version {art['version']} is newer "
                             f"than supported {ARTIFACT_VERSION}")
        cplan = CompressionPlan.from_dict(art["plan"])
        metas = cplan.metas()
        odp_rt = _odp_from_dict(art["odp"])
        scan_safe = bool(art["scan_safe"])
        runtime = MCRuntime(
            odp=odp_rt,
            quant_meta=metas[0] if scan_safe else None,
            layer_metas=None if scan_safe else tuple(metas))
        report = _report_from_plan(cplan, params, metas)
        return cls(params=params, metas=metas, runtime=runtime, plan=cplan,
                   report=report)


def apply(model: DecoderModel, params: Dict, cplan: CompressionPlan,
          record: CalibrationRecord) -> CompressedArtifact:
    """Stage 3: GPTQ + pack every expert at its planned width and assemble
    the deployable artifact."""
    cfg = model.cfg
    if cplan.model_fingerprint != record.model_fingerprint:
        raise ValueError(
            "plan/record model mismatch: plan was made for "
            f"{cplan.model_fingerprint}, record for "
            f"{record.model_fingerprint}")
    if len(cplan.layers) != len(record.layers):
        raise ValueError(f"plan covers {len(cplan.layers)} MoE layers but "
                         f"record captured {len(record.layers)}")
    for lp in cplan.layers:
        if len(lp.bits) != record.num_experts:
            raise ValueError(
                f"plan layer {lp.layer} allocates {len(lp.bits)} experts "
                f"but the model has {record.num_experts}")
    ccfg = CompressionConfig(
        enabled=True, target_bits=cplan.target_bits,
        bit_choices=cplan.bit_choices, group_size=cplan.group_size,
        gptq_percdamp=cplan.gptq_percdamp)
    eps_tables = record.eps.get((cplan.bit_choices, cplan.group_size))
    moe_slots = _moe_slots(model)

    metas: List[MoEQuantMeta] = []
    reports: List[pmq_lib.PMQLayerReport] = []
    q_layers: List[Dict] = []
    for li, (lc, lp) in enumerate(zip(record.layers, cplan.layers)):
        moe_p = _get_moe_params(params, model, moe_slots, li)
        bits = np.asarray(lp.bits, np.int64)
        order = np.asarray(lp.permutation, np.int64)
        meta = MoEQuantMeta(bit_classes=lp.bit_classes,
                            class_counts=lp.class_counts,
                            group_size=cplan.group_size,
                            pack_block=cplan.pack_block)
        q_params = pmq_lib.quantize_moe_layer(
            cfg, ccfg, moe_p, jnp.asarray(lc.x), lc.topk_idx,
            bits_per_expert=bits, order=order, meta=meta)
        q_layers.append(q_params)
        metas.append(meta)
        reports.append(pmq_lib.PMQLayerReport(
            layer=lp.layer, bits=bits, permutation=order,
            achieved_bits=lp.achieved_bits, objective=lp.objective,
            eps=(eps_tables[li] if eps_tables is not None else None),
            frequency=lc.frequency, mean_weight=lc.mean_weight))

    # single source of truth: group_size/pack_block are plan-global, so
    # meta equality reduces to the plan's class-layout comparison
    scan_safe = cplan.scan_safe
    new_params = _assemble_params(params, q_layers, moe_slots, scan_safe)

    odp_rt = _odp_from_dict(cplan.odp)
    runtime = MCRuntime(
        odp=odp_rt,
        quant_meta=metas[0] if scan_safe else None,
        layer_metas=None if scan_safe else tuple(metas))

    avg_bits = float(np.mean([r.achieved_bits for r in reports]))
    pmq_res = pmq_lib.PMQResult(
        params=new_params, metas=metas, reports=reports, avg_bits=avg_bits,
        compressed_bytes=cplan.predicted_bytes,
        original_bytes=cplan.original_bytes)
    report = MCReport(
        pmq=pmq_res,
        odp_threshold=(cplan.odp or {}).get("threshold", 0.0),
        odp_prune_rate=(cplan.odp or {}).get("prune_rate", 0.0),
        capacity_scale=(cplan.odp or {}).get("capacity_scale", 1.0),
        avg_bits=avg_bits)
    return CompressedArtifact(params=new_params, metas=metas,
                              runtime=runtime, plan=cplan, report=report)


# ---------------------------------------------------------------- helpers
def _moe_slots(model: DecoderModel) -> List[int]:
    return [s for s in range(model.period) if model.slot_kinds[s] == "moe"]


def _get_moe_params(params, model, moe_slots, li):
    n_moe_per_step = len(moe_slots)
    step = li // n_moe_per_step
    slot = moe_slots[li % n_moe_per_step]
    stack = params[f"layers{slot}"]["ffn"]
    return jax.tree.map(lambda a: a[step], stack)


_EXPERT_KEYS = ("w_in", "w_gate", "w_out", "router")


def _assemble_params(params, q_layers, moe_slots, scan_safe):
    """Place quantized MoE layers back into the model tree.

    Scan-safe (identical metas): stack the quantized layers into the
    scanned stacks. Heterogeneous: carry them as the per-layer
    ``moe_layers`` list (loop-mode forward) and strip the dense expert
    stacks and the stale unpermuted router — the artifact must not ship a
    second copy of anything the quantized layers already carry.
    """
    new_params = dict(params)
    if scan_safe:
        for slot in moe_slots:
            key = f"layers{slot}"
            per_step = [q_layers[i] for i in range(len(q_layers))
                        if moe_slots[i % len(moe_slots)] == slot]
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_step)
            layer = dict(new_params[key])
            layer["ffn"] = {**{k: v for k, v in layer["ffn"].items()
                               if k not in _EXPERT_KEYS},
                            **stacked}
            new_params[key] = layer
    else:
        for slot in moe_slots:
            key = f"layers{slot}"
            layer = dict(new_params[key])
            layer["ffn"] = {k: v for k, v in layer["ffn"].items()
                            if k not in _EXPERT_KEYS}
            new_params[key] = layer
        new_params["moe_layers"] = q_layers
    return new_params


def _odp_to_dict(odp: Optional[OdpRuntime]) -> Optional[Dict]:
    if odp is None:
        return None
    return {"threshold": odp.threshold, "protect_ratio": odp.protect_ratio,
            "capacity_scale": odp.capacity_scale, "enabled": odp.enabled,
            "importance_metric": odp.importance_metric}


def _odp_from_dict(d: Optional[Dict]) -> Optional[OdpRuntime]:
    if d is None:
        return None
    return OdpRuntime(
        threshold=float(d["threshold"]),
        protect_ratio=float(d["protect_ratio"]),
        capacity_scale=float(d.get("capacity_scale", 1.0)),
        enabled=bool(d.get("enabled", True)),
        importance_metric=d.get("importance_metric", "eq6"))


def _report_from_plan(cplan: CompressionPlan, params: Dict,
                      metas: List[MoEQuantMeta]) -> MCReport:
    """Light report rebuilt at load time (no calibration arrays on disk)."""
    reports = [pmq_lib.PMQLayerReport(
        layer=lp.layer, bits=np.asarray(lp.bits, np.int64),
        permutation=np.asarray(lp.permutation, np.int64),
        achieved_bits=lp.achieved_bits, objective=lp.objective,
        eps=None, frequency=None, mean_weight=None)
        for lp in cplan.layers]
    pmq_res = pmq_lib.PMQResult(
        params=params, metas=metas, reports=reports,
        avg_bits=cplan.achieved_bits,
        compressed_bytes=cplan.predicted_bytes,
        original_bytes=cplan.original_bytes)
    odp = cplan.odp or {}
    return MCReport(pmq=pmq_res,
                    odp_threshold=odp.get("threshold", 0.0),
                    odp_prune_rate=odp.get("prune_rate", 0.0),
                    capacity_scale=odp.get("capacity_scale", 1.0),
                    avg_bits=cplan.achieved_bits)
