"""PMQ — Pre-Loading Mixed-Precision Quantization (paper Sec. 3.2).

Pipeline per MoE layer (driven by a single calibration forward pass that
captures each layer's FFN inputs and routing decisions):

1. **significance stats**: activation frequency phi_i + routing mass w_i
   (`core.significance.ExpertStats`);
2. **eps_{i,j}**: expert-local output reconstruction F-norm at each candidate
   width (Eq. 3), RTN fake-quant probes;
3. **IP allocation** (Eq. 4) — exact DP (`core.allocation`). Two layouts:
   * ``per_layer`` — the paper's formulation, independent optimum per layer;
   * ``uniform``  — beyond-paper production mode: class sizes fixed across
     layers (median of per-layer optima) and experts assigned to classes by
     an exact linear-sum-assignment solve, so the quantized model keeps one
     static layout and stays scan-over-layers compatible;
4. **GPTQ** each expert matrix at its width (sign-GPTQ for 1-bit), Hessians
   from the tokens actually routed to that expert;
5. **pack**: experts sorted by class; packed kernel-layout planes per class;
   the router's output columns are permuted identically.

Non-expert weights are 4-bit in the paper; here they stay bf16 at runtime
(experts are >96% of MoE-LLM weights) and the 4-bit storage is accounted
analytically in reports — DESIGN.md §8.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CompressionConfig, ModelConfig
from repro.core import allocation as alloc_lib
from repro.core.significance import ExpertStats
from repro.kernels.common import pack_kernel_layout
from repro.models.layers.core import mlp_activation
from repro.models.layers.moe import MoEQuantMeta
from repro.quant import gptq as gptq_lib
from repro.quant.quantizer import quant_dequant
from repro.quant.binary import binary_quant_dequant


@dataclass
class PMQLayerReport:
    layer: int
    bits: np.ndarray                 # (E,) allocated widths (original order)
    permutation: np.ndarray          # class-sorted expert order
    achieved_bits: float
    objective: float
    # calibration-time arrays; None on reports rebuilt from a loaded artifact
    eps: Optional[np.ndarray]        # (E, |choices|)
    frequency: Optional[np.ndarray]
    mean_weight: Optional[np.ndarray]


@dataclass
class PMQResult:
    params: Dict                     # model params with quantized experts
    metas: List[Optional[MoEQuantMeta]]   # per MoE layer (model order)
    reports: List[PMQLayerReport]
    avg_bits: float
    compressed_bytes: int
    original_bytes: int

    @property
    def compression_ratio(self) -> float:
        return 1.0 - self.compressed_bytes / max(self.original_bytes, 1)


# --------------------------------------------------------------- eps probes
def _expert_apply(cfg: ModelConfig, w_in, w_gate, w_out, x):
    act = mlp_activation(cfg)
    h = x @ w_in
    g = x @ w_gate
    return (act(g) * h) @ w_out


def _fake_quant(w, bits, group_size):
    if bits == 1:
        return binary_quant_dequant(w, group_size)
    return quant_dequant(w, bits, group_size)


def compute_eps(cfg: ModelConfig, moe_params: Dict, calib_x: jax.Array,
                topk_idx: jax.Array, topk_w: jax.Array,
                bit_choices: Sequence[int], group_size: int) -> np.ndarray:
    """eps_{i,j} (Eq. 3) on the tokens routed to each expert."""
    e = cfg.num_experts
    t = calib_x.shape[0]
    eps = np.zeros((e, len(bit_choices)))
    idx_np = np.asarray(topk_idx).reshape(t, -1)
    w_np = np.asarray(topk_w).reshape(t, -1)
    w_in = np.asarray(moe_params["w_in"], np.float32)
    w_gate = np.asarray(moe_params["w_gate"], np.float32)
    w_out = np.asarray(moe_params["w_out"], np.float32)
    x32 = calib_x.astype(jnp.float32)

    for i in range(e):
        hits = (idx_np == i)
        rows = hits.any(axis=1)
        if not rows.any():
            continue
        xs = x32[np.nonzero(rows)[0]]
        ws = jnp.asarray(w_np[rows][hits[rows]].reshape(-1, 1))
        ref = _expert_apply(cfg, w_in[i], w_gate[i], w_out[i], xs)
        for bj, bits in enumerate(bit_choices):
            qi = _fake_quant(jnp.asarray(w_in[i]), bits, group_size)
            qg = _fake_quant(jnp.asarray(w_gate[i]), bits, group_size)
            qo = _fake_quant(jnp.asarray(w_out[i]), bits, group_size)
            out = _expert_apply(cfg, qi, qg, qo, xs)
            delta = (ref - out) * ws
            eps[i, bj] = float(jnp.sqrt(jnp.sum(delta ** 2)))
    return eps


# ------------------------------------------------------------- gptq experts
def _gptq_expert(cfg: ModelConfig, w_in, w_gate, w_out, xs, bits: int,
                 ccfg: CompressionConfig):
    """GPTQ all three matrices of one expert on its routed tokens."""
    gs = ccfg.group_size
    x32 = xs.astype(jnp.float32)
    h_in, _ = gptq_lib.accumulate_hessian(
        gptq_lib.init_hessian(w_in.shape[0]), x32, 0)
    r_in = gptq_lib.gptq_quantize(w_in, h_in, bits=bits, group_size=gs,
                                  percdamp=ccfg.gptq_percdamp)
    r_gate = gptq_lib.gptq_quantize(w_gate, h_in, bits=bits, group_size=gs,
                                    percdamp=ccfg.gptq_percdamp)
    # intermediate activations for w_out's Hessian
    act = mlp_activation(cfg)
    h_mid = act(x32 @ w_gate.astype(jnp.float32)) * \
        (x32 @ w_in.astype(jnp.float32))
    h_out, _ = gptq_lib.accumulate_hessian(
        gptq_lib.init_hessian(w_out.shape[0]), h_mid, 0)
    r_out = gptq_lib.gptq_quantize(w_out, h_out, bits=bits, group_size=gs,
                                   percdamp=ccfg.gptq_percdamp)
    return r_in, r_gate, r_out


def _pack_class(results, pack_block: int):
    """Stack per-expert GPTQResults of one class into packed planes dicts."""
    out = {}
    for tag, rs in results.items():
        bits = rs[0].bits
        planes = [pack_kernel_layout(r.codes, bits, pack_block) for r in rs]
        n_planes = len(planes[0])
        for pi in range(n_planes):
            out[f"{tag}_p{pi}"] = jnp.stack([p[pi] for p in planes])
        out[f"{tag}_s"] = jnp.stack([r.scales for r in rs])
        if bits > 1:
            out[f"{tag}_z"] = jnp.stack([r.zeros for r in rs])
    return out


# ------------------------------------------------------------ layer compress
def compress_moe_layer(cfg: ModelConfig, ccfg: CompressionConfig,
                       moe_params: Dict, calib_x: jax.Array,
                       topk_idx: jax.Array, topk_w: jax.Array,
                       layer_idx: int,
                       forced_counts: Optional[Tuple[int, ...]] = None,
                       ) -> Tuple[Dict, MoEQuantMeta, PMQLayerReport]:
    """Quantize one MoE layer's experts. Returns (new params, meta, report).

    calib_x: (T, D) FFN inputs; topk_idx/w: (T, k) routing decisions.
    forced_counts: fix per-class expert counts (uniform layout mode).
    """
    e = cfg.num_experts
    bit_choices = tuple(ccfg.bit_choices)
    stats = ExpertStats(num_experts=e)
    stats.update(topk_idx, topk_w)

    eps = compute_eps(cfg, moe_params, calib_x, topk_idx, topk_w,
                      bit_choices, ccfg.group_size)
    costs = alloc_lib.build_costs(stats.frequency, stats.mean_weight, eps,
                                  alpha=ccfg.alpha, beta=ccfg.beta,
                                  gamma=ccfg.gamma)
    if forced_counts is None:
        res = alloc_lib.solve_allocation(costs, ccfg.target_bits, bit_choices)
        bits_per_expert = res.bits
        objective = res.objective
    else:
        bits_per_expert, objective = assign_with_counts(costs, bit_choices,
                                                        forced_counts)

    # class-sort experts (ascending width); permute router columns to match
    order = np.argsort(bits_per_expert, kind="stable")
    sorted_bits = bits_per_expert[order]
    classes, counts = np.unique(sorted_bits, return_counts=True)
    pack_block = 128 if (cfg.d_model % 128 == 0 and cfg.moe_d_ff % 128 == 0) \
        else ccfg.group_size
    meta = MoEQuantMeta(bit_classes=tuple(int(b) for b in classes),
                        class_counts=tuple(int(c) for c in counts),
                        group_size=ccfg.group_size, pack_block=pack_block)

    new_params = quantize_moe_layer(cfg, ccfg, moe_params, calib_x, topk_idx,
                                    bits_per_expert=bits_per_expert,
                                    order=order, meta=meta)

    report = PMQLayerReport(
        layer=layer_idx, bits=bits_per_expert, permutation=order,
        achieved_bits=float(bits_per_expert.mean()), objective=objective,
        eps=eps, frequency=stats.frequency, mean_weight=stats.mean_weight)
    return new_params, meta, report


def quantize_moe_layer(cfg: ModelConfig, ccfg: CompressionConfig,
                       moe_params: Dict, calib_x: jax.Array,
                       topk_idx: jax.Array, *,
                       bits_per_expert: np.ndarray, order: np.ndarray,
                       meta: MoEQuantMeta) -> Dict:
    """GPTQ + pack one MoE layer's experts at pre-planned widths.

    The allocation (``bits_per_expert``/``order``/``meta``) comes from a
    :class:`repro.core.pipeline.CompressionPlan`; this stage only does the
    heavy weight work. Returns the quantized layer params (class-sorted
    packed planes + permuted router; expert mats removed).
    """
    del bits_per_expert  # encoded by order + meta's class layout
    idx_np = np.asarray(topk_idx).reshape(-1, topk_idx.shape[-1])
    x32 = calib_x.astype(jnp.float32)
    w_in = np.asarray(moe_params["w_in"], np.float32)
    w_gate = np.asarray(moe_params["w_gate"], np.float32)
    w_out = np.asarray(moe_params["w_out"], np.float32)

    experts_q = {}
    pos = 0
    for ci, (bits, cnt) in enumerate(zip(meta.bit_classes,
                                         meta.class_counts)):
        results = {"in": [], "gate": [], "out": []}
        for j in range(cnt):
            eid = int(order[pos + j])
            rows = (idx_np == eid).any(axis=1)
            xs = x32[np.nonzero(rows)[0]] if rows.any() else x32[:8]
            r_in, r_gate, r_out = _gptq_expert(
                cfg, jnp.asarray(w_in[eid]), jnp.asarray(w_gate[eid]),
                jnp.asarray(w_out[eid]), xs, int(bits), ccfg)
            results["in"].append(r_in)
            results["gate"].append(r_gate)
            results["out"].append(r_out)
        experts_q[f"cls{ci}"] = _pack_class(results, meta.pack_block)
        pos += cnt

    new_params = {k: v for k, v in moe_params.items()
                  if k not in ("w_in", "w_gate", "w_out")}
    new_params["router"] = jnp.asarray(
        np.asarray(moe_params["router"])[:, np.asarray(order)])
    new_params["experts_q"] = experts_q
    return new_params


def assign_with_counts(costs: np.ndarray, bit_choices: Sequence[int],
                       counts: Sequence[int]) -> Tuple[np.ndarray, float]:
    """Exact expert->class assignment with fixed class sizes (uniform
    layout): linear-sum-assignment on a class-slot-expanded cost matrix."""
    from scipy.optimize import linear_sum_assignment
    n = costs.shape[0]
    assert sum(counts) == n
    col_bits = []
    cols = []
    for j, c in enumerate(counts):
        for _ in range(c):
            cols.append(costs[:, j])
            col_bits.append(bit_choices[j])
    cmat = np.stack(cols, axis=1)          # (n, n)
    rows, colsel = linear_sum_assignment(cmat)
    bits = np.zeros(n, np.int64)
    for r, c in zip(rows, colsel):
        bits[r] = col_bits[c]
    return bits, float(cmat[rows, colsel].sum())


def uniform_counts(per_layer_bits: List[np.ndarray],
                   bit_choices: Sequence[int]
                   ) -> Tuple[Tuple[int, ...], float]:
    """Median class sizes across layers, repaired to sum to E *without*
    silently exceeding the bit budget the per-layer optima realized.

    Rounding the per-class medians can leave ``sum(counts) != E``; absorbing
    the remainder into the widest class (the old behavior) could push the
    mean width past ``target_bits``. Instead, missing experts go to the
    narrowest class and surplus experts are removed widest-first; if the
    medians still overshoot the realized per-layer budget, experts are
    demoted widest->narrowest until within it. Returns ``(counts,
    achieved_bits)`` so the plan reports what the shared layout actually
    costs.
    """
    if not per_layer_bits:
        raise ValueError("uniform_counts: no per-layer allocations given "
                         "(the model has no captured MoE layers)")
    e = len(per_layer_bits[0])
    if any(len(lb) != e for lb in per_layer_bits):
        raise ValueError(
            "uniform_counts: per-layer allocations disagree on expert count: "
            f"{[len(lb) for lb in per_layer_bits]}")
    choices = [int(b) for b in bit_choices]
    med = [int(np.median([(lb == b).sum() for lb in per_layer_bits]))
           for b in choices]
    # realized per-layer budget: the mean total bits the optima spent
    budget = int(np.floor(np.mean([int(lb.sum()) for lb in per_layer_bits])))
    # class positions in ascending-width order (bit_choices itself is a
    # user-settable tuple with no ordering guarantee)
    asc = sorted(range(len(choices)), key=lambda j: choices[j])

    diff = e - sum(med)
    if diff > 0:
        med[asc[0]] += diff     # narrowest class: never raises the mean
    elif diff < 0:
        need = -diff            # drop surplus experts widest-first
        for j in reversed(asc):
            take = min(med[j], need)
            med[j] -= take
            need -= take
            if need == 0:
                break

    def total_bits():
        return sum(c * b for c, b in zip(med, choices))

    while total_bits() > budget:
        for k in range(len(asc) - 1, 0, -1):
            if med[asc[k]] > 0:       # demote one expert a single width
                med[asc[k]] -= 1      # step — the smallest decrement, so
                med[asc[k - 1]] += 1  # the layout lands closest to budget
                break
        else:
            raise ValueError(
                "uniform_counts: degenerate median layout — class counts "
                f"{tuple(med)} over bit choices {tuple(choices)} cannot meet "
                f"the realized per-layer budget of {budget} bits for {e} "
                "experts; widen bit_choices or use layout='per_layer'")
    achieved = total_bits() / e
    return tuple(med), achieved


# ------------------------------------------------------------ size account
def packed_expert_bytes(cfg: ModelConfig, meta: MoEQuantMeta) -> int:
    return packed_expert_bytes_dims(cfg.d_model, cfg.moe_d_ff, meta)


def packed_expert_bytes_dims(d: int, f: int, meta: MoEQuantMeta) -> int:
    """Config-free byte accounting (the plan stage has dims, not a cfg)."""
    gs = meta.group_size
    total = 0
    for bits, cnt in zip(meta.bit_classes, meta.class_counts):
        per_mat = (d * f * bits) // 8
        scale_rows = {  # groups along contraction dim
            "in": d // gs, "gate": d // gs, "out": f // gs}
        sz = 3 * per_mat
        sz += (scale_rows["in"] + scale_rows["gate"]) * f * 2 * \
            (2 if bits > 1 else 1)
        sz += scale_rows["out"] * d * 2 * (2 if bits > 1 else 1)
        total += cnt * sz
    return total


def dense_expert_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    return dense_expert_bytes_dims(cfg.num_experts, cfg.d_model,
                                   cfg.moe_d_ff, dtype_bytes)


def dense_expert_bytes_dims(num_experts: int, d: int, f: int,
                            dtype_bytes: int = 2) -> int:
    return num_experts * 3 * d * f * dtype_bytes
