"""Expert significance analysis (paper Sec. 3.2.1, Fig. 3).

Three per-expert statistics gathered on a calibration set:

* **access frequency**  ``phi_i = n_i / N`` — how often expert *i* lands in
  the top-k;
* **activation weight** ``w_i = (sum_j sigma_j) / N`` — the routing mass it
  receives;
* **quantization reconstruction error** ``eps_{i,j}`` — the Frobenius norm of
  the MoE-layer output change when expert *i* alone is quantized to *j* bits
  (Eq. 3).  Because the layer output is ``y = sum_i w_i E_i(t)``, quantizing
  a single expert perturbs it by ``w_t * (E_i(t) - Q_j(E_i)(t))`` over the
  tokens routed to *i* — so eps can be computed expert-locally without
  re-running the full network, which is what makes PMQ cheap.

All functions are model-agnostic: they consume router outputs / expert
activations, not model objects.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ExpertStats:
    """Accumulated router statistics for one MoE layer."""

    num_experts: int
    counts: np.ndarray = field(default=None)        # (E,) activations
    weight_sums: np.ndarray = field(default=None)   # (E,) routing mass
    ratio_samples: List[np.ndarray] = field(default_factory=list)  # w1/w0
    tokens_seen: int = 0

    def __post_init__(self):
        if self.counts is None:
            self.counts = np.zeros(self.num_experts, np.int64)
        if self.weight_sums is None:
            self.weight_sums = np.zeros(self.num_experts, np.float64)

    def update(self, topk_idx: jax.Array, topk_weights: jax.Array) -> None:
        """topk_idx/weights: (..., k) routing decisions for a token batch."""
        idx = np.asarray(topk_idx).reshape(-1)
        w = np.asarray(topk_weights, dtype=np.float64).reshape(-1)
        self.counts += np.bincount(idx, minlength=self.num_experts)
        self.weight_sums += np.bincount(idx, weights=w,
                                        minlength=self.num_experts)
        tk = np.asarray(topk_weights).reshape(-1, topk_weights.shape[-1])
        self.tokens_seen += tk.shape[0]
        if tk.shape[-1] >= 2:
            w0 = np.maximum(tk[:, 0], 1e-9)
            self.ratio_samples.append(tk[:, 1] / w0)

    @property
    def frequency(self) -> np.ndarray:
        """phi_i — normalized activation frequency."""
        n = max(self.tokens_seen, 1)
        return self.counts / n

    @property
    def mean_weight(self) -> np.ndarray:
        """w_i — mean routing weight (mass per calibration token)."""
        n = max(self.tokens_seen, 1)
        return self.weight_sums / n

    def ratio_median(self) -> float:
        """Calibrated ODP threshold mu = median(w1 / w0)  (paper Sec. 3.3.1)."""
        if not self.ratio_samples:
            return 0.0
        return float(np.median(np.concatenate(self.ratio_samples)))

    def significance(self, alpha: float, beta: float) -> np.ndarray:
        """phi^alpha * w^beta with epsilon flooring for never-hit experts."""
        phi = np.maximum(self.frequency, 1e-6)
        w = np.maximum(self.mean_weight, 1e-8)
        return phi ** alpha * w ** beta


def expert_quant_errors(
    expert_apply: Callable[[Dict, jax.Array], jax.Array],
    expert_params: Sequence[Dict],
    quantize_params: Callable[[Dict, int], Dict],
    calib_x: jax.Array,
    routed_weights: jax.Array,
    routed_mask: jax.Array,
    bit_choices: Sequence[int] = (1, 2, 3),
) -> np.ndarray:
    """eps_{i,j} per Eq. 3, computed expert-locally.

    Args:
      expert_apply: fn(params_i, x) -> expert output for token batch x.
      expert_params: per-expert parameter trees (len E).
      quantize_params: fn(params_i, bits) -> fake-quantized params.
      calib_x: (T, d) calibration tokens (layer inputs).
      routed_weights: (T, E) routing weight of each token for each expert
        (0 where not routed).
      routed_mask: (T, E) bool, token routed to expert.
      bit_choices: candidate bit-widths.

    Returns:
      eps (E, len(bit_choices)) float64.
    """
    num_e = len(expert_params)
    eps = np.zeros((num_e, len(bit_choices)))
    for i in range(num_e):
        mask = np.asarray(routed_mask[:, i])
        if mask.sum() == 0:
            continue  # never routed: zero reconstruction impact
        xs = calib_x[mask]
        ws = routed_weights[mask, i][:, None]
        ref = expert_apply(expert_params[i], xs)
        for bj, bits in enumerate(bit_choices):
            qp = quantize_params(expert_params[i], bits)
            out = expert_apply(qp, xs)
            delta = (ref - out).astype(jnp.float32) * ws
            eps[i, bj] = float(jnp.sqrt(jnp.sum(delta ** 2)))
    return eps


def expert_drop_fnorm(
    expert_apply: Callable[[Dict, jax.Array], jax.Array],
    expert_params: Sequence[Dict],
    calib_x: jax.Array,
    routed_weights: jax.Array,
    routed_mask: jax.Array,
) -> np.ndarray:
    """Fig. 3 red channel: layer-output F-norm change if expert dropped."""
    num_e = len(expert_params)
    out = np.zeros(num_e)
    for i in range(num_e):
        mask = np.asarray(routed_mask[:, i])
        if mask.sum() == 0:
            continue
        xs = calib_x[mask]
        ws = routed_weights[mask, i][:, None]
        y = expert_apply(expert_params[i], xs).astype(jnp.float32) * ws
        out[i] = float(jnp.sqrt(jnp.sum(y ** 2)))
    return out
