"""Deterministic synthetic data pipeline (offline container stand-in for C4).

Properties a production loader must have and this one does:

* **step-indexed determinism**: batch ``i`` is a pure function of
  ``(seed, host, step)`` via counter-based Philox — restart/elastic resume
  is exact with no state files;
* host sharding (each host materializes only its slice);
* background prefetch (thread + bounded queue) overlapping host->device;
* structured batches: next-token LM pairs, plus the modality stubs
  (frame/patch embeddings) the audio/VLM archs need.

The token stream is Zipf-distributed with Markov bigram structure so MoE
routers see a non-uniform, correlated distribution (expert stats in the MC
calibration are non-degenerate).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.config import ModelConfig, ShapeConfig


@dataclass
class SyntheticTextConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    zipf_a: float = 1.2


class SyntheticTokenDataset:
    """Deterministic random-access LM batches."""

    def __init__(self, cfg: SyntheticTextConfig,
                 model_cfg: Optional[ModelConfig] = None):
        self.cfg = cfg
        self.model_cfg = model_cfg
        assert cfg.global_batch % cfg.num_hosts == 0
        self.local_batch = cfg.global_batch // cfg.num_hosts

    def _rng(self, step: int) -> np.random.Generator:
        ss = np.random.SeedSequence(
            entropy=(self.cfg.seed, self.cfg.host_id, step))
        return np.random.Generator(np.random.Philox(ss))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng(step)
        b, s, v = self.local_batch, cfg.seq_len, cfg.vocab_size
        # zipf body + markov-ish repetition for router correlation
        base = rng.zipf(cfg.zipf_a, size=(b, s + 1)).astype(np.int64)
        tokens = (base % (v - 2)) + 1
        rep = rng.random((b, s + 1)) < 0.3
        rep[:, 0] = False
        idx = np.where(rep)
        tokens[idx] = tokens[idx[0], idx[1] - 1]
        out = {"tokens": tokens[:, :-1].astype(np.int32),
               "labels": tokens[:, 1:].astype(np.int32)}
        mc = self.model_cfg
        if mc is not None and mc.family == "encdec":
            out["enc_frames"] = rng.standard_normal(
                (b, mc.encoder_seq, mc.d_model)).astype(np.float32)
        if mc is not None and mc.family == "vlm":
            out["prefix_embeds"] = rng.standard_normal(
                (b, mc.num_prefix_tokens, mc.d_model)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Bounded background prefetch over a step-indexed dataset."""

    def __init__(self, dataset: SyntheticTokenDataset, start_step: int = 0,
                 depth: int = 2):
        self.dataset = dataset
        self.queue: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.dataset.batch(step)
            while not self._stop.is_set():
                try:
                    self.queue.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        return self.queue.get()

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2)


def calibration_batch(model_cfg: ModelConfig, n_sequences: int,
                      seq_len: int, seed: int = 1234) -> np.ndarray:
    """The MC calibration set (paper: 128 x 2048-token C4 samples)."""
    ds = SyntheticTokenDataset(SyntheticTextConfig(
        vocab_size=model_cfg.vocab_size, seq_len=seq_len,
        global_batch=n_sequences, seed=seed), model_cfg)
    return ds.batch(0)["tokens"]
