"""Perplexity + synthetic task-accuracy evaluation (LM-Eval stand-in).

The offline container has no WikiText2/C4; benches evaluate PPL on held-out
synthetic data (same distribution as training/calibration but disjoint
seeds) and a synthetic "retrieval accuracy" probe (repeat-last-seen-token)
that plays the role of the zero-shot suite: it degrades monotonically with
compression error, so the *relative* orderings the paper reports (PMQ vs
uniform vs Hessian, ODP with/without protection) are measurable.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.data.pipeline import SyntheticTextConfig, SyntheticTokenDataset
from repro.models.transformer import MCRuntime


def perplexity(model, params, tokens: jax.Array, *,
               mc: Optional[MCRuntime] = None, metas=None,
               batch_size: int = 4) -> float:
    """Token-level PPL of next-token prediction.

    ``metas`` is a legacy kwarg for heterogeneous per-layer quantization;
    it folds into the uniform ``MCRuntime`` path (``layer_metas``) that
    ``model.forward`` consumes for both layouts.
    """
    if metas is not None:
        odp = mc.odp if mc else None
        mc = (MCRuntime(odp=odp, layer_metas=tuple(metas))
              if "moe_layers" in params
              else MCRuntime(odp=odp, quant_meta=metas[0]))
    total_nll, total_tok = 0.0, 0
    for i in range(0, tokens.shape[0], batch_size):
        tb = tokens[i:i + batch_size]
        logits, _, _ = model.forward(params, tb, mc=mc)
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32))
        tgt = tb[:, 1:]
        nll = -jnp.take_along_axis(lp, tgt[..., None], -1)[..., 0]
        total_nll += float(nll.sum())
        total_tok += int(np.prod(tgt.shape))
    return float(np.exp(total_nll / max(total_tok, 1)))


def eval_tokens(cfg: ModelConfig, n_seq: int = 8, seq_len: int = 128,
                seed: int = 777) -> jax.Array:
    ds = SyntheticTokenDataset(SyntheticTextConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len, global_batch=n_seq,
        seed=seed))
    return jnp.asarray(ds.batch(0)["tokens"])


def recall_probe_accuracy(model, params, cfg: ModelConfig, *,
                          mc: Optional[MCRuntime] = None, n: int = 16,
                          seq_len: int = 48, seed: int = 31) -> float:
    """Synthetic benchmark: can the (untrained or compressed) model keep a
    repeated marker token's logit ranking stable? Used for *relative*
    comparisons between compression settings, mirroring the paper's
    accuracy-delta reporting."""
    rng = np.random.RandomState(seed)
    toks = rng.randint(1, cfg.vocab_size, size=(n, seq_len)).astype(np.int32)
    marker = rng.randint(1, cfg.vocab_size, size=(n,)).astype(np.int32)
    toks[:, seq_len // 3] = marker
    toks[:, -1] = marker
    logits, _, _ = model.forward(params, jnp.asarray(toks), mc=mc)
    last = logits[:, -2].astype(jnp.float32)      # predicting final marker
    ranks = (last >= jnp.take_along_axis(
        last, jnp.asarray(marker)[:, None], -1)).sum(-1)
    return float((ranks <= max(cfg.vocab_size // 20, 5)).mean())
