"""Pallas TPU kernel for 1-bit (sign) expert GEMM.

Shares the tiled dequant-GEMM machinery with ``quant_matmul`` — the 1-bit
path unpacks a (bk/8, bn) bit-plane tile, maps {0,1} -> {-1,+1}, applies the
per-group l1 scale, and feeds the MXU.  See DESIGN.md §3 for why the paper's
add/sub trick is replaced by a scaled matmul on TPU (bandwidth, not
multiplier count, is the binding resource).
"""
from repro.kernels.quant_matmul.kernel import quant_matmul_pallas  # noqa: F401


def binary_matmul_pallas(x, plane, scales, **kw):
    return quant_matmul_pallas(x, (plane,), scales, None, bits=1, **kw)
