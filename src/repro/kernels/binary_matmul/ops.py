"""Public op: 1-bit sign-quantized matmul (MC's 1-bit experts)."""
import jax.numpy as jnp

from repro.kernels.quant_matmul.ops import quant_matmul


def binary_matmul(x, plane, scales, *, group_size=128, pack_block=128,
                  impl="auto", out_dtype=jnp.float32):
    return quant_matmul(x, (plane,), scales, None, bits=1,
                        group_size=group_size, pack_block=pack_block,
                        impl=impl, out_dtype=out_dtype)
