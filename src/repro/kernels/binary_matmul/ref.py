"""Pure-jnp oracle for the 1-bit GEMM."""
import jax.numpy as jnp

from repro.kernels.quant_matmul.ref import quant_matmul_ref


def binary_matmul_ref(x, plane, scales, *, group_size, pack_block,
                      out_dtype=jnp.float32):
    return quant_matmul_ref(x, (plane,), scales, None, bits=1,
                            group_size=group_size, pack_block=pack_block,
                            out_dtype=out_dtype)
