"""Shared helpers for the Pallas kernels.

Kernel weight layout
--------------------
The GEMM kernels consume packed sub-byte weights in a **block-local
deinterleaved** layout: within every ``pack_block`` logical rows (the kernel's
K tile), byte-row ``b`` packs logical rows ``{b + p * pack_block//per}`` at
bit-shift ``p*bits``.  In-kernel unpacking is then `per` static shifts plus a
single sublane-axis concatenate — no cross-lane shuffles and no reshapes that
Mosaic would have to relayout.  The layout transform runs offline in XLA at
pack time (:func:`pack_kernel_layout`).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Default tiling — MXU-aligned (multiples of 128 lanes / 8 sublanes).
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128  # == pack_block == quant group size by default


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _plane_split(bits: int) -> Tuple[int, ...]:
    """Bit-widths of the packed planes for a logical width."""
    if bits == 3:
        return (2, 1)
    assert bits in (1, 2, 4, 8)
    return (bits,)


def pack_plane_kernel_layout(codes: jax.Array, plane_bits: int,
                             pack_block: int) -> jax.Array:
    """Pack one plane (values < 2**plane_bits) deinterleaved per K block."""
    if plane_bits == 8:
        return codes.astype(jnp.uint8)
    per = 8 // plane_bits
    d_in, d_out = codes.shape
    assert d_in % pack_block == 0 and pack_block % per == 0
    sub = pack_block // per
    c = codes.reshape(d_in // pack_block, per, sub, d_out).astype(jnp.uint32)
    c = c.transpose(0, 2, 1, 3)              # (KB, sub, per, N)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * plane_bits)[None, None, :, None]
    packed = jnp.sum(c << shifts, axis=2)    # (KB, sub, N)
    return packed.reshape(d_in // per, d_out).astype(jnp.uint8)


def pack_kernel_layout(codes: jax.Array, bits: int, pack_block: int
                       ) -> Tuple[jax.Array, ...]:
    """Split ``bits`` codes into planes and pack each for the kernel."""
    if bits == 3:
        lo = codes & jnp.uint8(0x3)
        hi = (codes >> 2) & jnp.uint8(0x1)
        return (pack_plane_kernel_layout(lo, 2, pack_block),
                pack_plane_kernel_layout(hi, 1, pack_block))
    return (pack_plane_kernel_layout(codes, bits, pack_block),)


def unpack_plane_reference(plane: jax.Array, plane_bits: int, d_in: int,
                           pack_block: int) -> jax.Array:
    """XLA inverse of :func:`pack_plane_kernel_layout` (tests / CPU path)."""
    if plane_bits == 8:
        return plane
    per = 8 // plane_bits
    sub = pack_block // per
    d_out = plane.shape[-1]
    p = plane.reshape(d_in // pack_block, sub, d_out).astype(jnp.uint32)
    mask = jnp.uint32(2 ** plane_bits - 1)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * plane_bits)[None, None, :, None]
    vals = (p[:, :, None, :] >> shifts) & mask          # (KB, sub, per, N)
    vals = vals.transpose(0, 2, 1, 3)                   # (KB, per, sub, N)
    return vals.reshape(d_in, d_out).astype(jnp.uint8)


def unpack_kernel_layout(planes: Tuple[jax.Array, ...], bits: int, d_in: int,
                         pack_block: int) -> jax.Array:
    if bits == 3:
        lo = unpack_plane_reference(planes[0], 2, d_in, pack_block)
        hi = unpack_plane_reference(planes[1], 1, d_in, pack_block)
        return (lo | (hi << 2)).astype(jnp.uint8)
    return unpack_plane_reference(planes[0], bits, d_in, pack_block)


def unpack_tile(plane_tile: jax.Array, plane_bits: int) -> jax.Array:
    """In-kernel unpack of one deinterleaved K-tile -> (bk, bn) int32.

    ``plane_tile``: (bk // per, bn) uint8 slice of a kernel-layout plane.
    Static `per`-way shift loop + one sublane concat.
    """
    if plane_bits == 8:
        return plane_tile.astype(jnp.int32)
    per = 8 // plane_bits
    mask = jnp.int32(2 ** plane_bits - 1)
    p32 = plane_tile.astype(jnp.int32)
    parts = [(p32 >> (i * plane_bits)) & mask for i in range(per)]
    return jnp.concatenate(parts, axis=0)


def pad_to_multiple(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


@functools.lru_cache(maxsize=None)
def choose_bm(m_hint: int) -> int:
    """Pick an M tile: decode uses tiny M, keep it sublane-aligned."""
    for bm in (8, 16, 32, 64, 128):
        if m_hint <= bm:
            return bm
    return DEFAULT_BM


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def product(xs) -> int:
    return int(np.prod(list(xs))) if xs else 1
