"""Shared helpers for the Pallas kernels.

Kernel weight layout
--------------------
The GEMM kernels consume packed sub-byte weights in a **block-local
deinterleaved** layout: within every ``pack_block`` logical rows (the kernel's
K tile), byte-row ``b`` packs logical rows ``{b + p * pack_block//per}`` at
bit-shift ``p*bits``.  In-kernel unpacking is then `per` static shifts plus a
single sublane-axis concatenate — no cross-lane shuffles and no reshapes that
Mosaic would have to relayout.  The layout transform runs offline in XLA at
pack time (:func:`pack_kernel_layout`).
"""
from __future__ import annotations

import contextlib
import functools
import threading
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Default tiling — MXU-aligned (multiples of 128 lanes / 8 sublanes).
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128  # == pack_block == quant group size by default


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _plane_split(bits: int) -> Tuple[int, ...]:
    """Bit-widths of the packed planes for a logical width."""
    if bits == 3:
        return (2, 1)
    assert bits in (1, 2, 4, 8)
    return (bits,)


def pack_plane_kernel_layout(codes: jax.Array, plane_bits: int,
                             pack_block: int) -> jax.Array:
    """Pack one plane (values < 2**plane_bits) deinterleaved per K block."""
    if plane_bits == 8:
        return codes.astype(jnp.uint8)
    per = 8 // plane_bits
    d_in, d_out = codes.shape
    assert d_in % pack_block == 0 and pack_block % per == 0
    sub = pack_block // per
    c = codes.reshape(d_in // pack_block, per, sub, d_out).astype(jnp.uint32)
    c = c.transpose(0, 2, 1, 3)              # (KB, sub, per, N)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * plane_bits)[None, None, :, None]
    packed = jnp.sum(c << shifts, axis=2)    # (KB, sub, N)
    return packed.reshape(d_in // per, d_out).astype(jnp.uint8)


def pack_kernel_layout(codes: jax.Array, bits: int, pack_block: int
                       ) -> Tuple[jax.Array, ...]:
    """Split ``bits`` codes into planes and pack each for the kernel."""
    if bits == 3:
        lo = codes & jnp.uint8(0x3)
        hi = (codes >> 2) & jnp.uint8(0x1)
        return (pack_plane_kernel_layout(lo, 2, pack_block),
                pack_plane_kernel_layout(hi, 1, pack_block))
    return (pack_plane_kernel_layout(codes, bits, pack_block),)


def unpack_plane_reference(plane: jax.Array, plane_bits: int, d_in: int,
                           pack_block: int) -> jax.Array:
    """XLA inverse of :func:`pack_plane_kernel_layout` (tests / CPU path)."""
    if plane_bits == 8:
        return plane
    per = 8 // plane_bits
    sub = pack_block // per
    d_out = plane.shape[-1]
    p = plane.reshape(d_in // pack_block, sub, d_out).astype(jnp.uint32)
    mask = jnp.uint32(2 ** plane_bits - 1)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * plane_bits)[None, None, :, None]
    vals = (p[:, :, None, :] >> shifts) & mask          # (KB, sub, per, N)
    vals = vals.transpose(0, 2, 1, 3)                   # (KB, per, sub, N)
    return vals.reshape(d_in, d_out).astype(jnp.uint8)


def unpack_kernel_layout(planes: Tuple[jax.Array, ...], bits: int, d_in: int,
                         pack_block: int) -> jax.Array:
    if bits == 3:
        lo = unpack_plane_reference(planes[0], 2, d_in, pack_block)
        hi = unpack_plane_reference(planes[1], 1, d_in, pack_block)
        return (lo | (hi << 2)).astype(jnp.uint8)
    return unpack_plane_reference(planes[0], bits, d_in, pack_block)


def unpack_tile(plane_tile: jax.Array, plane_bits: int) -> jax.Array:
    """In-kernel unpack of one deinterleaved K-tile -> (bk, bn) int32.

    ``plane_tile``: (bk // per, bn) uint8 slice of a kernel-layout plane.
    Static `per`-way shift loop + one sublane concat.
    """
    if plane_bits == 8:
        return plane_tile.astype(jnp.int32)
    per = 8 // plane_bits
    mask = jnp.int32(2 ** plane_bits - 1)
    p32 = plane_tile.astype(jnp.int32)
    parts = [(p32 >> (i * plane_bits)) & mask for i in range(per)]
    return jnp.concatenate(parts, axis=0)


def unpack_tile_blocks(plane_tile: jax.Array, plane_bits: int,
                       pack_block: int) -> jax.Array:
    """In-kernel unpack of a K-tile spanning >= 1 deinterleave blocks.

    The kernel layout deinterleaves per ``pack_block`` logical rows; a tile
    of ``q * pack_block`` logical rows holds ``q`` stacked blocks.  Static
    per-block :func:`unpack_tile` + one concat keeps it Mosaic-legal.
    """
    if plane_bits == 8:
        return plane_tile.astype(jnp.int32)
    per = 8 // plane_bits
    rows = pack_block // per
    nb = plane_tile.shape[0] // rows
    if nb == 1:
        return unpack_tile(plane_tile, plane_bits)
    parts = [unpack_tile(plane_tile[i * rows:(i + 1) * rows], plane_bits)
             for i in range(nb)]
    return jnp.concatenate(parts, axis=0)


def dequant_tile(plane_tiles, scale_tile, zero_tile, *, bits: int, bk: int,
                 group_size: int, pack_block: int, compute_dtype):
    """Unpack + affine-dequant one (bk, bn) weight tile (shared by the
    quant_matmul and fused moe_ffn kernels; ``bk`` may span several
    ``pack_block`` deinterleave blocks)."""
    split = _plane_split(bits)
    if bits == 3:
        lo = unpack_tile_blocks(plane_tiles[0], 2, pack_block)
        hi = unpack_tile_blocks(plane_tiles[1], 1, pack_block)
        codes = lo + (hi << 2)
    else:
        codes = unpack_tile_blocks(plane_tiles[0], split[0], pack_block)
    codes = codes.astype(jnp.float32)
    n_g = bk // group_size
    bn = codes.shape[-1]
    if bits == 1:
        pm1 = codes * 2.0 - 1.0
        if n_g == 1:
            w = pm1 * scale_tile[0][None, :]
        else:
            w = (pm1.reshape(n_g, group_size, bn)
                 * scale_tile[:, None, :]).reshape(bk, bn)
    else:
        if n_g == 1:
            w = (codes - zero_tile[0][None, :]) * scale_tile[0][None, :]
        else:
            w = ((codes.reshape(n_g, group_size, bn)
                  - zero_tile[:, None, :])
                 * scale_tile[:, None, :]).reshape(bk, bn)
    return w.astype(compute_dtype)


def plane_suffixes(bits: int) -> Tuple[str, ...]:
    """Packed-plane key suffixes (``p0``[, ``p1``]) for one bit width —
    static, so MoE layers never have to scan param dicts for plane keys."""
    return tuple(f"p{i}" for i in range(len(_plane_split(bits))))


def pad_to_multiple(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


@functools.lru_cache(maxsize=None)
def choose_bm(m_hint: int) -> int:
    """Pick an M tile: decode uses tiny M, keep it sublane-aligned."""
    for bm in (8, 16, 32, 64, 128):
        if m_hint <= bm:
            return bm
    return DEFAULT_BM


@functools.lru_cache(maxsize=None)
def choose_ffn_blocks(m_hint: int, d_ff: int, pack_block: int
                      ) -> Tuple[int, int]:
    """(bm, bf) tiles for the fused expert-FFN kernel.

    bm follows :func:`choose_bm` (decode regime M in 8..128).  bf — the
    intermediate-width tile shared by the h/g accumulators and the second
    GEMM's K step — must be a multiple of ``pack_block`` (the packed
    deinterleave unit of the w_out planes) that divides ``d_ff``.  Small
    decode tiles take a narrower bf so the dead-tile skip window stays
    fine-grained; full tiles take the widest bf <= 512 to amortize the
    second GEMM's accumulator traffic (table in docs/kernels.md).
    """
    bm = choose_bm(m_hint)
    target = 256 if bm <= 32 else 512
    bf = pack_block
    q = 2
    while (pack_block * q <= min(target, d_ff)
           and d_ff % (pack_block * q) == 0):
        bf = pack_block * q
        q *= 2
    return bm, bf


def fit_block(n: int, requested: int, align: int = 8) -> int:
    """Largest divisor of ``n`` that is <= ``requested`` and a multiple of
    ``align``; 0 if none exists (caller should pad instead)."""
    for cand in range(min(requested, n), align - 1, -1):
        if n % cand == 0 and cand % align == 0:
            return cand
    return 0


# ------------------------------------------------------ impl override hook
_impl_override = threading.local()


def impl_override():
    """Active kernel-impl override (None | 'pallas' | 'interpret' | 'ref'):
    what ``impl='auto'`` ops resolve to while :func:`override_impl` is
    entered.  Lets tests and launch-count probes force the Pallas lowering
    on CPU hosts without threading an impl argument through the model."""
    return getattr(_impl_override, "value", None)


@contextlib.contextmanager
def override_impl(value: str):
    prev = impl_override()
    _impl_override.value = value
    try:
        yield
    finally:
        _impl_override.value = prev


# ------------------------------------------------------- launch accounting
def count_pallas_calls(fn, *args, **kwargs) -> int:
    """Number of ``pallas_call`` equations in ``fn``'s jaxpr (recursing
    through nested jaxprs: jit/scan/cond/...).  This is the per-trace
    kernel *launch-site* count — the probe the tests and benchmarks use to
    assert the fused MoE path launches one kernel per layer instead of
    three per bit-class."""
    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)
    return _count_jaxpr(jaxpr.jaxpr)


def _count_jaxpr(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            n += _count_param(v)
    return n


def _count_param(v) -> int:
    if hasattr(v, "jaxpr"):          # ClosedJaxpr
        return _count_jaxpr(v.jaxpr)
    if hasattr(v, "eqns"):           # raw Jaxpr
        return _count_jaxpr(v)
    if isinstance(v, (tuple, list)):
        return sum(_count_param(x) for x in v)
    return 0


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def product(xs) -> int:
    return int(np.prod(list(xs))) if xs else 1
