"""Pallas TPU kernel: fused grouped quantized-MoE expert FFN.

One ``pallas_call`` per MoE layer computes the full gated FFN

    y = (act(x @ Wg) * (x @ Wi)) @ Wo

for **every expert of every bit class**, dequantizing all three packed
projections in-kernel.  This replaces the staged path's three
``quant_matmul`` launches per bit class (9 launches per layer at 3
classes) and its HBM round-trip of the intermediate ``h``.

Grid and tiling
---------------
::

    grid = (E, M/bm, F/bf, D/bk)          # k innermost, then f, m, e

    per (e, m):   y_acc (bm, D) f32 accumulator lives across (f, k)
    per (e,m,f):  h_acc/g_acc (bm, bf) f32 accumulate the first GEMM
                  over k; at k == nk-1 the gate activation fires and the
                  second GEMM folds the (bm, bf) tile into y_acc.

* the ``x`` tile ``(bm, bk)`` is indexed ``(e, m, k)`` — constant over
  ``f``, so Pallas fetches it **once** per (e, m, k) and both the in- and
  gate-projections consume the same VMEM tile;
* the intermediate ``h`` never exists outside VMEM scratch;
* ``bk == pack_block``: each in/gate weight K-step is exactly one
  deinterleaved pack block; the w_out tile spans ``bf/pack_block`` blocks
  (``common.unpack_tile_blocks``).

Grouping over bit classes
-------------------------
Experts are class-sorted; grid dim 0 sweeps the **global** expert index.
Each class contributes its own packed-plane/scale refs (static shapes per
class) and a static segment ``[e0, e0+cnt)``; the kernel selects the
segment's refs with ``pl.when`` on the expert id.  Out-of-segment index
maps collapse to block (clamped-expert, 0, 0) so a class's planes are
fetched only while the sweep is inside its segment (one stale-block fetch
per boundary).

Dead-slot skipping
------------------
``counts`` (scalar-prefetched, one int32 per expert) gives the number of
live leading capacity rows.  M-tiles past the count skip both GEMMs
(``pl.when``) — empty/underfull experts cost no MXU work — and output
rows ``>= counts[e]`` are written as zeros (the contract the XLA oracle
``ref.moe_ffn_ref`` mirrors).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import _plane_split, dequant_tile
from repro.kernels.moe_ffn.ref import ACTIVATIONS


@dataclass(frozen=True)
class _ClassSpec:
    """Static per-bit-class segment descriptor (ref layout bookkeeping)."""

    bits: int
    e0: int          # first global (class-sorted) expert index
    cnt: int         # experts in the class
    n_planes: int    # packed planes per projection (2 for 3-bit, else 1)
    has_zeros: bool  # affine zero-points present (bits > 1)

    @property
    def refs_per_tag(self) -> int:
        return self.n_planes + 1 + (1 if self.has_zeros else 0)

    @property
    def n_refs(self) -> int:
        return 3 * self.refs_per_tag


def _class_specs(meta) -> Tuple[_ClassSpec, ...]:
    out = []
    for bits, e0, cnt in meta.class_slices():
        out.append(_ClassSpec(bits=int(bits), e0=int(e0), cnt=int(cnt),
                              n_planes=len(_plane_split(bits)),
                              has_zeros=bits > 1))
    return tuple(out)


def _read(ref):
    return ref[...][0]          # drop the leading expert block dim


def _moe_ffn_kernel(counts_ref, x_ref, *refs, classes: Tuple[_ClassSpec, ...],
                    act: str, bm: int, bf: int, bk: int, d: int,
                    group_size: int, pack_block: int, nf: int, nk: int,
                    compute_dtype):
    out_ref = refs[-4]
    h_acc, g_acc, y_acc = refs[-3], refs[-2], refs[-1]
    e = pl.program_id(0)
    m = pl.program_id(1)
    f = pl.program_id(2)
    k = pl.program_id(3)
    count = counts_ref[e]
    live = (m * bm) < count
    act_fn = ACTIVATIONS[act]

    @pl.when(jnp.logical_and(f == 0, k == 0))
    def _init_y():
        y_acc[...] = jnp.zeros_like(y_acc)

    @pl.when(k == 0)
    def _init_hg():
        h_acc[...] = jnp.zeros_like(h_acc)
        g_acc[...] = jnp.zeros_like(g_acc)

    x_tile = _read(x_ref).astype(compute_dtype)          # (bm, bk)

    off = 0
    for cls in classes:
        base = off
        off += cls.n_refs
        seg = jnp.logical_and(e >= cls.e0, e < cls.e0 + cls.cnt)

        def tag_refs(tag_idx, base=base, cls=cls):
            lo = base + tag_idx * cls.refs_per_tag
            planes = tuple(refs[lo + i] for i in range(cls.n_planes))
            scale = refs[lo + cls.n_planes]
            zero = refs[lo + cls.n_planes + 1] if cls.has_zeros else None
            return planes, scale, zero

        @pl.when(jnp.logical_and(live, seg))
        def _first_gemm(cls=cls, tag_refs=tag_refs):
            for tag_idx, acc in ((0, h_acc), (1, g_acc)):
                planes, scale, zero = tag_refs(tag_idx)
                w = dequant_tile(
                    tuple(_read(p) for p in planes), _read(scale),
                    _read(zero) if zero is not None else None,
                    bits=cls.bits, bk=bk, group_size=group_size,
                    pack_block=pack_block, compute_dtype=compute_dtype)
                acc[...] += jnp.dot(x_tile, w,
                                    preferred_element_type=jnp.float32)

        @pl.when(jnp.logical_and(jnp.logical_and(live, seg), k == nk - 1))
        def _second_gemm(cls=cls, tag_refs=tag_refs):
            planes, scale, zero = tag_refs(2)
            wo = dequant_tile(
                tuple(_read(p) for p in planes), _read(scale),
                _read(zero) if zero is not None else None,
                bits=cls.bits, bk=bf, group_size=group_size,
                pack_block=pack_block, compute_dtype=compute_dtype)
            a = (act_fn(g_acc[...]) * h_acc[...]).astype(compute_dtype)
            y_acc[...] += jnp.dot(a, wo, preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(f == nf - 1, k == nk - 1))
    def _write():
        rows = m * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
        y = jnp.where(rows < count, y_acc[...], 0.0)
        out_ref[...] = y.astype(out_ref.dtype)[None]


def moe_ffn_pallas(x: jax.Array, class_args, counts: jax.Array, *,
                   meta, act: str, block_m: int, block_f: int,
                   compute_dtype=jnp.float32, out_dtype=jnp.float32,
                   interpret: bool = False) -> jax.Array:
    """x: (E, M, D) class-sorted; class_args: per-class flat ref groups.

    ``class_args[ci]`` is the tuple ``(in planes..., in_s, [in_z],
    gate planes..., gate_s, [gate_z], out planes..., out_s, [out_z])``
    with kernel-layout packed planes (``meta.pack_block`` deinterleave).
    ``counts``: (E,) int32 live leading rows per expert.
    """
    e, m, d = x.shape
    gs, pack_block = meta.group_size, meta.pack_block
    classes = _class_specs(meta)
    f_dim = class_args[0][classes[0].n_planes].shape[-1]   # in_s: (cnt,.,F)
    bm, bf, bk = block_m, block_f, pack_block
    assert m % bm == 0 and f_dim % bf == 0 and d % bk == 0, (m, f_dim, d)
    assert bf % pack_block == 0 and bk % gs == 0 and bf % gs == 0
    nm, nf, nk = m // bm, f_dim // bf, d // bk
    grid = (e, nm, nf, nk)

    def im_x(e_, m_, f_, k_, *_):
        return (e_, m_, k_)

    def im_out(e_, m_, f_, k_, *_):
        return (e_, m_, 0)

    def seg_idx(cls, e_):
        ins = jnp.logical_and(e_ >= cls.e0, e_ < cls.e0 + cls.cnt)
        ec = jnp.clip(e_ - cls.e0, 0, cls.cnt - 1)
        return ins, ec

    def im_kf(cls):
        # in/gate tiles advance with (k, f) inside the class segment and
        # pin to block (ec, 0, 0) outside it -> no out-of-segment traffic
        def im(e_, m_, f_, k_, *_):
            ins, ec = seg_idx(cls, e_)
            return (ec, jnp.where(ins, k_, 0), jnp.where(ins, f_, 0))
        return im

    def im_f(cls):
        def im(e_, m_, f_, k_, *_):
            ins, ec = seg_idx(cls, e_)
            return (ec, jnp.where(ins, f_, 0), 0)
        return im

    in_specs = [pl.BlockSpec((1, bm, bk), im_x)]
    args = [x]
    for cls, cargs in zip(classes, class_args):
        split = _plane_split(cls.bits)
        it = iter(cargs)
        for tag in ("in", "gate", "out"):
            first = tag != "out"
            for pb_bits in split:
                plane = next(it)
                if first:
                    shape = (1, bk * pb_bits // 8, bf)
                    in_specs.append(pl.BlockSpec(shape, im_kf(cls)))
                else:
                    shape = (1, bf * pb_bits // 8, d)
                    in_specs.append(pl.BlockSpec(shape, im_f(cls)))
                args.append(plane)
            n_sz = 1 + (1 if cls.has_zeros else 0)
            for _ in range(n_sz):
                sz = next(it)
                if first:
                    in_specs.append(
                        pl.BlockSpec((1, bk // gs, bf), im_kf(cls)))
                else:
                    in_specs.append(
                        pl.BlockSpec((1, bf // gs, d), im_f(cls)))
                args.append(sz.astype(jnp.float32))
        assert next(it, None) is None

    kern = functools.partial(
        _moe_ffn_kernel, classes=classes, act=act, bm=bm, bf=bf, bk=bk,
        d=d, group_size=gs, pack_block=pack_block, nf=nf, nk=nk,
        compute_dtype=compute_dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, d), im_out),
        scratch_shapes=[
            pltpu.VMEM((bm, bf), jnp.float32),
            pltpu.VMEM((bm, bf), jnp.float32),
            pltpu.VMEM((bm, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((e, m, d), out_dtype),
        interpret=interpret,
    )(counts.astype(jnp.int32), *args)
