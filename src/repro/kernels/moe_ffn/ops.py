"""Public op: fused grouped quantized expert FFN (one launch per layer).

``moe_ffn_quant`` consumes the class-sorted packed expert params exactly
as they sit in a compressed artifact (``experts_q = {"cls0": {...}, ...}``)
plus the per-expert live-row counts, and returns the gated-FFN output for
every expert in a **single** ``pallas_call`` — the staged alternative
launches ``3 x num_classes`` ``quant_matmul`` kernels and round-trips the
intermediate activation through HBM.

Dispatches to the Pallas TPU kernel on TPU backends (or in interpret mode
for CPU validation) and to the XLA reference otherwise, honoring
``kernels.common.override_impl`` so tests/benchmarks can force either
lowering.
"""
from __future__ import annotations

import functools
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.common import plane_suffixes
from repro.kernels.moe_ffn.kernel import moe_ffn_pallas
from repro.kernels.moe_ffn.ref import moe_ffn_ref


def _use_pallas(mode: str) -> bool:
    if mode == "auto":
        return common.on_tpu()
    return mode in ("pallas", "interpret")


def class_arg_lists(experts_q: Dict, meta) -> List[List[jax.Array]]:
    """Flatten ``experts_q`` into the kernel's per-class ref order using
    the static plane suffixes (no param-dict key scans)."""
    out = []
    for ci, (bits, _, _) in enumerate(meta.class_slices()):
        w = experts_q[f"cls{ci}"]
        flat: List[jax.Array] = []
        for tag in ("in", "gate", "out"):
            for s in plane_suffixes(bits):
                flat.append(w[f"{tag}_{s}"])
            flat.append(w[f"{tag}_s"])
            if bits > 1:
                flat.append(w[f"{tag}_z"])
        out.append(flat)
    return out


def _validate(d: int, f: int, meta) -> None:
    pb, gs = meta.pack_block, meta.group_size
    if d % pb:
        raise ValueError(
            f"moe_ffn_quant: d_model={d} is not a multiple of "
            f"pack_block={pb}; the packed plane layout fixes the K tiling "
            "— repack with a pack_block dividing d_model")
    if f % pb:
        raise ValueError(
            f"moe_ffn_quant: moe_d_ff={f} is not a multiple of "
            f"pack_block={pb}; repack with a pack_block dividing moe_d_ff")
    if pb % gs:
        raise ValueError(
            f"moe_ffn_quant: pack_block={pb} must be a multiple of "
            f"group_size={gs} so scale rows tile with the K step")


def moe_ffn_quant(x: jax.Array, experts_q: Dict, counts: jax.Array, *,
                  meta, act: str, impl: str = "auto", block_m: int = 0,
                  block_f: int = 0, out_dtype=jnp.float32) -> jax.Array:
    """Fused ``y[e] = (act(x[e] @ Wg[e]) * (x[e] @ Wi[e])) @ Wo[e]``.

    Args:
        x: (E, M, D) class-sorted expert token blocks (capacity slots).
        experts_q: packed per-class planes, the artifact layout
            (``cls{ci}`` -> ``{in,gate,out}_{p*,s,z}``).
        counts: (E,) int32 — live leading rows per expert; output rows
            ``>= counts[e]`` are zero and dead M-tiles skip their GEMMs.
        meta: :class:`repro.models.layers.moe.MoEQuantMeta` (static).
        act: gate activation name (``cfg.mlp_act``).
    """
    # resolve the thread-local override *outside* the jit boundary so the
    # resolved impl is part of the trace cache key
    if impl == "auto":
        impl = common.impl_override() or "auto"
    return _moe_ffn_quant(x, experts_q, counts, meta=meta, act=act,
                          impl=impl, block_m=block_m, block_f=block_f,
                          out_dtype=out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("meta", "act", "impl", "block_m", "block_f",
                     "out_dtype"))
def _moe_ffn_quant(x: jax.Array, experts_q: Dict, counts: jax.Array, *,
                   meta, act: str, impl: str, block_m: int,
                   block_f: int, out_dtype) -> jax.Array:
    e, m, d = x.shape
    f_dim = experts_q["cls0"]["in_s"].shape[-1]
    _validate(d, f_dim, meta)

    if not _use_pallas(impl):
        classes = [experts_q[f"cls{ci}"]
                   for ci in range(len(meta.bit_classes))]
        return moe_ffn_ref(x, classes, counts, meta=meta, act=act,
                           out_dtype=out_dtype)
    class_args = class_arg_lists(experts_q, meta)

    interpret = (impl == "interpret") or not common.on_tpu()
    bm, bf = common.choose_ffn_blocks(m, f_dim, meta.pack_block)
    if block_m:
        bm = block_m
    if block_f:
        bf = block_f
    xp = common.pad_to_multiple(x, 1, bm)
    out = moe_ffn_pallas(xp, class_args, counts, meta=meta, act=act,
                         block_m=bm, block_f=bf, out_dtype=out_dtype,
                         interpret=interpret)
    return out[:, :m, :]
