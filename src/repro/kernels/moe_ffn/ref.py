"""Pure-jnp oracle for the fused grouped quantized expert-FFN kernel.

Semantics contract (shared with the Pallas kernel):

* input ``x`` is the class-sorted expert token matrix ``(E, M, D)`` — the
  gathered capacity slots of every expert, experts ordered by ascending
  bit class exactly as the packed planes are stored;
* per expert, rows ``>= counts[e]`` of the output are **zero** (dead
  capacity slots are skipped by the kernel, so their contents must be
  pinned, not left unspecified);
* each live row is the gated FFN ``y = (act(x @ Wg) * (x @ Wi)) @ Wo``
  with all three projections dequantized from that expert's packed planes.
"""
from __future__ import annotations

import functools
from typing import Dict, Sequence

import jax
import jax.numpy as jnp

from repro.kernels.common import plane_suffixes
from repro.kernels.quant_matmul.ref import dequant_ref

ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def _dequant_class(w: Dict, tag: str, bits: int, d_in: int, group_size: int,
                   pack_block: int, dtype):
    """Dequantize one class's (cnt, d_in, d_out) projection stack."""
    planes = tuple(w[f"{tag}_{s}"] for s in plane_suffixes(bits))
    scales = w[f"{tag}_s"]
    zeros = w.get(f"{tag}_z")
    deq = functools.partial(dequant_ref, bits=bits, group_size=group_size,
                            d_in=d_in, pack_block=pack_block, dtype=dtype)
    if zeros is None:
        return jax.vmap(lambda ps, s: deq(ps, s, None))(planes, scales)
    return jax.vmap(lambda ps, s, z: deq(ps, s, z))(planes, scales, zeros)


def moe_ffn_ref(x: jax.Array, class_params: Sequence[Dict],
                counts: jax.Array, *, meta, act: str,
                compute_dtype=jnp.float32,
                out_dtype=jnp.float32) -> jax.Array:
    """x: (E, M, D) class-sorted expert rows -> (E, M, D)."""
    e, m, d = x.shape
    act_fn = ACTIVATIONS[act]
    gs, pb = meta.group_size, meta.pack_block
    outs = []
    for ci, (bits, e0, cnt) in enumerate(meta.class_slices()):
        w = class_params[ci]
        f = w["in_s"].shape[-1]
        xc = x[e0:e0 + cnt].astype(compute_dtype)
        wi = _dequant_class(w, "in", bits, d, gs, pb, compute_dtype)
        wg = _dequant_class(w, "gate", bits, d, gs, pb, compute_dtype)
        wo = _dequant_class(w, "out", bits, f, gs, pb, compute_dtype)
        h = jnp.einsum("emd,edf->emf", xc, wi,
                       preferred_element_type=jnp.float32)
        g = jnp.einsum("emd,edf->emf", xc, wg,
                       preferred_element_type=jnp.float32)
        a = (act_fn(g) * h).astype(compute_dtype)
        y = jnp.einsum("emf,efd->emd", a, wo,
                       preferred_element_type=jnp.float32)
        outs.append(y)
    y = jnp.concatenate(outs, axis=0)
    mask = jnp.arange(m)[None, :] < counts[:, None]
    return jnp.where(mask[..., None], y, 0.0).astype(out_dtype)
