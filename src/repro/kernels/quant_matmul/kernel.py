"""Pallas TPU kernel: fused sub-byte dequantization x GEMM.

The inference hot spot of MC-compressed experts is ``y = x @ dequant(W)``
with W packed at 1/2/3/4 bits.  Tiling:

* grid ``(E?, M/bm, N/bn, K/bk)`` — K innermost (sequential accumulation);
* ``x`` tile ``(bm, bk)`` in VMEM;
* packed plane tile ``(bk * plane_bits / 8, bn)`` uint8 in VMEM — unpacked on
  the VPU with ``per`` static shifts + one sublane concat (see
  ``kernels.common`` for the deinterleaved layout that makes this legal);
* per-group ``(scale, zero)`` tiles ``(bk/group, bn)``;
* f32 accumulator scratch ``(bm, bn)``; the MXU consumes the dequantized
  bf16/f32 tile.

Weight bytes fetched per K-step are ``bits/16`` of the bf16 equivalent — the
kernel turns the PMQ storage win directly into an HBM-bandwidth win, which is
what the memory-roofline term of decode is bound by.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import _plane_split, dequant_tile


def _dequant_tile(plane_tiles, scale_tile, zero_tile, bits: int,
                  bk: int, group_size: int, compute_dtype):
    """Unpack + affine-dequant one (bk, bn) weight tile (bk == pack_block
    here: quant_matmul's K tile is exactly one deinterleave block)."""
    return dequant_tile(plane_tiles, scale_tile, zero_tile, bits=bits,
                        bk=bk, group_size=group_size, pack_block=bk,
                        compute_dtype=compute_dtype)


def _qmm_kernel(x_ref, *refs, bits: int, group_size: int, bk: int,
                nk: int, compute_dtype, batched: bool):
    n_planes = len(_plane_split(bits))
    plane_refs = refs[:n_planes]
    scale_ref = refs[n_planes]
    zero_ref = refs[n_planes + 1] if bits > 1 else None
    out_ref = refs[-2]
    acc_ref = refs[-1]

    k = pl.program_id(3 if batched else 2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def read(ref):
        t = ref[...]
        return t[0] if batched else t   # squeeze expert block dim

    plane_tiles = tuple(read(r) for r in plane_refs)
    scale_tile = read(scale_ref)
    zero_tile = read(zero_ref) if zero_ref is not None else None
    w = _dequant_tile(plane_tiles, scale_tile, zero_tile, bits, bk,
                      group_size, compute_dtype)
    x_tile = read(x_ref).astype(compute_dtype)
    acc_ref[...] += jnp.dot(x_tile, w, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        t = acc_ref[...].astype(out_ref.dtype)
        out_ref[...] = t[None] if batched else t


def quant_matmul_pallas(x: jax.Array, planes: Tuple[jax.Array, ...],
                        scales: jax.Array, zeros: jax.Array, *, bits: int,
                        group_size: int, block_m: int = 128,
                        block_n: int = 128, block_k: int = 128,
                        compute_dtype=jnp.float32, out_dtype=jnp.float32,
                        interpret: bool = False) -> jax.Array:
    """x: (M, K) or (E, M, K); planes kernel-layout packed (pack_block == block_k)."""
    batched = x.ndim == 3
    if batched:
        e, m, kdim = x.shape
        n = planes[0].shape[-1]
    else:
        m, kdim = x.shape
        n = planes[0].shape[-1]
    assert kdim % block_k == 0 and n % block_n == 0 and m % block_m == 0
    assert block_k % group_size == 0
    nk = kdim // block_k
    split = _plane_split(bits)

    def em(i):
        # index maps; grid is (e?, m, n, k)
        if batched:
            return {
                "x": lambda e_, m_, n_, k_: (e_, m_, k_),
                "w": lambda e_, m_, n_, k_: (e_, k_, n_),
                "s": lambda e_, m_, n_, k_: (e_, k_, n_),
                "o": lambda e_, m_, n_, k_: (e_, m_, n_),
            }[i]
        return {
            "x": lambda m_, n_, k_: (m_, k_),
            "w": lambda m_, n_, k_: (k_, n_),
            "s": lambda m_, n_, k_: (k_, n_),
            "o": lambda m_, n_, k_: (m_, n_),
        }[i]

    def bshape(shape):
        return ((1,) + shape) if batched else shape

    in_specs = [pl.BlockSpec(bshape((block_m, block_k)), em("x"))]
    for pb in split:
        in_specs.append(
            pl.BlockSpec(bshape((block_k * pb // 8, block_n)), em("w")))
    n_g = block_k // group_size
    in_specs.append(pl.BlockSpec(bshape((n_g, block_n)), em("s")))
    args = [x] + list(planes) + [scales.astype(jnp.float32)]
    if bits > 1:
        in_specs.append(pl.BlockSpec(bshape((n_g, block_n)), em("s")))
        args.append(zeros.astype(jnp.float32))

    grid = (m // block_m, n // block_n, nk)
    if batched:
        grid = (e,) + grid

    kern = functools.partial(
        _qmm_kernel, bits=bits, group_size=group_size, bk=block_k, nk=nk,
        compute_dtype=compute_dtype, batched=batched)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(bshape((block_m, block_n)), em("o")),
        out_shape=jax.ShapeDtypeStruct(
            ((e, m, n) if batched else (m, n)), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(*args)
