"""Public op: quantized matmul with packed sub-byte weights.

Dispatches to the Pallas TPU kernel on TPU backends (or in interpret mode for
CPU validation) and to the XLA reference otherwise.  The XLA path is also what
the multi-pod dry-run lowers on the CPU host — it has identical math and
byte-traffic structure (packed uint8 weight loads + on-chip dequant), so the
roofline terms derived from it are representative.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.quant_matmul.kernel import quant_matmul_pallas
from repro.kernels.quant_matmul.ref import quant_matmul_ref


def _use_pallas(mode: str) -> bool:
    if mode == "auto":
        return common.on_tpu()
    return mode in ("pallas", "interpret")


def _fit_n(n: int, block_n: int):
    """(block_n', pad_n): shrink block_n to a divisor of n, or — when no
    aligned divisor exists — pad N up to the next sublane-aligned multiple
    a block can tile. Returns the block plus the padded N (== n if none)."""
    bn = common.fit_block(n, block_n)
    if bn:
        return bn, n
    pad_n = -(-n // 8) * 8
    bn = common.fit_block(pad_n, block_n)
    return bn, pad_n


def _pad_last(arr, pad_n: int):
    return common.pad_to_multiple(arr, arr.ndim - 1, pad_n)


def quant_matmul(x: jax.Array, planes: Tuple[jax.Array, ...],
                 scales: jax.Array, zeros: Optional[jax.Array], *, bits: int,
                 group_size: int = 128, pack_block: int = 128,
                 impl: str = "auto", block_m: int = 0, block_n: int = 128,
                 block_k: int = 0, out_dtype=jnp.float32) -> jax.Array:
    """``y = x @ dequant(planes)``.

    x: ``(..., K)`` (or ``(E, M, K)`` with per-expert planes ``(E, ., N)``).
    ``block_k`` is fixed by the packed layout at ``pack_block`` (one K step
    = one deinterleave block); passing any other value is an error.
    """
    if block_k and block_k != pack_block:
        raise ValueError(
            f"quant_matmul: block_k={block_k} conflicts with "
            f"pack_block={pack_block} — the deinterleaved plane layout "
            "fixes the K tile at pack_block; omit block_k")
    # resolve the thread-local override *outside* the jit boundary so the
    # resolved impl is part of the trace cache key
    if impl == "auto":
        impl = common.impl_override() or "auto"
    return _quant_matmul(x, planes, scales, zeros, bits=bits,
                         group_size=group_size, pack_block=pack_block,
                         impl=impl, block_m=block_m, block_n=block_n,
                         out_dtype=out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "group_size", "pack_block", "impl", "block_m",
                     "block_n", "out_dtype"))
def _quant_matmul(x: jax.Array, planes: Tuple[jax.Array, ...],
                  scales: jax.Array, zeros: Optional[jax.Array], *,
                  bits: int, group_size: int, pack_block: int, impl: str,
                  block_m: int, block_n: int, out_dtype) -> jax.Array:
    if not _use_pallas(impl):
        return quant_matmul_ref(x, planes, scales, zeros, bits=bits,
                                group_size=group_size, pack_block=pack_block,
                                out_dtype=out_dtype)

    interpret = (impl == "interpret") or not common.on_tpu()
    batched = planes[0].ndim == 3
    lead = x.shape[:-1] if not batched else x.shape[1:-1]
    k = x.shape[-1]
    if batched:
        e = x.shape[0]
        xm = x.reshape(e, -1, k)
    else:
        xm = x.reshape(-1, k)
    m = xm.shape[-2]
    bm = block_m or common.choose_bm(m)
    xm = common.pad_to_multiple(xm, xm.ndim - 2, bm)

    # the packed deinterleave layout fixes the K tiling: one K step is
    # exactly one pack_block, so a non-multiple K cannot be retiled here
    if k % pack_block:
        raise ValueError(
            f"quant_matmul: contraction dim K={k} is not a multiple of "
            f"pack_block={pack_block}; the kernel-layout planes fix the K "
            "tiling at pack time — repack with a pack_block dividing K "
            "(d_model / moe_d_ff for the in/gate / out projections)")
    block_k = pack_block
    if block_k % group_size:
        raise ValueError(
            f"quant_matmul: pack_block={pack_block} must be a multiple of "
            f"group_size={group_size} so per-group scales tile the K step")

    n = planes[0].shape[-1]
    bn, pad_n = _fit_n(n, block_n)
    if pad_n != n:
        planes = tuple(_pad_last(p, pad_n) for p in planes)
        scales = _pad_last(scales, pad_n)
        zeros = _pad_last(zeros, pad_n) if zeros is not None else None

    out = quant_matmul_pallas(
        xm, planes, scales, zeros, bits=bits, group_size=group_size,
        block_m=bm, block_n=bn, block_k=block_k, out_dtype=out_dtype,
        interpret=interpret)
    out = out[..., :m, :n]
    return out.reshape((e,) + lead + (n,)) if batched else out.reshape(lead + (n,))
