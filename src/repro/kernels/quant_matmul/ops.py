"""Public op: quantized matmul with packed sub-byte weights.

Dispatches to the Pallas TPU kernel on TPU backends (or in interpret mode for
CPU validation) and to the XLA reference otherwise.  The XLA path is also what
the multi-pod dry-run lowers on the CPU host — it has identical math and
byte-traffic structure (packed uint8 weight loads + on-chip dequant), so the
roofline terms derived from it are representative.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.quant_matmul.kernel import quant_matmul_pallas
from repro.kernels.quant_matmul.ref import quant_matmul_ref


def _use_pallas(mode: str) -> bool:
    if mode == "auto":
        return common.on_tpu()
    return mode in ("pallas", "interpret")


@functools.partial(
    jax.jit,
    static_argnames=("bits", "group_size", "pack_block", "impl", "block_m",
                     "block_n", "block_k", "out_dtype"))
def quant_matmul(x: jax.Array, planes: Tuple[jax.Array, ...],
                 scales: jax.Array, zeros: Optional[jax.Array], *, bits: int,
                 group_size: int = 128, pack_block: int = 128,
                 impl: str = "auto", block_m: int = 0, block_n: int = 128,
                 block_k: int = 128, out_dtype=jnp.float32) -> jax.Array:
    """``y = x @ dequant(planes)``.

    x: ``(..., K)`` (or ``(E, M, K)`` with per-expert planes ``(E, ., N)``).
    """
    if not _use_pallas(impl):
        return quant_matmul_ref(x, planes, scales, zeros, bits=bits,
                                group_size=group_size, pack_block=pack_block,
                                out_dtype=out_dtype)

    interpret = (impl == "interpret") or not common.on_tpu()
    batched = planes[0].ndim == 3
    lead = x.shape[:-1] if not batched else x.shape[1:-1]
    k = x.shape[-1]
    if batched:
        e = x.shape[0]
        xm = x.reshape(e, -1, k)
    else:
        xm = x.reshape(-1, k)
    m = xm.shape[-2]
    bm = block_m or common.choose_bm(m)
    xm = common.pad_to_multiple(xm, xm.ndim - 2, bm)

    out = quant_matmul_pallas(
        xm, planes, scales, zeros, bits=bits, group_size=group_size,
        block_m=bm, block_n=block_n, block_k=block_k, out_dtype=out_dtype,
        interpret=interpret)
    out = out[..., :m, :]
    n = out.shape[-1]
    return out.reshape((e,) + lead + (n,)) if batched else out.reshape(lead + (n,))
