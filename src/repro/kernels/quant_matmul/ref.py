"""Pure-jnp oracle for the fused dequant GEMM kernel."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import unpack_kernel_layout


def dequant_ref(planes: Tuple[jax.Array, ...], scales: jax.Array,
                zeros: jax.Array, *, bits: int, group_size: int, d_in: int,
                pack_block: int, dtype=jnp.float32) -> jax.Array:
    """Unpack kernel-layout planes -> dense (d_in, d_out) weights."""
    codes = unpack_kernel_layout(planes, bits, d_in, pack_block)
    codes = codes.astype(jnp.float32)
    d_out = codes.shape[-1]
    g = codes.reshape(d_in // group_size, group_size, d_out)
    if bits == 1:
        w = (g * 2.0 - 1.0) * scales[:, None, :]
    else:
        w = (g - zeros[:, None, :]) * scales[:, None, :]
    return w.reshape(d_in, d_out).astype(dtype)


def quant_matmul_ref(x: jax.Array, planes: Tuple[jax.Array, ...],
                     scales: jax.Array, zeros: jax.Array, *, bits: int,
                     group_size: int, pack_block: int,
                     compute_dtype=jnp.float32,
                     out_dtype=jnp.float32) -> jax.Array:
    """x: (..., K) or batched-expert (E, ..., K) with per-expert planes."""
    if x.ndim == 3 and planes[0].ndim == 3:   # (E, M, K) x (E, packed, N)
        e = x.shape[0]
        outs = [
            quant_matmul_ref(x[i], tuple(p[i] for p in planes), scales[i],
                             zeros[i] if zeros is not None else None,
                             bits=bits, group_size=group_size,
                             pack_block=pack_block,
                             compute_dtype=compute_dtype, out_dtype=out_dtype)
            for i in range(e)
        ]
        return jnp.stack(outs)
    k = x.shape[-1]
    w = dequant_ref(planes, scales, zeros, bits=bits, group_size=group_size,
                    d_in=k, pack_block=pack_block, dtype=compute_dtype)
    y = jnp.dot(x.astype(compute_dtype), w,
                preferred_element_type=jnp.float32)
    return y.astype(out_dtype)
