"""Pallas TPU kernel: fused Mamba-1 selective scan (forward).

The XLA forms of the selective scan materialize the (B, S, I, N) decay and
input tensors in HBM (associative: x log-depth passes; fused-seq: per-step
carry traffic). This kernel keeps the state ``h (bi, N)`` resident in VMEM
and computes ``exp(dt*A)`` on the fly from the (bs, bi) time-slice, so HBM
traffic is just the natural inputs/outputs:

    reads:  delta/x (S, I), B/C (S, N) per I-block, A (I, N), h0
    writes: y (S, I), h_last (I, N)

— an O(N * log c)-fold reduction vs the associative form (falcon-mamba-7b:
N=16, c=128 -> ~50x less scan traffic; EXPERIMENTS.md §Perf cell A).

Grid ``(B, I/bi, S/bs)``: the time dimension is innermost/sequential and the
state scratch persists across its steps (standard TPU accumulator pattern);
each (batch row, channel block) owns an independent recurrence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(delta_ref, x_ref, b_ref, c_ref, a_ref, h0_ref,
                 y_ref, hlast_ref, h_scr, *, bs: int, ns: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    a = a_ref[...]                                  # (bi, N)

    def step(t, h):
        dt_t = delta_ref[0, t]                      # (bi,)
        x_t = x_ref[0, t]
        bv = b_ref[0, t]                            # (N,)
        cv = c_ref[0, t]
        da = jnp.exp(dt_t[:, None] * a)             # (bi, N) transient
        h = da * h + (dt_t * x_t)[:, None] * bv[None, :]
        y_ref[0, t, :] = (h * cv[None, :]).sum(axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, step, h_scr[...])
    h_scr[...] = h

    @pl.when(s == ns - 1)
    def _done():
        hlast_ref[0] = h_scr[...].astype(hlast_ref.dtype)


def selective_scan_pallas(delta: jax.Array, x: jax.Array, b_mat: jax.Array,
                          c_mat: jax.Array, a: jax.Array, h0: jax.Array, *,
                          block_i: int = 128, block_s: int = 128,
                          interpret: bool = False):
    """delta/x: (B, S, I) f32; b/c: (B, S, N); a: (I, N); h0: (B, I, N).

    Returns (y (B, S, I) f32, h_last (B, I, N) f32).
    """
    bsz, s, i = delta.shape
    n = a.shape[-1]
    assert i % block_i == 0 and s % block_s == 0
    ns = s // block_s
    grid = (bsz, i // block_i, ns)

    kern = functools.partial(_scan_kernel, bs=block_s, ns=ns)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_i), lambda b, ib, sb: (b, sb, ib)),
            pl.BlockSpec((1, block_s, block_i), lambda b, ib, sb: (b, sb, ib)),
            pl.BlockSpec((1, block_s, n), lambda b, ib, sb: (b, sb, 0)),
            pl.BlockSpec((1, block_s, n), lambda b, ib, sb: (b, sb, 0)),
            pl.BlockSpec((block_i, n), lambda b, ib, sb: (ib, 0)),
            pl.BlockSpec((1, block_i, n), lambda b, ib, sb: (b, ib, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, block_i), lambda b, ib, sb: (b, sb, ib)),
            pl.BlockSpec((1, block_i, n), lambda b, ib, sb: (b, ib, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, i), jnp.float32),
            jax.ShapeDtypeStruct((bsz, i, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_i, n), jnp.float32)],
        interpret=interpret,
    )(delta, x, b_mat, c_mat, a, h0)
