"""Public op: fused selective scan (Mamba-1 inner recurrence)."""
import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.selective_scan.kernel import selective_scan_pallas
from repro.kernels.selective_scan.ref import selective_scan_ref


@functools.partial(jax.jit, static_argnames=("impl", "block_i", "block_s"))
def selective_scan(delta, x, b_mat, c_mat, a, h0, *, impl="auto",
                   block_i=128, block_s=128):
    use_pallas = impl in ("pallas", "interpret") or (
        impl == "auto" and common.on_tpu())
    i, s = delta.shape[-1], delta.shape[-2]
    if not use_pallas or i % block_i or s % block_s:
        return selective_scan_ref(delta, x, b_mat, c_mat, a, h0)
    interpret = (impl == "interpret") or not common.on_tpu()
    f32 = jnp.float32
    return selective_scan_pallas(
        delta.astype(f32), x.astype(f32), b_mat.astype(f32),
        c_mat.astype(f32), a.astype(f32), h0.astype(f32),
        block_i=block_i, block_s=block_s, interpret=interpret)
