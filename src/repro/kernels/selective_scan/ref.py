"""Pure-jnp oracle for the selective scan."""
import jax
import jax.numpy as jnp


def selective_scan_ref(delta, x, b_mat, c_mat, a, h0):
    """Sequential reference: h_t = exp(dt*A) h + (dt*x) B_t; y_t = h_t.C_t."""
    def step(h, args):
        dt_t, x_t, bt, ct = args
        da = jnp.exp(dt_t[..., None] * a)
        h = da * h + (dt_t * x_t)[..., None] * bt[:, None, :]
        return h, jnp.einsum("bin,bn->bi", h, ct)

    sw = lambda t: t.swapaxes(0, 1)
    h_last, ys = jax.lax.scan(step, h0, (sw(delta), sw(x), sw(b_mat),
                                         sw(c_mat)))
    return ys.swapaxes(0, 1), h_last
