"""Pallas TPU kernel: fused token-importance reduction (ODP, paper Eq. 6).

    I_j = ||t_j||_1 * mean_{q >= j} A[h, q, j]        (mean over heads too)

The heavy part is the masked column reduction over the (H, L, L) attention
probabilities — O(H L^2) reads with a triangular predicate. Tiling:

* grid ``(nj, nq, nh)`` — key/column blocks outermost (they own the output),
  query and head blocks accumulate sequentially;
* probs tile ``(bh, bq, bj)`` in VMEM, mask built from global iotas;
* f32 accumulator scratch ``(1, bj)``; on the last (q, h) step the partial
  column sums are normalized by ``(L - j)`` and multiplied by the token's
  precomputed l1 magnitude ``(1, bj)`` tile.

The l1 norms are a cheap elementwise reduce handled by XLA outside the
kernel; fusing them here would add a d-sized grid axis for no bandwidth win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ti_kernel(probs_ref, tl1_ref, out_ref, acc_ref, *, bq: int, bj: int,
               nq: int, nh: int, seq_len: int, num_heads: int):
    jb = pl.program_id(0)
    qb = pl.program_id(1)
    hb = pl.program_id(2)

    @pl.when(jnp.logical_and(qb == 0, hb == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p = probs_ref[...]                                  # (bh, bq, bj)
    q_idx = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bj), 0)
    j_idx = jb * bj + jax.lax.broadcasted_iota(jnp.int32, (bq, bj), 1)
    mask = (q_idx >= j_idx).astype(p.dtype)
    acc_ref[...] += jnp.sum(p * mask[None, :, :], axis=(0, 1))[None, :]

    @pl.when(jnp.logical_and(qb == nq - 1, hb == nh - 1))
    def _done():
        j = jb * bj + jax.lax.broadcasted_iota(jnp.int32, (1, bj), 1)
        denom = jnp.maximum(seq_len - j, 1).astype(jnp.float32)
        mean_recv = acc_ref[...] / (denom * num_heads)
        out_ref[...] = (mean_recv * tl1_ref[...]).astype(out_ref.dtype)


def token_importance_pallas(probs: jax.Array, tl1: jax.Array, *,
                            block_q: int = 128, block_j: int = 128,
                            block_h: int = 4,
                            interpret: bool = False) -> jax.Array:
    """probs: (H, L, L) attention probabilities; tl1: (1, L) l1 norms."""
    h, l, l2 = probs.shape
    assert l == l2 and l % block_j == 0 and l % block_q == 0
    block_h = min(block_h, h)
    assert h % block_h == 0
    nj, nq, nh = l // block_j, l // block_q, h // block_h

    kern = functools.partial(_ti_kernel, bq=block_q, bj=block_j, nq=nq,
                             nh=nh, seq_len=l, num_heads=h)
    return pl.pallas_call(
        kern,
        grid=(nj, nq, nh),
        in_specs=[
            pl.BlockSpec((block_h, block_q, block_j),
                         lambda j, q, hh: (hh, q, j)),
            pl.BlockSpec((1, block_j), lambda j, q, hh: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_j), lambda j, q, hh: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, l), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_j), jnp.float32)],
        interpret=interpret,
    )(probs, tl1)
