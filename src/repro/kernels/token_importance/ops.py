"""Public op: fused token-importance (ODP token protection metric)."""
import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.token_importance.kernel import token_importance_pallas
from repro.kernels.token_importance.ref import token_importance_ref


@jax.jit
def token_importance_decode(x, received, counts=None):
    """Decode-path Eq. 6: importance of the *current* step's tokens.

    x: (B, S, D) hidden states entering the MoE block; received: (B, S)
    attention each of the same tokens received this step (query-aligned —
    ``apply_attention`` gathers the cached-branch column sums back at the
    slots the queries wrote); counts: optional (S,) / (B, S) number of
    queries that could have attended each token (the Eq. 6 denominator —
    mask-aware callers pass suffix counts of *valid* queries so pad tails
    do not deflate live tokens' scores). Returns (B, S) float32.

    This is the serving-side sibling of :func:`token_importance`: the
    square (H, L, L) Pallas kernel serves calibration/prefill shapes,
    while decode steps have already reduced the probabilities to column
    sums inside ``attend`` — what remains is an elementwise combine that
    XLA fuses into the surrounding dispatch, so no dedicated kernel is
    warranted (S is 1 in the decode hot path).
    """
    tl1 = jnp.sum(jnp.abs(x.astype(jnp.float32)), axis=-1)      # (B, S)
    imp = tl1 * received.astype(jnp.float32)
    if counts is not None:
        imp = imp / jnp.maximum(counts.astype(jnp.float32), 1.0)
    return imp


@functools.partial(jax.jit, static_argnames=("impl",))
def token_importance(probs, t, *, impl="auto"):
    """probs: (H, L, L) or (B, H, L, L); t matching (L, d) / (B, L, d)."""
    if probs.ndim == 4:
        return jax.vmap(lambda p, tt: token_importance(p, tt, impl=impl)
                        )(probs, t)
    use_pallas = impl in ("pallas", "interpret") or (
        impl == "auto" and common.on_tpu())
    l = probs.shape[-1]
    if not use_pallas or l % 128 != 0:
        return token_importance_ref(probs, t)
    interpret = (impl == "interpret") or not common.on_tpu()
    tl1 = jnp.sum(jnp.abs(t.astype(jnp.float32)), axis=-1)[None, :]
    out = token_importance_pallas(probs.astype(jnp.float32), tl1,
                                  interpret=interpret)
    return out[0]
