"""Public op: fused token-importance (ODP token protection metric)."""
import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.token_importance.kernel import token_importance_pallas
from repro.kernels.token_importance.ref import token_importance_ref


@functools.partial(jax.jit, static_argnames=("impl",))
def token_importance(probs, t, *, impl="auto"):
    """probs: (H, L, L) or (B, H, L, L); t matching (L, d) / (B, L, d)."""
    if probs.ndim == 4:
        return jax.vmap(lambda p, tt: token_importance(p, tt, impl=impl)
                        )(probs, t)
    use_pallas = impl in ("pallas", "interpret") or (
        impl == "auto" and common.on_tpu())
    l = probs.shape[-1]
    if not use_pallas or l % 128 != 0:
        return token_importance_ref(probs, t)
    interpret = (impl == "interpret") or not common.on_tpu()
    tl1 = jnp.sum(jnp.abs(t.astype(jnp.float32)), axis=-1)[None, :]
    out = token_importance_pallas(probs.astype(jnp.float32), tl1,
                                  interpret=interpret)
    return out[0]
