"""Pure-jnp oracle for token importance (MC paper Eq. 6)."""
import jax.numpy as jnp


def token_importance_ref(probs, t):
    """probs: (H, L, L) softmax attention; t: (L, d) hidden states -> (L,).

    I_j = ||t_j||_1 * sum_{q >= j} mean_h A[h, q, j] / (L - j)
    (0-based j; the denominator counts the queries that can attend to j).
    """
    h, l, _ = probs.shape
    q_idx = jnp.arange(l)[:, None]
    j_idx = jnp.arange(l)[None, :]
    mask = (q_idx >= j_idx).astype(probs.dtype)
    col = jnp.sum(probs.mean(axis=0) * mask, axis=0)       # (L,)
    denom = jnp.maximum(l - jnp.arange(l), 1).astype(col.dtype)
    tl1 = jnp.sum(jnp.abs(t.astype(jnp.float32)), axis=-1)
    return (tl1 * col / denom).astype(jnp.float32)
