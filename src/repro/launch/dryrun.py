import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 host placeholder devices, lowers the real step
function (train_step / prefill / serve_step) against ShapeDtypeStruct
stand-ins (no allocation), compiles it, and records

* ``compiled.memory_analysis()``  — per-device bytes (fits-in-HBM proof),
* ``compiled.cost_analysis()``    — FLOPs / bytes for the roofline,
* collective bytes parsed from the compiled HLO,

into ``experiments/dryrun/<arch>__<shape>__<mesh>[__mc].json``.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
        --shape decode_32k --mesh both [--mc] [--all]
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import SHAPES, ModelConfig, ShapeConfig, TrainConfig
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch import roofline as rf
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.models.model_registry import build_model
from repro.models.transformer import DecoderModel, MCRuntime
from repro.sharding import context as shctx
from repro.sharding.partitioning import batch_spec, sanitize_spec
from repro.train import optimizer as opt_lib
from repro.train.train_step import TrainState, init_train_state, \
    make_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _bf16_structs(tree):
    def cast(s):
        if s.dtype == jnp.float32:
            return jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        return s
    return jax.tree.map(cast, tree)


def _shard_tree(mesh, spec_tree, struct_tree):
    def one(sp, st):
        sp = sp if isinstance(sp, P) else P()
        return NamedSharding(mesh, sanitize_spec(mesh, sp, st.shape))
    return jax.tree.map(one, spec_tree, struct_tree,
                        is_leaf=lambda v: isinstance(v, P))


def _batch_shardings(mesh, batch_structs):
    def one(st):
        sp = batch_spec(mesh, st.shape[0] if st.ndim else 1, max(st.ndim, 1))
        if st.ndim == 0:
            sp = P()
        return NamedSharding(mesh, sanitize_spec(mesh, sp, st.shape))
    return jax.tree.map(one, batch_structs)


def _generic_cache_spec(cfg: ModelConfig, st) -> P:
    """Heuristic cache sharding: (L, B, ...) -> batch over data, any dim
    equal to num_kv_heads over model."""
    entries = [None] * st.ndim
    if st.ndim >= 2:
        entries[1] = "data"
    for i in range(2, st.ndim):
        if cfg.num_kv_heads and st.shape[i] == cfg.num_kv_heads:
            entries[i] = "model"
            break
    return P(*entries)


def _cache_shardings(mesh, cfg, cache_structs):
    return jax.tree.map(
        lambda st: NamedSharding(
            mesh, sanitize_spec(mesh, _generic_cache_spec(cfg, st),
                                st.shape)),
        cache_structs)


# --------------------------------------------------------------- MC variant
def synthetic_meta(cfg: ModelConfig, target_bits: float = 2.54):
    """Representative PMQ class layout for dry-run lowering (uniform-layout
    mode; counts from the target budget — see EXPERIMENTS.md §Dry-run)."""
    from repro.models.layers.moe import MoEQuantMeta
    e = cfg.num_experts
    if target_bits >= 2.0:
        n3 = int(round(e * (target_bits - 2.0)))
        n3 = min(max(n3, 1), e - 1)
        counts, classes = (e - n3, n3), (2, 3)
    else:
        n1 = int(round(e * (2.0 - target_bits)))
        n1 = min(max(n1, 1), e - 1)
        counts, classes = (n1, e - n1), (1, 2)
    return MoEQuantMeta(bit_classes=classes, class_counts=counts,
                        group_size=128, pack_block=128)


def quantize_param_structs(model: DecoderModel, cfg: ModelConfig,
                           param_structs, meta):
    """Replace dense expert stacks with packed-plane ShapeDtypeStructs."""
    d, f = cfg.d_model, cfg.moe_d_ff
    gs = meta.group_size
    n_steps = model.n_steps
    u8 = jnp.uint8

    def cls_struct(bits, cnt):
        out = {}
        def planes(tag, kdim, ndim):
            split = (2, 1) if bits == 3 else (bits,)
            for pi, pb in enumerate(split):
                out[f"{tag}_p{pi}"] = jax.ShapeDtypeStruct(
                    (n_steps, cnt, kdim * pb // 8, ndim), u8)
            out[f"{tag}_s"] = jax.ShapeDtypeStruct(
                (n_steps, cnt, kdim // gs, ndim), jnp.float32)
            if bits > 1:
                out[f"{tag}_z"] = jax.ShapeDtypeStruct(
                    (n_steps, cnt, kdim // gs, ndim), jnp.float32)
        planes("in", d, f)
        planes("gate", d, f)
        planes("out", f, d)
        return out

    experts_q = {f"cls{ci}": cls_struct(bits, cnt)
                 for ci, (bits, cnt) in
                 enumerate(zip(meta.bit_classes, meta.class_counts))}

    new = dict(param_structs)
    for slot in range(model.period):
        if model.slot_kinds[slot] != "moe":
            continue
        layer = dict(new[f"layers{slot}"])
        ffn = {k: v for k, v in layer["ffn"].items()
               if k not in ("w_in", "w_gate", "w_out")}
        ffn["experts_q"] = experts_q
        layer["ffn"] = ffn
        new[f"layers{slot}"] = layer
    return new


def quantized_param_specs(model: DecoderModel, cfg: ModelConfig, specs,
                          meta):
    new = dict(specs)
    def cls_spec(bits, cnt):
        out = {}
        def planes(tag, kspec, nspec):
            split = (2, 1) if bits == 3 else (bits,)
            for pi in range(len(split)):
                out[f"{tag}_p{pi}"] = P(None, "data", kspec, nspec)
            out[f"{tag}_s"] = P(None, "data", None, nspec)
            if bits > 1:
                out[f"{tag}_z"] = P(None, "data", None, nspec)
        planes("in", None, "model")
        planes("gate", None, "model")
        planes("out", "model", None)
        return out

    experts_q = {f"cls{ci}": cls_spec(bits, cnt)
                 for ci, (bits, cnt) in
                 enumerate(zip(meta.bit_classes, meta.class_counts))}
    for slot in range(model.period):
        if model.slot_kinds[slot] != "moe":
            continue
        layer = dict(new[f"layers{slot}"])
        ffn = {k: v for k, v in layer["ffn"].items()
               if k not in ("w_in", "w_gate", "w_out")}
        ffn["experts_q"] = experts_q
        layer["ffn"] = ffn
        new[f"layers{slot}"] = layer
    return new


# ------------------------------------------------------------------ lowering
def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               mc_mode: bool = False, overrides=None):
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    shctx.set_mesh_axes(tuple(mesh.axis_names),
                        tuple(mesh.shape[a] for a in mesh.axis_names))

    model = build_model(cfg)
    pspecs = model.param_specs()
    param_structs = _bf16_structs(jax.eval_shape(
        lambda k: model.init(k), jax.random.PRNGKey(0)))

    mc = None
    if mc_mode:
        assert cfg.is_moe, "--mc only applies to MoE archs"
        from repro.models.layers.moe import OdpRuntime
        meta = synthetic_meta(cfg)
        param_structs = quantize_param_structs(model, cfg, param_structs,
                                               meta)
        pspecs = quantized_param_specs(model, cfg, pspecs, meta)
        odp = OdpRuntime(threshold=0.5, protect_ratio=0.02,
                         capacity_scale=0.85) if cfg.top_k >= 2 else None
        mc = MCRuntime(odp=odp, quant_meta=meta)

    param_sh = _shard_tree(mesh, pspecs, param_structs)
    batch_structs = specs_lib.input_specs(arch, shape_name, cfg)
    batch_sh = _batch_shardings(mesh, batch_structs)

    if shape.mode == "train":
        tcfg = TrainConfig(optimizer="adamw8bit",
                           grad_compression="none")
        step = make_train_step(model, cfg, tcfg)
        state_structs = jax.eval_shape(
            lambda k: init_train_state(model, k, tcfg),
            jax.random.PRNGKey(0))
        state_structs = TrainState(
            params=param_structs, opt=state_structs.opt,
            ef=state_structs.ef)
        mspecs = opt_lib.moment_specs(pspecs, param_structs, quantized=True)
        vspecs = opt_lib.moment_specs(pspecs, param_structs, quantized=True,
                                      second=True)
        state_specs = TrainState(
            params=pspecs,
            opt=opt_lib.AdamWState(step=P(), m=mspecs, v=vspecs),
            ef=None)
        state_sh = _shard_tree(mesh, state_specs, state_structs)
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     donate_argnums=(0,))
        args = (state_structs, batch_structs)
    elif shape.mode == "prefill":
        _, prefill = specs_lib.build_prefill_fn(cfg, shape, mc=mc)
        fn = jax.jit(prefill, in_shardings=(param_sh, batch_sh))
        args = (param_structs, batch_structs)
    else:  # decode
        _, serve_step = specs_lib.build_decode_fn(cfg, shape, mc=mc)
        cache_structs = specs_lib.cache_structs(model, cfg, shape)
        cache_sh = _cache_shardings(mesh, cfg, cache_structs)
        extra = specs_lib.decode_extra_structs(model, cfg, shape)
        if extra:
            batch_structs = {**batch_structs, **extra}
            batch_sh = {
                **batch_sh,
                **{k: jax.tree.map(lambda st: NamedSharding(
                    mesh, sanitize_spec(mesh,
                                        _generic_cache_spec(cfg, st),
                                        st.shape)), v)
                   for k, v in extra.items()}}
        fn = jax.jit(serve_step, in_shardings=(param_sh, cache_sh, batch_sh),
                     donate_argnums=(1,))
        args = (param_structs, cache_structs, batch_structs)

    t0 = time.time()
    with jax.set_mesh(mesh):
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return cfg, shape, mesh, chips, compiled, t_lower, t_compile


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             mc_mode: bool = False, out_dir: Path = OUT_DIR,
             overrides=None, tag_suffix: str = ""):
    multi_pod = mesh_kind == "multi"
    tag = f"{arch}__{shape_name}__{mesh_kind}" + ("__mc" if mc_mode else "") \
        + tag_suffix
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{tag}.json"

    ok, note = specs_lib.cell_supported(arch, shape_name)
    if not ok:
        rec = {"cell": tag, "status": "skipped", "note": note}
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[dryrun] SKIP {tag}: {note}")
        return rec

    try:
        cfg, shape, mesh, chips, compiled, t_lower, t_compile = lower_cell(
            arch, shape_name, multi_pod, mc_mode, overrides=overrides)
        mem = compiled.memory_analysis()
        cost_list = compiled.cost_analysis()
        cost = cost_list if isinstance(cost_list, dict) else cost_list[0]
        print(f"[dryrun] {tag} memory_analysis:\n{mem}")
        print(f"[dryrun] {tag} cost_analysis: flops={cost.get('flops', 0):.3e}"
              f" bytes={cost.get('bytes accessed', 0):.3e}")
        hlo = compiled.as_text()
        from repro.launch import hlo_analysis
        hc = hlo_analysis.analyze(hlo)
        mf = rf.model_flops_estimate(cfg, shape)
        terms = rf.roofline_from_hlo(hc, chips, model_flops_global=mf)
        mem_rec = {}
        for attr in ("generated_code_size_in_bytes",
                     "argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "peak_memory_in_bytes"):
            mem_rec[attr] = getattr(mem, attr, None)
        rec = {
            "cell": tag, "status": "ok", "arch": arch, "shape": shape_name,
            "mesh": mesh_kind, "chips": chips, "mc": mc_mode,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory_analysis": mem_rec,
            # raw XLA numbers (per device; while bodies counted ONCE — kept
            # for reference, not used by the roofline)
            "xla_cost_analysis": {k: float(v) for k, v in cost.items()
                                  if isinstance(v, (int, float))},
            "hlo_analysis": {
                "flops_per_chip": hc.flops,
                "bytes_per_chip": hc.bytes_accessed,
                "collective_bytes_per_chip": hc.collective_bytes,
                "collective_by_kind": hc.collective_by_kind,
                "collective_counts": hc.collective_counts,
                "dot_count": hc.dot_count,
                "warnings": hc.warnings[:20],
            },
            "roofline": terms.to_dict(),
        }
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec = {"cell": tag, "status": "error", "error": repr(e),
               "traceback": traceback.format_exc()[-4000:]}
        print(f"[dryrun] FAIL {tag}: {e!r}")
    out_path.write_text(json.dumps(rec, indent=2, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--mc", action="store_true",
                    help="PMQ+ODP compressed serving variant")
    ap.add_argument("--all", action="store_true",
                    help="sweep all assigned archs x shapes")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"{arch}__{shape}__{mesh_kind}" + \
                    ("__mc" if args.mc else "")
                if args.skip_done and (OUT_DIR / f"{tag}.json").exists():
                    prev = json.loads((OUT_DIR / f"{tag}.json").read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[dryrun] done already: {tag}")
                        continue
                results.append(run_cell(arch, shape, mesh_kind, args.mc))
    bad = [r for r in results if r.get("status") == "error"]
    print(f"[dryrun] finished: {len(results)} cells, {len(bad)} errors")
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
