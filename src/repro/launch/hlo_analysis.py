"""HLO-text cost analysis with while-loop multiplicities.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies **once** —
useless for scan-over-layers programs where >95% of the work sits inside the
layer loop (verified empirically; see EXPERIMENTS.md §Dry-run methodology).
This module re-derives the roofline inputs directly from the compiled HLO:

* builds the computation call graph (ENTRY -> while bodies x trip count,
  fusions, calls, conditionals) and propagates execution multiplicities;
* **flops**: ``2 * prod(out) * prod(contracting dims)`` per ``dot`` at its
  computation's multiplicity (MXU work; elementwise flops are bandwidth-
  bound and accounted by the memory term);
* **bytes**: per top-level op in non-fusion-internal computations, operand
  bytes + output bytes (the same convention XLA's bytes_accessed uses),
  fusion internals excluded — they never touch HBM;
* **collective bytes**: operand sizes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, by kind, x multiplicity.

All shapes in compiled SPMD HLO are per-device, so every number reported
here is **per chip per step**.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 0.5, "u4": 0.5, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_ATOM = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?)\s+([\w\-]+)\((.*)$")
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BATCH = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")


def _parse_type(t: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """'(s32[], f32[64,256]{1,0})' or 'bf16[8,16]{1,0}' -> atoms."""
    out = []
    for m in _SHAPE_ATOM.finditer(t):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _atoms_bytes(atoms) -> float:
    total = 0.0
    for dt, shape in atoms:
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str                      # text after the opening paren
    operands: List[str] = field(default_factory=list)

    def out_bytes(self) -> float:
        return _atoms_bytes(_parse_type(self.type_str))

    def out_atoms(self):
        return _parse_type(self.type_str)


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    instrs: Dict[str, Instr] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)


@dataclass
class HloCost:
    flops: float = 0.0                      # per chip per step
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, int] = field(default_factory=dict)
    dot_count: int = 0
    warnings: List[str] = field(default_factory=list)


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator",
    "opt-barrier",
    # control ops alias their bodies' buffers; the body instrs are counted
    "while", "conditional", "call",
}

# XLA:CPU emulates bf16 dots by materializing f32 copies of the operands;
# TPU reads bf16 natively in the MXU datapath. Pure dtype-conversion
# fusions are therefore discounted from the TPU roofline (methodology note
# in EXPERIMENTS.md §Dry-run). Layout copies/transposes still count.
_CONVERT_ONLY_OPS = {"parameter", "convert", "bitcast", "copy", "reshape",
                     "broadcast", "transpose", "tuple", "get-tuple-element"}


def _is_dtype_conversion_fusion(fcomp: "Computation") -> bool:
    has_convert = False
    for iname in fcomp.order:
        fi = fcomp.instrs[iname]
        if fi.op not in _CONVERT_ONLY_OPS:
            return False
        if fi.op == "convert":
            has_convert = True
    return has_convert

# ops that read/write only a slice of their big operand — count the slice,
# not the base buffer (matches XLA HloCostAnalysis; without this, stacked
# scan-over-layers parameters are charged L^2 times)
_SLICING_OPS = {"dynamic-slice", "slice", "gather"}
_UPDATE_OPS = {"dynamic-update-slice", "scatter"}


def _instr_bytes(comp: "Computation", ins: Instr,
                 comps: Dict[str, "Computation"]) -> float:
    """Effective HBM bytes for one top-level instruction."""
    if ins.op in _SKIP_BYTES_OPS:
        return 0.0
    if ins.op in _SLICING_OPS:
        return 2.0 * ins.out_bytes()          # read slice + write result
    if ins.op == "dynamic-update-slice":
        upd = comp.instrs.get(ins.operands[1]) if len(ins.operands) > 1 \
            else None
        ub = upd.out_bytes() if upd is not None else ins.out_bytes()
        return 2.0 * ub                        # read update + write in place
    if ins.op == "scatter":
        upd = comp.instrs.get(ins.operands[-1]) if ins.operands else None
        ub = upd.out_bytes() if upd is not None else ins.out_bytes()
        return 2.0 * ub
    if ins.op == "fusion":
        fm = _CALLS.search(ins.rest)
        fc = comps.get(fm.group(1)) if fm else None
        if fc is not None and _is_dtype_conversion_fusion(fc):
            return 0.0
        return _fusion_bytes(comp, ins, comps)
    if ins.op == "convert":
        return 0.0
    b = ins.out_bytes()
    for o in ins.operands:
        src = comp.instrs.get(o)
        if src is not None:
            b += src.out_bytes()
    return b


def _fusion_bytes(comp: "Computation", ins: Instr,
                  comps: Dict[str, "Computation"]) -> float:
    """Fusion: parameters consumed only through slicing ops count at slice
    size; root dynamic-update-slice writes only the update."""
    fm = _CALLS.search(ins.rest)
    fcomp = comps.get(fm.group(1)) if fm else None
    if fcomp is None:
        b = ins.out_bytes()
        for o in ins.operands:
            src = comp.instrs.get(o)
            if src is not None:
                b += src.out_bytes()
        return b

    # map parameter number -> internal instr name, and uses per instr
    param_names: Dict[int, str] = {}
    uses: Dict[str, List[Instr]] = defaultdict(list)
    root_name = fcomp.order[-1] if fcomp.order else None
    for iname in fcomp.order:
        fi = fcomp.instrs[iname]
        if fi.op == "parameter":
            pm = re.match(r"\s*(\d+)", fi.rest)
            if pm:
                param_names[int(pm.group(1))] = iname
        for o in fi.operands:
            uses[o].append(fi)

    _TRANSPARENT = ("convert", "bitcast", "copy", "reshape", "broadcast")

    def sliced_bytes(name: str, depth: int = 0):
        """Effective read bytes if `name` is consumed only through slicing
        (following elementwise-transparent wrappers); None if not."""
        if depth > 3:
            return None
        eff = 0.0
        for u in uses.get(name, []):
            if u.op in _SLICING_OPS:
                eff += u.out_bytes()
            elif u.op == "dynamic-update-slice" and u.operands and \
                    u.operands[0] == name:
                upd = fcomp.instrs.get(u.operands[1]) if \
                    len(u.operands) > 1 else None
                eff += upd.out_bytes() if upd is not None else 0.0
            elif u.op in _TRANSPARENT:
                sub = sliced_bytes(u.name, depth + 1)
                if sub is None:
                    return None
                eff += sub
            else:
                return None
        return eff if uses.get(name) else None

    total = 0.0
    for k, oname in enumerate(ins.operands):
        src = comp.instrs.get(oname)
        if src is None:
            continue
        pname = param_names.get(k)
        eff = sliced_bytes(pname) if pname else None
        if eff is not None:
            total += min(eff, src.out_bytes())
        else:
            total += src.out_bytes()

    # output: if the fusion accumulates into a same-shaped parameter via
    # dynamic-update-slice (scan residual stacking), only the update is
    # written — walk through trailing convert/bitcast/copy wrappers.
    root = fcomp.instrs.get(root_name) if root_name else None
    seen = 0
    while root is not None and root.op in ("convert", "bitcast", "copy",
                                           "transpose") and root.operands \
            and seen < 4:
        root = fcomp.instrs.get(root.operands[0])
        seen += 1
    if root is not None and root.op == "dynamic-update-slice" and \
            len(root.operands) > 1:
        upd = fcomp.instrs.get(root.operands[1])
        total += upd.out_bytes() if upd is not None else ins.out_bytes()
    else:
        dus_updates = [
            fcomp.instrs.get(fi.operands[1])
            for n in fcomp.order
            for fi in [fcomp.instrs[n]]
            if fi.op == "dynamic-update-slice" and len(fi.operands) > 1
            and fi.operands[0] in uses  # writes into a parameter buffer
        ]
        dus_updates = [u for u in dus_updates if u is not None]
        out_b = ins.out_bytes()
        if dus_updates:
            upd_b = sum(u.out_bytes() for u in dus_updates)
            # in-place accumulation: write only the updates
            param_b = sum(
                fcomp.instrs[param_names[k]].out_bytes()
                for k in param_names
                if any(fcomp.instrs[n].op == "dynamic-update-slice"
                       and fcomp.instrs[n].operands
                       and fcomp.instrs[n].operands[0] == param_names[k]
                       for n in fcomp.order))
            if param_b > 0 and abs(param_b - out_b) / max(out_b, 1) < 0.6:
                out_b = min(out_b, upd_b + max(out_b - param_b, 0))
        total += out_b
    return total


def parse_computations(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = ""
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith((" ", "\t")):
            hm = _COMP_HEADER.match(line.strip())
            if hm and "{" in line:
                cur = Computation(name=hm.group(2),
                                  is_entry=bool(hm.group(1)))
                comps[cur.name] = cur
                if cur.is_entry:
                    entry_name = cur.name
            continue
        if cur is None:
            continue
        dm = _DEF_LINE.match(line)
        if not dm:
            continue
        name, type_str, op, rest = dm.groups()
        # operand list = %refs before any ', key=' metadata — good enough:
        # take refs in the argument parens segment (up to matching depth 0)
        arg_seg = _args_segment(rest)
        operands = _OPERAND.findall(arg_seg)
        ins = Instr(name=name, type_str=type_str, op=op, rest=rest,
                    operands=operands)
        cur.instrs[name] = ins
        cur.order.append(name)
    return comps, entry_name


def _args_segment(rest: str) -> str:
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i]
    return rest


def _multiplicities(comps: Dict[str, Computation], entry: str
                    ) -> Tuple[Dict[str, float], set]:
    """computation name -> execution count; plus fusion-internal set."""
    mult: Dict[str, float] = defaultdict(float)
    fused_internal = set()
    mult[entry] = 1.0
    # BFS through call edges
    todo = [entry]
    seen_edges = set()
    while todo:
        cname = todo.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for iname in comp.order:
            ins = comp.instrs[iname]
            targets: List[Tuple[str, float, bool]] = []
            if ins.op == "while":
                trip_m = _TRIP.search(ins.rest)
                trips = float(trip_m.group(1)) if trip_m else 1.0
                bm = _BODY.search(ins.rest)
                cm = _COND.search(ins.rest)
                if bm:
                    targets.append((bm.group(1), trips, False))
                if cm:
                    targets.append((cm.group(1), trips + 1, False))
            elif ins.op == "fusion":
                fm = _CALLS.search(ins.rest)
                if fm:
                    targets.append((fm.group(1), 1.0, True))
            elif ins.op in ("call", "custom-call"):
                tm = _TO_APPLY.search(ins.rest)
                if tm:
                    targets.append((tm.group(1), 1.0, False))
            elif ins.op == "conditional":
                bm = _BRANCHES.search(ins.rest)
                if bm:
                    for b in _OPERAND.findall(bm.group(1)):
                        targets.append((b, 1.0, False))
            elif ins.op in ("reduce", "reduce-window", "scatter", "sort",
                            "map", "select-and-scatter", "all-reduce",
                            "reduce-scatter"):
                tm = _TO_APPLY.search(ins.rest)
                if tm:
                    # applied elementwise; tiny comparator/adder — skip body
                    fused_internal.add(tm.group(1))
            for tgt, k, is_fused in targets:
                if is_fused:
                    fused_internal.add(tgt)
                key = (cname, iname, tgt)
                if key in seen_edges:
                    continue
                seen_edges.add(key)
                mult[tgt] += m * k
                todo.append(tgt)
    return mult, fused_internal


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_atoms = ins.out_atoms()
    out_elems = 1
    for _, shape in out_atoms:
        for d in shape:
            out_elems *= d
    cm = _CONTRACT.search(ins.rest)
    contract = 1
    if cm and ins.operands:
        lhs = comp.instrs.get(ins.operands[0])
        lhs_shape = None
        if lhs is not None:
            atoms = lhs.out_atoms()
            if atoms:
                lhs_shape = atoms[0][1]
        if lhs_shape is not None and cm.group(1):
            for d in cm.group(1).split(","):
                di = int(d)
                if di < len(lhs_shape):
                    contract *= lhs_shape[di]
    return 2.0 * out_elems * contract


def analyze(text: str) -> HloCost:
    comps, entry = parse_computations(text)
    cost = HloCost()
    if not entry:
        cost.warnings.append("no ENTRY computation found")
        return cost
    mult, fused_internal = _multiplicities(comps, entry)

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        internal = cname in fused_internal
        for iname in comp.order:
            ins = comp.instrs[iname]
            if ins.op == "dot":
                cost.flops += m * _dot_flops(comp, ins)
                cost.dot_count += 1
            elif ins.op == "convolution":
                cost.warnings.append(f"convolution not counted: {iname}")
            if internal:
                continue
            base = ins.op.replace("-start", "").replace("-done", "")
            if base in COLLECTIVE_OPS and not ins.op.endswith("-done"):
                opb = 0.0
                for o in ins.operands:
                    src = comp.instrs.get(o)
                    if src is not None:
                        opb += src.out_bytes()
                if opb == 0.0:
                    opb = ins.out_bytes()
                cost.collective_bytes += m * opb
                cost.collective_by_kind[base] = \
                    cost.collective_by_kind.get(base, 0.0) + m * opb
                cost.collective_counts[base] = \
                    cost.collective_counts.get(base, 0) + 1
            cost.bytes_accessed += m * _instr_bytes(comp, ins, comps)
    return cost
