"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required for the smoke tests and benches
that must see exactly one CPU device.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.config import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds a leading DCN 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axis_names)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    if multi_pod:
        return MeshConfig(shape=(2, 16, 16),
                          axis_names=("pod", "data", "model"))
    return MeshConfig(shape=(16, 16), axis_names=("data", "model"))


def single_device_mesh():
    """1x1 mesh for CPU tests exercising the pjit code path."""
    return jax.make_mesh((1, 1), ("data", "model"))
