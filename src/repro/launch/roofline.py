"""Roofline-term extraction from lowered/compiled XLA artifacts.

Three terms per (arch x shape x mesh), in seconds/step/chip (DESIGN.md §6):

    compute    = HLO_FLOPs   / (chips * PEAK_FLOPS)
    memory     = HLO_bytes   / (chips * HBM_BW)
    collective = coll_bytes  / (chips * ICI_BW)

FLOPs/bytes come from ``compiled.cost_analysis()`` (already whole-program,
all chips). Collective bytes are parsed from the compiled HLO text — the sum
of operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops, scaled by how many times each op's instruction
executes per step (ops inside a scanned while-loop execute trip-count times;
we recover trip counts from the scan bounds in the HLO when present).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.
"""
from __future__ import annotations

import dataclasses
import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    """'bf16[8,128]{1,0}' -> bytes. Tuple shapes handled by the caller."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0.0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt)
    if nbytes is None:
        return 0.0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nbytes


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    total_bytes: float = 0.0
    details: List[Dict] = field(default_factory=list)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective op, x while-loop trip counts."""
    stats = CollectiveStats()
    # map computation name -> trip count for while bodies created by scan:
    # jax scans lower to while loops whose condition compares the induction
    # variable against a constant; recover "constant" per body heuristically.
    trip_counts = _scan_trip_counts(hlo_text)

    current_comp = None
    for line in hlo_text.splitlines():
        striped = line.strip()
        comp_m = re.match(r"%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->", striped)
        if striped.startswith(("ENTRY", "%")) and "{" in striped and "=" not in striped:
            name_m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", striped)
            if name_m:
                current_comp = name_m.group(1)
            continue
        m = re.match(r"%?[\w\.\-]+\s*=\s*(\([^=]*?\)|[\w\[\],{}\/ ]+?)\s+"
                     r"([\w\-]+)\(", striped)
        if not m:
            continue
        shape_part, op = m.groups()
        base_op = op.replace("-start", "").replace("-done", "")
        if base_op not in _COLLECTIVES or op.endswith("-done"):
            continue
        # operand shapes: for *-start / plain ops, use the output shape
        # (all-reduce: out == in). For tuple outputs take the summed parts.
        if shape_part.startswith("("):
            parts = re.findall(r"\w+\[[\d,]*\]", shape_part)
            nb = sum(_shape_bytes(p) for p in parts) / 2  # (in, out) tuple
        else:
            nb = _shape_bytes(shape_part)
        mult = trip_counts.get(current_comp, 1)
        stats.counts[base_op] = stats.counts.get(base_op, 0) + 1
        stats.bytes_by_kind[base_op] = (
            stats.bytes_by_kind.get(base_op, 0.0) + nb * mult)
        stats.total_bytes += nb * mult
        if len(stats.details) < 200:
            stats.details.append({"op": base_op, "bytes": nb,
                                  "computation": current_comp,
                                  "trip_mult": mult})
    return stats


def _scan_trip_counts(hlo_text: str) -> Dict[str, int]:
    """Best-effort: body computation name -> trip count for scan loops."""
    out: Dict[str, int] = {}
    # while ops reference body=%name; trip count appears in backend_config
    # or via the condition's compare-with-constant. Try known_trip_count.
    for m in re.finditer(
            r'body=%?([\w\.\-]+).{0,400}?"known_trip_count":\{"n":"(\d+)"\}',
            hlo_text, re.S):
        out[m.group(1)] = int(m.group(2))
    if out:
        return out
    # fallback: constants in while conditions "compare(..., constant.N)"
    for m in re.finditer(
            r'known_trip_count[^\d]*(\d+)[^%]*body=%?([\w\.\-]+)', hlo_text):
        out[m.group(2)] = int(m.group(1))
    return out


@dataclass
class RooflineTerms:
    """All per-chip per-step (compiled SPMD HLO shapes are per-device)."""

    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_chip: float = 0.0
    useful_ratio: float = 0.0          # MODEL_FLOPS / HLO_FLOPs

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline_from_hlo(hlo_cost, chips: int,
                      model_flops_global: float = 0.0) -> RooflineTerms:
    """hlo_cost: launch.hlo_analysis.HloCost (per-chip numbers)."""
    flops = float(hlo_cost.flops)
    byts = float(hlo_cost.bytes_accessed)
    coll = float(hlo_cost.collective_bytes)
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    coll_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf_chip = model_flops_global / chips
    return RooflineTerms(
        flops_per_chip=flops, bytes_per_chip=byts,
        collective_bytes_per_chip=coll, chips=chips, compute_s=compute_s,
        memory_s=memory_s, collective_s=coll_s, dominant=dominant,
        model_flops_per_chip=mf_chip,
        useful_ratio=(mf_chip / flops if flops else 0.0))


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N_active*tokens (train) or 2*N_active*tokens (fwd)."""
    n_active = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one new token per sequence
    return 2.0 * n_active * tokens
