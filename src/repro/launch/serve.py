"""Serving driver: ``python -m repro.launch.serve --arch <id> [--mc]``.

Two deployment paths, mirroring the paper's compress-once/pre-loading
premise:

* ``--mc`` — run the staged compression pipeline inline (calibrate ->
  plan -> apply), optionally persisting the result with
  ``--save-artifact DIR``;
* ``--artifact DIR`` — boot straight from a saved
  :class:`~repro.core.pipeline.CompressedArtifact`: no calibration data, no
  GPTQ, just load + serve.

Deployment topology is orthogonal (see ``docs/serving.md``):

* ``--mesh DxM`` — build a (data, model) device mesh; artifacts stream in
  via :meth:`CompressedArtifact.load_sharded` (expert-major shard groups,
  per-host byte accounting printed) and packed expert planes are placed
  expert-parallel over the ``data`` axis;
* ``--ep`` — additionally route MoE dispatch through the explicit
  shard_map schedule (``sharding.moe_parallel``): dense expert stacks
  take the bf16 TP'd body, compressed artifacts take the quantized body
  (per-class packed planes sharded over ``data``, fused grouped
  ``kernels.moe_ffn`` kernel per shard — every bit class's expert count
  must divide the data axis);
* ``--num-hosts H [--host h]`` — *simulated* multi-host streaming on one
  process: every host's byte-balanced artifact slice is streamed and
  byte-accounted separately (``--host`` picks which host's view leads),
  then the slices are merged to boot the engine;
* ``--coordinator ADDR --processes N --process-id I`` — real
  ``jax.distributed`` boot (gloo collectives on CPU): with a ``--mesh``
  spanning the processes, each process streams only its placement slice
  of the artifact and serves as one shard of the distributed engine;
* ``--fleet --replicas N --fleet-hosts H`` — elastic fault-tolerant
  fleet serving (requires ``--artifact``): N block-owning replicas
  behind the admission-controlled router (``serve.router``), all
  traffic as messages over the fleet transport (``serve.transport``),
  each replica assembled from H per-host expert-block streams.
  Deterministic fault injection via ``--inject-failure`` covers process
  faults (``replica:<r>@<tick>`` / ``host:<r>.<h>@<tick>`` /
  ``join:<r>@<tick>``), message faults (``drop:<r>@<tick>`` /
  ``delay:<r>@<tick>+<d>`` / ``partition:<r>@<t1>..<t2>``) and
  stragglers (``slow:<r>@<tick>x<f>``, countered by hedging unless
  ``--no-hedge``); ``--chaos-seed`` + ``--chaos-drop/-dup/-delay/
  -reorder`` add seeded-random message chaos. The run reports
  availability, the shed-reason breakdown, retry/dedup/hedge counters,
  recovery events and delta vs full-reload bytes.

Then serves a synthetic batched workload and reports throughput +
compression stats.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import numpy as np

from repro.config import CompressionConfig
from repro.configs import get_config
from repro.core import pipeline as pipeline_lib
from repro.data.pipeline import calibration_batch
from repro.models.model_registry import build_model
from repro.serve.engine import (EngineConfig, GenerationOptions, Request,
                                ServeEngine, StaticServeEngine)
from repro.sharding import partitioning as part_lib


def _parse_odp(spec: str):
    """``'off'`` / ``'default'`` / a prune ratio like ``'0.3'``."""
    if spec in ("off", "default"):
        return spec
    try:
        return float(spec)
    except ValueError:
        raise SystemExit(
            f"--odp expects 'off', 'default' or a prune ratio in [0, 1), "
            f"got {spec!r}")


def _parse_mesh(spec: str):
    """``'2x1'`` -> a (data, model) mesh of that shape."""
    try:
        d, m = (int(v) for v in spec.lower().split("x"))
    except ValueError:
        raise SystemExit(f"--mesh expects DxM (e.g. 2x1), got {spec!r}")
    if d < 1 or m < 1:
        raise SystemExit(f"--mesh expects positive dims DxM (e.g. 2x1), "
                         f"got {spec!r}")
    n = len(jax.devices())
    if d * m > n:
        raise SystemExit(f"--mesh {spec} needs {d * m} devices, "
                         f"{n} visible (set XLA_FLAGS="
                         f"--xla_force_host_platform_device_count={d * m} "
                         "to simulate on CPU)")
    return jax.make_mesh((d, m), ("data", "model"))


def init_distributed(coordinator: str, num_processes: int,
                     process_id: int) -> None:
    """``jax.distributed`` boot for multi-process serving.

    CPU backends get the gloo collectives implementation first — the
    default (``'none'``) cannot run cross-process computations. Must run
    before any other jax call touches devices.
    """
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except AttributeError:      # option absent on this jax version
        pass
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)


def _boot_engine(build):
    """Run an engine constructor, turning EngineConfig/state-layer
    validation errors (per-capability checks naming the family's state
    kinds — e.g. ``--kv-pages`` with a pure-SSM model, where KV paging is
    a no-op) into CLI errors instead of tracebacks."""
    try:
        return build()
    except ValueError as e:
        raise SystemExit(f"[serve] {e}") from None


def serve(arch: str, *, smoke: bool = True, mc: bool = False,
          target_bits: float = 2.54, n_requests: int = 8,
          max_new: int = 16, batch_size: int = 4, prompt_len: int = 32,
          static: bool = False, mixed_lengths: bool = False,
          layout: str = "uniform", artifact_path=None, save_artifact=None,
          mesh_spec: Optional[str] = None, ep_dispatch: bool = False,
          num_hosts: Optional[int] = None, host: Optional[int] = None,
          coordinator: Optional[str] = None,
          num_processes: Optional[int] = None, odp="default",
          process_id: Optional[int] = None,
          kv_pages: Optional[int] = None, kv_page_size: int = 16,
          kv_quant: str = "off", kv_prefill_chunk: Optional[int] = None):
    if coordinator is not None:
        init_distributed(coordinator, num_processes, process_id)
    cfg = get_config(arch, smoke=smoke)
    model = build_model(cfg)
    engine_cls = StaticServeEngine if static else ServeEngine
    mesh = _parse_mesh(mesh_spec) if mesh_spec else None
    kv_pool = None
    max_seq_len = None
    if kv_pages is not None:
        from repro.serve.kv_pool import KVPoolConfig
        try:
            kv_pool = KVPoolConfig(num_pages=kv_pages,
                                   page_size=kv_page_size, quant=kv_quant,
                                   prefill_chunk=kv_prefill_chunk)
        except ValueError as e:
            raise SystemExit(f"--kv-pages: {e}") from None
        # workload bound (mixed <= it); vlm slots also hold the prefix
        max_seq_len = (prompt_len + max_new
                       + (cfg.num_prefix_tokens
                          if cfg.family == "vlm" else 0))
    eng_cfg = EngineConfig(batch_size=batch_size, mesh=mesh,
                           ep_dispatch=ep_dispatch, odp=odp,
                           max_seq_len=max_seq_len, kv_pool=kv_pool)
    artifact = None
    report = None

    if num_hosts is not None and part_lib.mesh_spans_processes(mesh):
        raise SystemExit(
            "--num-hosts simulates multi-host streaming on a single "
            "process; on a real multi-process mesh drop it — each "
            "process streams its own slice automatically")
    if artifact_path is not None:
        t0 = time.time()
        if num_hosts is not None:
            order = list(range(num_hosts))
            if host is not None:
                if not 0 <= host < num_hosts:
                    raise SystemExit(f"--host {host} out of range for "
                                     f"--num-hosts {num_hosts}")
                order.remove(host)
                order.insert(0, host)
            parts = []
            for h in order:
                part = pipeline_lib.CompressedArtifact.load_sharded(
                    artifact_path, num_hosts=num_hosts, host=h)
                st = part.load_stats
                k0, k1 = part.expert_range
                print(f"[serve] host {h}/{num_hosts} streams experts "
                      f"[{k0}:{k1}): {st.bytes_read}/{st.total_bytes} "
                      f"bytes ({st.read_fraction:.0%}), "
                      f"{st.groups_read}/{st.total_groups} shard groups")
                parts.append(part)
            print("[serve] simulated multi-host: merging host slices to "
                  "boot a single-process engine")
            artifact = pipeline_lib.CompressedArtifact.merge(parts)
            if mesh is not None:
                artifact.params = pipeline_lib.place_params(
                    artifact.params, mesh)
                artifact.placed_mesh = mesh
        elif mesh is not None:
            # load_sharded resolves single- vs multi-process internally:
            # on a mesh spanning processes this process streams only the
            # slice its addressable devices own — the partial artifact
            # becomes the local shard of the distributed engine
            artifact = pipeline_lib.CompressedArtifact.load_sharded(
                artifact_path, mesh)
            st = artifact.load_stats
            who = (f"process {jax.process_index()} streamed experts "
                   f"{artifact.expert_ranges}"
                   if part_lib.mesh_spans_processes(mesh)
                   else "sharded load")
            print(f"[serve] {who}: {st.bytes_read}/{st.total_bytes} "
                  f"bytes ({st.read_fraction:.0%}) in {st.files_read} "
                  f"files, {st.groups_read}/{st.total_groups} shard groups")
        else:
            artifact = pipeline_lib.CompressedArtifact.load(artifact_path)
        report = artifact.report
        print(f"[serve] loaded artifact from {artifact_path} in "
              f"{time.time() - t0:.2f}s: avg_bits={report.avg_bits:.2f} "
              f"layout={artifact.plan.layout} "
              f"scan_safe={artifact.scan_safe}")
        eng = _boot_engine(lambda: engine_cls.from_artifact(
            model, artifact, config=eng_cfg))
    else:
        params = model.init(jax.random.PRNGKey(0))
        if mc:
            assert cfg.is_moe, "--mc applies to MoE archs (DESIGN.md §4)"
            ccfg = CompressionConfig(enabled=True, target_bits=target_bits,
                                     group_size=32 if smoke else 128,
                                     odp_enabled=True)
            calib = jax.numpy.asarray(
                calibration_batch(cfg, 4 if smoke else ccfg.calib_sequences,
                                  64 if smoke else ccfg.calib_seq_len))
            t0 = time.time()
            record = pipeline_lib.calibrate(
                model, params, calib, bit_choices=tuple(ccfg.bit_choices),
                group_size=ccfg.group_size)
            plan = pipeline_lib.plan(record, ccfg, layout=layout)
            artifact = pipeline_lib.apply(model, params, plan, record)
            report = artifact.report
            print(f"[serve] MC compression in {time.time() - t0:.1f}s: "
                  f"avg_bits={report.avg_bits:.2f} "
                  f"compression={report.pmq.compression_ratio:.1%} "
                  f"odp_mu={report.odp_threshold:.3f} "
                  f"prune_rate={report.odp_prune_rate:.1%}")
            if save_artifact is not None:
                t0 = time.time()
                artifact.save(save_artifact)
                print(f"[serve] artifact saved to {save_artifact} in "
                      f"{time.time() - t0:.2f}s (boot it later with "
                      f"--artifact {save_artifact})")
        if artifact is not None:
            eng = _boot_engine(lambda: engine_cls.from_artifact(
                model, artifact, config=eng_cfg))
        else:       # uncompressed serving
            eng = _boot_engine(
                lambda: engine_cls(model, params, config=eng_cfg))

    rng = np.random.RandomState(0)
    reqs = []
    for i in range(n_requests):
        pl, mn = prompt_len, max_new
        if mixed_lengths:   # the regime where lockstep batching wastes most
            pl = int(rng.randint(max(4, prompt_len // 4), prompt_len + 1))
            mn = int(rng.randint(max(2, max_new // 4), max_new + 1))
        enc = None          # the encoder-side input some families need
        if cfg.family == "encdec":
            enc = rng.randn(cfg.encoder_seq,
                            cfg.d_model).astype(np.float32)
        elif cfg.family == "vlm":
            enc = rng.randn(cfg.num_prefix_tokens,
                            cfg.d_model).astype(np.float32)
        reqs.append(Request(
            uid=i, prompt=rng.randint(1, cfg.vocab_size, pl).astype(np.int32),
            enc_input=enc,
            options=GenerationOptions(max_new_tokens=mn)))
    results = eng.run(reqs)
    s = eng.stats
    print(f"[serve] {s.requests} requests, {s.generated_tokens} tokens, "
          f"prefill {s.prefill_s:.2f}s decode {s.decode_s:.2f}s "
          f"({s.decode_tokens_per_s:.1f} tok/s, "
          f"slot occupancy {s.occupancy:.0%})")
    return results, eng.stats, report


def serve_fleet(arch: str, *, artifact_path, smoke: bool = True,
                replicas: int = 2, fleet_hosts: int = 2,
                blocks_per_host: int = 2, n_requests: int = 8,
                max_new: int = 16, batch_size: int = 4,
                prompt_len: int = 32, inject=(), sla: Optional[int] = None,
                max_queue: int = 64, max_retries: int = 2,
                heartbeat_dir=None, odp="default", hedge: bool = True,
                chaos_seed: Optional[int] = None, chaos_drop: float = 0.0,
                chaos_dup: float = 0.0, chaos_delay: float = 0.0,
                chaos_reorder: float = 0.0,
                chaos_until: Optional[int] = None):
    """Boot an elastic fleet from a saved artifact and serve through the
    router's message transport, with optional scripted fault injection
    and/or seeded message chaos. Returns the
    :class:`~repro.serve.router.FleetReport`."""
    import tempfile
    from repro.runtime.supervisor import FaultInjector, parse_fault_spec
    from repro.serve.fleet import ShardedReplica
    from repro.serve.router import FleetRouter, RouterConfig
    from repro.serve.transport import ChaosConfig, FaultyTransport

    if artifact_path is None:
        raise SystemExit("--fleet requires --artifact DIR (fleet replicas "
                         "boot from per-host expert-block streams)")
    cfg = get_config(arch, smoke=smoke)
    model = build_model(cfg)
    t0 = time.time()
    pool = []
    for i in range(replicas):
        rep = ShardedReplica(model, artifact_path, replica_id=i,
                             num_hosts=fleet_hosts,
                             blocks_per_host=blocks_per_host,
                             batch_size=batch_size, odp=odp)
        st = rep.load_stats
        print(f"[fleet] replica {i}: {fleet_hosts} hosts x "
              f"{blocks_per_host} blocks, boot streamed "
              f"{st.bytes_read}/{st.total_bytes} bytes in {st.reads} reads")
        pool.append(rep)
    print(f"[fleet] {replicas} replicas booted in {time.time() - t0:.2f}s")

    events = [parse_fault_spec(s) for s in inject]
    chaos = None
    if any((chaos_seed is not None, chaos_drop, chaos_dup, chaos_delay,
            chaos_reorder)):
        chaos = ChaosConfig(seed=chaos_seed or 0, p_drop=chaos_drop,
                            p_dup=chaos_dup, p_delay=chaos_delay,
                            p_reorder=chaos_reorder, until=chaos_until)
        print(f"[fleet] message chaos on: seed {chaos.seed}, "
              f"drop {chaos.p_drop:.0%} dup {chaos.p_dup:.0%} "
              f"delay {chaos.p_delay:.0%} reorder {chaos.p_reorder:.0%}"
              + (f", heals after tick {chaos.until}"
                 if chaos.until is not None else ""))
    hb = heartbeat_dir or tempfile.mkdtemp(prefix="fleet_hb_")
    router = FleetRouter(
        pool, hb,
        config=RouterConfig(max_queue=max_queue, default_sla=sla,
                            max_retries=max_retries, hedge=hedge),
        injector=FaultInjector(events),
        transport=FaultyTransport(chaos))

    rng = np.random.RandomState(0)
    reqs = [Request(uid=i,
                    prompt=rng.randint(1, cfg.vocab_size,
                                       prompt_len).astype(np.int32),
                    options=GenerationOptions(max_new_tokens=max_new))
            for i in range(n_requests)]
    t0 = time.time()
    report = router.run(reqs)      # run() validates report.check()
    wall = time.time() - t0
    print(f"[fleet] {report.ticks} ticks in {wall:.2f}s: "
          f"{len(report.completed)}/{report.admitted} admitted requests "
          f"completed (availability {report.availability:.1%}), "
          f"{report.retries} retries, {len(report.sla_misses)} SLA misses")
    shed = {k: len(v) for k, v in report.shed.items() if v}
    print(f"[fleet] accounting balanced: shed by reason {shed or '{}'}"
          f", {len(report.fatal)} fatal")
    print(f"[fleet] transport: {report.transport.get('sent', 0)} sent, "
          f"{report.transport.get('dropped', 0)} dropped, "
          f"{report.transport.get('duplicated', 0)} duplicated; "
          f"{report.dedup_hits} dedup hits, "
          f"{report.duplicate_results} duplicate results discarded, "
          f"{report.redispatches} redispatches, "
          f"{report.hedges} hedges ({report.hedge_wins} wins)")
    for ev in report.breaker_events:
        print(f"[fleet] breaker: replica {ev['replica']} -> "
              f"{ev['state']} at tick {ev['tick']}"
              + (f" ({ev['reason']})" if "reason" in ev else ""))
    for d in report.deaths:
        print(f"[fleet] death: replica {d['replica']} at tick {d['tick']} "
              f"({d['reason']})")
    for ev in report.reshards:
        print(f"[fleet] reshard: {ev.kind} host {ev.host} — streamed "
              f"{ev.delta_bytes}/{ev.full_reload_bytes} expert bytes "
              f"({ev.blocks_moved} blocks, {ev.requeued} requeued, "
              f"{ev.recovery_s:.2f}s); {ev.note}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--mc", action="store_true")
    ap.add_argument("--bits", type=float, default=2.54)
    ap.add_argument("--layout", default="uniform",
                    choices=("uniform", "per_layer"))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--static", action="store_true",
                    help="use the lockstep static-batch engine")
    ap.add_argument("--mixed-lengths", action="store_true",
                    help="randomize prompt/output lengths per request")
    ap.add_argument("--artifact", default=None, metavar="DIR",
                    help="boot from a saved CompressedArtifact "
                         "(skips calibration/compression entirely)")
    ap.add_argument("--save-artifact", default=None, metavar="DIR",
                    help="with --mc: persist the CompressedArtifact here")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve expert-parallel on a (data, model) device "
                         "mesh, e.g. 2x1; artifacts stream in sharded")
    ap.add_argument("--ep", action="store_true",
                    help="with --mesh: explicit shard_map MoE dispatch "
                         "(dense experts or quantized artifacts whose "
                         "class counts divide the data axis)")
    ap.add_argument("--num-hosts", type=int, default=None, metavar="H",
                    help="with --artifact: simulate H-host streaming — "
                         "each host's byte-balanced slice is loaded and "
                         "accounted separately, then merged to boot")
    ap.add_argument("--host", type=int, default=None, metavar="I",
                    help="with --num-hosts: lead with host I's stream")
    ap.add_argument("--coordinator", default=None, metavar="ADDR",
                    help="jax.distributed coordinator (host:port); with "
                         "--processes/--process-id boots this process as "
                         "one shard of a multi-process engine")
    ap.add_argument("--processes", type=int, default=None, metavar="N")
    ap.add_argument("--process-id", type=int, default=None, metavar="I")
    ap.add_argument("--fleet", action="store_true",
                    help="elastic fleet serving behind the router "
                         "(requires --artifact); see --replicas, "
                         "--fleet-hosts, --inject-failure")
    ap.add_argument("--replicas", type=int, default=2, metavar="N",
                    help="with --fleet: engine replicas behind the router")
    ap.add_argument("--fleet-hosts", type=int, default=2, metavar="H",
                    help="with --fleet: hosts per replica (each streams "
                         "its expert-block share of the artifact)")
    ap.add_argument("--blocks-per-host", type=int, default=2, metavar="B",
                    help="with --fleet: block granularity for the "
                         "re-shard planner")
    ap.add_argument("--inject-failure", action="append", default=[],
                    metavar="SPEC",
                    help="with --fleet: scripted fault, repeatable — "
                         "'replica:<r>@<tick>' kills a replica, "
                         "'host:<r>.<h>@<tick>' kills one host (live "
                         "delta re-shard), 'join:<r>@<tick>' joins a "
                         "fresh host, 'drop:<r>@<tick>' loses that "
                         "tick's link messages, 'delay:<r>@<tick>+<d>' "
                         "holds them d ticks, 'partition:<r>@<t1>..<t2>' "
                         "cuts the link for the window, "
                         "'slow:<r>@<tick>x<f>' makes the replica an "
                         "f-times straggler (hedging target)")
    ap.add_argument("--no-hedge", action="store_true",
                    help="with --fleet: disable straggler hedging")
    ap.add_argument("--chaos-seed", type=int, default=None, metavar="S",
                    help="with --fleet: seeded-random message chaos on "
                         "the transport (see --chaos-drop/-dup/-delay/"
                         "-reorder)")
    ap.add_argument("--chaos-drop", type=float, default=0.0, metavar="P",
                    help="chaos: per-message drop probability")
    ap.add_argument("--chaos-dup", type=float, default=0.0, metavar="P",
                    help="chaos: per-message duplication probability")
    ap.add_argument("--chaos-delay", type=float, default=0.0, metavar="P",
                    help="chaos: per-message delay probability")
    ap.add_argument("--chaos-reorder", type=float, default=0.0,
                    metavar="P",
                    help="chaos: per-poll reorder probability")
    ap.add_argument("--chaos-until", type=int, default=None,
                    metavar="TICK",
                    help="chaos: heal the network after this tick "
                         "(guarantees eventual completion)")
    ap.add_argument("--sla", type=int, default=None, metavar="TICKS",
                    help="with --fleet: per-request completion deadline "
                         "in scheduling ticks (late queued requests are "
                         "shed)")
    ap.add_argument("--max-queue", type=int, default=64, metavar="Q",
                    help="with --fleet: admission queue bound (overflow "
                         "is shed)")
    ap.add_argument("--max-retries", type=int, default=2, metavar="R",
                    help="with --fleet: retries per request after "
                         "replica deaths")
    ap.add_argument("--kv-pages", type=int, default=None, metavar="N",
                    help="back the continuous engine's slots with a paged "
                         "KV pool of N pages (page 0 is reserved); see "
                         "--kv-page-size/--kv-quant/--kv-prefill-chunk")
    ap.add_argument("--kv-page-size", type=int, default=16, metavar="T",
                    help="with --kv-pages: tokens per KV page")
    ap.add_argument("--kv-quant", default="off",
                    choices=("off", "int8", "int4"),
                    help="with --kv-pages: quantized KV page storage "
                         "('off' is token-identical to contiguous)")
    ap.add_argument("--kv-prefill-chunk", type=int, default=None,
                    metavar="C",
                    help="with --kv-pages: prefill long prompts C tokens "
                         "per scheduling round, interleaved with decode")
    ap.add_argument("--odp", default="default", metavar="KNOB",
                    help="engine-wide Online Dynamic Pruning knob: "
                         "'default' (the artifact's calibrated threshold), "
                         "'off' (no pruning — token-identical to serving "
                         "without ODP), or an explicit prune ratio in "
                         "[0, 1) mapped via the calibration quantiles; "
                         "requests can still override per request")
    args = ap.parse_args()
    if args.host is not None and args.num_hosts is None:
        ap.error("--host requires --num-hosts")
    if args.coordinator is not None and (args.processes is None
                                         or args.process_id is None):
        ap.error("--coordinator requires --processes and --process-id")
    if args.fleet:
        if args.artifact is None:
            ap.error("--fleet requires --artifact")
        serve_fleet(args.arch, artifact_path=args.artifact,
                    replicas=args.replicas, fleet_hosts=args.fleet_hosts,
                    blocks_per_host=args.blocks_per_host,
                    n_requests=args.requests, max_new=args.max_new,
                    batch_size=args.batch, inject=args.inject_failure,
                    sla=args.sla, max_queue=args.max_queue,
                    max_retries=args.max_retries,
                    odp=_parse_odp(args.odp), hedge=not args.no_hedge,
                    chaos_seed=args.chaos_seed,
                    chaos_drop=args.chaos_drop, chaos_dup=args.chaos_dup,
                    chaos_delay=args.chaos_delay,
                    chaos_reorder=args.chaos_reorder,
                    chaos_until=args.chaos_until)
        return
    serve(args.arch, mc=args.mc, target_bits=args.bits,
          n_requests=args.requests, max_new=args.max_new,
          batch_size=args.batch, static=args.static,
          mixed_lengths=args.mixed_lengths, layout=args.layout,
          artifact_path=args.artifact, save_artifact=args.save_artifact,
          mesh_spec=args.mesh, ep_dispatch=args.ep,
          num_hosts=args.num_hosts, host=args.host,
          coordinator=args.coordinator, num_processes=args.processes,
          process_id=args.process_id, odp=_parse_odp(args.odp),
          kv_pages=args.kv_pages, kv_page_size=args.kv_page_size,
          kv_quant=args.kv_quant, kv_prefill_chunk=args.kv_prefill_chunk)


if __name__ == "__main__":
    main()
