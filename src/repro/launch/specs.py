"""Input specs + step builders for every (arch x shape) dry-run cell.

``input_specs(arch, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every model input (no device allocation) — tokens/labels for
training, request batches + KV caches for serving; modality frontends are
stubs supplying precomputed frame/patch embeddings per the assignment.

Cell policy (DESIGN.md §4): train_4k -> train_step; prefill_32k -> prefill;
decode_32k / long_500k -> serve_step (1 token against a seq_len cache).
long_500k only for sub-quadratic archs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SHAPES, ModelConfig, ShapeConfig, TrainConfig
from repro.configs import get_config
from repro.models.model_registry import build_model
from repro.train import optimizer as opt_lib
from repro.train.train_step import (TrainState, init_train_state,
                                    make_train_step)

F32 = jnp.float32
I32 = jnp.int32

# archs able to run the 500k-decode cell (sub-quadratic / bounded caches)
LONG_CONTEXT_ARCHS = {
    "falcon-mamba-7b",        # SSM: O(1) state
    "zamba2-1.2b",            # hybrid: SSM + windowed shared attention
    "h2o-danube-3-4b",        # SWA: ring KV bounded by the window
    "llama4-maverick-400b-a17b",  # chunked-local rings + sparse global layers
}

SKIP_NOTES = {
    "long_500k": "pure full-attention arch: unbounded KV + quadratic "
                 "prefill at 500k — skipped per assignment "
                 "(DESIGN.md §4)",
}


def cell_supported(arch: str, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, SKIP_NOTES["long_500k"]
    return True, ""


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def _frontend_specs(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    out = {}
    if cfg.family == "encdec":
        out["enc_frames"] = sds((batch, cfg.encoder_seq, cfg.d_model), F32)
    if cfg.family == "vlm":
        out["prefix_embeds"] = sds((batch, cfg.num_prefix_tokens,
                                    cfg.d_model), F32)
    return out


def input_specs(arch: str, shape_name: str,
                cfg: Optional[ModelConfig] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step function's *batch* inputs."""
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if shape.mode == "train":
        text = s - (cfg.num_prefix_tokens if cfg.family == "vlm" else 0)
        out["tokens"] = sds((b, text), I32)
        out["labels"] = sds((b, text), I32)
        out.update(_frontend_specs(cfg, b))
    elif shape.mode == "prefill":
        text = s - (cfg.num_prefix_tokens if cfg.family == "vlm" else 0)
        out["tokens"] = sds((b, text), I32)
        out.update(_frontend_specs(cfg, b))
    else:  # decode
        out["tokens"] = sds((b, 1), I32)
        out["pos"] = sds((), I32)
    return out


# ------------------------------------------------------------ step builders
def build_train_fn(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    model = build_model(cfg)
    step = make_train_step(model, cfg, tcfg)
    return model, step


def train_state_structs(model, tcfg: TrainConfig):
    return jax.eval_shape(lambda k: init_train_state(model, k, tcfg),
                          jax.random.PRNGKey(0))


def build_prefill_fn(cfg: ModelConfig, shape: ShapeConfig, mc=None):
    model = build_model(cfg)

    def prefill(params, batch):
        caches = model.init_caches(shape.global_batch, shape.seq_len)
        kwargs = {k: v for k, v in batch.items() if k != "tokens"}
        if cfg.family == "encdec":
            logits, caches2, _ = model.forward(
                params, batch["tokens"], caches=caches, mc=mc, **kwargs)
            return logits[:, -1], caches2
        logits, caches2, _ = model.forward(
            params, batch["tokens"], caches=caches, mc=mc, **kwargs)
        return logits[:, -1], caches2

    return model, prefill


def build_decode_fn(cfg: ModelConfig, shape: ShapeConfig, mc=None):
    model = build_model(cfg)

    def serve_step(params, caches, batch):
        extra = {}
        if cfg.family == "encdec":
            extra["cross"] = batch["cross"]
        logits, new_caches = model.decode_step(
            params, caches, batch["tokens"], batch["pos"],
            **({"mc": mc} if cfg.family not in ("encdec",) else {}),
            **extra)
        return logits, new_caches

    return model, serve_step


def cache_structs(model, cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        functools.partial(model.init_caches, shape.global_batch,
                          shape.seq_len))


def decode_extra_structs(model, cfg: ModelConfig, shape: ShapeConfig):
    """Extra serve_step inputs beyond tokens/pos (whisper cross-KV)."""
    if cfg.family != "encdec":
        return {}
    b = shape.global_batch
    nkv, h = cfg.num_kv_heads, cfg.head_dim
    kv = sds((cfg.num_layers, b, cfg.encoder_seq, nkv, h), jnp.bfloat16)
    from repro.models.encdec import CrossKV
    return {"cross": CrossKV(k=kv, v=kv)}
