"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

Production path on a pod: build the mesh, shard the train state, run the
preemption-safe loop (checkpoint/resume, heartbeat, straggler detection)
over the deterministic data pipeline. On this CPU container use ``--smoke``
(reduced config, 1x1 mesh) — the same code path end to end.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import MeshConfig, TrainConfig
from repro.configs import get_config
from repro.data.pipeline import SyntheticTextConfig, SyntheticTokenDataset
from repro.checkpoint.checkpointer import CheckpointManager
from repro.launch.mesh import make_mesh, single_device_mesh
from repro.models.model_registry import build_model
from repro.runtime.fault_tolerance import (Heartbeat, StragglerDetector,
                                           run_with_fault_tolerance)
from repro.sharding import context as shctx
from repro.train.train_step import init_train_state, make_train_step


def train(arch: str, *, smoke: bool = True, steps: int = 50,
          global_batch: int = 8, seq_len: int = 64,
          checkpoint_dir: str = "/tmp/repro_ckpt", checkpoint_every: int = 20,
          learning_rate: float = 1e-3, log_every: int = 10,
          metrics_path: str | None = None, resume: bool = True):
    cfg = get_config(arch, smoke=smoke)
    tcfg = TrainConfig(learning_rate=learning_rate, warmup_steps=10,
                       total_steps=steps, checkpoint_every=checkpoint_every,
                       optimizer="adamw8bit")
    model = build_model(cfg)
    mesh = single_device_mesh()
    shctx.set_mesh_axes(tuple(mesh.axis_names),
                        tuple(mesh.shape[a] for a in mesh.axis_names))

    ds = SyntheticTokenDataset(SyntheticTextConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len,
        global_batch=global_batch, seed=tcfg.seed), cfg)
    step_fn = jax.jit(make_train_step(model, cfg, tcfg))
    mgr = CheckpointManager(checkpoint_dir, keep=tcfg.keep_checkpoints)
    if not resume and mgr.latest_step() is not None:
        import shutil
        shutil.rmtree(checkpoint_dir, ignore_errors=True)
    hb = Heartbeat(Path(checkpoint_dir) / "heartbeats")
    det = StragglerDetector()
    metrics_log = []

    def make_state():
        return init_train_state(model, jax.random.PRNGKey(tcfg.seed), tcfg)

    def one_step(state, step):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(step).items()}
        state, metrics = step_fn(state, batch)
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            metrics_log.append(m)
            print(f"[train] step {step:5d} loss={m['loss']:.4f} "
                  f"lr={m['lr']:.2e} gnorm={m['grad_norm']:.2f}")
        return state

    with jax.set_mesh(mesh):
        report = run_with_fault_tolerance(
            total_steps=steps, make_state=make_state, step_fn=one_step,
            ckpt_manager=mgr, checkpoint_every=checkpoint_every,
            heartbeat=hb, detector=det)
    if metrics_path:
        Path(metrics_path).write_text(json.dumps(metrics_log, indent=2))
    print(f"[train] done: {report.completed_steps} steps, "
          f"{report.restarts} restarts, "
          f"{report.straggler_events} straggler events")
    return metrics_log, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()
    train(args.arch, smoke=args.smoke, steps=args.steps,
          global_batch=args.batch, seq_len=args.seq,
          learning_rate=args.lr, checkpoint_dir=args.ckpt_dir,
          metrics_path=args.metrics, resume=not args.fresh)


if __name__ == "__main__":
    main()
