"""Encoder-decoder transformer (whisper-medium backbone).

Per the assignment the audio conv frontend is a stub: the encoder consumes
precomputed frame embeddings ``(B, T_enc, d_model)`` from ``input_specs()``.
Decoder blocks: causal self-attention (KV-cached) + cross-attention over the
encoder output (K/V precomputed once at prefill) + FFN.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models.layers import attention as attn_lib
from repro.models.layers import core as core_lib
from repro.models.layers.attention import KVCache
from repro.sharding import context as shctx

Params = Dict


class CrossKV(NamedTuple):
    k: jax.Array    # (B, T_enc, Nkv, H)
    v: jax.Array


def _init_enc_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {"norm_attn": core_lib.init_norm(cfg),
            "attn": attn_lib.init_attention(ks[0], cfg),
            "norm_ffn": core_lib.init_norm(cfg),
            "ffn": core_lib.init_mlp(ks[1], cfg)}


def _init_dec_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {"norm_self": core_lib.init_norm(cfg),
            "self_attn": attn_lib.init_attention(ks[0], cfg),
            "norm_cross": core_lib.init_norm(cfg),
            "cross_attn": attn_lib.init_attention(ks[1], cfg, cross=True),
            "norm_ffn": core_lib.init_norm(cfg),
            "ffn": core_lib.init_mlp(ks[2], cfg)}


def _specs_enc_block(cfg):
    return {"norm_attn": core_lib.specs_norm(cfg),
            "attn": attn_lib.specs_attention(cfg),
            "norm_ffn": core_lib.specs_norm(cfg),
            "ffn": core_lib.specs_mlp(cfg)}


def _specs_dec_block(cfg):
    return {"norm_self": core_lib.specs_norm(cfg),
            "self_attn": attn_lib.specs_attention(cfg),
            "norm_cross": core_lib.specs_norm(cfg),
            "cross_attn": attn_lib.specs_attention(cfg, cross=True),
            "norm_ffn": core_lib.specs_norm(cfg),
            "ffn": core_lib.specs_mlp(cfg)}


class EncDecModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key) -> Params:
        cfg = self.cfg
        ne, nd = cfg.encoder_layers, cfg.num_layers
        keys = jax.random.split(key, ne + nd + 4)
        enc = [_init_enc_block(keys[i], cfg) for i in range(ne)]
        dec = [_init_dec_block(keys[ne + i], cfg) for i in range(nd)]
        return {
            "embed": core_lib.init_embedding(keys[-1], cfg),
            "enc_pos": core_lib.init_learned_pos(keys[-2], cfg.encoder_seq,
                                                 cfg.d_model),
            "dec_pos": core_lib.init_learned_pos(keys[-3], cfg.max_pos,
                                                 cfg.d_model),
            "encoder": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
            "decoder": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
            "enc_final_norm": core_lib.init_norm(cfg),
            "final_norm": core_lib.init_norm(cfg),
        }

    def param_specs(self) -> Params:
        cfg = self.cfg
        stack = lambda tree: jax.tree.map(
            lambda sp: P(*((None,) + tuple(sp))), tree,
            is_leaf=lambda v: isinstance(v, P))
        return {
            "embed": core_lib.specs_embedding(cfg),
            "enc_pos": core_lib.specs_learned_pos(),
            "dec_pos": core_lib.specs_learned_pos(),
            "encoder": stack(_specs_enc_block(cfg)),
            "decoder": stack(_specs_dec_block(cfg)),
            "enc_final_norm": core_lib.specs_norm(cfg),
            "final_norm": core_lib.specs_norm(cfg),
        }

    # ---- encoder ----
    def encode(self, params, enc_frames: jax.Array, *, scan=None) -> jax.Array:
        cfg = self.cfg
        dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        x = enc_frames.astype(dtype)
        x = core_lib.add_learned_pos(params["enc_pos"], x, 0)
        x = shctx.constrain_batch(x)
        t = x.shape[1]
        positions = jnp.arange(t, dtype=jnp.int32)

        def body(x, p_l):
            h = core_lib.apply_norm(p_l["norm_attn"], x, cfg)
            out, _, _ = attn_lib.apply_attention(
                p_l["attn"], h, cfg=cfg, positions=positions, causal=False)
            x = x + out
            h2 = core_lib.apply_norm(p_l["norm_ffn"], x, cfg)
            x = x + core_lib.apply_mlp(p_l["ffn"], h2, cfg)
            return x, None

        use_scan = cfg.scan_layers if scan is None else scan
        if use_scan:
            body_fn = body
            if cfg.remat_policy != "none":
                body_fn = jax.checkpoint(body)
            x, _ = jax.lax.scan(body_fn, x, params["encoder"])
        else:
            for i in range(cfg.encoder_layers):
                x, _ = body(x, jax.tree.map(lambda a: a[i],
                                            params["encoder"]))
        return core_lib.apply_norm(params["enc_final_norm"], x, cfg)

    # ---- cross K/V precompute (prefill-time) ----
    def cross_kv(self, params, enc_out: jax.Array):
        cfg = self.cfg
        h, nkv = cfg.head_dim, cfg.num_kv_heads

        def per_layer(p_l):
            src = enc_out
            k = (src @ p_l["cross_attn"]["wk"].astype(src.dtype))
            v = (src @ p_l["cross_attn"]["wv"].astype(src.dtype))
            if "bv" in p_l["cross_attn"]:
                v = v + p_l["cross_attn"]["bv"].astype(src.dtype)
            b, t = src.shape[:2]
            return CrossKV(k.reshape(b, t, nkv, h), v.reshape(b, t, nkv, h))

        return jax.lax.map(per_layer, params["decoder"])

    # ---- decoder ----
    def decode(self, params, tokens, enc_out=None, cross=None, *,
               caches=None, start_pos=0, scan=None, kv_table=None):
        cfg = self.cfg
        dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        x = core_lib.embed_tokens(params["embed"], tokens, cfg, dtype)
        x = core_lib.add_learned_pos(params["dec_pos"], x, start_pos)
        x = shctx.constrain_batch(x)
        s = x.shape[1]
        # (S,) for a shared scalar start, (B, S) for per-row slot positions
        positions = core_lib.position_grid(s, start_pos)

        def cross_attend(p_l, x, kv: CrossKV):
            h = core_lib.apply_norm(p_l["norm_cross"], x, cfg)
            b, sq, _ = h.shape
            nq, hd = cfg.num_heads, cfg.head_dim
            q = h @ p_l["cross_attn"]["wq"].astype(h.dtype)
            if "bq" in p_l["cross_attn"]:
                q = q + p_l["cross_attn"]["bq"].astype(h.dtype)
            q = q.reshape(b, sq, nq, hd)
            mask = jnp.ones((sq, kv.k.shape[1]), bool)
            out, _ = attn_lib.attend(q, kv.k, kv.v, mask)
            out = out.reshape(b, sq, nq * hd) @ \
                p_l["cross_attn"]["wo"].astype(h.dtype)
            if "bo" in p_l["cross_attn"]:
                out = out + p_l["cross_attn"]["bo"].astype(h.dtype)
            return out

        def body(x, xs):
            p_l, kv_l, cache_l = xs
            h = core_lib.apply_norm(p_l["norm_self"], x, cfg)
            out, new_cache, _ = attn_lib.apply_attention(
                p_l["self_attn"], h, cfg=cfg, positions=positions,
                cache=cache_l, kv_table=kv_table)
            x = x + out
            x = x + cross_attend(p_l, x, kv_l)
            h2 = core_lib.apply_norm(p_l["norm_ffn"], x, cfg)
            x = x + core_lib.apply_mlp(p_l["ffn"], h2, cfg)
            return x, new_cache

        if cross is None:
            assert enc_out is not None
            cross = self.cross_kv(params, enc_out)

        use_scan = cfg.scan_layers if scan is None else scan
        if use_scan:
            body_fn = body
            if cfg.remat_policy != "none":
                body_fn = jax.checkpoint(body)
            x, new_caches = jax.lax.scan(body_fn, x,
                                         (params["decoder"], cross, caches))
        else:
            ncs = [] if caches is not None else None
            for i in range(cfg.num_layers):
                xs_i = (jax.tree.map(lambda a: a[i], params["decoder"]),
                        jax.tree.map(lambda a: a[i], cross),
                        None if caches is None else
                        jax.tree.map(lambda a: a[i], caches))
                x, nc = body(x, xs_i)
                if ncs is not None:
                    ncs.append(nc)
            new_caches = None if ncs is None else \
                jax.tree.map(lambda *t: jnp.stack(t), *ncs)

        x = core_lib.apply_norm(params["final_norm"], x, cfg)
        logits = core_lib.unembed(params["embed"], x, cfg)
        return logits, new_caches

    # ---- top-level entry points ----
    def forward(self, params, tokens, *, enc_frames=None, cross=None,
                caches=None, start_pos=0, mc=None, scan=None,
                collect_aux=False, token_mask=None, odp_threshold=None,
                kv_table=None):
        # token_mask / odp_threshold accepted for engine API parity (no
        # MoE dispatch). ``cross`` lets the engine reuse admission-time
        # cross-KV instead of re-encoding every prefill.
        if cross is None:
            if enc_frames is None:
                raise ValueError(
                    "EncDecModel.forward needs enc_frames (to encode) or "
                    "a precomputed cross (cross-attention K/V)")
            cross = self.cross_kv(params,
                                  self.encode(params, enc_frames, scan=scan))
        logits, new_caches = self.decode(params, tokens, cross=cross,
                                         caches=caches, start_pos=start_pos,
                                         scan=scan, kv_table=kv_table)
        return logits, new_caches, {}

    def init_caches(self, batch: int, capacity: int, *,
                    linear: bool = False):
        # linear accepted for state-layer API parity; encdec decoder
        # caches are always full linear layout
        cfg = self.cfg
        one = attn_lib.init_cache(cfg, batch, capacity)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape),
            one)

    def init_paged_caches(self, num_pages: int, page_size: int, *,
                          quant: str = "off", batch: int = 1):
        """Per-decoder-layer paged self-attention KV pools, leaves
        (num_layers, P, ps, Nkv, H). ``batch`` is accepted for state-layer
        API parity — cross-KV lives in the engine's shared-state pool, not
        here."""
        cfg = self.cfg
        cdt = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
        bits = {"off": 16, "int8": 8, "int4": 4}[quant]
        one = attn_lib.init_paged_cache(cfg, num_pages, page_size,
                                        bits=bits, dtype=cdt)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape),
            one)

    def init_cross_state(self, batch: int) -> CrossKV:
        """Zero per-slot cross-KV pool entry: (L, B, T_enc, Nkv, H) per
        leaf, batch at axis 1 like every other per-slot state kind."""
        cfg = self.cfg
        dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        shape = (cfg.num_layers, batch, cfg.encoder_seq,
                 cfg.num_kv_heads, cfg.head_dim)
        return CrossKV(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))

    def state_kinds(self):
        from repro.serve import slot_state
        return slot_state.state_kinds(self.cfg)

    def decode_step(self, params, caches, tokens, pos, *, cross, mc=None,
                    token_mask=None, odp_threshold=None, kv_table=None):
        logits, new_caches = self.decode(params, tokens, cross=cross,
                                         caches=caches, start_pos=pos,
                                         kv_table=kv_table)
        return logits, new_caches
