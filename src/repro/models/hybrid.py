"""Hybrid Mamba2 + weight-shared attention backbone (zamba2-1.2b).

A stack of Mamba-2 layers with a single **weight-shared** transformer block
(attention + FFN) interleaved every ``shared_attn_period`` layers — the
zamba2 signature. Mamba layers are grouped and scanned; the shared block is
invoked between groups (weight sharing across invocations is exact). The
shared block uses a sliding window so the long_500k decode cell stays
sub-quadratic (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models.layers import attention as attn_lib
from repro.models.layers import core as core_lib
from repro.models.layers import ssm as ssm_lib
from repro.models.layers.attention import KVCache
from repro.models.layers.ssm import SSMState
from repro.sharding import context as shctx

Params = Dict


class HybridModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.period = cfg.shared_attn_period or cfg.num_layers
        self.n_groups = cfg.num_layers // self.period
        self.remainder = cfg.num_layers - self.n_groups * self.period

    def init(self, key) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, cfg.num_layers + 4)
        layers = [
            {"norm": core_lib.init_norm(cfg),
             "mixer": ssm_lib.init_mamba2(keys[i], cfg)}
            for i in range(cfg.num_layers)
        ]
        shared = {
            "norm_attn": core_lib.init_norm(cfg),
            "attn": attn_lib.init_attention(keys[-3], cfg),
            "norm_ffn": core_lib.init_norm(cfg),
            "ffn": core_lib.init_mlp(keys[-4], cfg),
        }
        return {
            "embed": core_lib.init_embedding(keys[-1], cfg),
            "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *layers),
            "shared": shared,
            "final_norm": core_lib.init_norm(cfg),
        }

    def param_specs(self) -> Params:
        cfg = self.cfg
        blk = {"norm": core_lib.specs_norm(cfg),
               "mixer": ssm_lib.specs_mamba2(cfg)}
        return {
            "embed": core_lib.specs_embedding(cfg),
            "layers": jax.tree.map(
                lambda sp: P(*((None,) + tuple(sp))), blk,
                is_leaf=lambda v: isinstance(v, P)),
            "shared": {
                "norm_attn": core_lib.specs_norm(cfg),
                "attn": attn_lib.specs_attention(cfg),
                "norm_ffn": core_lib.specs_norm(cfg),
                "ffn": core_lib.specs_mlp(cfg),
            },
            "final_norm": core_lib.specs_norm(cfg),
        }

    def _shared_block(self, params, x, positions, cache, kv_table=None):
        cfg = self.cfg
        p = params["shared"]
        h = core_lib.apply_norm(p["norm_attn"], x, cfg)
        window = jnp.asarray(cfg.window_size or attn_lib.GLOBAL_WINDOW,
                             jnp.int32)
        out, new_cache, _ = attn_lib.apply_attention(
            p["attn"], h, cfg=cfg, positions=positions, window=window,
            cache=cache, kv_table=kv_table)
        x = x + out
        h2 = core_lib.apply_norm(p["norm_ffn"], x, cfg)
        return x + core_lib.apply_mlp(p["ffn"], h2, cfg), new_cache

    def forward(self, params, tokens, *, caches=None, start_pos=0,
                mc=None, scan=None, collect_aux=False, prefix_embeds=None,
                token_mask=None, odp_threshold=None, kv_table=None):
        # token_mask / odp_threshold are accepted for engine API parity
        # (no MoE dispatch here); kv_table routes the shared attention
        # block's KV through the engine's page table
        cfg = self.cfg
        dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        x = core_lib.embed_tokens(params["embed"], tokens, cfg, dtype)
        x = shctx.constrain_batch(x)
        s = x.shape[1]
        positions = core_lib.position_grid(s, start_pos)
        use_scan = cfg.scan_layers if scan is None else scan

        ssm_caches = None if caches is None else caches["ssm"]
        attn_caches = None if caches is None else caches["attn"]

        def mamba_body(x, xs):
            p_l, st = xs
            h = core_lib.apply_norm(p_l["norm"], x, cfg)
            out, new_state = ssm_lib.apply_mamba2(p_l["mixer"], h, cfg,
                                                  state=st)
            return x + out, new_state

        def run_group(x, g0, count, group_idx):
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, g0, count, 0)
            p_g = jax.tree.map(sl, params["layers"])
            st_g = None if ssm_caches is None else \
                jax.tree.map(sl, ssm_caches)
            if use_scan:
                body = jax.checkpoint(mamba_body) \
                    if cfg.remat_policy != "none" else mamba_body
                x, new_states = jax.lax.scan(body, x, (p_g, st_g))
            else:
                ns = []
                for i in range(count):
                    x, st = mamba_body(x, (
                        jax.tree.map(lambda a: a[i], p_g),
                        None if st_g is None else
                        jax.tree.map(lambda a: a[i], st_g)))
                    ns.append(st)
                new_states = None if st_g is None else \
                    jax.tree.map(lambda *t: jnp.stack(t), *ns)
            return x, new_states

        new_ssm, new_attn = [], []
        for g in range(self.n_groups):
            x, ns = run_group(x, g * self.period, self.period, g)
            new_ssm.append(ns)
            ac = None if attn_caches is None else \
                jax.tree.map(lambda a: a[g], attn_caches)
            x, nac = self._shared_block(params, x, positions, ac,
                                        kv_table=kv_table)
            new_attn.append(nac)
        if self.remainder:
            x, ns = run_group(x, self.n_groups * self.period,
                              self.remainder, self.n_groups)
            new_ssm.append(ns)

        new_caches = None
        if caches is not None:
            ssm_all = jax.tree.map(lambda *t: jnp.concatenate(t, 0),
                                   *new_ssm)
            attn_all = jax.tree.map(lambda *t: jnp.stack(t), *new_attn)
            new_caches = {"ssm": ssm_all, "attn": attn_all}

        x = core_lib.apply_norm(params["final_norm"], x, cfg)
        logits = core_lib.unembed(params["embed"], x, cfg)
        return logits, new_caches, {}

    def init_caches(self, batch: int, capacity: int, *,
                    linear: bool = False):
        # linear=True forces a full-capacity non-ring attention cache (the
        # engine's paged-prefill scratch: every position must survive to
        # be scattered into pages)
        cfg = self.cfg
        states = [ssm_lib.init_ssm_state(cfg, batch)
                  for _ in range(cfg.num_layers)]
        ssm = jax.tree.map(lambda *t: jnp.stack(t), *states)
        ring = (not linear) and capacity > (cfg.window_size or capacity)
        cap = min(capacity, cfg.window_size + 8) if ring else capacity
        cdt = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
        one = attn_lib.init_cache(cfg, batch, cap, ring=ring, dtype=cdt)
        attn = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.n_groups,) + a.shape), one)
        return {"ssm": ssm, "attn": attn}

    def init_paged_caches(self, num_pages: int, page_size: int, *,
                          quant: str = "off", batch: int = 1):
        """Paged pools for the shared attention block — one pool per
        group, leaves (n_groups, P, ps, Nkv, H) — next to a dense SSM
        state pool with ``batch`` per-row-lifetime entries."""
        cfg = self.cfg
        states = [ssm_lib.init_ssm_state(cfg, batch)
                  for _ in range(cfg.num_layers)]
        ssm = jax.tree.map(lambda *t: jnp.stack(t), *states)
        cdt = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
        bits = {"off": 16, "int8": 8, "int4": 4}[quant]
        one = attn_lib.init_paged_cache(cfg, num_pages, page_size,
                                        bits=bits, dtype=cdt)
        attn = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.n_groups,) + a.shape), one)
        return {"ssm": ssm, "attn": attn}

    def state_kinds(self):
        from repro.serve import slot_state
        return slot_state.state_kinds(self.cfg)

    def decode_step(self, params, caches, tokens, pos, *, mc=None,
                    token_mask=None, odp_threshold=None, kv_table=None):
        logits, new_caches, _ = self.forward(
            params, tokens, caches=caches, start_pos=pos, mc=mc,
            token_mask=token_mask, odp_threshold=odp_threshold,
            kv_table=kv_table)
        return logits, new_caches
