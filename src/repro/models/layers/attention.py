"""Attention: GQA/MQA/MHA with the mask family the assigned archs need.

Variants (selected per config / per layer-kind scalars so alternating
patterns run inside a single scanned layer stack):

* full causal, sliding-window (mistral/danube/zamba2-shared), chunked-local
  with periodic global layers (llama4 iRoPE), local/global alternation
  (gemma2), bidirectional encoder, prefix-LM (paligemma), cross-attention
  (whisper decoder);
* attention-logit softcapping (gemma2), QK-norm (llama4), biases (whisper);
* decode with a preallocated KV cache — linear or ring-buffer (sliding
  window) layout; ring buffers bound long_500k cache memory by the window.

The layer can additionally emit the **attention-received column sums** that
ODP's token-importance metric consumes (paper Eq. 6) — computed from the
same probabilities tensor before it is contracted with V, so the only extra
cost is an (H,Sq,Sk)->(Sk,) reduction (fused by the `token_importance`
Pallas kernel on TPU).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models.layers.core import _dense_init, apply_rope

Params = Dict
NEG_INF = -2.0e38

# layer-kind window sentinel: "global" layers get an effectively-infinite
# window so alternation is a per-layer scalar, not a structural change.
GLOBAL_WINDOW = np.int32(2 ** 30)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    """KV cache; optionally int8-quantized (beyond-paper, KIVI-style).

    int8 mode stores per-(position, head) absmax scales and **folds them
    into the attention math** instead of dequantizing the cache:
        scores[.., s] = (q . k_q[s]) * kscale[s]
        out           = (probs * vscale[s]) @ v_q
    — exact, zero extra HBM traffic, int8 MXU-native.
    """

    k: jax.Array          # (B, C, Nkv, H) bf16 or int8
    v: jax.Array          # (B, C, Nkv, H)
    pos: jax.Array        # (B, C) absolute position stored per row (-1 empty)
    # pos is per batch row so rows can live independent lifetimes — the
    # continuous-batching engine admits/retires requests per slot (row)
    # static: ring-buffer (sliding window) vs linear layout
    ring: bool = dataclasses.field(default=False,
                                   metadata=dict(static=True))
    kscale: Optional[jax.Array] = None   # (B, C, Nkv) f32
    vscale: Optional[jax.Array] = None

    @property
    def quantized(self) -> bool:
        return self.kscale is not None


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PagedKVCache:
    """Physical KV page pool for the paged serving memory layer.

    Unlike :class:`KVCache` there is no batch axis: storage is a flat pool
    of ``(num_pages, page_size)`` token slots shared by every decode slot.
    Which pages belong to which batch row is the engine's **page table**
    (``kv_table``, a ``(B, max_pages)`` int32 jit *input* of
    ``decode_step`` — mixed page counts never retrace). Logical token
    index ``t`` of a row lives at ``table[b, t // page_size]`` offset
    ``t % page_size``; page 0 is the trash page unused entries point at.

    ``bits`` selects storage: 16 = model dtype, 8 = int8 codes +
    per-(position, head) absmax scales, 4 = packed int4 (two codes per
    byte along head_dim) + scales. Scales are folded into the attention
    math on read (exact — pinned by ``tests/test_kv_quant.py``).
    """

    k: jax.Array          # (P, ps, Nkv, H) model-dtype/int8; (.., H//2) int4
    v: jax.Array
    kscale: Optional[jax.Array] = None   # (P, ps, Nkv) f32
    vscale: Optional[jax.Array] = None
    bits: int = dataclasses.field(default=16, metadata=dict(static=True))

    @property
    def page_size(self) -> int:
        return self.k.shape[1]


def _kv_quantize(x: jax.Array, bits: int = 8):
    """(..., Nkv, H) -> int8 codes + (..., Nkv) per-(position, head) absmax
    scales. ``bits`` selects the code range: 8 -> [-127, 127], 4 -> [-7, 7]
    (int4 codes, stored packed two-per-byte in the paged pool)."""
    levels = 2 ** (bits - 1) - 1
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax, 1e-8) / levels
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -levels, levels).astype(jnp.int8)
    return q, scale


def _pack_int4(codes: jax.Array) -> jax.Array:
    """Pack int4 codes in [-7, 7] pairwise along the last axis:
    (..., H) int8 -> (..., H//2) int8 (low nibble = even index)."""
    lo = (codes[..., 0::2] + 8).astype(jnp.uint8)
    hi = (codes[..., 1::2] + 8).astype(jnp.uint8)
    return (lo | (hi << 4)).astype(jnp.int8)


def _unpack_int4(packed: jax.Array) -> jax.Array:
    """(..., H//2) int8 -> (..., H) int8 codes in [-7, 7]."""
    u = packed.astype(jnp.uint8)
    lo = (u & 0xF).astype(jnp.int8) - 8
    hi = (u >> 4).astype(jnp.int8) - 8
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    d, h = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 6)
    p = {
        "wq": _dense_init(ks[0], (d, nq * h)),
        "wk": _dense_init(ks[1], (d, nkv * h)),
        "wv": _dense_init(ks[2], (d, nkv * h)),
        "wo": _dense_init(ks[3], (nq * h, d)),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((nq * h,), jnp.float32)
        p["bv"] = jnp.zeros((nkv * h,), jnp.float32)
        p["bo"] = jnp.zeros((d,), jnp.float32)
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.ones((h,), jnp.float32)
        p["k_norm"] = jnp.ones((h,), jnp.float32)
    return p


def specs_attention(cfg: ModelConfig, cross: bool = False) -> Params:
    s = {"wq": P("data", "model"), "wk": P("data", "model"),
         "wv": P("data", "model"), "wo": P("model", "data")}
    if cfg.attn_bias:
        s.update(bq=P("model"), bv=P("model"), bo=P(None))
    if cfg.use_qk_norm:
        s.update(q_norm=P(None), k_norm=P(None))
    return s


def _qk_rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(x32 ** 2, -1, keepdims=True)
    return (x32 * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


def build_mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool = True,
               window: Optional[jax.Array] = None,
               chunk: Optional[jax.Array] = None,
               prefix_len: int = 0,
               k_valid: Optional[jax.Array] = None) -> jax.Array:
    """Boolean (.., Sq, Sk) attention-allowed mask from position vectors."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    allowed = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if causal:
        allowed &= k <= q
    if window is not None:
        allowed &= (q - k) < window
    if chunk is not None:
        allowed &= (q // chunk) == (k // chunk)
    if prefix_len > 0:
        allowed |= (q < prefix_len) & (k < prefix_len)
    if k_valid is not None:
        allowed &= k_valid[..., None, :]
    return allowed


def attend(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array, *,
           softcap: float = 0.0, need_colsums: bool = False,
           kscale: Optional[jax.Array] = None,
           vscale: Optional[jax.Array] = None,
           q_valid: Optional[jax.Array] = None,
           ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Core GQA attention.

    q: (B, Sq, Nq, H); k/v: (B, Sk, Nkv, H); mask: (B?, Sq, Sk) bool.
    kscale/vscale: (B, Sk, Nkv) — int8-KV scales folded into scores/probs.
    q_valid: optional (B, Sq) bool — invalid (pad / idle-slot) queries are
    excluded from the colsums reduction, so ODP importance only counts
    attention received from *live* tokens; attention outputs are unaffected.
    Returns (out (B, Sq, Nq, H), colsums (B, Sk) or None) — colsums are the
    mean-over-heads attention each key position received (for ODP Eq. 6).
    """
    b, sq, nq, h = q.shape
    nkv = k.shape[2]
    g = nq // nkv
    qg = q.reshape(b, sq, nkv, g, h)
    scale = 1.0 / np.sqrt(h)
    # keep operands in model dtype, accumulate in f32 on the MXU — casting
    # K to f32 materializes a full copy of the KV cache per decode layer
    # (§Perf: 38 GB/chip/step of convert traffic on mixtral decode_32k)
    kk = k.astype(q.dtype) if k.dtype == jnp.int8 else k
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, kk,
                        preferred_element_type=jnp.float32) * scale
    if kscale is not None:
        scores = scores * kscale.transpose(0, 2, 1)[:, :, None, None, :]
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    m = mask[:, None, None] if mask.ndim == 3 else mask[None, None, None]
    scores = jnp.where(m, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    # fully-masked query rows (e.g. cache padding) -> zero probabilities
    probs = jnp.where(m, probs, 0.0)
    pv = probs
    if vscale is not None:
        pv = probs * vscale.transpose(0, 2, 1)[:, :, None, None, :]
    vv = v.astype(q.dtype) if v.dtype == jnp.int8 else v
    out = jnp.einsum("bkgqs,bskh->bqkgh", pv.astype(qg.dtype), vv)
    colsums = None
    if need_colsums:
        cp = probs
        if q_valid is not None:
            cp = cp * q_valid.astype(cp.dtype)[:, None, None, :, None]
        colsums = cp.sum(axis=(1, 2, 3)) / nq         # (B, Sk)
    return out.reshape(b, sq, nq, h), colsums


def apply_attention(
    p: Params, x: jax.Array, *, cfg: ModelConfig,
    positions: jax.Array,
    window: Optional[jax.Array] = None,
    chunk: Optional[jax.Array] = None,
    causal: bool = True,
    prefix_len: int = 0,
    kv_src: Optional[jax.Array] = None,
    cache: Optional[KVCache] = None,
    need_colsums: bool = False,
    q_valid: Optional[jax.Array] = None,
    kv_table: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[KVCache], Optional[jax.Array]]:
    """One attention layer.

    positions: (Sq,) absolute positions of the query tokens (decode: the
    single new position). kv_src: encoder states for cross-attention.
    q_valid: optional (B, Sq) bool live-token mask, forwarded to the
    colsums reduction only (see :func:`attend`).
    kv_table: (B, max_pages) int32 page table, required when ``cache`` is
    a :class:`PagedKVCache` — the decode path writes this step's K/V into
    the pool through it and attends over the gathered logical view.
    Returns (output, updated cache, attention-received colsums).
    """
    b, sq, d = x.shape
    h, nq, nkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    dt = x.dtype

    q = x @ p["wq"].astype(dt)
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    src = kv_src if kv_src is not None else x
    k = src @ p["wk"].astype(dt)
    v = src @ p["wv"].astype(dt)
    if "bv" in p:
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, sq, nq, h)
    k = k.reshape(b, -1, nkv, h)
    v = v.reshape(b, -1, nkv, h)

    if cfg.use_qk_norm:
        q = _qk_rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = _qk_rmsnorm(k, p["k_norm"], cfg.norm_eps)

    if cfg.use_rope and kv_src is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    # --- sequence-parallel attention (§Perf iteration) ---------------------
    # When the query-head count does not divide the TP axis (arctic 56H,
    # llama4 40H, paligemma 8H vs model=16), GSPMD falls back to splitting
    # the head_dim *contraction* and ALL-REDUCES the full (Sq, Sk) score
    # tensor (observed: 60 GB/layer/chip on arctic prefill_32k). Sharding
    # queries over the sequence instead keeps scores collective-free; K/V
    # are small and get gathered once. Applies to training forward AND
    # prefill (cache-filling) — not single-token decode.
    from repro.sharding import context as shctx
    tp = shctx.axis_size("model")
    if (tp > 1 and kv_src is None and sq > 1
            and nq % tp != 0 and sq % tp == 0):
        from jax.sharding import PartitionSpec as _P
        ba = shctx.batch_axes()
        q = shctx.constrain(q, _P(ba, "model", None, None))
        k = shctx.constrain(k, _P(ba, None, None, None))
        v = shctx.constrain(v, _P(ba, None, None, None))

    new_cache = None
    kscale = vscale = None
    q_slots = None              # cache slots this step's queries wrote
    if isinstance(cache, PagedKVCache):
        if kv_table is None:
            raise ValueError("a PagedKVCache needs the engine's kv_table "
                             "(B, max_pages) page-table array")
        if positions.ndim != 2:
            raise ValueError("the paged KV path expects per-row (B, Sq) "
                             f"positions, got shape {positions.shape}")
        new_cache = _paged_write(cache, kv_table, positions, k, v)
        k, v, kscale, vscale = _paged_gather(new_cache, kv_table)
        # logical index inside a row's page list == absolute position, so
        # the key-position vector is just arange over the gathered view;
        # entries past a row's live length are causally masked (junk the
        # trash page / unwritten offsets hold is never attended)
        k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
        mask = build_mask(positions, k_pos, causal=causal, window=window,
                          chunk=chunk, prefix_len=prefix_len)
        q_slots = positions
    elif cache is not None and kv_src is None:
        cap = cache.k.shape[1]
        s_new = k.shape[1]
        quant = cache.quantized
        if quant:
            kq, ks_new = _kv_quantize(k)
            vq, vs_new = _kv_quantize(v)
        else:
            kq, vq = k.astype(cache.k.dtype), v.astype(cache.v.dtype)
        if s_new > 1 and s_new > cap:
            # prefill overflowing a ring cache: attend over the fresh K/V
            # (standard masks), store only the last `cap` positions — older
            # keys fall outside every local window by construction.
            assert cache.ring, "linear cache smaller than prefill length"
            mask = build_mask(positions, positions, causal=causal,
                              window=window, chunk=chunk,
                              prefix_len=prefix_len)
            tail_pos = positions[-cap:]
            slots = tail_pos % cap
            ck = cache.k.at[:, slots].set(kq[:, -cap:])
            cv = cache.v.at[:, slots].set(vq[:, -cap:])
            cpos = cache.pos.at[:, slots].set(
                tail_pos.astype(cache.pos.dtype)[None, :])
            cks = cvs = None
            if quant:
                cks = cache.kscale.at[:, slots].set(ks_new[:, -cap:])
                cvs = cache.vscale.at[:, slots].set(vs_new[:, -cap:])
            new_cache = KVCache(ck, cv, cpos, cache.ring, cks, cvs)
        elif positions.ndim == 2:
            # per-row positions (continuous batching): each batch row writes
            # its own cache slots — rows have independent lifetimes/lengths.
            idx = positions % cap if cache.ring else positions     # (B, Sq)
            rows = jnp.arange(b)[:, None]
            ck = cache.k.at[rows, idx].set(kq)
            cv = cache.v.at[rows, idx].set(vq)
            cpos = cache.pos.at[rows, idx].set(
                positions.astype(cache.pos.dtype))
            cks = cvs = None
            if quant:
                cks = cache.kscale.at[rows, idx].set(ks_new)
                cvs = cache.vscale.at[rows, idx].set(vs_new)
                kscale, vscale = cks, cvs
            new_cache = KVCache(ck, cv, cpos, cache.ring, cks, cvs)
            k, v = ck, cv
            q_slots = idx
            k_valid = cpos >= 0
            mask = build_mask(positions, cpos, causal=causal, window=window,
                              chunk=chunk, prefix_len=prefix_len,
                              k_valid=k_valid)
        else:
            # decode / fitting prefill: insert then attend over the cache
            slot = positions[0] % cap if cache.ring else positions[0]
            ck = jax.lax.dynamic_update_slice(cache.k, kq, (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache.v, vq, (0, slot, 0, 0))
            cpos = jax.lax.dynamic_update_slice(
                cache.pos,
                jnp.broadcast_to(positions.astype(cache.pos.dtype),
                                 (b, s_new)), (0, slot))
            cks = cvs = None
            if quant:
                cks = jax.lax.dynamic_update_slice(cache.kscale, ks_new,
                                                   (0, slot, 0))
                cvs = jax.lax.dynamic_update_slice(cache.vscale, vs_new,
                                                   (0, slot, 0))
                kscale, vscale = cks, cvs
            new_cache = KVCache(ck, cv, cpos, cache.ring, cks, cvs)
            k, v = ck, cv
            q_slots = positions % cap if cache.ring else positions  # (Sq,)
            k_pos = cpos
            k_valid = cpos >= 0
            mask = build_mask(positions, k_pos, causal=causal, window=window,
                              chunk=chunk, prefix_len=prefix_len,
                              k_valid=k_valid)
    elif kv_src is not None:
        mask = jnp.ones((sq, kv_src.shape[1]), bool)
    else:
        mask = build_mask(positions, positions, causal=causal, window=window,
                          chunk=chunk, prefix_len=prefix_len)

    out, colsums = attend(q, k, v, mask, softcap=cfg.attn_logit_softcap,
                          need_colsums=need_colsums, kscale=kscale,
                          vscale=vscale, q_valid=q_valid)
    if colsums is not None and q_slots is not None:
        # cached branches attend over the whole cache, so colsums span its
        # capacity — gather at the slots this step's queries wrote, giving
        # the (B, Sq) attention received by the *current* tokens (the
        # decode-time Eq. 6 numerator, query-aligned like the no-cache path)
        if q_slots.ndim == 1:
            colsums = jnp.take(colsums, q_slots, axis=1)
        else:
            colsums = jnp.take_along_axis(colsums, q_slots, axis=1)
    out = out.reshape(b, sq, nq * h) @ p["wo"].astype(dt)
    if "bo" in p:
        out = out + p["bo"].astype(dt)
    return out, new_cache, colsums


def _paged_write(cache: PagedKVCache, table: jax.Array,
                 positions: jax.Array, k: jax.Array,
                 v: jax.Array) -> PagedKVCache:
    """Scatter this step's fresh K/V (B, Sq, Nkv, H) into the page pool at
    the physical slots ``positions`` map to. Rows parked on the trash page
    (idle/finished slots) scatter harmlessly into storage nobody reads."""
    ps = cache.page_size
    pages = jnp.take_along_axis(table, positions // ps, axis=1)   # (B, Sq)
    offs = positions % ps
    if cache.bits == 16:
        kq, vq = k.astype(cache.k.dtype), v.astype(cache.v.dtype)
        ks = vs = None
    else:
        kq, ks = _kv_quantize(k, cache.bits)
        vq, vs = _kv_quantize(v, cache.bits)
        if cache.bits == 4:
            kq, vq = _pack_int4(kq), _pack_int4(vq)
    ck = cache.k.at[pages, offs].set(kq)
    cv = cache.v.at[pages, offs].set(vq)
    cks = cvs = None
    if cache.bits != 16:
        cks = cache.kscale.at[pages, offs].set(ks)
        cvs = cache.vscale.at[pages, offs].set(vs)
    return PagedKVCache(ck, cv, cks, cvs, cache.bits)


def _paged_gather(cache: PagedKVCache, table: jax.Array):
    """Gather a row-major logical view of each batch row's pages:
    (B, max_pages * page_size, Nkv, H) K/V plus folded-scale arrays (int4
    codes are unpacked here; scale folding in :func:`attend` does the
    dequantization as part of the attention math)."""
    b, n_pages = table.shape
    def view(pool):
        g = jnp.take(pool, table, axis=0)          # (B, n_pages, ps, ...)
        return g.reshape(b, n_pages * cache.page_size, *pool.shape[2:])
    k, v = view(cache.k), view(cache.v)
    kscale = vscale = None
    if cache.bits != 16:
        kscale, vscale = view(cache.kscale), view(cache.vscale)
        if cache.bits == 4:
            k, v = _unpack_int4(k), _unpack_int4(v)
    return k, v, kscale, vscale


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int, *,
                     bits: int = 16, dtype=jnp.bfloat16) -> PagedKVCache:
    nkv, h = cfg.num_kv_heads, cfg.head_dim
    if bits == 4 and h % 2:
        raise ValueError(f"int4 KV packs head_dim pairwise; head_dim {h} "
                         "is odd")
    quant = bits != 16
    hh = h // 2 if bits == 4 else h
    dt = jnp.int8 if quant else dtype
    return PagedKVCache(
        k=jnp.zeros((num_pages, page_size, nkv, hh), dt),
        v=jnp.zeros((num_pages, page_size, nkv, hh), dt),
        kscale=jnp.zeros((num_pages, page_size, nkv), jnp.float32)
        if quant else None,
        vscale=jnp.zeros((num_pages, page_size, nkv), jnp.float32)
        if quant else None,
        bits=bits,
    )


def init_cache(cfg: ModelConfig, batch: int, capacity: int, *,
               ring: bool = False, dtype=jnp.bfloat16) -> KVCache:
    nkv, h = cfg.num_kv_heads, cfg.head_dim
    quant = getattr(cfg, "kv_quant", False)
    if quant:
        dtype = jnp.int8
    return KVCache(
        k=jnp.zeros((batch, capacity, nkv, h), dtype),
        v=jnp.zeros((batch, capacity, nkv, h), dtype),
        pos=jnp.full((batch, capacity), -1, jnp.int32),
        ring=ring,
        kscale=jnp.zeros((batch, capacity, nkv), jnp.float32) if quant
        else None,
        vscale=jnp.zeros((batch, capacity, nkv), jnp.float32) if quant
        else None,
    )


def cache_specs(ring: bool = False) -> KVCache:
    return KVCache(k=P(("data",), None, "model", None),
                   v=P(("data",), None, "model", None),
                   pos=P(("data",), None), ring=ring)
