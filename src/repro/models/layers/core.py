"""Primitive layers: norms, rotary embeddings, dense MLP, embeddings.

Functional module convention used across the zoo: each layer provides
``init_<name>(key, cfg, ...) -> params`` returning a dict pytree, an
``apply``-style function, and ``specs_<name>(...) -> matching pytree of
PartitionSpec`` for the partitioner.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig

Params = Dict


def _dense_init(key, shape, in_axis_size=None) -> jax.Array:
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(jnp.float32)


# ------------------------------------------------------------------ norms
def init_norm(cfg: ModelConfig, dim: Optional[int] = None) -> Params:
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def specs_norm(cfg: ModelConfig) -> Params:
    s = {"scale": P(None)}
    if cfg.norm_type == "layernorm":
        s["bias"] = P(None)
    return s


def apply_norm(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x32 = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = x32.mean(-1, keepdims=True)
        var = jnp.mean((x32 - mu) ** 2, -1, keepdims=True)
        out = (x32 - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(x32 ** 2, -1, keepdims=True)
        out = x32 * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return out.astype(x.dtype)


# ----------------------------------------------------------------- rotary
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, N, H); positions: broadcastable to (..., S)."""
    h = x.shape[-1]
    freqs = rope_frequencies(h, theta)                        # (H/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (..., S, H/2)
    cos = jnp.cos(ang)[..., None, :]                          # (..., S, 1, H/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# -------------------------------------------------------------------- MLP
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    keys = jax.random.split(key, 3)
    p = {"w_in": _dense_init(keys[0], (d, f)),
         "w_out": _dense_init(keys[1], (f, d))}
    if cfg.mlp_gated:
        p["w_gate"] = _dense_init(keys[2], (d, f))
    return p


def specs_mlp(cfg: ModelConfig) -> Params:
    s = {"w_in": P("data", "model"), "w_out": P("model", "data")}
    if cfg.mlp_gated:
        s["w_gate"] = P("data", "model")
    return s


def mlp_activation(cfg: ModelConfig):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[cfg.mlp_act]


def apply_mlp(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = mlp_activation(cfg)
    dt = x.dtype
    h = x @ p["w_in"].astype(dt)
    if cfg.mlp_gated:
        h = act(x @ p["w_gate"].astype(dt)) * h
    else:
        h = act(h)
    return h @ p["w_out"].astype(dt)


# -------------------------------------------------------------- embedding
def init_embedding(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 2)
    p = {"table": (jax.random.normal(keys[0],
                                     (cfg.vocab_size, cfg.d_model)) * 0.02
                   ).astype(jnp.float32)}
    if not cfg.tie_embeddings:
        p["unembed"] = _dense_init(keys[1], (cfg.d_model, cfg.vocab_size),
                                   in_axis_size=cfg.d_model)
    return p


def specs_embedding(cfg: ModelConfig) -> Params:
    s = {"table": P("model", "data")}
    if not cfg.tie_embeddings:
        s["unembed"] = P("data", "model")
    return s


def embed_tokens(p: Params, tokens: jax.Array, cfg: ModelConfig,
                 dtype=jnp.bfloat16) -> jax.Array:
    x = jnp.take(p["table"], tokens, axis=0).astype(dtype)
    if cfg.embedding_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    return x


def unembed(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    table = p.get("unembed")
    if table is None:
        table = p["table"].T
    logits = x.astype(jnp.float32) @ table.astype(jnp.float32)
    if cfg.final_logit_softcap > 0:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


# ------------------------------------------------------- learned positions
def init_learned_pos(key, max_len: int, d: int) -> Params:
    return {"pos": (jax.random.normal(key, (max_len, d)) * 0.02
                    ).astype(jnp.float32)}


def specs_learned_pos() -> Params:
    return {"pos": P(None, "data")}


def position_grid(s: int, start_pos) -> jax.Array:
    """Absolute query positions: (S,) for a scalar/int ``start_pos`` shared
    by the batch, (B, S) for per-row (B,) starts (continuous-batching
    decode slots)."""
    if not isinstance(start_pos, int) and jnp.ndim(start_pos) == 1:
        return (jnp.arange(s, dtype=jnp.int32)[None, :]
                + start_pos[:, None].astype(jnp.int32))
    return jnp.arange(s, dtype=jnp.int32) + start_pos


def add_learned_pos(p: Params, x: jax.Array, offset=0) -> jax.Array:
    s = x.shape[-2]
    if not isinstance(offset, int) and jnp.ndim(offset) == 1:
        # per-row offsets (continuous-batching decode): gather per row
        idx = offset[:, None] + jnp.arange(s)                    # (B, S)
        return x + p["pos"][idx].astype(x.dtype)
    pos = jax.lax.dynamic_slice_in_dim(p["pos"], offset, s, 0)
    return x + pos.astype(x.dtype)
