"""Mixture-of-Experts layer with MC integration.

Dispatch is GShard-style **capacity-based top-C gather** (static shapes, no
one-hot dispatch tensor — memory O(B*E*C*d) = O(k * cf * tokens * d)):

1. router -> top-k (expert, weight) per token;
2. **ODP hook** (paper Sec. 3.3): secondary experts with ``w1/w0 < mu`` are
   pruned unless the token is protected by its importance score; pruned
   assignments never enter the dispatch, and the calibrated prune rate
   shrinks the static expert capacity (``capacity_scale``) — the TPU-native
   form of the paper's dynamic compute saving;
3. per expert, top-C token selection by router score (capacity dropping);
4. batched expert FFN — dense bf16 einsum, or the **PMQ quantized path**:
   experts are stored class-sorted by allocated bit-width and the whole
   gated FFN runs as one grouped fused dequant kernel over every class
   (`kernels.moe_ffn`, a single ``pallas_call`` per layer with per-expert
   live-row counts; `quant_path='staged'` keeps the legacy per-class
   `kernels.quant_matmul` composition as the oracle/baseline);
5. weighted scatter-combine (+ optional always-on shared expert — llama4 —
   and/or parallel dense residual branch — arctic).

Decode batches (S == 1) are re-laid out as a single token group so capacity
math stays meaningful (C = ceil(k * B * cf / E) instead of per-row C >= 1).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.core import odp as odp_lib
from repro.kernels import common as kcommon
from repro.kernels.moe_ffn.ops import moe_ffn_quant
from repro.kernels.quant_matmul.ops import quant_matmul
from repro.models.layers.core import (_dense_init, init_mlp, mlp_activation,
                                      specs_mlp)

Params = Dict


@dataclass(frozen=True)
class MoEQuantMeta:
    """Static metadata for PMQ-quantized experts (class-sorted layout)."""

    bit_classes: Tuple[int, ...]     # ascending widths present, e.g. (1, 2, 3)
    class_counts: Tuple[int, ...]    # experts per class; sums to num_experts
    group_size: int = 128
    pack_block: int = 128
    #: per-class packed-plane key suffixes (("p0",) or ("p0", "p1")) —
    #: precomputed here (pipeline.apply populates it; __post_init__ derives
    #: it for direct constructions) so the hot path never rescans param
    #: dict keys per trace.
    plane_suffixes: Tuple[Tuple[str, ...], ...] = ()

    def __post_init__(self):
        if not self.plane_suffixes:
            object.__setattr__(
                self, "plane_suffixes",
                tuple(kcommon.plane_suffixes(b) for b in self.bit_classes))

    @property
    def num_experts(self) -> int:
        return sum(self.class_counts)

    def class_slices(self):
        out, start = [], 0
        for bits, cnt in zip(self.bit_classes, self.class_counts):
            out.append((bits, start, cnt))
            start += cnt
        return out

    def class_segments(self) -> Tuple[Tuple[int, int], ...]:
        """(global start, count) per bit class — the segmentation the
        expert-parallel placement and the per-host artifact streams share
        (``sharding.moe_parallel.ep_owned_ranges``)."""
        return tuple((e0, cnt) for _, e0, cnt in self.class_slices())


@dataclass(frozen=True)
class OdpRuntime:
    """Static ODP inference settings (calibrated).

    importance_metric: how token importance (for protection) is computed —
    ``eq6`` (paper: l1 x attention received), ``l1`` (attention-free archs,
    DESIGN.md §4), or the Tab. 11 ablation baselines ``kurtosis`` /
    ``variance`` / ``mean``.

    ratio_quantiles: quantile table of the calibration w_s/w_0 ratio
    distribution (``core.odp.ratio_quantiles``) — lets serving map a
    per-request prune *ratio* to a threshold mu without the calibration
    set. Empty for artifacts planned before the table existed.
    """

    threshold: float
    protect_ratio: float
    capacity_scale: float = 1.0
    enabled: bool = True
    importance_metric: str = "eq6"
    ratio_quantiles: Tuple[float, ...] = ()


def init_moe(key, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 6)
    p = {
        "router": _dense_init(ks[0], (d, e)),
        "w_in": _dense_init(ks[1], (e, d, f), in_axis_size=d),
        "w_gate": _dense_init(ks[2], (e, d, f), in_axis_size=d),
        "w_out": _dense_init(ks[3], (e, f, d), in_axis_size=f),
    }
    if cfg.shared_expert:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.moe_d_ff)
    if cfg.dense_residual:
        p["dense_res"] = init_mlp(ks[5], cfg,
                                  d_ff=cfg.dense_residual_ff or cfg.d_ff)
    return p


def specs_moe(cfg: ModelConfig) -> Params:
    s = {
        "router": P(None, None),
        "w_in": P("data", None, "model"),
        "w_gate": P("data", None, "model"),
        "w_out": P("data", "model", None),
    }
    if cfg.shared_expert:
        s["shared"] = specs_mlp(cfg)
    if cfg.dense_residual:
        s["dense_res"] = specs_mlp(cfg)
    return s


def expert_capacity(cfg: ModelConfig, tokens_per_group: int,
                    capacity_scale: float = 1.0) -> int:
    c = int(np.ceil(cfg.top_k * tokens_per_group * cfg.capacity_factor
                    * capacity_scale / cfg.num_experts))
    c = int(np.ceil(c / 8) * 8) if c > 8 else max(c, 1)
    return min(c, tokens_per_group)


def _route(p, x32, cfg: ModelConfig):
    logits = x32 @ p["router"].astype(jnp.float32)          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, cfg.top_k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    return logits, probs, topw, topi


def _aux_losses(logits, probs, topi, cfg: ModelConfig):
    e = cfg.num_experts
    # Switch/GShard load-balance: E * sum_e f_e * p_e
    hits = jax.nn.one_hot(topi, e, dtype=jnp.float32).sum(-2)    # (B,S,E)
    frac_tokens = hits.mean(axis=(0, 1)) / cfg.top_k
    frac_probs = probs.mean(axis=(0, 1))
    lb = e * jnp.sum(frac_tokens * frac_probs)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return {"load_balance": lb, "router_z": z}


def _expert_ffn_dense(p, xg, cfg: ModelConfig):
    """xg: (B, E, C, D) -> (B, E, C, D) through each expert's gated FFN."""
    act = mlp_activation(cfg)
    dt = xg.dtype
    h = jnp.einsum("becd,edf->becf", xg, p["w_in"].astype(dt))
    g = jnp.einsum("becd,edf->becf", xg, p["w_gate"].astype(dt))
    h = act(g) * h
    return jnp.einsum("becf,efd->becd", h, p["w_out"].astype(dt))


def _expert_ffn_quant(p, xe, cfg: ModelConfig, meta: MoEQuantMeta,
                      counts: jax.Array, quant_path: str = "fused"):
    """PMQ path over class-sorted expert rows ``xe: (E, M, D)``.

    ``counts``: (E,) int32 live leading rows per expert — rows past the
    count come out zero and (in the fused kernel) skip their GEMMs.

    ``quant_path='fused'`` runs the whole gated FFN as **one** grouped
    ``pallas_call`` (`kernels.moe_ffn`); ``'staged'`` is the legacy
    composition — three ``quant_matmul`` launches per bit class with the
    intermediate activation round-tripping HBM — kept as the equivalence
    oracle and the launch-count baseline for the benchmarks.
    """
    if quant_path == "fused":
        return moe_ffn_quant(xe, p["experts_q"], counts, meta=meta,
                             act=cfg.mlp_act, out_dtype=jnp.float32)
    act = mlp_activation(cfg)
    e, m, d = xe.shape
    outs = []
    for ci, (bits, e0, cnt) in enumerate(meta.class_slices()):
        w = p["experts_q"][f"cls{ci}"]
        xc = xe[e0:e0 + cnt]                                     # (ec,M,D)

        def planes(tag, ci=ci):
            return tuple(w[f"{tag}_{s}"] for s in meta.plane_suffixes[ci])

        def qmm(tag, xin):
            return quant_matmul(
                xin, planes(tag), w[f"{tag}_s"],
                w.get(f"{tag}_z"), bits=bits, group_size=meta.group_size,
                pack_block=meta.pack_block, out_dtype=jnp.float32)

        h = qmm("in", xc)
        g = qmm("gate", xc)
        outs.append(qmm("out", act(g) * h))                      # (ec,M,D)
    y = jnp.concatenate(outs, axis=0)
    mask = jnp.arange(m)[None, :] < counts[:, None]
    return jnp.where(mask[..., None], y, 0.0)


def apply_moe(
    p: Params, x: jax.Array, cfg: ModelConfig, *,
    odp: Optional[OdpRuntime] = None,
    token_importance: Optional[jax.Array] = None,
    quant_meta: Optional[MoEQuantMeta] = None,
    capacity_scale: float = 1.0,
    token_mask: Optional[jax.Array] = None,
    odp_threshold: Optional[jax.Array] = None,
    quant_path: str = "fused",
) -> Tuple[jax.Array, Dict]:
    """MoE layer forward. x: (B, S, D) -> (y, aux).

    aux carries router statistics: load-balance/z losses (training), and the
    top-k decisions + prune mask (MC calibration / reporting).

    token_mask: optional (B, S) bool — False tokens (padding, inactive
    decode slots) are withheld from dispatch so they never consume expert
    capacity; their output rows are zero.

    odp_threshold: optional (B,) float32 — per-row **dynamic** ODP
    threshold, a traced value (the serving engines' per-request knob rides
    through jit here; changing it never retraces). Overrides
    ``odp.threshold``; a row of 0.0 keeps every slot, bit-identically to
    ODP being off. In dynamic mode the static ``odp.capacity_scale`` is NOT
    applied (rows opting out must never lose capacity) — the saving shows
    up as dead capacity rows the fused kernel skips instead.
    """
    b, s, d = x.shape
    if odp_threshold is not None:
        odp_threshold = jnp.broadcast_to(
            odp_threshold.reshape(b, -1), (b, s))
    decode_regroup = s == 1 and b > 1
    if decode_regroup:
        x = x.reshape(1, b, d)
        if token_importance is not None:
            token_importance = token_importance.reshape(1, b)
        if token_mask is not None:
            token_mask = token_mask.reshape(1, b)
        if odp_threshold is not None:
            odp_threshold = odp_threshold.reshape(1, b)
        b, s = 1, b

    x32 = x.astype(jnp.float32)
    logits, probs, topw, topi = _route(p, x32, cfg)
    aux = _aux_losses(logits, probs, topi, cfg)
    aux["topk_idx"] = topi
    aux["topk_weights"] = topw

    eff_scale = capacity_scale
    if odp is not None and odp.enabled and cfg.top_k >= 2:
        protected = None
        if token_importance is not None and odp.protect_ratio > 0:
            # masked (pad / inactive-slot) tokens must not steal protection
            # quota from live tokens
            protected = odp_lib.protect_tokens(token_importance,
                                               odp.protect_ratio,
                                               valid=token_mask)
        thr = (odp_threshold if odp_threshold is not None
               else odp.threshold)
        keep = odp_lib.prune_mask(topw, thr, protected)
        topw = odp_lib.apply_pruning(topw, keep)
        aux["odp_keep"] = keep
        aux["odp_pruned_frac"] = odp_lib.pruned_fraction(
            keep, cfg.top_k, valid=token_mask)
        if odp_threshold is None:
            eff_scale = eff_scale * odp.capacity_scale

    e = cfg.num_experts
    cap = expert_capacity(cfg, s, eff_scale)
    aux["capacity"] = cap

    # (B,S,E) post-ODP combine weights
    full_w = jnp.zeros((b, s, e), jnp.float32)
    oh = jax.nn.one_hot(topi, e, dtype=jnp.float32)              # (B,S,k,E)
    full_w = (oh * topw[..., None]).sum(-2)
    if token_mask is not None:
        full_w = full_w * token_mask.astype(jnp.float32)[..., None]

    # per-expert top-C token choice by router prob (tie-break by position)
    choice = jnp.where(full_w > 0, probs, -1.0).transpose(0, 2, 1)  # (B,E,S)
    gscore, gidx = jax.lax.top_k(choice, cap)                    # (B,E,C)
    w_sel = jnp.take_along_axis(full_w.transpose(0, 2, 1), gidx, -1)
    valid = (gscore > 0) & (w_sel > 0)
    w_sel = jnp.where(valid, w_sel, 0.0)
    # live dispatched rows per expert — the activated-expert-params metric
    # (ODP pruning shrinks these; the fused kernel skips the dead rows)
    aux["active_rows"] = valid.sum(-1).astype(jnp.int32)        # (B,E)

    if quant_meta is not None:
        counts = valid.sum(-1).astype(jnp.int32)                 # (B,E)
        if b == 1:
            # decode fast path (and batch-1 prefill): gather straight to
            # (E, C, D) — no (B, E, C, D) materialization or transpose —
            # with exact per-expert live counts (top_k sorts scores, so
            # valid slots are a prefix)
            xe = x[0][gidx[0]]
            ce = counts[0]
        else:
            xg = jax.vmap(lambda xb, ib: xb[ib])(x, gidx)        # (B,E,C,D)
            xe = xg.transpose(1, 0, 2, 3).reshape(e, b * cap, d)
            # per-batch-row valid prefixes interleave, so only fully idle
            # experts can skip; the rest run all b*C rows
            ce = jnp.where(counts.sum(0) > 0, b * cap, 0).astype(jnp.int32)
        ye = _expert_ffn_quant(p, xe, cfg, quant_meta, ce,
                               quant_path=quant_path)
        ye = (ye.reshape(e, b, cap, d).transpose(1, 0, 2, 3)
              if b > 1 else ye[None])
        ye = ye.astype(x.dtype)
    else:
        xg = jax.vmap(lambda xb, ib: xb[ib])(x, gidx)            # (B,E,C,D)
        ye = _expert_ffn_dense(p, xg, cfg)
    ye = ye * w_sel[..., None].astype(ye.dtype)

    def combine(yb, ib):
        return jnp.zeros((s, d), yb.dtype).at[ib.reshape(-1)].add(
            yb.reshape(-1, d), mode="drop")

    y = jax.vmap(combine)(ye, gidx)

    # dropped-token accounting (capacity overflow)
    aux["dispatched_frac"] = valid.sum() / jnp.maximum(
        (full_w > 0).sum(), 1)

    if cfg.shared_expert:
        from repro.models.layers.core import apply_mlp
        y = y + apply_mlp(p["shared"], x, cfg)
    if cfg.dense_residual:
        from repro.models.layers.core import apply_mlp
        y = y + apply_mlp(p["dense_res"], x, cfg)

    if decode_regroup:
        y = y.reshape(s, 1, d)
    return y.astype(x.dtype), aux
