"""State-space layers: Mamba-1 (falcon-mamba) and Mamba-2/SSD (zamba2).

Training/prefill uses **chunked scans** to bound the materialized state:

* Mamba-1: sequential ``lax.scan`` over chunks carrying ``h (B, I, N)``;
  within a chunk the recurrence is an associative scan over
  ``(exp(dt*A), dt*x*B)`` pairs — O(B * chunk * I * N) transient memory.
* Mamba-2: the SSD block-decomposition — intra-chunk attention-like matmul
  ``(C B^T) ⊙ decay`` plus an inter-chunk scalar-decay state pass; all
  MXU-friendly contractions (the paper's "matmul-form" insight maps directly
  onto TPU).

Decode is O(1)/token: carry ``(conv_state, h)`` per layer. No KV cache —
this is why the SSM/hybrid archs are the ones assigned the ``long_500k``
cell (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models.layers.core import _dense_init

Params = Dict


class SSMState(NamedTuple):
    conv: jax.Array   # (B, Kc-1, I) rolling conv inputs
    h: jax.Array      # mamba1: (B, I, N); mamba2: (B, nh, hd, N)


# ----------------------------------------------------------------- common
def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via static shifts. x: (B,S,I); w: (I,Kc)."""
    kc = w.shape[1]
    out = x * w[:, -1]
    for i in range(1, kc):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :x.shape[1]]
        out = out + shifted * w[:, -1 - i]
    return out + b


def _conv_step(state: jax.Array, x_new: jax.Array, w: jax.Array,
               b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token conv. state: (B, Kc-1, I); x_new: (B, 1, I)."""
    window = jnp.concatenate([state, x_new], axis=1)      # (B, Kc, I)
    out = jnp.einsum("bki,ik->bi", window, w) + b
    return window[:, 1:], out[:, None, :]


def _conv_prefill(state: jax.Array, x: jax.Array, w: jax.Array,
                  b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Multi-token conv continuing from history. x: (B, S, I)."""
    kc = w.shape[1]
    hist = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = _causal_conv(hist, w, b)[:, kc - 1:]
    new_state = hist[:, -(kc - 1):] if kc > 1 else hist[:, :0]
    return new_state, out


# ----------------------------------------------------------------- mamba1
def init_mamba1(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    i = d * cfg.ssm_expand
    n, r, kc = cfg.ssm_state, cfg.ssm_dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 8)
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (i, 1))
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * i)),
        "conv_w": (jax.random.normal(ks[1], (i, kc)) / np.sqrt(kc)
                   ).astype(jnp.float32),
        "conv_b": jnp.zeros((i,), jnp.float32),
        "x_proj": _dense_init(ks[2], (i, r + 2 * n)),
        "dt_proj": _dense_init(ks[3], (r, i)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(ks[4], (i,))
                             * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3)),
                     1e-4))),
        "a_log": jnp.log(a_init),
        "d_skip": jnp.ones((i,), jnp.float32),
        "out_proj": _dense_init(ks[5], (i, d)),
    }


def specs_mamba1(cfg: ModelConfig) -> Params:
    return {
        "in_proj": P("data", "model"), "conv_w": P("model", None),
        "conv_b": P("model"), "x_proj": P("model", None),
        "dt_proj": P(None, "model"), "dt_bias": P("model"),
        "a_log": P("model", None), "d_skip": P("model"),
        "out_proj": P("model", "data"),
    }


def _mamba1_inner(p, x_c, z, cfg: ModelConfig, h0: Optional[jax.Array]):
    """Selective scan. x_c/z: (B,S,I) post-conv; returns (y, h_last).

    Two schedules (cfg.ssm_scan):
    * ``assoc`` — chunked associative scan: O(log c) passes over the
      materialized (B, c, I, N) decay/input tensors (paper-standard form);
    * ``fused_seq`` (§Perf it.) — sequential ``lax.scan`` over time whose
      body computes ``exp(dt*A)`` **on the fly** from the (B, I) slice: the
      (B, S, I, N) tensors are never materialized, cutting the scan's HBM
      traffic from O(S*I*N*log c) to O(S*(I+N)) + the (B, I, N) carry.
      The TPU endgame is `kernels/selective_scan` (same dataflow in VMEM).
    """
    b, s, i = x_c.shape
    n = cfg.ssm_state
    dbc = x_c.astype(jnp.float32) @ p["x_proj"]
    r = cfg.ssm_dt_rank
    dt_raw, b_ssm, c_ssm = jnp.split(dbc, [r, r + n], axis=-1)
    delta = jax.nn.softplus(dt_raw @ p["dt_proj"] + p["dt_bias"])   # (B,S,I)
    a = -jnp.exp(p["a_log"])                                        # (I,N)
    h0 = h0 if h0 is not None else jnp.zeros((b, i, n), jnp.float32)
    schedule = getattr(cfg, "ssm_scan", "assoc")

    if schedule == "fused_seq":
        def step(h, args):
            xt, dt_t, bt, ct = args                 # (B,I),(B,I),(B,N),(B,N)
            da = jnp.exp(dt_t[..., None] * a)       # (B,I,N) transient
            h = da * h + (dt_t * xt)[..., None] * bt[:, None, :]
            y = jnp.einsum("bin,bn->bi", h, ct)
            return h, y

        sw = lambda t: t.swapaxes(0, 1)             # time-major
        h_last, ys = jax.lax.scan(
            step, h0, (sw(x_c.astype(jnp.float32)), sw(delta), sw(b_ssm),
                       sw(c_ssm)), unroll=4)
        y = ys.swapaxes(0, 1)
    else:
        chunk = min(cfg.ssm_chunk, s)
        pad = (-s) % chunk
        xp, dp, bp, cp = x_c, delta, b_ssm, c_ssm
        if pad:
            xp, dp, bp, cp = (
                jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
                for t in (x_c, delta, b_ssm, c_ssm))
        nc = (s + pad) // chunk

        def chunk_body(h, args):
            xc, dl, bs, cs = args                   # (B,c,I), ..., (B,c,N)
            da = jnp.exp(dl[..., None] * a)         # (B,c,I,N)
            dbx = (dl * xc.astype(jnp.float32))[..., None] * bs[:, :, None, :]

            def op(l, rgt):
                return (l[0] * rgt[0], rgt[0] * l[1] + rgt[1])

            cum_a, cum_b = jax.lax.associative_scan(op, (da, dbx), axis=1)
            h_all = cum_a * h[:, None] + cum_b      # (B,c,I,N)
            y = jnp.einsum("bcin,bcn->bci", h_all, cs)
            return h_all[:, -1], y

        resh = lambda t: t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
        h_last, ys = jax.lax.scan(
            chunk_body, h0, (resh(xp), resh(dp), resh(bp), resh(cp)))
        y = ys.swapaxes(0, 1).reshape(b, nc * chunk, i)[:, :s]

    y = y + x_c[:, :s].astype(jnp.float32) * p["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y, h_last


def apply_mamba1(p: Params, x: jax.Array, cfg: ModelConfig,
                 state: Optional[SSMState] = None
                 ) -> Tuple[jax.Array, Optional[SSMState]]:
    """x: (B,S,D). state given: S == 1 -> decode; S > 1 -> prefill
    continuing from (and updating) the state."""
    dt = x.dtype
    xz = x @ p["in_proj"].astype(dt)
    x_in, z = jnp.split(xz, 2, axis=-1)

    if state is None:
        x_c = jax.nn.silu(_causal_conv(x_in.astype(jnp.float32),
                                       p["conv_w"], p["conv_b"]))
        y, _ = _mamba1_inner(p, x_c, z, cfg, None)
        return (y @ p["out_proj"]).astype(dt), None

    if x.shape[1] > 1:  # prefill with state carry
        conv_state, xc = _conv_prefill(state.conv, x_in.astype(jnp.float32),
                                       p["conv_w"], p["conv_b"])
        x_c = jax.nn.silu(xc)
        y, h_last = _mamba1_inner(p, x_c, z, cfg, state.h)
        out = (y @ p["out_proj"]).astype(dt)
        return out, SSMState(conv_state.astype(x.dtype), h_last)

    conv_state, h = state.conv, state.h
    conv_state, xc1 = _conv_step(conv_state.astype(jnp.float32),
                                 x_in.astype(jnp.float32),
                                 p["conv_w"], p["conv_b"])
    x_c = jax.nn.silu(xc1)                                   # (B,1,I)
    n, r = cfg.ssm_state, cfg.ssm_dt_rank
    dbc = x_c.astype(jnp.float32) @ p["x_proj"]
    dt_raw, b_ssm, c_ssm = jnp.split(dbc, [r, r + n], axis=-1)
    delta = jax.nn.softplus(dt_raw @ p["dt_proj"] + p["dt_bias"])[:, 0]
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(delta[..., None] * a)                       # (B,I,N)
    dbx = (delta * x_c[:, 0].astype(jnp.float32))[..., None] \
        * b_ssm[:, 0, None, :]
    h = da * h + dbx
    y = jnp.einsum("bin,bn->bi", h, c_ssm[:, 0])
    y = y + x_c[:, 0].astype(jnp.float32) * p["d_skip"]
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    out = (y @ p["out_proj"])[:, None].astype(dt)
    return out, SSMState(conv_state.astype(x.dtype), h)


# ----------------------------------------------------------------- mamba2
def init_mamba2(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    i = d * cfg.ssm_expand
    n, kc = cfg.ssm_state, cfg.ssm_conv
    nh = i // cfg.ssm_head_dim
    ks = jax.random.split(key, 6)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * i + 2 * n + nh)),
        "conv_w": (jax.random.normal(ks[1], (i, kc)) / np.sqrt(kc)
                   ).astype(jnp.float32),
        "conv_b": jnp.zeros((i,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_w": jnp.ones((i,), jnp.float32),
        "out_proj": _dense_init(ks[2], (i, d)),
    }


def specs_mamba2(cfg: ModelConfig) -> Params:
    return {
        "in_proj": P("data", "model"), "conv_w": P("model", None),
        "conv_b": P("model"), "a_log": P(None), "dt_bias": P(None),
        "d_skip": P(None), "norm_w": P("model"),
        "out_proj": P("model", "data"),
    }


def _split_mamba2(xz, cfg: ModelConfig):
    i = cfg.d_model * cfg.ssm_expand
    n = cfg.ssm_state
    nh = i // cfg.ssm_head_dim
    z, x_in, b_ssm, c_ssm, dt_raw = jnp.split(
        xz, [i, 2 * i, 2 * i + n, 2 * i + 2 * n], axis=-1)
    return z, x_in, b_ssm, c_ssm, dt_raw, nh


def _ssd_chunked(x, dt, a, b_ssm, c_ssm, chunk, h0):
    """Minimal SSD. x: (B,S,nh,hd); dt: (B,S,nh); a: (nh,) (negative);
    b/c: (B,S,N). Returns (y (B,S,nh,hd), h_last (B,nh,hd,N))."""
    b, s, nh, hd = x.shape
    n = b_ssm.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x, dt, b_ssm, c_ssm = (
            jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
            for t in (x, dt, b_ssm, c_ssm))
    nc = (s + pad) // chunk
    log_a = dt * a                                    # (B,S,nh), <= 0

    def chunk_body(h, args):
        xc, dtc, lac, bc, cc = args
        cum = jnp.cumsum(lac, axis=1)                 # (B,c,nh)
        # intra-chunk: masked decay "attention". Mask the *exponent* (not the
        # exp) so the upper triangle never produces inf -> NaN-grad via where.
        diff = cum[:, :, None, :] - cum[:, None, :, :]            # (B,t,s,nh)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.exp(jnp.where(tri[None, :, :, None], diff, -1e30))
        cb = jnp.einsum("btn,bsn->bts", cc, bc)
        m = cb[..., None] * decay                     # (B,t,s,nh)
        dx = dtc[..., None] * xc                      # (B,c,nh,hd)
        y = jnp.einsum("btsh,bshp->bthp", m, dx)
        # inter-chunk: contribution of carried state
        y = y + jnp.einsum("btn,bhpn->bthp", cc, h) \
            * jnp.exp(cum)[..., None]
        # state update
        tail = jnp.exp(cum[:, -1:, :] - cum)          # (B,c,nh)
        h_new = jnp.exp(cum[:, -1])[:, :, None, None] * h + jnp.einsum(
            "bshp,bsn,bsh->bhpn", dx, bc, tail)
        return h_new, y

    resh = lambda t: t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    h_last, ys = jax.lax.scan(
        chunk_body, h0,
        (resh(x), resh(dt), resh(log_a), resh(b_ssm), resh(c_ssm)))
    y = ys.swapaxes(0, 1).reshape(b, nc * chunk, nh, hd)[:, :s]
    return y, h_last


def apply_mamba2(p: Params, x: jax.Array, cfg: ModelConfig,
                 state: Optional[SSMState] = None
                 ) -> Tuple[jax.Array, Optional[SSMState]]:
    dt_ = x.dtype
    bsz, s, _ = x.shape
    i = cfg.d_model * cfg.ssm_expand
    hd = cfg.ssm_head_dim
    xz = x @ p["in_proj"].astype(dt_)
    z, x_in, b_ssm, c_ssm, dt_raw, nh = _split_mamba2(xz, cfg)
    a = -jnp.exp(p["a_log"])
    n = cfg.ssm_state

    if state is None or s > 1:
        if state is None:
            conv_state = None
            x_c = jax.nn.silu(_causal_conv(x_in.astype(jnp.float32),
                                           p["conv_w"], p["conv_b"]))
            h0 = jnp.zeros((bsz, nh, hd, n), jnp.float32)
        else:  # prefill continuing from carried state
            conv_state, xc = _conv_prefill(
                state.conv, x_in.astype(jnp.float32), p["conv_w"],
                p["conv_b"])
            x_c = jax.nn.silu(xc)
            h0 = state.h
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
        xh = x_c.reshape(bsz, s, nh, hd)
        y, h_last = _ssd_chunked(xh, dt, a, b_ssm.astype(jnp.float32),
                                 c_ssm.astype(jnp.float32), cfg.ssm_chunk,
                                 h0)
        y = y + p["d_skip"][:, None] * xh.astype(jnp.float32)
        y = y.reshape(bsz, s, i)
        new_state = None if state is None else \
            SSMState(conv_state.astype(x.dtype), h_last)
    else:
        conv_state, h = state.conv, state.h
        conv_state, xc1 = _conv_step(conv_state.astype(jnp.float32),
                                     x_in.astype(jnp.float32),
                                     p["conv_w"], p["conv_b"])
        x_c = jax.nn.silu(xc1)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
        xh = x_c[:, 0].reshape(bsz, nh, hd)
        da = jnp.exp(dt * a)                                   # (B,nh)
        h = da[:, :, None, None] * h + jnp.einsum(
            "bhp,bn,bh->bhpn", xh.astype(jnp.float32),
            b_ssm[:, 0].astype(jnp.float32), dt)
        y = jnp.einsum("bn,bhpn->bhp", c_ssm[:, 0].astype(jnp.float32), h)
        y = y + p["d_skip"][:, None] * xh.astype(jnp.float32)
        y = y.reshape(bsz, 1, i)
        new_state = SSMState(conv_state.astype(x.dtype), h)

    # gated RMSNorm then out-projection (mamba2 block tail)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(y ** 2, -1, keepdims=True)
    y = y * jax.lax.rsqrt(ms + cfg.norm_eps) * p["norm_w"]
    return (y @ p["out_proj"]).astype(dt_), new_state


def init_ssm_state(cfg: ModelConfig, batch: int) -> SSMState:
    i = cfg.d_model * cfg.ssm_expand
    kc = cfg.ssm_conv
    if cfg.ssm_type == "mamba1":
        h = jnp.zeros((batch, i, cfg.ssm_state), jnp.float32)
    else:
        nh = i // cfg.ssm_head_dim
        h = jnp.zeros((batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                      jnp.float32)
    # conv must match the activation dtype the layer writes back
    # (``conv_state.astype(x.dtype)``): a narrower initial dtype makes the
    # state's dtype flip on the first update, so a state row landed before
    # vs after the first decode step rounds differently.
    cdt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return SSMState(conv=jnp.zeros((batch, kc - 1, i), cdt), h=h)


def ssm_state_specs(cfg: ModelConfig) -> SSMState:
    if cfg.ssm_type == "mamba1":
        return SSMState(conv=P(("data",), None, "model"),
                        h=P(("data",), "model", None))
    return SSMState(conv=P(("data",), None, "model"),
                    h=P(("data",), "model", None, None))
