"""Model factory: ModelConfig -> model object with the common interface.

All models expose: ``init``, ``forward``, ``param_specs``, ``init_caches``,
``decode_step`` (where the family has one), plus ``state_kinds()`` — the
per-slot state bundle the serving engines program against
(:mod:`repro.serve.slot_state`): ``init_paged_caches`` where the bundle
has a pageable kind, ``init_cross_state`` where it has a shared kind.
"""
from __future__ import annotations

from repro.config import ModelConfig
from repro.models.encdec import EncDecModel
from repro.models.hybrid import HybridModel
from repro.models.transformer import DecoderModel


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        return EncDecModel(cfg)
    if cfg.family == "hybrid":
        return HybridModel(cfg)
    return DecoderModel(cfg)
