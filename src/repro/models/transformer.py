"""Decoder-only LM assembly covering dense / MoE / SSM / VLM families.

* Layers are stored **stacked** ``(L, ...)`` per super-layer slot and run
  either under ``lax.scan`` (production: O(1) HLO size, per-layer FSDP
  all-gathers inside the loop) or a Python loop (smoke tests, calibration
  passes that want per-layer stats).
* Alternating attention patterns (gemma2 local/global, llama4 chunked+NoPE)
  are **per-layer scalars** (window / chunk arrays scanned alongside params)
  — no structural branching inside the scan body.
* MoE layers take the MC runtime: ODP pruning fed by the *current layer's*
  attention-received column sums (paper Eq. 6 / Fig. 4), and the PMQ
  quantized expert path.
* ``moe_layer_period > 1`` (llama4) groups one dense + one MoE block per
  scan step.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.kernels.token_importance import ops as ti_ops
from repro.models.layers import attention as attn_lib
from repro.models.layers import core as core_lib
from repro.models.layers import moe as moe_lib
from repro.models.layers import ssm as ssm_lib
from repro.models.layers.attention import GLOBAL_WINDOW, KVCache
from repro.models.layers.moe import MoEQuantMeta, OdpRuntime
from repro.models.layers.ssm import SSMState
from repro.sharding import context as shctx

Params = Dict


@dataclass(frozen=True)
class MCRuntime:
    """Static inference-compression settings threaded through the model.

    ``quant_meta`` is the scan-safe case: one expert layout shared by every
    MoE layer. ``layer_metas`` is the heterogeneous per-layer case (PMQ
    ``layout='per_layer'``): the model pulls each layer's quantized params
    from ``params['moe_layers']`` and runs loop-mode — one runtime object
    covers both, so engines and ``forward`` consume artifacts uniformly.
    """

    odp: Optional[OdpRuntime] = None
    quant_meta: Optional[MoEQuantMeta] = None
    layer_metas: Optional[Tuple[MoEQuantMeta, ...]] = None

    @property
    def active(self) -> bool:
        return (self.odp is not None or self.quant_meta is not None
                or self.layer_metas is not None)


# --------------------------------------------------------- layer-kind arrays
def layer_kinds(cfg: ModelConfig) -> Dict[str, np.ndarray]:
    """Per-layer (window, chunk) scalars implementing attention alternation."""
    l = cfg.num_layers
    window = np.full(l, GLOBAL_WINDOW, np.int32)
    chunk = np.full(l, GLOBAL_WINDOW, np.int32)
    if cfg.attn_type == "sliding" and cfg.window_size:
        window[:] = cfg.window_size
    elif cfg.attn_type == "local_global":
        for i in range(l):
            if (i % cfg.local_global_period) != cfg.local_global_period - 1:
                window[i] = cfg.window_size
    elif cfg.attn_type == "chunked":
        for i in range(l):
            if (i + 1) % cfg.local_global_period != 0:
                chunk[i] = cfg.chunk_size
    return {"window": window, "chunk": chunk}


def block_kind(cfg: ModelConfig, layer_idx: int) -> str:
    if cfg.ssm_type and cfg.family == "ssm":
        return cfg.ssm_type
    if cfg.is_moe and layer_idx in set(cfg.moe_layer_ids()):
        return "moe"
    return "dense"


# ------------------------------------------------------------------- blocks
def init_block(key, cfg: ModelConfig, kind: str) -> Params:
    ks = jax.random.split(key, 8)
    if kind == "mamba1":
        return {"norm": core_lib.init_norm(cfg),
                "mixer": ssm_lib.init_mamba1(ks[0], cfg)}
    if kind == "mamba2":
        return {"norm": core_lib.init_norm(cfg),
                "mixer": ssm_lib.init_mamba2(ks[0], cfg)}
    p = {
        "norm_attn": core_lib.init_norm(cfg),
        "attn": attn_lib.init_attention(ks[0], cfg),
    }
    if not cfg.use_parallel_residual:
        p["norm_ffn"] = core_lib.init_norm(cfg)
    if cfg.pre_post_norm:
        p["post_attn"] = core_lib.init_norm(cfg)
        p["post_ffn"] = core_lib.init_norm(cfg)
    if kind == "moe":
        p["ffn"] = moe_lib.init_moe(ks[1], cfg)
    else:
        p["ffn"] = core_lib.init_mlp(ks[1], cfg)
    return p


def specs_block(cfg: ModelConfig, kind: str) -> Params:
    if kind == "mamba1":
        return {"norm": core_lib.specs_norm(cfg),
                "mixer": ssm_lib.specs_mamba1(cfg)}
    if kind == "mamba2":
        return {"norm": core_lib.specs_norm(cfg),
                "mixer": ssm_lib.specs_mamba2(cfg)}
    s = {"norm_attn": core_lib.specs_norm(cfg),
         "attn": attn_lib.specs_attention(cfg)}
    if not cfg.use_parallel_residual:
        s["norm_ffn"] = core_lib.specs_norm(cfg)
    if cfg.pre_post_norm:
        s["post_attn"] = core_lib.specs_norm(cfg)
        s["post_ffn"] = core_lib.specs_norm(cfg)
    s["ffn"] = (moe_lib.specs_moe(cfg) if kind == "moe"
                else core_lib.specs_mlp(cfg))
    return s


def apply_block(p: Params, x: jax.Array, cfg: ModelConfig, kind: str, *,
                positions: jax.Array, window=None, chunk=None,
                prefix_len: int = 0, cache=None,
                mc: Optional[MCRuntime] = None,
                capture: bool = False,
                token_mask: Optional[jax.Array] = None,
                odp_threshold: Optional[jax.Array] = None,
                kv_table: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Any, Dict]:
    """One residual block. Returns (x, new_cache, aux).

    capture=True additionally stores the FFN/MoE input activations in aux
    (PMQ calibration taps them for Hessians and eps_{i,j}).

    odp_threshold: optional (B,) traced per-row ODP threshold (the serving
    engines' per-request knob) — forwarded to the MoE dispatch, where it
    overrides the runtime's static ``odp.threshold``.
    """
    aux: Dict = {}
    if kind in ("mamba1", "mamba2"):
        h = core_lib.apply_norm(p["norm"], x, cfg)
        fn = ssm_lib.apply_mamba1 if kind == "mamba1" else ssm_lib.apply_mamba2
        out, new_state = fn(p["mixer"], h, cfg, state=cache)
        return x + out, new_state, aux

    need_colsums = bool(mc and mc.odp is not None
                        and mc.odp.protect_ratio > 0 and kind == "moe")
    need_colsums = need_colsums or (capture and kind == "moe")
    h = core_lib.apply_norm(p["norm_attn"], x, cfg)
    attn_out, new_cache, colsums = attn_lib.apply_attention(
        p["attn"], h, cfg=cfg, positions=positions, window=window,
        chunk=chunk, prefix_len=prefix_len, cache=cache,
        need_colsums=need_colsums, q_valid=token_mask, kv_table=kv_table)
    if cfg.pre_post_norm:
        attn_out = core_lib.apply_norm(p["post_attn"], attn_out, cfg)

    token_imp = None
    metric = mc.odp.importance_metric if (mc and mc.odp) else "eq6"
    if kind == "moe" and metric != "eq6" and (need_colsums or capture):
        x32 = x.astype(jnp.float32)
        token_imp = {
            "l1": lambda: jnp.sum(jnp.abs(x32), -1),
            "mean": lambda: jnp.mean(jnp.abs(x32), -1),
            "variance": lambda: x32.var(-1),
            "kurtosis": lambda: jnp.mean(
                ((x32 - x32.mean(-1, keepdims=True))
                 / (x32.std(-1, keepdims=True) + 1e-6)) ** 4, -1),
        }[metric]()
    elif need_colsums and colsums is not None:
        # Eq. 6: l1 magnitude x mean attention received
        seq = x.shape[1]
        if cache is None:
            denom = jnp.maximum(seq - positions, 1).astype(jnp.float32)
            tl1 = jnp.sum(jnp.abs(x.astype(jnp.float32)), -1)
            token_imp = tl1 * colsums / denom
        else:
            # cached branches (serving prefill + decode): colsums come
            # back query-aligned (B, S) — attention the *current* tokens
            # received this step. The denominator counts the queries that
            # could attend each token; with a token_mask, only valid
            # queries count (suffix sums), so a padded prefill tail can
            # neither feed nor deflate live tokens' importance.
            if token_mask is not None:
                tm = token_mask.astype(jnp.float32)
                counts = jnp.cumsum(tm[:, ::-1], axis=1)[:, ::-1]
            else:
                counts = (seq - jnp.arange(seq)).astype(jnp.float32)
            token_imp = ti_ops.token_importance_decode(x, colsums,
                                                       counts=counts)

    if cfg.use_parallel_residual:
        ffn_out, moe_aux = _apply_ffn(p, h, cfg, kind, mc, token_imp,
                                      token_mask, odp_threshold)
        if cfg.pre_post_norm:
            ffn_out = core_lib.apply_norm(p["post_ffn"], ffn_out, cfg)
        aux.update(moe_aux)
        if capture:
            aux["ffn_input"] = h
            aux["token_importance"] = token_imp
        return x + attn_out + ffn_out, new_cache, aux

    x = x + attn_out
    h2 = core_lib.apply_norm(p["norm_ffn"], x, cfg)
    ffn_out, moe_aux = _apply_ffn(p, h2, cfg, kind, mc, token_imp,
                                  token_mask, odp_threshold)
    if cfg.pre_post_norm:
        ffn_out = core_lib.apply_norm(p["post_ffn"], ffn_out, cfg)
    aux.update(moe_aux)
    if capture:
        aux["ffn_input"] = h2
        aux["token_importance"] = token_imp
    return x + ffn_out, new_cache, aux


def _apply_ffn(p, h, cfg, kind, mc, token_imp, token_mask=None,
               odp_threshold=None):
    if kind == "moe":
        ep = shctx.ep_mesh()
        ep_size = dict(ep.shape).get("data", 0) if ep is not None else 0
        qm = mc.quant_meta if mc else None
        if ep_size > 0 and h.shape[0] % ep_size == 0:
            # explicit expert-parallel dispatch (serving engines enter the
            # EP-mesh context): deterministic 2xall_to_all (+ psum on the
            # dense TP'd path) — engages when the batch tiles the data
            # axis, i.e. the pool-wide decode step; batch-1 prefill falls
            # back to the gather path below. Dense expert stacks take the
            # bf16 body; packed PMQ planes take the quantized body (class
            # stacks sharded over `data`, fused grouped kernel per shard).
            from repro.sharding.moe_parallel import apply_moe_shard_map
            dense_ok = ("w_in" in p["ffn"] and qm is None
                        and not (mc and mc.layer_metas))
            quant_ok = qm is not None and "experts_q" in p["ffn"]
            if dense_ok or quant_ok:
                y = apply_moe_shard_map(
                    p["ffn"], h, cfg, ep,
                    quant_meta=qm if quant_ok else None,
                    odp=mc.odp if mc else None,
                    token_importance=token_imp, token_mask=token_mask,
                    odp_threshold=odp_threshold)
                return y, {}
        return moe_lib.apply_moe(
            p["ffn"], h, cfg,
            odp=mc.odp if mc else None,
            token_importance=token_imp,
            quant_meta=qm,
            token_mask=token_mask,
            odp_threshold=odp_threshold)
    return core_lib.apply_mlp(p["ffn"], h, cfg), {}


_SCALAR_AUX = ("load_balance", "router_z", "odp_pruned_frac",
               "dispatched_frac")


def _scalar_aux(aux: Dict) -> Dict:
    return {k: v for k, v in aux.items() if k in _SCALAR_AUX}


# -------------------------------------------------------------------- model
class DecoderModel:
    """Decoder-only LM (families: dense, moe, ssm, vlm)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.kinds = layer_kinds(cfg)
        moe_period = cfg.moe_layer_period if cfg.is_moe else 1
        # attention alternation also defines the scan period so per-slot KV
        # caches can differ (ring for local/chunked slots, linear for global)
        attn_period = 1
        if cfg.attn_type in ("local_global", "chunked"):
            attn_period = cfg.local_global_period
        period = int(np.lcm(moe_period, attn_period))
        if cfg.num_layers % period != 0:
            period = moe_period if cfg.num_layers % moe_period == 0 else 1
        self.period = period
        self.slot_kinds = [block_kind(cfg, i) for i in range(self.period)]
        self.n_steps = cfg.num_layers // self.period

    # ---- params ----
    def init(self, key) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, self.n_steps * self.period + 2)
        layers = []
        for slot in range(self.period):
            stack = [init_block(keys[step * self.period + slot], cfg,
                                self.slot_kinds[slot])
                     for step in range(self.n_steps)]
            layers.append(jax.tree.map(lambda *xs: jnp.stack(xs), *stack))
        p = {"embed": core_lib.init_embedding(keys[-1], cfg),
             "final_norm": core_lib.init_norm(cfg)}
        for slot in range(self.period):
            p[f"layers{slot}"] = layers[slot]
        if not cfg.use_rope and cfg.family != "ssm":
            p["pos"] = core_lib.init_learned_pos(keys[-2], cfg.max_pos,
                                                 cfg.d_model)
        return p

    def param_specs(self) -> Params:
        cfg = self.cfg
        s = {"embed": core_lib.specs_embedding(cfg),
             "final_norm": core_lib.specs_norm(cfg)}
        for slot in range(self.period):
            blk = specs_block(cfg, self.slot_kinds[slot])
            s[f"layers{slot}"] = jax.tree.map(
                lambda spec: P(*((None,) + tuple(spec))), blk,
                is_leaf=lambda v: isinstance(v, P))
        if not cfg.use_rope and cfg.family != "ssm":
            s["pos"] = core_lib.specs_learned_pos()
        return s

    # ---- kind arrays reshaped per (step, slot) ----
    def _kind_arrays(self):
        w = self.kinds["window"].reshape(self.n_steps, self.period)
        c = self.kinds["chunk"].reshape(self.n_steps, self.period)
        return jnp.asarray(w), jnp.asarray(c)

    # ---- forward ----
    def forward(self, params: Params, tokens: jax.Array, *,
                prefix_embeds: Optional[jax.Array] = None,
                caches=None, start_pos: int | jax.Array = 0,
                mc: Optional[MCRuntime] = None,
                scan: Optional[bool] = None,
                collect_aux: bool = False,
                capture: bool = False,
                moe_layer_params: Optional[list] = None,
                moe_layer_metas: Optional[list] = None,
                token_mask: Optional[jax.Array] = None,
                odp_threshold: Optional[jax.Array] = None,
                kv_table: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Any, Dict]:
        cfg = self.cfg
        dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        x = core_lib.embed_tokens(params["embed"], tokens, cfg, dtype)
        prefix_len = 0
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(dtype), x], axis=1)
            prefix_len = prefix_embeds.shape[1]
        if "pos" in params:
            x = core_lib.add_learned_pos(params["pos"], x, start_pos)
        x = shctx.constrain_batch(x)

        s = x.shape[1]
        # start_pos may be per-row (B,) — continuous-batching slots decode
        # at independent positions — yielding a (B, S) position grid.
        positions = core_lib.position_grid(s, start_pos)
        use_scan = cfg.scan_layers if scan is None else scan
        if (moe_layer_params is None and mc is not None
                and mc.layer_metas is not None):
            # heterogeneous PMQ artifact: per-layer quantized MoE params ride
            # in the param tree; metas come from the runtime
            moe_layer_params = params.get("moe_layers")
            moe_layer_metas = list(mc.layer_metas)
        if moe_layer_params is not None:
            use_scan = False     # per-layer metas are structurally unscannable
        win_arr, chunk_arr = self._kind_arrays()

        def run_slot(x, p_l, cache_l, slot, w, c):
            return apply_block(
                p_l, x, cfg, self.slot_kinds[slot], positions=positions,
                window=w, chunk=c, prefix_len=prefix_len, cache=cache_l,
                mc=mc, capture=capture and not use_scan,
                token_mask=token_mask, odp_threshold=odp_threshold,
                kv_table=kv_table)

        aux_all: Dict = {}
        if use_scan:
            def body(x, xs):
                step_params, step_caches, wrow, crow = xs
                new_caches, auxes = [], {}
                for slot in range(self.period):
                    cache_l = None if step_caches is None else \
                        step_caches[slot]
                    x, nc, aux = run_slot(x, step_params[slot], cache_l,
                                          slot, wrow[slot], crow[slot])
                    new_caches.append(nc)
                    auxes.update({f"{k}_s{slot}": v for k, v in
                                  _scalar_aux(aux).items()})
                if cfg.remat_policy != "none":
                    x = shctx.constrain_batch(x)
                return x, (tuple(new_caches) if step_caches is not None
                           else None, auxes)

            body_fn = body
            if cfg.remat_policy == "minimal":
                body_fn = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            elif cfg.remat_policy == "full":
                body_fn = jax.checkpoint(body)

            step_params = tuple(params[f"layers{slot}"]
                                for slot in range(self.period))
            xs = (step_params, caches, win_arr, chunk_arr)
            x, (new_caches, aux_stack) = jax.lax.scan(body_fn, x, xs)
            if aux_stack:
                aux_all = {k: jnp.mean(v) for k, v in aux_stack.items()}
        else:
            new_caches = [] if caches is not None else None
            per_layer_aux = []
            moe_counter = 0
            for step in range(self.n_steps):
                step_caches = None
                if caches is not None:
                    step_caches = jax.tree.map(lambda a: a[step], caches,
                                               is_leaf=_is_arr)
                ncs = []
                for slot in range(self.period):
                    p_l = jax.tree.map(lambda a: a[step],
                                       params[f"layers{slot}"])
                    cache_l = None if step_caches is None else \
                        step_caches[slot]
                    mc_l = mc
                    if (self.slot_kinds[slot] == "moe"
                            and moe_layer_params is not None):
                        p_l = {**p_l, "ffn": moe_layer_params[moe_counter]}
                        mc_l = MCRuntime(
                            odp=mc.odp if mc else None,
                            quant_meta=moe_layer_metas[moe_counter])
                        moe_counter += 1
                    x, nc, aux = apply_block(
                        p_l, x, cfg, self.slot_kinds[slot],
                        positions=positions,
                        window=win_arr[step, slot],
                        chunk=chunk_arr[step, slot],
                        prefix_len=prefix_len, cache=cache_l, mc=mc_l,
                        capture=capture, token_mask=token_mask,
                        odp_threshold=odp_threshold, kv_table=kv_table)
                    ncs.append(nc)
                    if collect_aux:
                        per_layer_aux.append(aux)
                if caches is not None:
                    new_caches.append(tuple(ncs))
            if caches is not None:
                new_caches = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *new_caches, is_leaf=_is_arr)
            if collect_aux:
                aux_all["per_layer"] = per_layer_aux

        x = core_lib.apply_norm(params["final_norm"], x, cfg)
        logits = core_lib.unembed(params["embed"], x, cfg)
        return logits, new_caches, aux_all

    # ---- caches ----
    def init_caches(self, batch: int, capacity: int, *,
                    linear: bool = False):
        """Per-(step, slot) contiguous caches. ``linear=True`` forces full
        linear layout for every attention slot (no ring buffers) — the
        paged engine's prefill scratch must be page-scatterable, and a
        ring layout would fold distinct logical indices onto one slot."""
        cfg = self.cfg
        cdt = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16

        def one(slot):
            kind = self.slot_kinds[slot]
            if kind in ("mamba1", "mamba2"):
                return ssm_lib.init_ssm_state(cfg, batch)
            # per-slot locality: a bounded ring buffer suffices for sliding /
            # chunked-local slots; global slots keep the full linear cache
            w = int(self.kinds["window"][slot])
            c = int(self.kinds["chunk"][slot])
            local_span = min(w, c)
            ring = (not linear) and 0 < local_span < capacity
            cap = min(capacity, local_span + 8) if ring else capacity
            return attn_lib.init_cache(cfg, batch, cap, ring=ring, dtype=cdt)

        caches = []
        for step in range(self.n_steps):
            caches.append(tuple(one(s) for s in range(self.period)))
        return jax.tree.map(lambda *xs: jnp.stack(xs), *caches,
                            is_leaf=_is_arr)

    def cache_specs(self):
        cfg = self.cfg

        def one(kind):
            if kind in ("mamba1", "mamba2"):
                sp = ssm_lib.ssm_state_specs(cfg)
            else:
                sp = attn_lib.cache_specs()
            return jax.tree.map(lambda v: P(*((None,) + tuple(v))), sp,
                                is_leaf=lambda v: isinstance(v, P))

        return tuple(one(self.slot_kinds[s]) for s in range(self.period))

    def init_paged_caches(self, num_pages: int, page_size: int, *,
                          quant: str = "off", batch: int = 1):
        """Per-(step, slot) paged KV pools (no batch axis — slots address
        pages through the engine's page table; ``batch`` is accepted for
        state-layer API parity with families that carry dense per-slot
        pools next to the paged KV). Only valid for pure attention
        stacks; mamba slots carry recurrent state, which rides the dense
        state pool instead (see ``repro.serve.slot_state``)."""
        for step in range(self.n_steps):
            for s in range(self.period):
                if self.slot_kinds[s] in ("mamba1", "mamba2"):
                    raise ValueError(
                        "paged KV caches are only supported for attention "
                        f"layers; slot {s} is {self.slot_kinds[s]!r}")
        cfg = self.cfg
        cdt = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
        bits = {"off": 16, "int8": 8, "int4": 4}[quant]
        caches = []
        for step in range(self.n_steps):
            caches.append(tuple(
                attn_lib.init_paged_cache(cfg, num_pages, page_size,
                                          bits=bits, dtype=cdt)
                for _ in range(self.period)))
        return jax.tree.map(lambda *xs: jnp.stack(xs), *caches,
                            is_leaf=_is_arr)

    def state_kinds(self):
        from repro.serve import slot_state
        return slot_state.state_kinds(self.cfg)

    def decode_step(self, params, caches, tokens, pos, *,
                    mc: Optional[MCRuntime] = None,
                    token_mask: Optional[jax.Array] = None,
                    odp_threshold: Optional[jax.Array] = None,
                    kv_table: Optional[jax.Array] = None):
        """tokens: (B, 1); pos: scalar int32 position shared by the batch,
        or (B,) int32 per-row positions (continuous-batching slots).
        token_mask: optional (B, 1) bool — masked rows (inactive slots)
        are withheld from MoE dispatch so they can't consume capacity.
        odp_threshold: optional (B,) float32 traced per-row ODP threshold
        (the engines' per-request quality/latency knob; 0.0 = keep all).
        kv_table: optional (B, max_pages) int32 page table — required when
        ``caches`` are paged pools (see ``init_paged_caches``)."""
        logits, new_caches, _ = self.forward(
            params, tokens, caches=caches, start_pos=pos, mc=mc,
            token_mask=token_mask, odp_threshold=odp_threshold,
            kv_table=kv_table)
        return logits, new_caches


def _is_arr(x):
    return isinstance(x, (jax.Array, np.ndarray)) or hasattr(x, "shape")
