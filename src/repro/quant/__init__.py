from repro.quant.quantizer import (  # noqa: F401
    QuantParams, quantize, dequantize, quant_dequant, quantization_mse,
    compute_scales, quantize_with,
)
from repro.quant.binary import (  # noqa: F401
    BinaryParams, binarize, debinarize, binary_quant_dequant,
    binary_matmul_addsub,
)
from repro.quant.packing import (  # noqa: F401
    PackedWeight, pack_codes, unpack_codes, pack_quantized,
    dequantize_packed, packed_bits_per_param,
)
from repro.quant.gptq import (  # noqa: F401
    GPTQResult, accumulate_hessian, init_hessian, gptq_quantize,
    gptq_dequantize, rtn_quantize, reconstruction_loss,
)
