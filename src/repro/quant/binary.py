"""1-bit expert quantization (MC paper Appendix A.2).

``B = sign(W)``; storage uses the paper's bit transform
``B~ = (sign(W) + 1) / 2 in {0,1}`` so each element costs exactly one bit.
Dequantization is ``W_hat = s * (2*B~ - 1)``.

The paper uses a single per-matrix scale ``s = ||W||_1 / (d*m)``
(XNOR-Net style). We default to per-(group, column) mean-|W| scales — the
same ``(n_groups, d_out)`` layout as the affine quantizer — which is strictly
more accurate and keeps the packed-GEMM kernel uniform across bit-widths;
``per_tensor=True`` reproduces the paper exactly.

TPU adaptation note (DESIGN.md §3): the paper's add/sub trick replaces
multiplies on scalar pipelines; on TPU the MXU makes the multiply free and
the win is the 16x storage/bandwidth reduction, which the packing provides.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class BinaryParams(NamedTuple):
    bits_plane: jax.Array   # (d_in, d_out) uint8 in {0,1}  (B~ of the paper)
    scales: jax.Array       # (n_groups, d_out) f32 (or (1, 1) if per-tensor)
    group_size: int


def binarize(w: jax.Array, group_size: int, per_tensor: bool = False
             ) -> BinaryParams:
    w32 = w.astype(jnp.float32)
    sign01 = (w32 >= 0).astype(jnp.uint8)
    if per_tensor:
        s = jnp.mean(jnp.abs(w32)).reshape(1, 1)
        return BinaryParams(sign01, s, group_size=w.shape[0])
    d_in, d_out = w.shape
    assert d_in % group_size == 0
    g = jnp.abs(w32).reshape(d_in // group_size, group_size, d_out)
    s = jnp.mean(g, axis=1)
    return BinaryParams(sign01, s, group_size)


def debinarize(bp: BinaryParams, dtype=jnp.float32) -> jax.Array:
    d_in, d_out = bp.bits_plane.shape
    pm1 = bp.bits_plane.astype(jnp.float32) * 2.0 - 1.0
    if bp.scales.size == 1:
        w = pm1 * bp.scales.reshape(())
    else:
        g = pm1.reshape(bp.scales.shape[0], bp.group_size, d_out)
        w = (g * bp.scales[:, None, :]).reshape(d_in, d_out)
    return w.astype(dtype)


def binary_quant_dequant(w: jax.Array, group_size: int,
                         per_tensor: bool = False) -> jax.Array:
    return debinarize(binarize(w, group_size, per_tensor), dtype=w.dtype)


def binary_matmul_addsub(x: jax.Array, bp: BinaryParams) -> jax.Array:
    """Paper Eq. (10): s * (sum_{B~=1} x_j - sum_{B~=0} x_j).

    Reference for the multiplication-free formulation. Numerically identical
    to ``x @ debinarize(bp)`` for per-tensor scales; kept as the fidelity
    oracle for the add/sub claim in tests.
    """
    b = bp.bits_plane.astype(x.dtype)
    pos = x @ b                       # sum over B~ == 1
    neg = x.sum(axis=-1, keepdims=True) - pos
    if bp.scales.size == 1:
        return bp.scales.reshape(()) * (pos - neg)
    # grouped scales: fold scale into per-group partial sums
    d_in, d_out = bp.bits_plane.shape
    n_g = bp.scales.shape[0]
    xg = x.reshape(*x.shape[:-1], n_g, bp.group_size)
    bg = b.reshape(n_g, bp.group_size, d_out)
    pos = jnp.einsum("...gk,gko->...go", xg, bg)
    neg = xg.sum(axis=-1)[..., None] - pos
    return jnp.einsum("...go,go->...o", pos - neg, bp.scales)
