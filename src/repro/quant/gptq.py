"""GPTQ (Frantar et al., 2022) in pure JAX — the paper's PTQ workhorse.

Quantizes a weight ``W (d_in, d_out)`` one contraction-row at a time,
compensating the rounding error of each row into the not-yet-quantized rows
through the inverse-Hessian Cholesky factor:

    H    = 2 * X^T X            (calibration activations X, Sec. 3.1)
    U    = chol(H^-1)^T         (upper factor, H^-1 = U^T U)
    err  = (w_i - dq(w_i)) / U[i, i]
    W[j] -= U[i, j] * err       for j > i

Blocked exactly like the reference implementation: the inner loop runs over a
``group_size`` block with in-block propagation, then one GEMM pushes the
accumulated error into all later rows. ``blocksize == group_size`` so group
scales are computed at block entry from the error-compensated weights.

Row quantizers are pluggable: group-wise affine for bits >= 2, sign
binarization (scale = mean |w| of the block) for 1-bit experts — this is how
PMQ realizes its {1, 2, 3}-bit allocation on a single code path.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class GPTQResult(NamedTuple):
    codes: jax.Array     # (d_in, d_out) uint8
    scales: jax.Array    # (n_groups, d_out) f32
    zeros: jax.Array     # (n_groups, d_out) f32 (unused for 1-bit)
    bits: int
    group_size: int


def accumulate_hessian(h: jax.Array, x: jax.Array, count: int,
                       ) -> Tuple[jax.Array, int]:
    """Running-mean Hessian update, GPTQ-style.

    ``x``: (..., d_in) activation samples; flattened over leading dims.
    """
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    n_new = x2.shape[0]
    total = count + n_new
    h = h * (count / total) + (2.0 / total) * (x2.T @ x2)
    return h, total


def init_hessian(d_in: int) -> jax.Array:
    return jnp.zeros((d_in, d_in), jnp.float32)


def _inv_hessian_chol(h: jax.Array, percdamp: float) -> jax.Array:
    d = h.shape[0]
    damp = percdamp * jnp.mean(jnp.diag(h)) + 1e-8
    hd = h + damp * jnp.eye(d, dtype=h.dtype)
    hinv = jnp.linalg.inv(hd)
    # enforce symmetry before Cholesky for numerical safety
    hinv = 0.5 * (hinv + hinv.T)
    ridge = 1e-8 * jnp.mean(jnp.diag(hinv)) * jnp.eye(d, dtype=h.dtype)
    u = jnp.linalg.cholesky(hinv + ridge).T   # upper: hinv = u^T u
    return u


def _affine_rowq(wrow, scale, zero, maxq):
    q = jnp.clip(jnp.round(wrow / scale + zero), 0, maxq)
    return q.astype(jnp.uint8), (q - zero) * scale


def _sign_rowq(wrow, scale):
    q = (wrow >= 0).astype(jnp.uint8)
    return q, (q.astype(jnp.float32) * 2.0 - 1.0) * scale


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "percdamp"))
def gptq_quantize(w: jax.Array, hessian: jax.Array, *, bits: int,
                  group_size: int = 128, percdamp: float = 0.01) -> GPTQResult:
    """Quantize ``w`` to ``bits`` with GPTQ error compensation."""
    d_in, d_out = w.shape
    assert d_in % group_size == 0, (d_in, group_size)
    g = group_size
    nb = d_in // g
    maxq = float(2 ** bits - 1)

    w = w.astype(jnp.float32)
    u = _inv_hessian_chol(hessian.astype(jnp.float32), percdamp)
    col_ids = jnp.arange(d_in)

    def block_body(b, carry):
        wcur, codes, scales, zeros = carry
        r0 = b * g
        wblk = jax.lax.dynamic_slice(wcur, (r0, 0), (g, d_out))
        ublk = jax.lax.dynamic_slice(u, (r0, 0), (g, d_in))        # rows of U
        ulocal = jax.lax.dynamic_slice(ublk, (0, r0), (g, g))      # in-block

        if bits == 1:
            scale = jnp.maximum(jnp.mean(jnp.abs(wblk), axis=0), 1e-8)
            zero = jnp.zeros_like(scale)
        else:
            wmax = jnp.maximum(wblk.max(axis=0), 0.0)
            wmin = jnp.minimum(wblk.min(axis=0), 0.0)
            rng = wmax - wmin
            scale = jnp.where(rng > 0, rng / maxq, 1.0)
            zero = jnp.round(-wmin / scale)

        def row_body(i, c):
            wb, qb, errb = c
            wrow = wb[i]
            if bits == 1:
                q, dq = _sign_rowq(wrow, scale)
            else:
                q, dq = _affine_rowq(wrow, scale, zero, maxq)
            d = jnp.maximum(ulocal[i, i], 1e-10)
            err = (wrow - dq) / d
            coef = ulocal[i] * (jnp.arange(g) > i)   # strictly-later rows
            wb = wb - coef[:, None] * err[None, :]
            return wb, qb.at[i].set(q), errb.at[i].set(err)

        _, qblk, errblk = jax.lax.fori_loop(
            0, g, row_body,
            (wblk, jnp.zeros((g, d_out), jnp.uint8),
             jnp.zeros((g, d_out), jnp.float32)))

        # push accumulated error into all rows >= r0 + g
        future = (col_ids >= r0 + g).astype(jnp.float32)
        wcur = wcur - (ublk * future[None, :]).T @ errblk

        codes = jax.lax.dynamic_update_slice(codes, qblk, (r0, 0))
        scales = scales.at[b].set(scale)
        zeros = zeros.at[b].set(zero)
        return wcur, codes, scales, zeros

    init = (w, jnp.zeros((d_in, d_out), jnp.uint8),
            jnp.zeros((nb, d_out), jnp.float32),
            jnp.zeros((nb, d_out), jnp.float32))
    _, codes, scales, zeros = jax.lax.fori_loop(0, nb, block_body, init)
    return GPTQResult(codes, scales, zeros, bits, group_size)


def gptq_dequantize(res: GPTQResult, dtype=jnp.float32) -> jax.Array:
    d_in, d_out = res.codes.shape
    c = res.codes.astype(jnp.float32).reshape(-1, res.group_size, d_out)
    if res.bits == 1:
        w = (c * 2.0 - 1.0) * res.scales[:, None, :]
    else:
        w = (c - res.zeros[:, None, :]) * res.scales[:, None, :]
    return w.reshape(d_in, d_out).astype(dtype)


def rtn_quantize(w: jax.Array, *, bits: int, group_size: int = 128
                 ) -> GPTQResult:
    """Round-to-nearest baseline in the same result container."""
    d_in, d_out = w.shape
    w32 = w.astype(jnp.float32)
    g = w32.reshape(-1, group_size, d_out)
    if bits == 1:
        scale = jnp.maximum(jnp.mean(jnp.abs(g), axis=1), 1e-8)
        zero = jnp.zeros_like(scale)
        codes = (g >= 0).reshape(d_in, d_out).astype(jnp.uint8)
    else:
        maxq = 2 ** bits - 1
        wmax = jnp.maximum(g.max(axis=1), 0.0)
        wmin = jnp.minimum(g.min(axis=1), 0.0)
        rng = wmax - wmin
        scale = jnp.where(rng > 0, rng / maxq, 1.0)
        zero = jnp.round(-wmin / scale)
        codes = jnp.clip(jnp.round(g / scale[:, None, :] + zero[:, None, :]),
                         0, maxq).reshape(d_in, d_out).astype(jnp.uint8)
    return GPTQResult(codes, scale, zero, bits, group_size)


def reconstruction_loss(w: jax.Array, res: GPTQResult, hessian: jax.Array
                        ) -> jax.Array:
    """Proxy objective tr(dW^T H dW) — what GPTQ minimizes (Eq. 2)."""
    dw = w.astype(jnp.float32) - gptq_dequantize(res)
    return jnp.einsum("io,ij,jo->", dw, hessian, dw) / w.shape[1]
