"""Sub-byte weight packing.

Codes are ``uint8`` values in ``[0, 2**bits)`` laid out ``(d_in, d_out)``.
We pack along ``d_in`` (the contraction dim) so a GEMM kernel can unpack a
``(bk, bn)`` tile from a ``(bk * bits / 8, bn)`` byte tile that lives
contiguously in VMEM.

* 1/2/4-bit: ``8 // bits`` values per byte, little-endian within the byte.
* 3-bit: plane decomposition ``c = 4 * hi1 + lo2`` — one 2-bit plane plus one
  1-bit plane (3 bits total, zero padding waste). This keeps every bit-width
  on the same two fast unpack paths instead of a 10-in-32 scheme with odd
  alignment. The MC paper restricts expert widths to {1,2,3}; attention uses
  4-bit, so these four cover the whole system.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PackedWeight(NamedTuple):
    """Packed planes + dequant params for one logical (d_in, d_out) matrix."""

    planes: Tuple[jax.Array, ...]   # one or two uint8 planes, packed over d_in
    scales: jax.Array               # (n_groups, d_out)
    zeros: jax.Array                # (n_groups, d_out); for 1-bit: all 0.5*2-1 handled in dequant
    bits: int
    group_size: int
    d_in: int

    @property
    def nbytes(self) -> int:
        n = sum(int(np.prod(p.shape)) for p in self.planes)
        n += int(np.prod(self.scales.shape)) * 2   # stored bf16 on device
        n += int(np.prod(self.zeros.shape)) * 2
        return n


def _pack_pow2(codes: jax.Array, bits: int) -> jax.Array:
    """Pack codes (d_in, d_out), bits in {1,2,4,8} -> (d_in*bits//8, d_out) uint8."""
    assert bits in (1, 2, 4, 8)
    if bits == 8:
        return codes.astype(jnp.uint8)
    per = 8 // bits
    d_in, d_out = codes.shape
    assert d_in % per == 0, (d_in, bits)
    c = codes.reshape(d_in // per, per, d_out).astype(jnp.uint32)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits)[None, :, None]
    packed = jnp.sum(c << shifts, axis=1)
    return packed.astype(jnp.uint8)


def _unpack_pow2(packed: jax.Array, bits: int, d_in: int) -> jax.Array:
    """Inverse of :func:`_pack_pow2` -> (d_in, d_out) uint8."""
    assert bits in (1, 2, 4, 8)
    if bits == 8:
        return packed
    per = 8 // bits
    mask = jnp.uint32(2 ** bits - 1)
    p = packed.astype(jnp.uint32)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits)[None, :, None]
    vals = (p[:, None, :] >> shifts) & mask
    return vals.reshape(d_in, packed.shape[-1]).astype(jnp.uint8)


def pack_codes(codes: jax.Array, bits: int) -> Tuple[jax.Array, ...]:
    """Pack (d_in, d_out) codes at any supported width -> tuple of planes."""
    if bits == 3:
        lo = codes & jnp.uint8(0x3)           # 2-bit plane
        hi = (codes >> 2) & jnp.uint8(0x1)    # 1-bit plane
        return (_pack_pow2(lo, 2), _pack_pow2(hi, 1))
    return (_pack_pow2(codes, bits),)


def unpack_codes(planes: Tuple[jax.Array, ...], bits: int, d_in: int) -> jax.Array:
    if bits == 3:
        lo = _unpack_pow2(planes[0], 2, d_in)
        hi = _unpack_pow2(planes[1], 1, d_in)
        return (lo | (hi << 2)).astype(jnp.uint8)
    return _unpack_pow2(planes[0], bits, d_in)


def pack_quantized(codes: jax.Array, scales: jax.Array, zeros: jax.Array,
                   bits: int, group_size: int) -> PackedWeight:
    return PackedWeight(pack_codes(codes, bits), scales.astype(jnp.float32),
                        zeros.astype(jnp.float32), bits, group_size,
                        d_in=codes.shape[0])


def dequantize_packed(pw: PackedWeight, dtype=jnp.bfloat16) -> jax.Array:
    """Reference unpack+dequant -> (d_in, d_out) float weights."""
    codes = unpack_codes(pw.planes, pw.bits, pw.d_in).astype(jnp.float32)
    d_in, d_out = codes.shape
    g = codes.reshape(pw.scales.shape[0], pw.group_size, d_out)
    if pw.bits == 1:
        w = (g * 2.0 - 1.0) * pw.scales[:, None, :]
    else:
        w = (g - pw.zeros[:, None, :]) * pw.scales[:, None, :]
    return w.reshape(d_in, d_out).astype(dtype)


def packed_bits_per_param(bits: int, group_size: int) -> float:
    """Effective storage bits/param incl. bf16 scale+zero overhead."""
    overhead = (16 + (16 if bits > 1 else 0)) / group_size
    return bits + overhead
