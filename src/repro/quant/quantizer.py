"""Group-wise uniform affine quantizer (the PMQ building block).

Layout convention used throughout the framework:

* weights ``W`` are ``(d_in, d_out)`` — activations multiply from the left,
  ``y = x @ W``;
* quantization groups run along ``d_in`` (the contraction dim), size
  ``group_size``; each group stores one ``(scale, zero)`` pair **per output
  column**, i.e. ``scales/zeros`` are ``(n_groups, d_out)``;
* integer codes live in ``[0, 2**bits - 1]`` stored as ``uint8`` (packing into
  denser planes is :mod:`repro.quant.packing`'s job).

1-bit weights use sign binarization (:mod:`repro.quant.binary`), not this
affine quantizer — the MC paper treats them separately (Appendix A.2).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class QuantParams(NamedTuple):
    """Quantized tensor: integer codes + affine dequant parameters."""

    codes: jax.Array    # (d_in, d_out) uint8, values in [0, 2**bits - 1]
    scales: jax.Array   # (n_groups, d_out) f32
    zeros: jax.Array    # (n_groups, d_out) f32  (stored as float zero-points)
    bits: int
    group_size: int


def _group_view(w: jax.Array, group_size: int) -> jax.Array:
    d_in, d_out = w.shape
    assert d_in % group_size == 0, (d_in, group_size)
    return w.reshape(d_in // group_size, group_size, d_out)


def compute_scales(w: jax.Array, bits: int, group_size: int
                   ) -> Tuple[jax.Array, jax.Array]:
    """Min/max affine scale+zero per (group, out-column)."""
    maxq = 2 ** bits - 1
    g = _group_view(w.astype(jnp.float32), group_size)
    wmax = jnp.maximum(g.max(axis=1), 0.0)
    wmin = jnp.minimum(g.min(axis=1), 0.0)
    rng = wmax - wmin
    scale = jnp.where(rng > 0, rng / maxq, 1.0)
    zero = jnp.round(-wmin / scale)
    return scale, zero


def quantize_with(w: jax.Array, scales: jax.Array, zeros: jax.Array,
                  bits: int, group_size: int) -> jax.Array:
    """Quantize with precomputed (scale, zero); returns uint8 codes."""
    maxq = 2 ** bits - 1
    g = _group_view(w.astype(jnp.float32), group_size)
    q = jnp.clip(jnp.round(g / scales[:, None, :] + zeros[:, None, :]), 0, maxq)
    return q.reshape(w.shape).astype(jnp.uint8)


def quantize(w: jax.Array, bits: int, group_size: int) -> QuantParams:
    """Round-to-nearest group-wise quantization (the GPTQ-free baseline)."""
    scales, zeros = compute_scales(w, bits, group_size)
    codes = quantize_with(w, scales, zeros, bits, group_size)
    return QuantParams(codes, scales, zeros, bits, group_size)


def dequantize(qp: QuantParams, dtype=jnp.float32) -> jax.Array:
    """codes -> float weights."""
    g = _group_view(qp.codes.astype(jnp.float32), qp.group_size)
    w = (g - qp.zeros[:, None, :]) * qp.scales[:, None, :]
    return w.reshape(qp.codes.shape).astype(dtype)


def quant_dequant(w: jax.Array, bits: int, group_size: int) -> jax.Array:
    """Fake-quantization pass (used for reconstruction-error probes)."""
    return dequantize(quantize(w, bits, group_size), dtype=w.dtype)


def quantization_mse(w: jax.Array, bits: int, group_size: int) -> jax.Array:
    wq = quant_dequant(w, bits, group_size)
    return jnp.mean((w.astype(jnp.float32) - wq.astype(jnp.float32)) ** 2)
