"""Elastic scaling: re-mesh after node loss / fleet resize.

Checkpoints are topology-free (full logical arrays, see checkpoint/), so
elasticity reduces to (1) planning a new mesh from the surviving device
count, (2) recomputing shardings for it, (3) rescaling the data plan.
``plan_elastic`` shrinks the ``data`` axis first (pure DP/FSDP degree —
model math unchanged), dropping to smaller power-of-two factors; the
``model`` axis is preserved so TP-sharded kernels keep their tile shapes.

**Serving elasticity** (the fleet layer, ``serve.fleet``) works at the
granularity of expert **blocks** instead of mesh axes: a replica's
artifact is cut into contiguous byte-weighted blocks of class-sorted
experts (``core.pipeline.byte_balanced_ranges``), each owned by exactly
one host. On topology change, ownership is re-planned here —
:func:`plan_host_loss` re-homes a dead host's blocks onto the lightest
survivors, :func:`plan_host_join` peels blocks off the heaviest hosts
for a fresh one — and every move names exactly the bytes that must be
*streamed* (the delta); blocks already resident never move, so re-shard
traffic is the dead/joined share of the artifact, not a full reload.
:func:`mesh_reshard_delta` is the mesh-native equivalent for real
multi-process replicas: old vs new ``expert_shard_expectation``, delta
per surviving process.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config import MeshConfig


@dataclass(frozen=True)
class ElasticPlan:
    old_mesh: MeshConfig
    new_mesh: MeshConfig
    new_global_batch: int
    grad_accum: int          # microbatching to preserve the effective batch
    note: str


def _largest_pow2_leq(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def plan_elastic(mesh: MeshConfig, surviving_devices: int,
                 global_batch: int) -> ElasticPlan:
    """New topology after failures, preserving the model axis."""
    model = mesh.axis_size("model")
    pods = mesh.axis_size("pod")
    if surviving_devices < model:
        raise ValueError(
            f"cannot keep model axis {model} with {surviving_devices} devices")
    per_pod = surviving_devices // max(pods, 1)
    new_data = _largest_pow2_leq(max(per_pod // model, 1))
    if mesh.multi_pod:
        new = MeshConfig(shape=(pods, new_data, model),
                         axis_names=("pod", "data", "model"))
    else:
        new = MeshConfig(shape=(new_data, model),
                         axis_names=("data", "model"))

    old_dp = mesh.axis_size("data") * max(mesh.axis_size("pod"), 1)
    new_dp = new_data * max(pods, 1)
    # keep the effective batch via gradient accumulation
    accum = int(np.ceil(old_dp / new_dp))
    nb = global_batch // accum
    nb = max(new_dp, nb - nb % new_dp)
    return ElasticPlan(
        old_mesh=mesh, new_mesh=new, new_global_batch=nb, grad_accum=accum,
        note=(f"data axis {mesh.axis_size('data')} -> {new_data}; "
              f"grad_accum x{accum} preserves the effective batch"))


def validate_resharding(param_shapes: Dict[str, Tuple[int, ...]],
                        new_mesh: MeshConfig) -> Dict[str, str]:
    """Check every parameter still shards on the new mesh (divisibility).

    Returns {param_path: issue} for any that must demote to replicated —
    empty dict means the plan is clean.
    """
    issues = {}
    model = new_mesh.axis_size("model")
    data = new_mesh.axis_size("data")
    for path, shape in param_shapes.items():
        if len(shape) >= 2:
            if shape[-1] % model != 0 and shape[-1] > 1:
                issues[path] = f"dim {shape[-1]} ! % model={model}"
            elif shape[0] % data != 0 and shape[0] > data:
                issues[path] = f"dim {shape[0]} ! % data={data}"
    return issues


# ---------------------------------------------- serving: block ownership
@dataclass(frozen=True)
class BlockAssignment:
    """Which host owns which expert block of one replica's artifact.

    ``blocks`` are contiguous, sorted, disjoint global expert ranges that
    tile ``[0, E)`` exactly (the invariant ``serve.fleet`` relies on to
    merge host holdings back into a full param tree); ``block_bytes`` is
    each block's on-disk weight and ``owner[i]`` the host id holding
    ``blocks[i]``.
    """

    blocks: Tuple[Tuple[int, int], ...]
    block_bytes: Tuple[int, ...]
    owner: Tuple[int, ...]

    def __post_init__(self):
        pos = 0
        for a, b in self.blocks:
            if a != pos or b <= a:
                raise ValueError(
                    f"blocks {self.blocks} do not tile [0, E) — gap or "
                    f"overlap at expert {pos}")
            pos = b
        if not (len(self.blocks) == len(self.block_bytes) == len(self.owner)):
            raise ValueError("blocks/block_bytes/owner length mismatch")

    @property
    def hosts(self) -> Tuple[int, ...]:
        return tuple(sorted(set(self.owner)))

    def ranges_of(self, host: int) -> Tuple[Tuple[int, int], ...]:
        return tuple(b for b, o in zip(self.blocks, self.owner)
                     if o == host)

    def bytes_of(self, host: int) -> int:
        return sum(w for w, o in zip(self.block_bytes, self.owner)
                   if o == host)

    @property
    def max_host_bytes(self) -> int:
        return max(self.bytes_of(h) for h in self.hosts)


@dataclass(frozen=True)
class BlockMove:
    """One unit of re-shard traffic: stream ``block`` (``nbytes`` on the
    wire) to ``dst``. ``src`` is the previous owner — the dead host for a
    loss, a surviving donor for a join — and streams nothing (blocks are
    read back from the artifact store, never peer-to-peer)."""

    block: Tuple[int, int]
    nbytes: int
    src: Optional[int]
    dst: int


@dataclass(frozen=True)
class ServingReshardPlan:
    """Delta plan for one replica topology change.

    ``moves`` name every block that changes owner; ``delta_bytes`` (the
    sum of moved block bytes) is what the survivors actually stream,
    asserted strictly below ``full_reload_bytes`` (what rebooting the
    replica from scratch would read) by the fleet tests/benchmarks.
    """

    old: BlockAssignment
    new: BlockAssignment
    moves: Tuple[BlockMove, ...]
    delta_bytes: int
    full_reload_bytes: int
    note: str


def _block_weights(ebytes: Sequence[int],
                   blocks: Sequence[Tuple[int, int]]) -> Tuple[int, ...]:
    return tuple(int(sum(ebytes[a:b])) for a, b in blocks)


def initial_assignment(ebytes: Sequence[int], hosts: Sequence[int],
                       blocks_per_host: int = 2) -> BlockAssignment:
    """Cut the expert axis into byte-balanced blocks and spread them over
    ``hosts`` (longest-processing-time greedy: heaviest block to the
    lightest host). ``blocks_per_host > 1`` gives the re-shard planner
    granularity — on a host loss the orphaned blocks can go to
    *different* survivors instead of one host eating the whole share.
    """
    from repro.core.pipeline import byte_balanced_ranges
    hosts = list(hosts)
    if not hosts:
        raise ValueError("need at least one host")
    n_blocks = min(max(len(hosts) * max(blocks_per_host, 1), 1),
                   len(ebytes))
    blocks = tuple((int(a), int(b))
                   for a, b in byte_balanced_ranges(ebytes, n_blocks))
    weights = _block_weights(ebytes, blocks)
    load = {h: 0 for h in hosts}
    owner = [0] * len(blocks)
    for i in sorted(range(len(blocks)), key=lambda i: (-weights[i], i)):
        dst = min(hosts, key=lambda h: (load[h], h))
        owner[i] = dst
        load[dst] += weights[i]
    return BlockAssignment(blocks=blocks, block_bytes=weights,
                           owner=tuple(owner))


def plan_host_loss(assignment: BlockAssignment,
                   dead_host: int) -> ServingReshardPlan:
    """Re-home a dead host's blocks onto the lightest survivors.

    Only the orphaned blocks move (and therefore stream); every
    survivor's resident blocks stay put. Raises when the dead host is
    the last one — there is nothing left to serve from.
    """
    if dead_host not in assignment.owner:
        raise ValueError(f"host {dead_host} owns no blocks "
                         f"(hosts: {assignment.hosts})")
    survivors = [h for h in assignment.hosts if h != dead_host]
    if not survivors:
        raise ValueError(
            f"host {dead_host} is the last host of the replica — a "
            "1-host replica cannot re-shard, only die (router-level "
            "replica failover handles that)")
    load = {h: assignment.bytes_of(h) for h in survivors}
    owner = list(assignment.owner)
    moves: List[BlockMove] = []
    orphans = [i for i, o in enumerate(owner) if o == dead_host]
    for i in sorted(orphans, key=lambda i: (-assignment.block_bytes[i], i)):
        dst = min(survivors, key=lambda h: (load[h], h))
        moves.append(BlockMove(block=assignment.blocks[i],
                               nbytes=assignment.block_bytes[i],
                               src=dead_host, dst=dst))
        owner[i] = dst
        load[dst] += assignment.block_bytes[i]
    new = BlockAssignment(blocks=assignment.blocks,
                          block_bytes=assignment.block_bytes,
                          owner=tuple(owner))
    delta = sum(m.nbytes for m in moves)
    total = sum(assignment.block_bytes)
    return ServingReshardPlan(
        old=assignment, new=new, moves=tuple(moves), delta_bytes=delta,
        full_reload_bytes=total,
        note=(f"host {dead_host} lost: {len(moves)} block(s), "
              f"{delta}/{total} expert bytes re-streamed onto "
              f"{sorted(set(m.dst for m in moves))}"))


def plan_host_join(assignment: BlockAssignment,
                   new_host: int) -> ServingReshardPlan:
    """Peel blocks off the heaviest hosts for a freshly joined one.

    Moves a block only while it strictly improves balance (the donor
    stays heavier than the joiner would become), so join traffic is
    bounded by the joiner's fair share. Donors *drop* their moved blocks
    from memory; only the joiner streams.
    """
    if new_host in assignment.owner:
        raise ValueError(f"host {new_host} already owns blocks")
    owner = list(assignment.owner)
    load = {h: assignment.bytes_of(h) for h in assignment.hosts}
    load[new_host] = 0
    moves: List[BlockMove] = []
    while True:
        best = None
        for i, o in enumerate(owner):
            if o == new_host:
                continue
            w = assignment.block_bytes[i]
            # strict improvement: after the move the donor must still
            # carry at least as much as the joiner — otherwise we just
            # swapped the imbalance around
            if load[o] - w >= load[new_host] + w and \
                    (best is None or w > assignment.block_bytes[best]
                     or (w == assignment.block_bytes[best] and i < best)):
                best = i
        if best is None:
            break
        o = owner[best]
        moves.append(BlockMove(block=assignment.blocks[best],
                               nbytes=assignment.block_bytes[best],
                               src=o, dst=new_host))
        load[o] -= assignment.block_bytes[best]
        load[new_host] += assignment.block_bytes[best]
        owner[best] = new_host
    if not moves:
        raise ValueError(
            "no block move improves balance — cut the artifact into more "
            "blocks (blocks_per_host) to give the planner granularity")
    new = BlockAssignment(blocks=assignment.blocks,
                          block_bytes=assignment.block_bytes,
                          owner=tuple(owner))
    delta = sum(m.nbytes for m in moves)
    total = sum(assignment.block_bytes)
    return ServingReshardPlan(
        old=assignment, new=new, moves=tuple(moves), delta_bytes=delta,
        full_reload_bytes=total,
        note=(f"host {new_host} joined: streams {len(moves)} block(s), "
              f"{delta}/{total} expert bytes; donors drop them"))


def mesh_reshard_delta(old_mesh, new_mesh, segments,
                       process_index: int = 0
                       ) -> Tuple[Tuple[int, int], ...]:
    """Mesh-native re-shard delta for one surviving process: the expert
    ranges its **new** placement expectation demands that its **old** one
    did not already hold — exactly what it must stream from the artifact
    after the fleet re-meshes (``jax.sharding.Mesh`` args, real or
    simulated devices)."""
    from repro.core.pipeline import (expert_range_delta,
                                     expert_shard_expectation)
    old = expert_shard_expectation(old_mesh, segments,
                                   process_index=process_index)
    new = expert_shard_expectation(new_mesh, segments,
                                   process_index=process_index)
    return expert_range_delta(old, new)
