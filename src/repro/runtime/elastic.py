"""Elastic scaling: re-mesh after node loss / fleet resize.

Checkpoints are topology-free (full logical arrays, see checkpoint/), so
elasticity reduces to (1) planning a new mesh from the surviving device
count, (2) recomputing shardings for it, (3) rescaling the data plan.
``plan_elastic`` shrinks the ``data`` axis first (pure DP/FSDP degree —
model math unchanged), dropping to smaller power-of-two factors; the
``model`` axis is preserved so TP-sharded kernels keep their tile shapes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.config import MeshConfig


@dataclass(frozen=True)
class ElasticPlan:
    old_mesh: MeshConfig
    new_mesh: MeshConfig
    new_global_batch: int
    grad_accum: int          # microbatching to preserve the effective batch
    note: str


def _largest_pow2_leq(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def plan_elastic(mesh: MeshConfig, surviving_devices: int,
                 global_batch: int) -> ElasticPlan:
    """New topology after failures, preserving the model axis."""
    model = mesh.axis_size("model")
    pods = mesh.axis_size("pod")
    if surviving_devices < model:
        raise ValueError(
            f"cannot keep model axis {model} with {surviving_devices} devices")
    per_pod = surviving_devices // max(pods, 1)
    new_data = _largest_pow2_leq(max(per_pod // model, 1))
    if mesh.multi_pod:
        new = MeshConfig(shape=(pods, new_data, model),
                         axis_names=("pod", "data", "model"))
    else:
        new = MeshConfig(shape=(new_data, model),
                         axis_names=("data", "model"))

    old_dp = mesh.axis_size("data") * max(mesh.axis_size("pod"), 1)
    new_dp = new_data * max(pods, 1)
    # keep the effective batch via gradient accumulation
    accum = int(np.ceil(old_dp / new_dp))
    nb = global_batch // accum
    nb = max(new_dp, nb - nb % new_dp)
    return ElasticPlan(
        old_mesh=mesh, new_mesh=new, new_global_batch=nb, grad_accum=accum,
        note=(f"data axis {mesh.axis_size('data')} -> {new_data}; "
              f"grad_accum x{accum} preserves the effective batch"))


def validate_resharding(param_shapes: Dict[str, Tuple[int, ...]],
                        new_mesh: MeshConfig) -> Dict[str, str]:
    """Check every parameter still shards on the new mesh (divisibility).

    Returns {param_path: issue} for any that must demote to replicated —
    empty dict means the plan is clean.
    """
    issues = {}
    model = new_mesh.axis_size("model")
    data = new_mesh.axis_size("data")
    for path, shape in param_shapes.items():
        if len(shape) >= 2:
            if shape[-1] % model != 0 and shape[-1] > 1:
                issues[path] = f"dim {shape[-1]} ! % model={model}"
            elif shape[0] % data != 0 and shape[0] > data:
                issues[path] = f"dim {shape[0]} ! % data={data}"
    return issues
