"""Fault tolerance: heartbeat, straggler detection, checkpoint-restart loop.

Designed for the 1000+-node regime (DESIGN.md §5): every worker heartbeats
to shared storage; the controller-side detector flags dead/straggling
workers; the training loop is preemption-safe — any crash resumes from the
last atomic checkpoint with the data pipeline fast-forwarded (deterministic
step-indexed batches make this exact).
"""
from __future__ import annotations

import json
import os
import time
import traceback
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import numpy as np


@dataclass
class Heartbeat:
    """Per-worker liveness file (shared filesystem / object store).

    Used by both the training loop and the serving fleet supervisor
    (``runtime.supervisor``). ``beat`` takes an optional ``now`` so a
    serving controller can run the whole liveness protocol on a logical
    clock — deterministic failure-detection tests, no wall-clock sleeps.
    """

    directory: Path
    worker_id: int = 0

    def beat(self, step: int, extra: Optional[Dict] = None,
             now: Optional[float] = None):
        self.directory.mkdir(parents=True, exist_ok=True)
        rec = {"worker": self.worker_id, "step": step,
               "time": time.time() if now is None else float(now)}
        if extra:
            rec.update(extra)
        tmp = self.directory / f".hb_{self.worker_id}.tmp"
        tmp.write_text(json.dumps(rec))
        os.rename(tmp, self.directory / f"hb_{self.worker_id}.json")

    def retire(self):
        """Remove this worker's liveness file (clean shutdown — a retired
        worker is *not* dead and must not trip the detector)."""
        try:
            (self.directory / f"hb_{self.worker_id}.json").unlink()
        except FileNotFoundError:
            pass

    @staticmethod
    def read_all(directory: Path) -> Dict[int, Dict]:
        """All parseable heartbeat records, keyed by worker id. A corrupt
        or partially-written file (a worker died mid-``os.rename``, or the
        shared store gave a torn read) is skipped, not raised: an
        unparseable heartbeat must never take the *detector* down."""
        out: Dict[int, Dict] = {}
        for f in sorted(Path(directory).glob("hb_*.json")):
            try:
                rec = json.loads(f.read_text())
                out[int(rec["worker"])] = rec
            except (json.JSONDecodeError, KeyError, TypeError, ValueError,
                    OSError):
                continue
        return out

    @staticmethod
    def dead_workers(directory: Path, timeout_s: float,
                     now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        return sorted(w for w, rec in Heartbeat.read_all(directory).items()
                      if now - rec["time"] > timeout_s)


@dataclass
class StragglerDetector:
    """EWMA step-time z-score detector.

    At fleet scale a straggling host slows every synchronous step; the
    detector flags sustained outliers so the controller can evict/replace
    the worker (here: reported via ``flagged``).
    """

    alpha: float = 0.05
    z_threshold: float = 4.0
    warmup: int = 10
    min_rel_std: float = 0.05      # std floor as a fraction of the mean
    _mean: float = 0.0
    _var: float = 0.0
    _n: int = 0
    _warm: List[float] = field(default_factory=list)
    flagged: List[Dict] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup:
            self._warm.append(dt)
            if self._n == self.warmup:
                self._mean = float(np.mean(self._warm))
                self._var = float(np.var(self._warm))
            return False
        std = max(np.sqrt(self._var), self.min_rel_std * abs(self._mean),
                  1e-9)
        z = (dt - self._mean) / std
        is_straggler = bool(z > self.z_threshold)
        if is_straggler:
            self.flagged.append({"step": step, "dt": dt, "z": float(z)})
        else:
            # only update stats on healthy steps
            self._mean = (1 - self.alpha) * self._mean + self.alpha * dt
            self._var = (1 - self.alpha) * self._var + \
                self.alpha * (dt - self._mean) ** 2
        return is_straggler


@dataclass
class FaultToleranceReport:
    #: restarts actually *completed* (the loop went back around); a crash
    #: that exhausts ``max_restarts`` re-raises without counting here —
    #: its description is the last entry of ``failures``
    restarts: int = 0
    failures: List[str] = field(default_factory=list)
    straggler_events: int = 0
    completed_steps: int = 0


def run_with_fault_tolerance(
    *, total_steps: int,
    make_state: Callable[[], Any],
    step_fn: Callable[[Any, int], Any],
    ckpt_manager,
    checkpoint_every: int = 50,
    max_restarts: int = 3,
    heartbeat: Optional[Heartbeat] = None,
    detector: Optional[StragglerDetector] = None,
    fail_injector: Optional[Callable[[int], None]] = None,
) -> FaultToleranceReport:
    """Preemption-safe step loop: crash -> restore -> continue.

    ``step_fn(state, step) -> state``. The data pipeline must be
    deterministic in ``step`` (see data.pipeline) so restarts are exact.
    """
    report = FaultToleranceReport()
    restarts = 0
    while True:
        try:
            latest = ckpt_manager.latest_step()
            state = make_state()
            start = 0
            if latest is not None:
                state, start = ckpt_manager.restore(state)
                start += 1
            for step in range(start, total_steps):
                t0 = time.time()
                if fail_injector is not None:
                    fail_injector(step)
                state = step_fn(state, step)
                dt = time.time() - t0
                if detector is not None and detector.observe(step, dt):
                    report.straggler_events += 1
                if heartbeat is not None:
                    heartbeat.beat(step)
                if (step + 1) % checkpoint_every == 0 or \
                        step == total_steps - 1:
                    ckpt_manager.save(step, state, block=True)
                report.completed_steps = step + 1
            return report
        except Exception as e:  # noqa: BLE001 — the whole point
            if report.restarts >= max_restarts:
                # fatal: budget exhausted. Record the final failure but do
                # NOT count a restart — none happens; we re-raise.
                report.failures.append(
                    f"{type(e).__name__}: {e} (fatal — max_restarts="
                    f"{max_restarts} exhausted)")
                # post-mortem accounting for the caller (the exception
                # escapes before the report can be returned)
                e.ft_report = report
                raise
            report.restarts += 1
            report.failures.append(
                f"{type(e).__name__}: {e} @ restart {report.restarts}")
            continue
