"""Fleet supervision for serving: liveness, stragglers, fault injection.

The serving counterpart of ``runtime.fault_tolerance``'s training loop.
A :class:`FleetSupervisor` watches a directory of per-replica
:class:`~repro.runtime.fault_tolerance.Heartbeat` files and reports which
replicas have gone silent; the router (``serve.router``) reacts by
requeueing their in-flight requests, and the sharded replica layer
(``serve.fleet``) reacts to *host* loss by re-sharding expert blocks onto
the survivors (``runtime.elastic``).

Everything runs on a **logical clock**: the router advances ``now`` by
one tick per scheduling round and both heartbeats and timeouts are
expressed in ticks. Failure detection is therefore exactly reproducible
— no wall-clock sleeps in tests, no flaky timing margins — while the
same code path serves real deployments by feeding ``time.time()``.

Deterministic fault injection rides the same clock:
:class:`FaultInjector` holds a script of ``(tick, kind, target)`` events
(kill a replica, kill one host of a replica, join a host) that the
router consults once per tick. CI's fleet smoke and
``benchmarks/bench_fleet.py`` drive every recovery path through these
hooks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.runtime.fault_tolerance import Heartbeat, StragglerDetector

#: fault-injection event kinds — process/topology faults
KILL_REPLICA = "kill_replica"
KILL_HOST = "kill_host"
JOIN_HOST = "join_host"
#: message faults (applied to the serve.transport layer)
DROP_LINK = "drop_link"          # lose link traffic sent at one tick
DELAY_LINK = "delay_link"        # hold link traffic sent at one tick
PARTITION = "partition"          # lose all link traffic for a window
#: performance faults
SLOW_REPLICA = "slow_replica"    # replica steps every Nth tick only
_KINDS = (KILL_REPLICA, KILL_HOST, JOIN_HOST, DROP_LINK, DELAY_LINK,
          PARTITION, SLOW_REPLICA)
#: kinds the router forwards to FaultyTransport.inject
NET_KINDS = (DROP_LINK, DELAY_LINK, PARTITION)


@dataclass(frozen=True)
class FaultEvent:
    """One scripted fault: at logical tick ``tick``, apply ``kind`` to
    ``replica`` (and, for host events, ``host`` within that replica;
    for message faults, the router↔replica link). ``delay`` is the
    extra ticks for ``delay_link``; ``until`` the inclusive end tick of
    a ``partition`` window; ``factor`` the ``slow_replica`` slowdown
    (the replica only advances its engine every ``factor``-th tick)."""

    tick: int
    kind: str
    replica: int
    host: Optional[int] = None
    delay: Optional[int] = None
    until: Optional[int] = None
    factor: Optional[int] = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{_KINDS}")
        if self.kind in (KILL_HOST, JOIN_HOST) and self.host is None:
            raise ValueError(f"{self.kind} needs a host index")
        if self.kind == DELAY_LINK and (self.delay is None
                                        or self.delay < 1):
            raise ValueError(
                f"{DELAY_LINK} needs delay >= 1 tick; got {self.delay!r}")
        if self.kind == PARTITION:
            if self.until is None or self.until < self.tick:
                raise ValueError(
                    f"{PARTITION} needs an end tick >= its start "
                    f"{self.tick}; got {self.until!r}")
        if self.kind == SLOW_REPLICA and (self.factor is None
                                          or self.factor < 1):
            raise ValueError(
                f"{SLOW_REPLICA} needs a slowdown factor >= 1; got "
                f"{self.factor!r}")


_SPEC_GRAMMAR = (
    "'replica:<r>@<tick>' (kill replica), 'host:<r>.<h>@<tick>' (kill "
    "one host), 'join:<r>@<tick>' (join a fresh host), 'drop:<r>@<tick>' "
    "(lose link messages sent that tick), 'delay:<r>@<tick>+<d>' (hold "
    "them <d> ticks), 'partition:<r>@<t1>..<t2>' (lose all link traffic "
    "for the window), or 'slow:<r>@<tick>x<f>' (replica steps every "
    "<f>th tick)")


def _spec_int(token: str, what: str, spec: str) -> int:
    try:
        return int(token)
    except (TypeError, ValueError):
        raise ValueError(
            f"bad fault spec {spec!r}: {what} {token!r} is not an "
            f"integer; expected {_SPEC_GRAMMAR}") from None


def parse_fault_spec(spec: str) -> FaultEvent:
    """Parse one ``--inject-failure`` spec into a :class:`FaultEvent`.

    Every malformed spec fails **loudly, naming the bad token** — an
    unknown kind, a missing ``@<tick>``, a non-integer field — instead
    of the silent fallthrough / cryptic unpack errors of the earlier
    three-kind parser. Grammar: ``replica:<r>@<t>``,
    ``host:<r>.<h>@<t>``, ``join:<r>@<t>``, ``drop:<r>@<t>``,
    ``delay:<r>@<t>+<d>``, ``partition:<r>@<t1>..<t2>``,
    ``slow:<r>@<t>x<f>``."""
    if ":" not in spec:
        raise ValueError(
            f"bad fault spec {spec!r}: missing ':' between kind and "
            f"target; expected {_SPEC_GRAMMAR}")
    kind, rest = spec.split(":", 1)
    kinds = {"replica": KILL_REPLICA, "host": KILL_HOST,
             "join": JOIN_HOST, "drop": DROP_LINK, "delay": DELAY_LINK,
             "partition": PARTITION, "slow": SLOW_REPLICA}
    if kind not in kinds:
        raise ValueError(
            f"unknown fault kind {kind!r} in spec {spec!r}; expected "
            f"one of {sorted(kinds)}")
    if "@" not in rest:
        raise ValueError(
            f"bad fault spec {spec!r}: missing '@<tick>'; expected "
            f"{_SPEC_GRAMMAR}")
    target, when = rest.rsplit("@", 1)

    if kind == "host":
        if "." not in target:
            raise ValueError(
                f"bad fault spec {spec!r}: host target {target!r} must "
                "be '<replica>.<host>'")
        r_tok, h_tok = target.split(".", 1)
        replica = _spec_int(r_tok, "replica", spec)
        host = _spec_int(h_tok, "host", spec)
    else:
        replica = _spec_int(target, "replica", spec)
        host = -1 if kind == "join" else None

    if kind == "delay":
        if "+" not in when:
            raise ValueError(
                f"bad fault spec {spec!r}: delay needs '@<tick>+<d>' "
                f"(got {when!r})")
        t_tok, d_tok = when.split("+", 1)
        return FaultEvent(tick=_spec_int(t_tok, "tick", spec),
                          kind=DELAY_LINK, replica=replica,
                          delay=_spec_int(d_tok, "delay", spec))
    if kind == "partition":
        if ".." not in when:
            raise ValueError(
                f"bad fault spec {spec!r}: partition needs "
                f"'@<t1>..<t2>' (got {when!r})")
        t_tok, u_tok = when.split("..", 1)
        tick = _spec_int(t_tok, "start tick", spec)
        until = _spec_int(u_tok, "end tick", spec)
        if until < tick:
            raise ValueError(
                f"bad fault spec {spec!r}: partition end tick {until} "
                f"is before its start tick {tick}")
        return FaultEvent(tick=tick, kind=PARTITION, replica=replica,
                          until=until)
    if kind == "slow":
        if "x" not in when:
            raise ValueError(
                f"bad fault spec {spec!r}: slow needs '@<tick>x<factor>' "
                f"(got {when!r})")
        t_tok, f_tok = when.split("x", 1)
        return FaultEvent(tick=_spec_int(t_tok, "tick", spec),
                          kind=SLOW_REPLICA, replica=replica,
                          factor=_spec_int(f_tok, "slowdown factor", spec))
    return FaultEvent(tick=_spec_int(when, "tick", spec),
                      kind=kinds[kind], replica=replica, host=host)


class FaultInjector:
    """Deterministic fault script, consulted once per router tick.

    ``due(tick)`` returns (and consumes) every event scheduled at or
    before ``tick`` — events fire exactly once, in tick order.
    """

    def __init__(self, events: List[FaultEvent] = ()):
        self._events = sorted(events, key=lambda e: e.tick)
        self.fired: List[FaultEvent] = []

    def due(self, tick: int) -> List[FaultEvent]:
        out = []
        while self._events and self._events[0].tick <= tick:
            out.append(self._events.pop(0))
        self.fired.extend(out)
        return out

    @property
    def pending(self) -> int:
        return len(self._events)


@dataclass
class FleetSupervisor:
    """Heartbeat-based failure detection over a replica fleet.

    Each live replica beats into ``directory`` once per scheduling tick
    (``beat``); ``check(now)`` returns the replicas whose last beat is
    older than ``timeout`` ticks — each reported exactly once, so the
    router acts on a death exactly once. A per-replica
    :class:`StragglerDetector` additionally flags replicas whose step
    time z-scores out (slow host, contended accelerator); stragglers are
    reported via ``stragglers`` but not auto-evicted — eviction is a
    policy decision left to the operator/router.
    """

    directory: Path
    timeout: float = 3.0
    straggler_z: float = 4.0
    _beats: Dict[int, Heartbeat] = field(default_factory=dict)
    _detectors: Dict[int, StragglerDetector] = field(default_factory=dict)
    _reported: Set[int] = field(default_factory=set)
    stragglers: List[Dict] = field(default_factory=list)

    def beat(self, replica: int, step: int, now: float,
             step_s: Optional[float] = None, **extra):
        hb = self._beats.get(replica)
        if hb is None:
            hb = self._beats[replica] = Heartbeat(
                directory=Path(self.directory), worker_id=replica)
        # a beat from a replica we reported dead is a *resurrection* —
        # e.g. a healed network partition, not a real crash. Forget the
        # report so a later genuine death is detected again.
        self._reported.discard(replica)
        hb.beat(step, extra=dict(extra) or None, now=now)
        if step_s is not None:
            det = self._detectors.setdefault(
                replica, StragglerDetector(z_threshold=self.straggler_z))
            if det.observe(step, step_s):
                self.stragglers.append(
                    {"replica": replica, "step": step, "dt": step_s})

    def retire(self, replica: int):
        """Clean shutdown: stop tracking without declaring a death."""
        hb = self._beats.pop(replica, None)
        if hb is not None:
            hb.retire()
        self._reported.discard(replica)

    def check(self, now: float) -> List[int]:
        """Newly-dead replicas (silent > ``timeout``), each reported once."""
        dead = Heartbeat.dead_workers(Path(self.directory), self.timeout,
                                      now=now)
        fresh = [r for r in dead if r not in self._reported]
        self._reported.update(fresh)
        return fresh
