"""Fleet supervision for serving: liveness, stragglers, fault injection.

The serving counterpart of ``runtime.fault_tolerance``'s training loop.
A :class:`FleetSupervisor` watches a directory of per-replica
:class:`~repro.runtime.fault_tolerance.Heartbeat` files and reports which
replicas have gone silent; the router (``serve.router``) reacts by
requeueing their in-flight requests, and the sharded replica layer
(``serve.fleet``) reacts to *host* loss by re-sharding expert blocks onto
the survivors (``runtime.elastic``).

Everything runs on a **logical clock**: the router advances ``now`` by
one tick per scheduling round and both heartbeats and timeouts are
expressed in ticks. Failure detection is therefore exactly reproducible
— no wall-clock sleeps in tests, no flaky timing margins — while the
same code path serves real deployments by feeding ``time.time()``.

Deterministic fault injection rides the same clock:
:class:`FaultInjector` holds a script of ``(tick, kind, target)`` events
(kill a replica, kill one host of a replica, join a host) that the
router consults once per tick. CI's fleet smoke and
``benchmarks/bench_fleet.py`` drive every recovery path through these
hooks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.runtime.fault_tolerance import Heartbeat, StragglerDetector

#: fault-injection event kinds
KILL_REPLICA = "kill_replica"
KILL_HOST = "kill_host"
JOIN_HOST = "join_host"
_KINDS = (KILL_REPLICA, KILL_HOST, JOIN_HOST)


@dataclass(frozen=True)
class FaultEvent:
    """One scripted fault: at logical tick ``tick``, apply ``kind`` to
    ``replica`` (and, for host events, ``host`` within that replica)."""

    tick: int
    kind: str
    replica: int
    host: Optional[int] = None

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{_KINDS}")
        if self.kind in (KILL_HOST, JOIN_HOST) and self.host is None:
            raise ValueError(f"{self.kind} needs a host index")


def parse_fault_spec(spec: str) -> FaultEvent:
    """Parse the CLI grammar: ``replica:<r>@<tick>`` kills replica ``r``;
    ``host:<r>.<h>@<tick>`` kills host ``h`` of replica ``r``;
    ``join:<r>@<tick>`` joins a fresh host to replica ``r``."""
    try:
        head, tick = spec.rsplit("@", 1)
        kind, target = head.split(":", 1)
        t = int(tick)
        if kind == "replica":
            return FaultEvent(tick=t, kind=KILL_REPLICA, replica=int(target))
        if kind == "host":
            r, h = target.split(".")
            return FaultEvent(tick=t, kind=KILL_HOST, replica=int(r),
                              host=int(h))
        if kind == "join":
            return FaultEvent(tick=t, kind=JOIN_HOST, replica=int(target),
                              host=-1)
    except (ValueError, IndexError):
        pass
    raise ValueError(
        f"bad fault spec {spec!r}; expected 'replica:<r>@<tick>', "
        "'host:<r>.<h>@<tick>' or 'join:<r>@<tick>'")


class FaultInjector:
    """Deterministic fault script, consulted once per router tick.

    ``due(tick)`` returns (and consumes) every event scheduled at or
    before ``tick`` — events fire exactly once, in tick order.
    """

    def __init__(self, events: List[FaultEvent] = ()):
        self._events = sorted(events, key=lambda e: e.tick)
        self.fired: List[FaultEvent] = []

    def due(self, tick: int) -> List[FaultEvent]:
        out = []
        while self._events and self._events[0].tick <= tick:
            out.append(self._events.pop(0))
        self.fired.extend(out)
        return out

    @property
    def pending(self) -> int:
        return len(self._events)


@dataclass
class FleetSupervisor:
    """Heartbeat-based failure detection over a replica fleet.

    Each live replica beats into ``directory`` once per scheduling tick
    (``beat``); ``check(now)`` returns the replicas whose last beat is
    older than ``timeout`` ticks — each reported exactly once, so the
    router acts on a death exactly once. A per-replica
    :class:`StragglerDetector` additionally flags replicas whose step
    time z-scores out (slow host, contended accelerator); stragglers are
    reported via ``stragglers`` but not auto-evicted — eviction is a
    policy decision left to the operator/router.
    """

    directory: Path
    timeout: float = 3.0
    straggler_z: float = 4.0
    _beats: Dict[int, Heartbeat] = field(default_factory=dict)
    _detectors: Dict[int, StragglerDetector] = field(default_factory=dict)
    _reported: Set[int] = field(default_factory=set)
    stragglers: List[Dict] = field(default_factory=list)

    def beat(self, replica: int, step: int, now: float,
             step_s: Optional[float] = None, **extra):
        hb = self._beats.get(replica)
        if hb is None:
            hb = self._beats[replica] = Heartbeat(
                directory=Path(self.directory), worker_id=replica)
        hb.beat(step, extra=dict(extra) or None, now=now)
        if step_s is not None:
            det = self._detectors.setdefault(
                replica, StragglerDetector(z_threshold=self.straggler_z))
            if det.observe(step, step_s):
                self.stragglers.append(
                    {"replica": replica, "step": step, "dt": step_s})

    def retire(self, replica: int):
        """Clean shutdown: stop tracking without declaring a death."""
        hb = self._beats.pop(replica, None)
        if hb is not None:
            hb.retire()
        self._reported.discard(replica)

    def check(self, now: float) -> List[int]:
        """Newly-dead replicas (silent > ``timeout``), each reported once."""
        dead = Heartbeat.dead_workers(Path(self.directory), self.timeout,
                                      now=now)
        fresh = [r for r in dead if r not in self._reported]
        self._reported.update(fresh)
        return fresh
