"""Serving engines for MC-compressed inference.

Two engines over the model's prefill/decode steps, both applying the MC
runtime (PMQ quantized experts + ODP pruning) at every step:

* ``ServeEngine`` — **continuous batching** (the production path): a fixed
  pool of decode slots backed by a slot-indexed KV cache whose rows have
  independent lifetimes (``KVCache.pos`` is per row). Pending requests are
  admitted into freed slots between decode steps — prefill runs batch-1
  into a fresh row, then the row is scattered into the pool — and every
  request stops on its own EOS / ``max_new_tokens``. The decode step is a
  single jitted call over the whole slot pool with an active-slot mask, so
  compiled shapes stay static no matter how requests come and go.

* ``StaticServeEngine`` — the lockstep baseline (paper Tab. 13/14 speed
  harness): requests grouped into fixed batches, prefilled once, decoded
  step-aligned for the batch-max ``max_new_tokens``. Finished requests burn
  compute as padding — ``benchmarks/bench_serving.py`` measures exactly
  that waste against the continuous engine.

MoE capacity semantics: during decode the MoE layer groups the whole slot
pool into one expert-capacity group. The continuous engine masks inactive
slots out of dispatch (``token_mask``) so idle-slot garbage never consumes
expert capacity — only *live* requests compete, exactly as in any batched
serving. Token-for-token equivalence with sequential generation addition-
ally requires a ``capacity_factor`` high enough that live requests never
overflow capacity (the equivalence tests pin this down).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core import odp as odp_lib
from repro.models.layers import attention as attn_lib
from repro.models.layers import ssm as ssm_lib
from repro.models.layers.attention import GLOBAL_WINDOW
from repro.models.transformer import DecoderModel, MCRuntime
from repro.serve import slot_state
from repro.serve.kv_pool import (KVBlockManager, KVPoolConfig,
                                 SharedStatePool, SlotAlloc, TRASH_PAGE)
from repro.sharding import context as shctx
from repro.sharding import partitioning as part_lib

#: the ODP knob's string settings; any float in [0, 1) is also accepted
#: (an explicit prune ratio, mapped through the artifact's calibration
#: ratio-quantile table).
ODP_KNOBS = ("off", "default")


@dataclass(frozen=True)
class GenerationOptions:
    """Per-request generation options (frozen, hashable).

    odp is the per-request **quality/latency knob** for Online Dynamic
    Pruning:

    * ``"default"`` — the artifact's calibrated threshold (a no-op when
      the engine's runtime carries no ODP calibration);
    * ``"off"`` — no pruning; token-for-token identical to serving the
      same artifact with ODP absent;
    * a float prune ratio in ``[0, 1)`` — prune that fraction of routed
      expert slots, mapped to a threshold via the artifact's calibration
      ratio quantiles (:func:`repro.core.odp.threshold_for_prune_ratio`).

    The knob is a **jit input** to the engines' decode step (a per-slot
    threshold array), so mixing settings across requests never retraces.
    """

    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    odp: Union[str, float] = "default"

    def __post_init__(self):
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}")
        if isinstance(self.odp, str):
            if self.odp not in ODP_KNOBS:
                raise ValueError(
                    f"odp must be one of {ODP_KNOBS} or a prune ratio in "
                    f"[0, 1); got {self.odp!r}")
        elif not 0.0 <= float(self.odp) < 1.0:
            raise ValueError(
                f"an explicit odp prune ratio must lie in [0, 1); got "
                f"{self.odp!r}")


@dataclass
class Request:
    """A generation request.

    Pass per-request settings via ``options``. ``max_new_tokens`` /
    ``eos_id`` remain as **deprecated aliases** (one release; they will be
    removed next release) and may not be combined with ``options``.
    """

    uid: int
    prompt: np.ndarray           # (L,) int32
    max_new_tokens: Optional[int] = None      # deprecated -> options
    eos_id: Optional[int] = None              # deprecated -> options
    options: Optional[GenerationOptions] = None
    #: per-request encoder-side input for families whose state bundle has
    #: a shared or prefix kind: encdec takes (encoder_seq, d_model) audio
    #: frames (CrossKV is computed once at admission and shared across
    #: requests with identical frames); vlm takes (num_prefix_tokens,
    #: d_model) image-prefix embeddings. Other families must leave it None.
    enc_input: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.max_new_tokens is not None or self.eos_id is not None:
            if self.options is not None:
                raise ValueError(
                    "pass either Request(options=...) or the deprecated "
                    "max_new_tokens/eos_id fields, not both")
            warnings.warn(
                "Request(max_new_tokens=..., eos_id=...) is deprecated; "
                "pass Request(options=GenerationOptions(...)). The loose "
                "fields will be removed in the next release.",
                DeprecationWarning, stacklevel=3)

    @property
    def opts(self) -> GenerationOptions:
        """The effective options (deprecated aliases folded in)."""
        if self.options is not None:
            return self.options
        return GenerationOptions(
            max_new_tokens=(16 if self.max_new_tokens is None
                            else self.max_new_tokens),
            eos_id=self.eos_id)


@dataclass(frozen=True)
class EngineConfig:
    """One shared keyword surface for both engines and ``from_artifact``.

    ``odp`` is the engine-wide default for the per-request knob (same
    semantics as :class:`GenerationOptions.odp`); requests override it.
    ``max_seq_len`` only applies to the continuous engine (the lockstep
    engine sizes its cache per batch). ``kv_pool`` switches the continuous
    engine's KV memory layer from contiguous per-slot rows to paged blocks
    (see :class:`repro.serve.kv_pool.KVPoolConfig`: free-list pages,
    optional int8/int4 storage, prefix sharing, chunked prefill); it
    requires ``max_seq_len`` and only applies to the continuous engine.
    Unknown keywords raise ``TypeError`` naming the valid fields — nothing
    is silently swallowed.
    """

    batch_size: int = 4
    pad_id: int = 0
    greedy: bool = True
    eos_id: Optional[int] = None
    max_seq_len: Optional[int] = None
    mesh: Any = None
    ep_dispatch: bool = False
    odp: Union[str, float] = "default"
    kv_pool: Optional[KVPoolConfig] = None


def _merge_config(config: Optional[EngineConfig],
                  kwargs: Dict) -> EngineConfig:
    """Fold loose keyword args into an EngineConfig, loudly rejecting
    unknown names (the old ``**kwargs``-swallowing surface is gone)."""
    cfg = config if config is not None else EngineConfig()
    if kwargs:
        fields = {f.name for f in dataclasses.fields(EngineConfig)}
        unknown = sorted(set(kwargs) - fields)
        if unknown:
            raise TypeError(
                f"unknown engine option(s) {unknown}; valid EngineConfig "
                f"fields: {sorted(fields)}")
        cfg = dataclasses.replace(cfg, **kwargs)
    return cfg


@dataclass
class Result:
    uid: int
    tokens: np.ndarray
    prefill_s: float
    decode_s: float
    new_tokens: int
    finish_reason: str = "length"     # "length" | "eos"


@dataclass
class EngineStats:
    requests: int = 0
    generated_tokens: int = 0         # useful tokens only (no padding waste)
    prefill_s: float = 0.0
    decode_s: float = 0.0
    decode_steps: int = 0
    slot_steps: int = 0               # decode_steps x pool width
    active_slot_steps: int = 0        # slot-steps doing useful work
    scratch_reuses: int = 0           # admissions served by the reused
                                      # batch-1 scratch (allocations saved)

    @property
    def decode_tokens_per_s(self) -> float:
        if self.decode_s <= 0:
            return 0.0
        return self.generated_tokens / self.decode_s

    @property
    def occupancy(self) -> float:
        """Fraction of slot-steps spent on live requests (1.0 = no waste)."""
        return self.active_slot_steps / max(self.slot_steps, 1)


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _fetch(x) -> np.ndarray:
    """Host value of a possibly multi-process global array. The engines
    replicate every cross-host output inside the jitted step, so any
    addressable shard carries the full value; plain arrays (and numpy)
    pass straight through."""
    if getattr(x, "is_fully_addressable", True):
        return np.asarray(x)
    return np.asarray(x.addressable_data(0))


class _ArtifactBoot:
    """Shared ``from_artifact`` constructor plus mesh plumbing for both
    engines: boot serving straight off a
    :class:`repro.core.pipeline.CompressedArtifact` (saved offline, loaded
    with no calibration data) — params and the MC runtime come from the
    artifact, covering scan-safe and per-layer layouts alike, optionally
    placed on a device mesh for expert-parallel serving.
    """

    @classmethod
    def from_artifact(cls, model: DecoderModel, artifact, mesh=None,
                      config: Optional[EngineConfig] = None, **kwargs):
        """Build an engine from a saved artifact.

        Args:
            model: the (uncompressed) model whose config fingerprint must
                match what the artifact was compressed for.
            artifact: a :class:`~repro.core.pipeline.CompressedArtifact`
                from :meth:`~repro.core.pipeline.CompressedArtifact.load`
                or ``load_sharded``. A partial artifact (one host's
                expert slice) boots only a process of a **multi-process
                mesh** whose placement expectation it matches exactly —
                its planes become this process's addressable shard of
                the global expert-parallel arrays; anything else is
                rejected loudly (no mesh, wrong slice, overlap/gap).
            mesh: optional ``jax.sharding.Mesh``. When given, packed
                expert planes are sharded along their expert axis over the
                mesh's expert-parallel axis (``data``) and all engine
                compute runs with the mesh active, so XLA partitions MoE
                dispatch across devices. Decoding stays token-identical to
                the single-device engine. An artifact already placed on an
                equal mesh (same axes, shape, and device order — identity
                not required) is not re-placed.
            config: an :class:`EngineConfig`; ``mesh`` (above) overrides
                its mesh field when given.
            **kwargs: individual :class:`EngineConfig` fields
                (``batch_size``, ``eos_id``, ``ep_dispatch``, ``odp``,
                ...) overriding ``config``; unknown names raise
                ``TypeError``.
        """
        from repro.core import pipeline as pl
        config = _merge_config(config, kwargs)
        if mesh is not None:
            config = dataclasses.replace(config, mesh=mesh)
        mesh = config.mesh
        fp = model.cfg.fingerprint()
        art_fp = getattr(artifact, "model_fingerprint", None)
        if art_fp and art_fp != fp:
            raise ValueError(
                "artifact/model mismatch: the artifact was compressed for "
                f"model config {art_fp}, this model is {fp}")
        params = artifact.params
        placed = getattr(artifact, "placed_mesh", None)
        if getattr(artifact, "is_partial", False):
            if mesh is None:
                k0, k1 = artifact.expert_range
                raise ValueError(
                    f"artifact holds only experts [{k0}:{k1}) of "
                    f"{artifact.num_experts} (a per-host stream from "
                    "load_sharded); an engine needs the full expert "
                    "layout — load without expert_range/num_hosts, or "
                    "pass the multi-process mesh this slice was streamed "
                    "for")
            from repro.sharding.moe_parallel import merge_ranges
            got = merge_ranges(artifact.owned_ranges)
            expected = pl.expert_shard_expectation(
                mesh, artifact.class_segments())
            if got != expected:
                raise ValueError(
                    f"partial artifact holds experts {got} but process "
                    f"{jax.process_index()} of the mesh expects exactly "
                    f"{expected} — the per-host stream and the "
                    "expert-parallel placement overlap/gap/misalign; "
                    "stream with load_sharded(dir, mesh) to get the "
                    "expected slice")
            if not pl.meshes_equal(placed, mesh):
                if placed is not None:
                    raise ValueError(
                        "partial artifact was already placed on a "
                        "different mesh; its planes are global arrays "
                        "that cannot be re-mapped here — re-stream with "
                        "CompressedArtifact.load_sharded(dir, mesh) for "
                        "this mesh")
                if artifact.load_stats is None:
                    raise ValueError(
                        "partial artifact carries no LoadStats, so its "
                        "planes cannot be mapped onto the mesh; re-load "
                        "it via CompressedArtifact.load_sharded")
                params = pl.distributed_params(params, mesh,
                                               artifact.load_stats)
        elif mesh is not None and not pl.meshes_equal(placed, mesh):
            if part_lib.mesh_spans_processes(mesh):
                # a full artifact on a multi-process mesh: place_params'
                # device_put cannot reach the other processes' devices —
                # assemble this process's shard instead (works because a
                # full load carries every expert), or point the caller at
                # the streaming path
                stats = getattr(artifact, "load_stats", None)
                if stats is None:
                    raise ValueError(
                        "cannot place an in-memory artifact on a mesh "
                        "spanning processes; save it and boot each "
                        "process via CompressedArtifact.load_sharded("
                        "dir, mesh)")
                params = pl.distributed_params(params, mesh, stats)
            else:
                params = pl.place_params(params, mesh)
        return cls(model, params, mc=artifact.runtime, config=config)

    def _init_odp(self, mc, default_knob) -> None:
        """Boot the ODP knob: remember the runtime (if any, enabled) and
        resolve the engine-wide default knob to its threshold once."""
        odp = getattr(mc, "odp", None) if mc is not None else None
        self._odp_rt = odp if (odp is not None and odp.enabled) else None
        # when a runtime carries ODP the threshold becomes a jit *input*
        # of the engine's prefill/decode steps (per-slot float32), so any
        # mix of per-request settings shares one compiled step
        self._odp_dynamic = self._odp_rt is not None
        self._odp_default_thr = self._resolve_odp(default_knob)

    def _resolve_odp(self, knob: Union[str, float]) -> float:
        """Map an ODP knob to the per-slot threshold fed into the jitted
        steps. 0.0 keeps every routed slot (= pruning off, bit-exact)."""
        odp = self._odp_rt
        if isinstance(knob, str):
            if knob == "off":
                return 0.0
            if knob == "default":
                return float(odp.threshold) if odp is not None else 0.0
            raise ValueError(
                f"odp knob must be one of {ODP_KNOBS} or a prune ratio in "
                f"[0, 1); got {knob!r}")
        ratio = float(knob)
        if not 0.0 <= ratio < 1.0:
            raise ValueError(
                f"an explicit odp prune ratio must lie in [0, 1); got "
                f"{knob!r}")
        if ratio == 0.0:
            return 0.0
        if odp is None:
            raise ValueError(
                "an explicit odp prune ratio needs an ODP-enabled runtime "
                "(an artifact planned with odp_enabled=True); this "
                "engine's runtime carries none — use odp='off' or "
                "odp='default'")
        return float(odp_lib.threshold_for_prune_ratio(
            odp.ratio_quantiles, ratio, self.cfg.top_k))

    def _slot_threshold(self, opts: GenerationOptions) -> float:
        """Per-request threshold: ``"default"`` inherits the engine-wide
        knob (``EngineConfig.odp``, itself defaulting to the artifact's
        calibrated threshold); anything else resolves directly."""
        if opts.odp == "default":
            return self._odp_default_thr
        return self._resolve_odp(opts.odp)

    def _init_mesh(self, mesh, ep_dispatch: bool, mc) -> None:
        self.mesh = mesh
        self.ep_dispatch = ep_dispatch
        self._distributed = part_lib.mesh_spans_processes(mesh)
        if ep_dispatch:
            if mesh is None:
                raise ValueError("ep_dispatch=True requires a mesh")
            # the mesh axis must exist before anything else is judged:
            # validating quant metas against a phantom axis would die
            # inside the class-divisibility check with a misleading
            # message (or silently validate against 1)
            dsize = dict(mesh.shape).get("data", 0)
            if dsize == 0:
                raise ValueError(
                    "ep_dispatch needs a mesh with a 'data' axis to "
                    "carry expert parallelism; mesh axes are "
                    f"{tuple(mesh.axis_names)}")
            if self.batch_size % dsize != 0:
                raise ValueError(
                    f"ep_dispatch needs batch_size ({self.batch_size}) "
                    f"divisible by the mesh 'data' axis ({dsize}) — "
                    "otherwise decode steps would silently fall back to "
                    "the gather path instead of the shard_map schedule")
            if mc is not None and (mc.quant_meta is not None
                                   or mc.layer_metas is not None):
                # quantized shard_map EP shards every bit class's packed
                # plane stack over the data axis — validate the layout up
                # front so misfits fail at boot, not at first decode
                from repro.sharding.moe_parallel import \
                    validate_ep_quant_meta
                metas = (mc.layer_metas if mc.layer_metas is not None
                         else (mc.quant_meta,))
                for meta in metas:
                    validate_ep_quant_meta(meta, dsize)

    def _init_host_io(self):
        """Host<->device conventions, distribution-aware. On a mesh
        spanning processes every engine input enters jit as numpy (each
        process holds the identical value — the SPMD serving loop — and
        jit treats it as replicated), and every output the host loop
        reads is constrained fully-replicated *inside* the jitted step
        so any addressable shard carries the whole value (``_fetch``).
        Returns the in-jit replicator (identity off-mesh)."""
        if getattr(self, "_distributed", False):
            from jax.sharding import NamedSharding, PartitionSpec
            rep_sh = NamedSharding(self.mesh, PartitionSpec())
            self._arr = np.asarray
            self._scalar = np.int32
            return lambda a: jax.lax.with_sharding_constraint(a, rep_sh)
        self._arr = jnp.asarray
        self._scalar = jnp.int32
        return lambda a: a

    def _host_caches(self, caches):
        """Fresh caches enter the distributed jit as numpy leaves (see
        ``_init_host_io``); subsequent steps carry global arrays."""
        if not getattr(self, "_distributed", False):
            return caches
        return jax.tree.map(np.asarray, caches)

    def _mesh_scope(self):
        """Context activating the engine's mesh (sharding constraints,
        shard_map) around all jitted compute; a no-op without a mesh."""
        stack = contextlib.ExitStack()
        if self.mesh is not None:
            stack.enter_context(shctx.activate_mesh(self.mesh))
            stack.enter_context(shctx.use_mesh_axes(
                tuple(self.mesh.axis_names),
                tuple(self.mesh.shape[a] for a in self.mesh.axis_names)))
            if self.ep_dispatch:
                stack.enter_context(shctx.use_ep_mesh(self.mesh))
        return stack


# --------------------------------------------------------------- continuous
@dataclass
class _Slot:
    req: Request
    opts: GenerationOptions           # resolved once at admission
    req_idx: int                      # position in the submitted batch
    prefill_s: float
    admitted_t: float
    n_new: int = 1                    # prefill emits the first token
    cross_key: Optional[bytes] = None  # shared-state pool key (encdec)


@dataclass
class Requeued:
    """A drained request: the original admission plus everything it had
    already generated.

    Produced by :meth:`ServeEngine.drain` when the fleet layer pulls
    in-flight work off a replica (re-shard, migration, shutdown).
    :meth:`continuation` rebuilds the :class:`Request` that resumes it
    exactly — prompt extended by the emitted tokens, token budget reduced,
    options preserved — so greedy decode after drain/requeue is
    **token-identical** to the uninterrupted run (the engine's
    prefill/decode equivalence, pinned by ``tests/test_serve_engine.py``,
    is exactly what makes the re-prefilled continuation exact). The
    caller stitches ``prior_tokens`` back in front of the continuation's
    result (``serve.fleet`` does this per uid).
    """

    request: Request
    prior_tokens: np.ndarray          # (n,) int32; empty for never-admitted

    def continuation(self) -> Request:
        if len(self.prior_tokens) == 0:
            return self.request
        opts = self.request.opts
        prompt = np.concatenate([
            np.asarray(self.request.prompt, np.int32),
            np.asarray(self.prior_tokens, np.int32)])
        return Request(
            uid=self.request.uid, prompt=prompt,
            options=GenerationOptions(
                max_new_tokens=opts.max_new_tokens - len(self.prior_tokens),
                eos_id=opts.eos_id, odp=opts.odp),
            enc_input=self.request.enc_input)


@dataclass
class _Prefilling:
    """An in-progress chunked prefill (paged engine, one at a time): the
    admission is split into fixed-size chunks, one consumed per ``pump``
    round between decode steps, so a long prompt no longer stalls the
    whole pool. The chunks accumulate in the engine's batch-1 scratch
    cache; the finished prompt is page-scattered like any full prefill."""

    slot: int
    idx: int                          # submission index
    req: Request
    opts: GenerationOptions
    alloc: SlotAlloc
    prompt: np.ndarray
    thr: float
    n_done: int                       # prompt tokens prefilled so far
    t0: float
    cross_key: Optional[bytes] = None  # shared-state pool key (encdec)
    extras: Dict[str, Any] = field(default_factory=dict)


@dataclass
class _PoolSession:
    """Live state of one stepwise serving session over the slot pool."""

    capacity: int
    caches: Any
    pending: deque                    # (submission idx, Request)
    active: np.ndarray                # (B,) bool
    cur: np.ndarray                   # (B,) last sampled token per slot
    pos: np.ndarray                   # (B,) its absolute position
    gen: List[List[int]]
    slots: List[Optional[_Slot]]
    thr: np.ndarray                   # (B,) per-slot ODP threshold
    done: Dict[int, Result]           # keyed by submission index
    n_submitted: int
    scope: contextlib.ExitStack
    # --- paged KV mode (EngineConfig.kv_pool) ---
    allocs: Optional[List[Optional[SlotAlloc]]] = None
    table: Optional[np.ndarray] = None      # (B, table_width) int32 pages
    prefilling: Optional[_Prefilling] = None
    # per-session slot-wide state beyond the per-slot caches: families with
    # a shared kind keep the pool-wide CrossKV here ("cross", (L, B, S, ...))
    extras: Dict[str, Any] = field(default_factory=dict)


class ServeEngine(_ArtifactBoot):
    """Continuous-batching engine over a fixed pool of decode slots.

    ``batch_size`` is the pool width. Requests are admitted into free slots
    as they open up; all slots decode in one jitted step with per-slot
    positions. Prefill is right-padded to a power-of-two bucket (no left
    padding anywhere) and the padded tail's cache entries are invalidated,
    so per-prompt-length recompiles stay logarithmic. Models whose cache
    rows are position-ring-buffered (sliding/chunked attention) or carry
    recurrent state (SSM) prefill at exact length instead — padding would
    clobber live ring entries / pollute the recurrence.
    """

    def __init__(self, model: DecoderModel, params, *,
                 mc: Optional[MCRuntime] = None,
                 config: Optional[EngineConfig] = None, **kwargs):
        config = _merge_config(config, kwargs)
        self.config = config
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.num_slots = self.batch_size = config.batch_size
        self.mc = mc
        self._init_mesh(config.mesh, config.ep_dispatch, mc)
        self.pad_id = config.pad_id
        if not config.greedy:
            raise NotImplementedError("sampling is not implemented; "
                                      "only greedy decoding is supported")
        self.greedy = config.greedy
        self.eos_id = config.eos_id
        self.max_seq_len = config.max_seq_len
        self._init_odp(mc, config.odp)
        self.stats = EngineStats()
        self._scratch = None
        self._session: Optional[_PoolSession] = None
        pad_id = config.pad_id

        # the per-slot state layer: the engine programs against the
        # family's state-kind bundle (pageable / recurrent / shared), not
        # against family names — capability checks replace special cases
        self.state = slot_state.SlotStateSpec.from_config(self.cfg)
        self._prefix_len = (self.cfg.num_prefix_tokens
                            if self.cfg.family == "vlm" else 0)

        self._kv_cfg = config.kv_pool
        self._paged = self._kv_cfg is not None
        if self._paged:
            if config.max_seq_len is None:
                raise ValueError(
                    "paged KV serving (EngineConfig.kv_pool) needs "
                    "max_seq_len — the page-table width is sized from it "
                    "once so mixed page counts never retrace")
            if not self.state.has_pageable:
                raise ValueError(
                    f"KV paging is a no-op for family {self.cfg.family!r}: "
                    f"its per-slot state is [{self.state.describe()}] — no "
                    "pageable kind; drop EngineConfig.kv_pool (recurrent "
                    "state rides the dense slot pool at fixed size)")
            if self.state.has_recurrent and \
                    self._kv_cfg.prefill_chunk is not None:
                raise ValueError(
                    "chunked prefill (KVPoolConfig.prefill_chunk) is not "
                    "supported with a recurrent state kind "
                    f"([{self.state.describe()}]): the final chunk's pad "
                    "tail would pollute the recurrence — drop "
                    "prefill_chunk for this family")
            if self._prefix_len and self._kv_cfg.prefill_chunk is not None:
                raise ValueError(
                    "chunked prefill (KVPoolConfig.prefill_chunk) is not "
                    "supported with a prefix-embedding family "
                    f"({self.cfg.family!r}): the prefix span is consumed "
                    "whole in the first forward — drop prefill_chunk")
            if getattr(self.cfg, "kv_quant", False):
                raise ValueError(
                    "ModelConfig.kv_quant quantizes the contiguous cache; "
                    "with EngineConfig.kv_pool the KV quantization mode is "
                    "KVPoolConfig.quant — disable kv_quant")
            # engine-lifetime state: the allocator, prefix cache and device
            # page pools persist across sessions so cached prefix pages
            # keep their content (that is the whole point of prefix reuse)
            self._kv_mgr = KVBlockManager(self._kv_cfg)
            self._table_width = self._kv_mgr.pages_for(config.max_seq_len)
            self._kv_caches = None      # device pools, built at first begin

        kinds = getattr(model, "kinds", None)
        all_global = (kinds is not None
                      and bool(np.all(kinds["window"] == GLOBAL_WINDOW))
                      and bool(np.all(kinds["chunk"] == GLOBAL_WINDOW)))
        # recurrent state can't be voided, so pad-tail prefill is out; a
        # model without a layer-kinds table (hybrid/encdec) prefills at
        # exact length too
        self._bucketed_prefill = all_global and not self.state.has_recurrent
        self._shared_pool = (SharedStatePool()
                             if self.state.has_shared else None)
        _rep = self._init_host_io()
        dyn = self._odp_dynamic

        if self.state.has_shared:
            # CrossKV is a pure function of the encoder input — computed
            # once per distinct input, refcount-shared across requests
            self._encode = jax.jit(
                lambda p, frames: model.cross_kv(
                    p, model.encode(p, frames)))
        if self.state.has_recurrent:
            # in-place zero of the scratch's recurrent leaves between
            # admissions (donation reuses the buffers)
            self._reset_scratch = jax.jit(slot_state.reset_recurrent,
                                          donate_argnums=(0,))

        def _prefill(params, tokens, length, caches, thr, extras):
            kw = dict(extras)
            pe = kw.get("prefix_embeds")
            plen = 0 if pe is None else pe.shape[1]   # static at trace
            if self._bucketed_prefill:
                # pad-tail tokens must not consume MoE expert capacity;
                # the mask spans the prefix-inclusive token axis
                mask = jnp.arange(tokens.shape[1])[None, :] < length
                if plen:
                    mask = jnp.concatenate(
                        [jnp.ones((1, plen), bool), mask], axis=1)
                kw["token_mask"] = mask
            if dyn:
                kw["odp_threshold"] = thr        # (1,) per-request knob
            logits, new_caches, _ = model.forward(
                params, tokens, caches=caches, mc=self.mc, **kw)
            last = jax.lax.dynamic_index_in_dim(
                logits, plen + length - 1, axis=1, keepdims=False)
            nxt = _rep(jnp.argmax(last, -1).astype(jnp.int32))  # (1,)
            # void the padded tail's cache entries: keys the pad tokens wrote
            # at positions >= plen + length must never be attended to
            new_caches = slot_state.void_attention_tail(
                new_caches, plen + length)
            return nxt, new_caches

        def _insert(pool, one, slot):
            # every state leaf carries batch at axis 1 after the model's
            # step-stacking — scatter row 0 of the fresh state into `slot`
            return slot_state.insert_row(pool, one, slot)

        def _decode(params, caches, cur, pos, active, thr, extras):
            # inactive slots are masked out of MoE dispatch so their junk
            # tokens never consume expert capacity from live requests
            kw = dict(extras)
            if dyn:
                kw["odp_threshold"] = thr        # (B,) per slot
            logits, new_caches = model.decode_step(
                params, caches, cur[:, None], pos, mc=self.mc,
                token_mask=active[:, None], **kw)
            nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            nxt = _rep(jnp.where(active, nxt, jnp.int32(pad_id)))
            return nxt, new_caches

        def _decode_paged(params, caches, cur, pos, active, thr, table,
                          extras):
            # identical to _decode, plus the page table — a jit *input*
            # (numpy each step), so any mix of per-slot page counts shares
            # one compiled step (the PR 6 no-retrace discipline)
            kw = dict(extras)
            if dyn:
                kw["odp_threshold"] = thr
            logits, new_caches = model.decode_step(
                params, caches, cur[:, None], pos, mc=self.mc,
                token_mask=active[:, None], kv_table=table, **kw)
            nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            nxt = _rep(jnp.where(active, nxt, jnp.int32(pad_id)))
            return nxt, new_caches

        def _prefill_chunk(params, tokens, start, length, caches, thr,
                           extras):
            # one fixed-size chunk of a long prompt into the batch-1 linear
            # scratch at traced offset `start` — every chunk shares one
            # compiled shape; only the final chunk carries padding, masked
            # out of MoE dispatch like the bucketed pad tail
            kw = dict(extras)
            kw["token_mask"] = (start + jnp.arange(tokens.shape[1])[None, :]
                                ) < length
            if dyn:
                kw["odp_threshold"] = thr
            logits, new_caches, _ = model.forward(
                params, tokens, caches=caches, start_pos=start, mc=self.mc,
                **kw)
            # only meaningful on the final chunk (the prompt's last token);
            # dynamic_index clamps harmlessly on earlier chunks
            last = jax.lax.dynamic_index_in_dim(
                logits, length - 1 - start, axis=1, keepdims=False)
            nxt = _rep(jnp.argmax(last, -1).astype(jnp.int32))   # (1,)
            return nxt, new_caches

        def _scatter_pages(pool, scratch, targets, slot):
            # land a finished batch-1 prefill in the device state pools,
            # per state kind: pageable leaves view the linear scratch as
            # (n_steps, table_width, page_size, ...) pages, quantize per
            # the pool's storage mode, and scatter whole pages at
            # `targets` — entries the request does not own (shared prefix
            # pages, beyond-prompt junk) target the trash page, so the
            # scatter shape never depends on the prompt. Recurrent leaves
            # (a dense per-row-lifetime pool) take the plain row insert.
            def land(pc, sc):
                if not isinstance(pc, attn_lib.PagedKVCache):
                    return slot_state.insert_row(pc, sc, slot)
                ps = pc.k.shape[2]       # leaves are (n_steps, P, ps, ...)

                def pages_of(a):
                    return a.reshape(a.shape[0], -1, ps, *a.shape[3:])

                k, v = pages_of(sc.k), pages_of(sc.v)
                cks = cvs = None
                if pc.bits == 16:
                    kq, vq = k.astype(pc.k.dtype), v.astype(pc.v.dtype)
                else:
                    kq, ks = attn_lib._kv_quantize(k, pc.bits)
                    vq, vs = attn_lib._kv_quantize(v, pc.bits)
                    if pc.bits == 4:
                        kq = attn_lib._pack_int4(kq)
                        vq = attn_lib._pack_int4(vq)
                    cks = pc.kscale.at[:, targets].set(ks)
                    cvs = pc.vscale.at[:, targets].set(vs)
                return attn_lib.PagedKVCache(
                    pc.k.at[:, targets].set(kq),
                    pc.v.at[:, targets].set(vq), cks, cvs, pc.bits)

            # flatten_up_to pairs each pool-side PagedKVCache / SSMState
            # node with the matching scratch subtree (a linear KVCache for
            # paged attention kinds)
            return jax.tree.map(
                land, pool, scratch,
                is_leaf=lambda c: isinstance(
                    c, (attn_lib.PagedKVCache, ssm_lib.SSMState)))

        self._prefill = jax.jit(_prefill)
        # donation lets XLA update the pool cache in place on accelerators
        # (ignored with a warning-free no-op on CPU)
        self._insert = jax.jit(_insert, donate_argnums=(0,))
        self._decode = jax.jit(_decode, donate_argnums=(1,))
        if self._paged:
            self._decode_paged = jax.jit(_decode_paged, donate_argnums=(1,))
            self._prefill_chunk = jax.jit(_prefill_chunk)
            self._scatter = jax.jit(_scatter_pages, donate_argnums=(0,))

    # ---- sizing ----
    def _span(self, r: Request) -> int:
        """Cache positions a request occupies: the fixed prefix span (vlm
        image embeddings) + prompt + generation budget."""
        return self._prefix_len + len(r.prompt) + r.opts.max_new_tokens

    def _capacity_for(self, requests: List[Request]) -> int:
        need = max(self._span(r) for r in requests)
        if self.max_seq_len is not None:
            # hard memory bound AND stable compiled shapes across runs
            if need > self.max_seq_len:
                raise ValueError(
                    f"request needs {need} cache positions > "
                    f"max_seq_len {self.max_seq_len}")
            return _round_up(self.max_seq_len, 8)
        return _round_up(need, 8)

    def _bucket(self, n: int, capacity: int) -> int:
        if not self._bucketed_prefill:
            return n
        b = 8
        while b < n:
            b *= 2
        return min(b, capacity - self._prefix_len)

    def _enc_shape(self) -> Optional[Tuple[int, int]]:
        """The fixed per-request ``enc_input`` shape this family needs
        (None when the family takes none). Fixed shapes keep the encoder
        jit and the prefill steps static across requests."""
        if self.state.has_shared:
            return (self.cfg.encoder_seq, self.cfg.d_model)
        if self._prefix_len:
            return (self._prefix_len, self.cfg.d_model)
        return None

    def _check_requests(self, requests: List[Request]) -> None:
        want = self._enc_shape()
        for r in requests:
            if want is None:
                if r.enc_input is not None:
                    raise ValueError(
                        f"request {r.uid}: enc_input is only meaningful "
                        f"for families with a shared or prefix state kind; "
                        f"family {self.cfg.family!r} carries "
                        f"[{self.state.describe()}]")
                continue
            got = None if r.enc_input is None else \
                tuple(np.asarray(r.enc_input).shape)
            if got != want:
                kind = ("encoder frames" if self.state.has_shared
                        else "prefix embeddings")
                raise ValueError(
                    f"request {r.uid}: family {self.cfg.family!r} needs "
                    f"enc_input ({kind}) of shape {want}, got "
                    f"{got} — fixed shapes keep the compiled steps "
                    "static across requests")

    # ---- lifecycle ----
    def run(self, requests: List[Request]) -> List[Result]:
        if not requests:
            return []
        self.begin(requests)
        while self.busy:
            self.pump()
        return self.collect()

    # ---- stepwise session API (drives run(); the fleet layer drives it
    #      directly so it can interleave scheduling rounds with heartbeats,
    #      fault handling and live re-sharding) ----
    @property
    def busy(self) -> bool:
        """True while the current session has pending or in-flight work."""
        s = self._session
        return s is not None and (bool(s.pending) or bool(s.active.any())
                                  or s.prefilling is not None)

    def begin(self, requests: List[Request]) -> None:
        """Open a serving session over the slot pool. The mesh scope is
        held for the whole session (closed by ``collect``)."""
        if self._session is not None:
            raise RuntimeError("a serving session is already active; "
                               "collect() or drain() it first")
        if not requests:
            raise ValueError("begin() needs at least one request")
        self._check_requests(requests)
        b = self.num_slots
        capacity = self._capacity_for(requests)
        if self._paged:
            # logical per-slot span = the fixed page-table width; device
            # page pools persist across sessions (prefix pages keep their
            # content), so only the first begin() pays the allocation
            capacity = self._table_width * self._kv_cfg.page_size
            self._check_pool_fit(requests)
            if self._kv_caches is None:
                self._kv_caches = self._host_caches(
                    self.model.init_paged_caches(
                        self._kv_cfg.num_pages, self._kv_cfg.page_size,
                        quant=self._kv_cfg.quant, batch=b))
            caches = self._kv_caches
        else:
            caches = self._host_caches(self.model.init_caches(b, capacity))
        extras = {}
        if self.state.has_shared:
            extras["cross"] = self._host_caches(
                self.model.init_cross_state(b))
        scope = self._mesh_scope()
        scope.__enter__()
        self._scratch = None          # reusable batch-1 prefill cache
        self._session = _PoolSession(
            capacity=capacity,
            caches=caches,
            pending=deque(enumerate(requests)),
            active=np.zeros(b, bool),
            cur=np.zeros(b, np.int32),
            pos=np.zeros(b, np.int32),
            gen=[[] for _ in range(b)],
            slots=[None] * b,
            # per-slot ODP threshold — a jit input of _decode, so requests
            # at different knob settings coexist in one compiled step
            thr=np.full(b, self._odp_default_thr, np.float32),
            done={},
            n_submitted=len(requests),
            scope=scope,
            allocs=[None] * b if self._paged else None,
            table=np.full((b, self._table_width), TRASH_PAGE, np.int32)
            if self._paged else None,
            extras=extras)

    def submit(self, requests: List[Request]) -> None:
        """Queue more requests into the open session; they are admitted
        as slots free up, exactly like the initial batch. Every request
        must fit the session's capacity (fixed at ``begin``)."""
        sess = self._session
        if sess is None:
            raise RuntimeError("no active session; begin() first")
        self._check_requests(requests)
        if self._paged:
            self._check_pool_fit(requests)
        for r in requests:
            need = self._span(r)
            if need > sess.capacity:
                raise ValueError(
                    f"request {r.uid}: needs {need} cache positions > "
                    f"session capacity {sess.capacity}; set max_seq_len "
                    "to size the pool for late arrivals")
            sess.pending.append((sess.n_submitted, r))
            sess.n_submitted += 1

    def _check_pool_fit(self, requests: List[Request]) -> None:
        """The loud half of paged admission: a request whose whole span
        can **never** fit the pool is an error at submission; one that
        merely has to wait for pages queues (see ``_pump_admissions``)."""
        mgr = self._kv_mgr
        for r in requests:
            need = self._span(r)
            pages = mgr.pages_for(need)
            if pages > mgr.usable_pages:
                raise ValueError(
                    f"request {r.uid} needs {pages} KV pages ({need} "
                    f"tokens at page_size {self._kv_cfg.page_size}) but "
                    f"the whole pool holds only {mgr.usable_pages} "
                    "allocatable pages — enlarge KVPoolConfig.num_pages "
                    "or shorten the request")

    def _finish(self, s: int, reason: str):
        sess = self._session
        sl = sess.slots[s]
        now = time.time()
        sess.done[sl.req_idx] = Result(
            uid=sl.req.uid, tokens=np.asarray(sess.gen[s], np.int32),
            prefill_s=sl.prefill_s,
            decode_s=now - sl.admitted_t - sl.prefill_s,
            new_tokens=sl.n_new, finish_reason=reason)
        self.stats.requests += 1
        self.stats.generated_tokens += sl.n_new
        sess.active[s] = False
        sess.slots[s] = None
        if sl.cross_key is not None:
            self._shared_pool.release(sl.cross_key)
        if self._paged:
            self._kv_mgr.release(sess.allocs[s])
            sess.allocs[s] = None
            sess.table[s] = TRASH_PAGE

    def _post_admit_checks(self, s: int) -> None:
        """Retire a freshly admitted slot whose first (prefill) token
        already satisfies its stop condition."""
        sess = self._session
        sl = sess.slots[s]
        eos = sl.opts.eos_id if sl.opts.eos_id is not None else self.eos_id
        if eos is not None and sess.gen[s] and sess.gen[s][0] == eos:
            self._finish(s, "eos")
        elif sl.opts.max_new_tokens <= 1:
            self._finish(s, "length")

    def pump(self) -> int:
        """One scheduling round: admit pending requests into free slots
        (paged mode: advance at most one prefill chunk), advance every
        active slot by one decode step, retire finished requests. Returns
        the number of slots still active afterwards."""
        sess = self._session
        if sess is None:
            raise RuntimeError("no active session; begin() first")
        b = self.num_slots
        if self._paged:
            self._pump_admissions_paged(sess)
        else:
            for s in range(b):
                while not sess.active[s] and sess.pending:
                    idx, req = sess.pending.popleft()
                    self._admit(sess, req, idx, s)
                    self._post_admit_checks(s)
        if not sess.active.any():
            return 0

        t0 = time.time()
        if self._paged:
            # grow each live slot's page list to cover this step's write;
            # a slot the pool cannot grow stalls for the round (it resumes
            # when another request's pages free up)
            step_active = self._grow_for_step(sess)
            nxt, sess.caches = self._decode_paged(
                self.params, sess.caches, self._arr(sess.cur),
                self._arr(sess.pos), self._arr(step_active),
                self._arr(sess.thr), self._arr(sess.table), sess.extras)
        else:
            step_active = sess.active
            nxt, sess.caches = self._decode(
                self.params, sess.caches, self._arr(sess.cur),
                self._arr(sess.pos), self._arr(sess.active),
                self._arr(sess.thr), sess.extras)
        nxt = _fetch(nxt)
        self.stats.decode_s += time.time() - t0
        self.stats.decode_steps += 1
        self.stats.slot_steps += b
        self.stats.active_slot_steps += int(step_active.sum())

        for s in np.nonzero(step_active)[0]:
            sl = sess.slots[s]
            tok = int(nxt[s])
            sess.gen[s].append(tok)
            sl.n_new += 1
            sess.cur[s] = tok
            sess.pos[s] += 1
            eos = sl.opts.eos_id if sl.opts.eos_id is not None else \
                self.eos_id
            if eos is not None and tok == eos:
                self._finish(s, "eos")
            elif sl.n_new >= sl.opts.max_new_tokens:
                self._finish(s, "length")
        return int(sess.active.sum())

    def drain(self) -> List[Requeued]:
        """Snapshot and release every in-flight and still-pending request.

        Active slots become :class:`Requeued` records carrying their
        generated-so-far tokens; never-admitted pending requests come back
        with an empty prefix. The session stays open (finished results
        remain collectable); the pool is left fully idle, so the caller
        may ``collect()`` and ``begin()`` a fresh session — e.g. after
        swapping ``self.params`` for a re-sharded replica."""
        sess = self._session
        if sess is None:
            raise RuntimeError("no active session; begin() first")
        out: List[Tuple[int, Requeued]] = []
        for s in range(self.num_slots):
            if sess.active[s]:
                sl = sess.slots[s]
                out.append((sl.req_idx, Requeued(
                    request=sl.req,
                    prior_tokens=np.asarray(sess.gen[s], np.int32))))
                sess.active[s] = False
                sess.slots[s] = None
                if sl.cross_key is not None:
                    self._shared_pool.release(sl.cross_key)
                if self._paged:
                    self._kv_mgr.release(sess.allocs[s])
                    sess.allocs[s] = None
                    sess.table[s] = TRASH_PAGE
        if sess.prefilling is not None:
            # a half-prefilled admission restarts from scratch elsewhere
            pf = sess.prefilling
            out.append((pf.idx, Requeued(request=pf.req,
                                         prior_tokens=np.zeros(0, np.int32))))
            if pf.cross_key is not None:
                self._shared_pool.release(pf.cross_key)
            self._kv_mgr.release(pf.alloc)
            sess.prefilling = None
        for idx, req in sess.pending:
            out.append((idx, Requeued(request=req,
                                      prior_tokens=np.zeros(0, np.int32))))
        sess.pending.clear()
        return [r for _, r in sorted(out, key=lambda t: t[0])]

    def take_finished(self) -> List[Result]:
        """Pop finished results out of the open session without closing
        it (submission order). Lets the fleet layer report completions
        per scheduling round instead of at session end."""
        sess = self._session
        if sess is None:
            return []
        out = [sess.done.pop(i) for i in sorted(sess.done)]
        return out

    def collect(self) -> List[Result]:
        """Close the session and return finished results in submission
        order (drained requests are absent — they finish elsewhere)."""
        sess = self._session
        if sess is None:
            raise RuntimeError("no active session; begin() first")
        if self.busy:
            raise RuntimeError("session still has in-flight work; "
                               "pump() it dry or drain() first")
        if self._paged:
            # the decode step donates the pools — save the live version
            # back so the next session (and its prefix-cache hits) sees
            # the pages' current content
            self._kv_caches = sess.caches
        self._session = None
        sess.scope.close()
        return [sess.done[i] for i in sorted(sess.done)]

    # ---- admission-time state helpers (family-agnostic) ----
    def _admission_state(self, req: Request):
        """Per-request admission-time state: ``(shared-pool key, prefill
        extras)``. Families with a **shared** kind (encdec) acquire their
        CrossKV from the content-addressed pool — computed once per
        distinct encoder input, refcount-shared across identical inputs;
        prefix families (vlm) pass their image embeddings straight into
        the prefill step."""
        if self.state.has_shared:
            enc = np.ascontiguousarray(
                np.asarray(req.enc_input, np.float32))
            key = SharedStatePool.key_of(enc)
            cross = self._shared_pool.acquire(
                key,
                lambda: self._encode(self.params, self._arr(enc[None])))
            return key, {"cross": cross}
        if self._prefix_len:
            pe = self._arr(np.asarray(req.enc_input, np.float32)[None])
            return None, {"prefix_embeds": pe}
        return None, {}

    def _admission_salt(self, req: Request) -> bytes:
        """Prefix-cache key salt: decoder KV depends on the encoder-side
        input (cross-attention / the prefix residual stream), so prefix
        pages are shareable only between requests whose encoder input is
        byte-identical."""
        if req.enc_input is None:
            return b""
        return SharedStatePool.key_of(
            np.ascontiguousarray(np.asarray(req.enc_input, np.float32)))

    def _next_scratch(self, capacity: int):
        """The batch-1 prefill scratch, reused across admissions so only
        the first one pays the allocation (``EngineStats.scratch_reuses``
        counts the saved ones). Stale attention entries sit at voided or
        causally-future positions, so they are never attended; recurrent
        leaves are zeroed **in place** (the reset jit donates its input)
        — the admission scratch is reused for every family."""
        one = self._scratch
        if one is None:
            return self._host_caches(
                self.model.init_caches(1, capacity, linear=self._paged))
        self._scratch = None
        self.stats.scratch_reuses += 1
        if self.state.has_recurrent:
            one = self._reset_scratch(one)
        return one

    def _admit(self, sess: _PoolSession, req: Request, idx: int,
               s: int) -> None:
        opts = req.opts
        prompt = np.asarray(req.prompt, np.int32)
        ln = len(prompt)
        plen = self._prefix_len
        assert plen + ln + opts.max_new_tokens <= sess.capacity, (
            f"request {req.uid}: prefix {plen} + prompt {ln} + max_new "
            f"{opts.max_new_tokens} exceeds pool capacity {sess.capacity}")
        lb = self._bucket(ln, sess.capacity)
        toks = np.full((1, lb), self.pad_id, np.int32)
        toks[0, :ln] = prompt
        sess.thr[s] = self._slot_threshold(opts)

        t0 = time.time()
        cross_key, pf_extras = self._admission_state(req)
        one = self._next_scratch(sess.capacity)
        nxt, one = self._prefill(self.params, self._arr(toks),
                                 self._scalar(ln), one,
                                 self._arr(sess.thr[s:s + 1]), pf_extras)
        self._scratch = one
        sess.caches = self._insert(sess.caches, one, self._scalar(s))
        if "cross" in pf_extras:
            # the request's CrossKV row lands in the session-wide pool
            # entry its decode steps read (the shared-pool entry itself
            # stays alive for other requests with the same encoder input)
            sess.extras["cross"] = self._insert(
                sess.extras["cross"], pf_extras["cross"], self._scalar(s))
        first = int(_fetch(nxt)[0])
        prefill_s = time.time() - t0
        self.stats.prefill_s += prefill_s

        sess.active[s] = True
        sess.cur[s] = first
        sess.pos[s] = plen + ln       # first generated token's position
        sess.gen[s] = [first]
        sess.slots[s] = _Slot(req=req, opts=opts, req_idx=idx,
                              prefill_s=prefill_s, admitted_t=t0,
                              cross_key=cross_key)

    # ---- paged admission (EngineConfig.kv_pool) ----
    def _pump_admissions_paged(self, sess: _PoolSession) -> None:
        """Paged scheduling-round admissions: continue the in-flight
        chunked prefill by one chunk, then admit pending requests into
        free slots. An admission the pool cannot page **right now** goes
        back to the front of the queue (FIFO, queue-until-pages-free);
        requests that can never fit raised at submission."""
        if sess.prefilling is not None:
            self._advance_prefill(sess)
        chunking = self._kv_cfg.prefill_chunk is not None
        for s in range(self.num_slots):
            if sess.prefilling is not None:
                break                     # one in-flight prefill at a time
            while not sess.active[s] and sess.pending:
                idx, req = sess.pending.popleft()
                opts = req.opts
                prompt = np.asarray(req.prompt, np.int32)
                thr_val = self._slot_threshold(opts)
                alloc = self._kv_mgr.admit(
                    prompt, self._span(req), thr_key=thr_val,
                    salt=self._admission_salt(req),
                    prefix_tokens=self._prefix_len)
                if alloc is None:
                    sess.pending.appendleft((idx, req))
                    return
                sess.thr[s] = thr_val
                # shared/prefix state only after the page allocation
                # succeeded — a queued request must hold no refcounts
                cross_key, pf_extras = self._admission_state(req)
                if chunking:
                    sess.prefilling = _Prefilling(
                        slot=s, idx=idx, req=req, opts=opts, alloc=alloc,
                        prompt=prompt, thr=thr_val, n_done=0,
                        t0=time.time(), cross_key=cross_key,
                        extras=pf_extras)
                    self._advance_prefill(sess)   # first chunk this round
                    break
                self._admit_paged_full(sess, s, idx, req, opts, prompt,
                                       thr_val, alloc, cross_key, pf_extras)
                self._post_admit_checks(s)

    def _admit_paged_full(self, sess, s, idx, req, opts, prompt, thr_val,
                          alloc, cross_key, pf_extras) -> None:
        ln = len(prompt)
        lb = self._bucket(ln, sess.capacity)
        toks = np.full((1, lb), self.pad_id, np.int32)
        toks[0, :ln] = prompt
        t0 = time.time()
        # the paged scratch is a **linear** full-capacity contiguous cache
        # (ring layout would fold logical indices, breaking the page
        # scatter) — _next_scratch passes linear=True in paged mode
        one = self._next_scratch(sess.capacity)
        nxt, self._scratch = self._prefill(
            self.params, self._arr(toks), self._scalar(ln), one,
            self._arr(sess.thr[s:s + 1]), pf_extras)
        first = int(_fetch(nxt)[0])
        self._land_prefill(sess, s, idx, req, opts, prompt, thr_val, alloc,
                           first, t0, cross_key, pf_extras)

    def _advance_prefill(self, sess: _PoolSession) -> None:
        """Consume one chunk of the in-flight prefill; on the final chunk
        the prompt lands in the page pools and the slot activates."""
        pf = sess.prefilling
        chunk = self._kv_cfg.prefill_chunk
        ln = len(pf.prompt)
        scratch = (self._next_scratch(sess.capacity) if pf.n_done == 0
                   else self._scratch)
        toks = np.full((1, chunk), self.pad_id, np.int32)
        piece = pf.prompt[pf.n_done:pf.n_done + chunk]
        toks[0, :len(piece)] = piece
        nxt, self._scratch = self._prefill_chunk(
            self.params, self._arr(toks), self._scalar(pf.n_done),
            self._scalar(ln), scratch,
            self._arr(np.asarray([pf.thr], np.float32)), pf.extras)
        pf.n_done += len(piece)
        if pf.n_done < ln:
            return
        first = int(_fetch(nxt)[0])
        sess.prefilling = None
        self._land_prefill(sess, pf.slot, pf.idx, pf.req, pf.opts,
                           pf.prompt, pf.thr, pf.alloc, first, pf.t0,
                           pf.cross_key, pf.extras)
        self._post_admit_checks(pf.slot)

    def _land_prefill(self, sess, s, idx, req, opts, prompt, thr_val,
                      alloc, first, t0, cross_key, pf_extras) -> None:
        """Land the finished scratch prefill in the device state pools,
        per state kind (pageable → page scatter, recurrent → dense row
        insert), and activate the slot. Shared prefix pages already hold
        exactly this content (prefix KV is a deterministic function of
        the prefix tokens, the encoder-input salt and the ODP threshold —
        the prefix-cache key), so their scatter targets the trash page
        instead of rewriting them."""
        targets = np.full(self._table_width, TRASH_PAGE, np.int32)
        for i in range(alloc.n_shared, len(alloc.pages)):
            targets[i] = alloc.pages[i]
        sess.caches = self._scatter(sess.caches, self._scratch,
                                    self._arr(targets), self._scalar(s))
        if "cross" in pf_extras:
            sess.extras["cross"] = self._insert(
                sess.extras["cross"], pf_extras["cross"], self._scalar(s))
        self._kv_mgr.register_prefix(alloc, prompt, thr_val,
                                     salt=self._admission_salt(req))
        sess.allocs[s] = alloc
        sess.table[s] = self._kv_mgr.table_row(alloc, self._table_width)
        prefill_s = time.time() - t0
        self.stats.prefill_s += prefill_s
        sess.active[s] = True
        sess.cur[s] = first
        sess.pos[s] = alloc.prefix_tokens + len(prompt)
        sess.gen[s] = [first]
        sess.slots[s] = _Slot(req=req, opts=opts, req_idx=idx,
                              prefill_s=prefill_s, admitted_t=t0,
                              cross_key=cross_key)

    def _grow_for_step(self, sess: _PoolSession) -> np.ndarray:
        """Cover each live slot's next KV write with a page, on demand.
        Slots the pool cannot grow are withheld from this decode step
        (their table rows route the masked write to the trash page); if
        **every** live slot is stalled nothing can ever free a page, so
        that is an error, not a hang."""
        step_active = sess.active.copy()
        for s in np.nonzero(sess.active)[0]:
            if self._kv_mgr.ensure(sess.allocs[s], int(sess.pos[s])):
                sess.table[s] = self._kv_mgr.table_row(sess.allocs[s],
                                                       self._table_width)
            else:
                step_active[s] = False
        if sess.active.any() and not step_active.any():
            raise RuntimeError(
                "KV pool deadlock: every active slot is stalled waiting "
                "for a free page and no in-flight request can complete to "
                "free one — enlarge KVPoolConfig.num_pages or lower the "
                "concurrency")
        return step_active


# ------------------------------------------------------------------- static
class StaticServeEngine(_ArtifactBoot):
    """Lockstep static batching (the pre-continuous baseline).

    Requests are grouped into fixed-size batches (left-padded to a common
    prompt length), prefilled once, then decoded step-aligned for the
    batch-max ``max_new_tokens`` — finished requests keep burning decode
    steps as padding. EOS-stopped requests are truncated post-hoc (the
    lockstep loop cannot retire them early; that waste is the point).
    """

    def __init__(self, model: DecoderModel, params, *,
                 mc: Optional[MCRuntime] = None,
                 config: Optional[EngineConfig] = None, **kwargs):
        config = _merge_config(config, kwargs)
        if not config.greedy:
            raise NotImplementedError("sampling is not implemented; "
                                      "only greedy decoding is supported")
        if config.kv_pool is not None:
            raise ValueError(
                "kv_pool (the paged KV memory layer) applies to the "
                "continuous ServeEngine only; the lockstep engine sizes "
                "one contiguous cache per batch")
        spec = slot_state.SlotStateSpec.from_config(model.cfg)
        if spec.has_shared or model.cfg.family == "vlm":
            raise ValueError(
                f"family {model.cfg.family!r} (per-slot state "
                f"[{spec.describe()}]) needs per-request encoder-side "
                "input, which the lockstep baseline does not carry — "
                "serve it with the continuous ServeEngine")
        self.config = config
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.batch_size = config.batch_size
        self.mc = mc
        self._init_mesh(config.mesh, config.ep_dispatch, mc)
        self.pad_id = config.pad_id
        self.greedy = config.greedy
        self.eos_id = config.eos_id
        self._init_odp(mc, config.odp)
        self.stats = EngineStats()

        _rep = self._init_host_io()
        dyn = self._odp_dynamic

        def _prefill(params, tokens, caches, thr):
            kw = {"odp_threshold": thr} if dyn else {}   # (B,) per row
            logits, new_caches, _ = model.forward(
                params, tokens, caches=caches, mc=self.mc, **kw)
            return _rep(logits[:, -1]), new_caches

        def _decode(params, caches, tokens, pos, thr):
            kw = {"odp_threshold": thr} if dyn else {}
            logits, new_caches = model.decode_step(params, caches, tokens,
                                                   pos, mc=self.mc, **kw)
            return _rep(logits[:, -1]), new_caches

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def _make_batch(self, requests: List[Request]):
        b = len(requests)
        lmax = max(len(r.prompt) for r in requests)
        toks = np.full((b, lmax), self.pad_id, np.int32)
        for i, r in enumerate(requests):
            toks[i, lmax - len(r.prompt):] = r.prompt   # left padding
        return self._arr(toks), lmax

    def run(self, requests: List[Request]) -> List[Result]:
        if self.ep_dispatch and len(requests) % self.batch_size:
            # a final partial batch would not tile the data axis and
            # would silently take the gather path instead of the
            # shard_map schedule the flag requests
            raise ValueError(
                f"ep_dispatch requires the request count "
                f"({len(requests)}) to be a multiple of batch_size "
                f"({self.batch_size}); pad the workload or drop "
                "ep_dispatch")
        out: List[Result] = []
        with self._mesh_scope():
            for i in range(0, len(requests), self.batch_size):
                out.extend(self._run_batch(requests[i:i + self.batch_size]))
        return out

    def _run_batch(self, requests: List[Request]) -> List[Result]:
        b = len(requests)
        tokens, lmax = self._make_batch(requests)
        opts = [r.opts for r in requests]
        max_new = max(o.max_new_tokens for o in opts)
        thr = self._arr(np.array([self._slot_threshold(o) for o in opts],
                                 np.float32))
        caches = self._host_caches(self.model.init_caches(b, lmax + max_new))

        def _next(logits):
            # distributed: logits come back replicated — argmax on host
            # keeps the loop free of eager multi-process device ops
            if self._distributed:
                return np.argmax(_fetch(logits), -1).astype(np.int32)
            return jnp.argmax(logits, -1).astype(jnp.int32)

        t0 = time.time()
        logits, caches = self._prefill(self.params, tokens, caches, thr)
        logits.block_until_ready()
        prefill_s = time.time() - t0

        generated = np.zeros((b, max_new), np.int32)
        t0 = time.time()
        cur = _next(logits)
        for t in range(max_new):
            generated[:, t] = _fetch(cur)
            if t == max_new - 1:        # last recorded token needs no step
                break
            logits, caches = self._decode(
                self.params, caches, cur[:, None],
                self._scalar(lmax + t), thr)
            cur = _next(logits)
        jax.block_until_ready(logits)
        decode_s = time.time() - t0

        out = []
        useful = 0
        for i, r in enumerate(requests):
            toks = generated[i, :opts[i].max_new_tokens]
            reason = "length"
            eos = opts[i].eos_id if opts[i].eos_id is not None \
                else self.eos_id
            if eos is not None:
                hits = np.nonzero(toks == eos)[0]
                if hits.size:
                    toks = toks[:int(hits[0]) + 1]
                    reason = "eos"
            useful += len(toks)
            out.append(Result(uid=r.uid, tokens=toks, prefill_s=prefill_s,
                              decode_s=decode_s, new_tokens=len(toks),
                              finish_reason=reason))
        self.stats.requests += b
        self.stats.generated_tokens += useful
        self.stats.prefill_s += prefill_s
        self.stats.decode_s += decode_s
        self.stats.decode_steps += max_new - 1
        self.stats.slot_steps += b * (max_new - 1)
        # a request is usefully decoding for new_tokens - 1 steps (its
        # first token came from prefill) — same accounting as continuous
        self.stats.active_slot_steps += sum(
            max(r.new_tokens - 1, 0) for r in out)
        return out
