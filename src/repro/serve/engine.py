"""Batched serving engine with MC-compressed inference.

Static-batch generation loop over the model's prefill/decode steps:
requests are grouped into fixed-size batches (left-padded to a common
prompt length), prefilled once, then decoded step-aligned with the MC
runtime (PMQ quantized experts + ODP pruning) applied at every step.
Throughput/latency stats are reported per batch — the harness behind the
paper's Tab. 13/14 speed analogues in ``benchmarks/bench_memory.py``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.transformer import DecoderModel, MCRuntime


@dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (L,) int32
    max_new_tokens: int = 16


@dataclass
class Result:
    uid: int
    tokens: np.ndarray
    prefill_s: float
    decode_s: float
    new_tokens: int


@dataclass
class EngineStats:
    requests: int = 0
    generated_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def decode_tokens_per_s(self) -> float:
        return self.generated_tokens / max(self.decode_s, 1e-9)


class ServeEngine:
    def __init__(self, model: DecoderModel, params, *, batch_size: int = 4,
                 mc: Optional[MCRuntime] = None, pad_id: int = 0,
                 greedy: bool = True):
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.batch_size = batch_size
        self.mc = mc
        self.pad_id = pad_id
        self.greedy = greedy
        self.stats = EngineStats()

        def _prefill(params, tokens, caches):
            logits, new_caches, _ = model.forward(
                params, tokens, caches=caches, mc=self.mc)
            return logits[:, -1], new_caches

        def _decode(params, caches, tokens, pos):
            logits, new_caches = model.decode_step(params, caches, tokens,
                                                   pos, mc=self.mc)
            return logits[:, -1], new_caches

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def _make_batch(self, requests: List[Request]):
        b = len(requests)
        lmax = max(len(r.prompt) for r in requests)
        toks = np.full((b, lmax), self.pad_id, np.int32)
        for i, r in enumerate(requests):
            toks[i, lmax - len(r.prompt):] = r.prompt   # left padding
        return jnp.asarray(toks), lmax

    def run(self, requests: List[Request]) -> List[Result]:
        out: List[Result] = []
        for i in range(0, len(requests), self.batch_size):
            out.extend(self._run_batch(requests[i:i + self.batch_size]))
        return out

    def _run_batch(self, requests: List[Request]) -> List[Result]:
        b = len(requests)
        tokens, lmax = self._make_batch(requests)
        max_new = max(r.max_new_tokens for r in requests)
        caches = self.model.init_caches(b, lmax + max_new)

        t0 = time.time()
        logits, caches = self._prefill(self.params, tokens, caches)
        logits.block_until_ready()
        prefill_s = time.time() - t0

        generated = np.zeros((b, max_new), np.int32)
        t0 = time.time()
        cur = jnp.argmax(logits, -1).astype(jnp.int32) if self.greedy else \
            jnp.zeros((b,), jnp.int32)
        for t in range(max_new):
            generated[:, t] = np.asarray(cur)
            logits, caches = self._decode(
                self.params, caches, cur[:, None],
                jnp.asarray(lmax + t, jnp.int32))
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(logits)
        decode_s = time.time() - t0

        self.stats.requests += b
        self.stats.generated_tokens += b * max_new
        self.stats.prefill_s += prefill_s
        self.stats.decode_s += decode_s
        return [Result(uid=r.uid, tokens=generated[i, :r.max_new_tokens],
                       prefill_s=prefill_s, decode_s=decode_s,
                       new_tokens=r.max_new_tokens)
                for i, r in enumerate(requests)]
