"""Block-owning engine replicas with live elastic re-sharding.

One :class:`ShardedReplica` is a serving engine whose artifact is held as
**blocks**: the expert axis is cut into contiguous byte-balanced blocks
(:func:`repro.runtime.elastic.initial_assignment`), each owned by exactly
one host of the replica. The replica boots by streaming the dense groups
once plus every block through the range-filtered subset reads
(:func:`repro.core.pipeline.load_expert_blocks`) and merging the parts
into the full param tree (``checkpointer.merge_subset_trees``) — the same
per-host streaming discipline ``launch.serve --num-hosts`` simulates, but
with re-shardable granularity.

Topology changes are **delta-streamed**:

* ``lose_host(h)`` — h's blocks are orphaned (its memory is gone). The
  planner re-homes them onto the lightest survivors
  (:func:`~repro.runtime.elastic.plan_host_loss`) and only those blocks
  are re-read from the artifact store; every survivor-resident block
  stays put. In-flight requests are drained off the engine first
  (:meth:`~repro.serve.engine.ServeEngine.drain`), re-admitted as
  generated-prefix continuations after the params swap, and their results
  stitched back together per uid — greedy decode makes the resumed stream
  token-identical to an uninterrupted run.
* ``join_host()`` — blocks peel off the heaviest hosts
  (:func:`~repro.runtime.elastic.plan_host_join`); the joiner streams
  them, donors simply drop theirs. Serving is not interrupted.

``LoadStats.accumulate`` folds boot + every delta read into one
accounting record, so ``delta_bytes < full reload`` is asserted on real
read counters, not estimates (``tests/test_fleet_serving.py``,
``benchmarks/bench_fleet.py``).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import pipeline as pl
from repro.runtime import elastic
from repro.serve import transport as tp
from repro.serve.engine import (EngineConfig, Request, Requeued, Result,
                                ServeEngine)


@dataclass(frozen=True)
class ReshardEvent:
    """One completed topology change on a replica."""

    kind: str                         # "host_loss" | "host_join"
    host: int
    delta_bytes: int                  # bytes actually re-streamed
    full_reload_bytes: int            # what a from-scratch boot would read
    blocks_moved: int
    requeued: int                     # in-flight requests drained+resumed
    recovery_s: float
    note: str


class ShardedReplica:
    """One engine replica assembled from per-host expert-block streams.

    Drives the engine through its stepwise session API (``begin`` /
    ``submit`` / ``pump`` / ``take_finished``) so the router can
    interleave scheduling rounds with heartbeats and fault handling; all
    results come back through :meth:`pump`, already stitched across any
    drain/resume cycles.
    """

    def __init__(self, model, directory, *, replica_id: int = 0,
                 num_hosts: int = 2, blocks_per_host: int = 2,
                 verify: bool = True,
                 config: Optional[EngineConfig] = None, **engine_kwargs):
        self.replica_id = replica_id
        self.directory = Path(directory)
        self._verify = verify
        self.alive = True
        self.reshards: List[ReshardEvent] = []
        #: per-uid tokens generated in sessions that were drained away
        self._prior: Dict[object, np.ndarray] = {}
        #: results finished right before a reshard, awaiting the next pump
        self._leftover_results: List[Result] = []

        num_experts, ebytes = pl.artifact_expert_bytes(self.directory)
        self.num_experts = num_experts
        self.assignment = elastic.initial_assignment(
            ebytes, list(range(num_hosts)), blocks_per_host=blocks_per_host)
        self._dense = pl.load_expert_blocks(
            self.directory, (), include_dense=True, verify=verify)[0]
        self.load_stats = dataclasses.replace(self._dense[1])
        self._blocks: Dict[int, Tuple] = {}
        for bi, blk in enumerate(self.assignment.blocks):
            part = pl.load_expert_blocks(self.directory, [blk],
                                         verify=verify)[0]
            self._blocks[bi] = part
            self.load_stats.accumulate(part[1])

        artifact = pl.CompressedArtifact.from_parts(
            self.directory, self._ordered_parts())
        self.engine = ServeEngine.from_artifact(
            model, artifact, config=config, **engine_kwargs)

    # ---- holdings ----
    def _ordered_parts(self) -> List[Tuple]:
        return [self._dense] + [self._blocks[i]
                                for i in range(len(self.assignment.blocks))]

    @property
    def hosts(self) -> Tuple[int, ...]:
        return self.assignment.hosts

    @property
    def busy(self) -> bool:
        return self.alive and self.engine.busy

    # ---- request flow (router-facing) ----
    def submit(self, requests: List[Request]) -> None:
        if not self.alive:
            raise RuntimeError(f"replica {self.replica_id} is dead")
        if not requests:
            return
        if self.engine._session is None:
            self.engine.begin(list(requests))
        else:
            self.engine.submit(list(requests))

    def pump(self) -> List[Result]:
        """One scheduling round; returns requests that finished, with
        pre-drain prefixes stitched back in."""
        if not self.alive:
            return []
        out = list(self._leftover_results)
        self._leftover_results.clear()
        if self.engine._session is None:
            return out
        self.engine.pump()
        out.extend(self._stitch(r) for r in self.engine.take_finished())
        if not self.engine.busy:
            self.engine.collect()     # close the idle session
        return out

    def _stitch(self, r: Result) -> Result:
        prior = self._prior.pop(r.uid, None)
        if prior is None or len(prior) == 0:
            return r
        return Result(uid=r.uid,
                      tokens=np.concatenate([prior,
                                             np.asarray(r.tokens, np.int32)]),
                      prefill_s=r.prefill_s, decode_s=r.decode_s,
                      new_tokens=len(prior) + r.new_tokens,
                      finish_reason=r.finish_reason)

    # ---- failure / elasticity ----
    def kill(self) -> None:
        """Replica-level death: engine and all its state are gone. The
        router requeues this replica's outstanding *originals* (any
        generated prefix died with the replica's memory)."""
        self.alive = False
        self.engine = None
        self._prior.clear()

    def _drain_for_reshard(self) -> List[Requeued]:
        if self.engine._session is None:
            return []
        requeued = self.engine.drain()
        # finished-but-unharvested results survive the reshard; keep them
        # for the next pump() by reopening their session bucket below
        leftovers = [self._stitch(r) for r in self.engine.collect()]
        self._leftover_results.extend(leftovers)
        for rq in requeued:
            prior = self._prior.get(rq.request.uid)
            tokens = np.asarray(rq.prior_tokens, np.int32)
            self._prior[rq.request.uid] = (
                tokens if prior is None or len(prior) == 0
                else np.concatenate([prior, tokens]))
        return requeued

    def _resume(self, requeued: List[Requeued]) -> None:
        conts = [rq.continuation() for rq in requeued]
        if conts:
            self.engine.begin(conts)

    def lose_host(self, host: int) -> ReshardEvent:
        """Live re-shard after losing one host of the replica.

        Raises ``ValueError`` when ``host`` is the last one — the caller
        must treat that as replica death (:meth:`kill`).
        """
        if not self.alive:
            raise RuntimeError(f"replica {self.replica_id} is dead")
        plan = elastic.plan_host_loss(self.assignment, host)
        t0 = time.time()
        requeued = self._drain_for_reshard()
        for mv in plan.moves:
            bi = self.assignment.blocks.index(mv.block)
            part = pl.load_expert_blocks(self.directory, [mv.block],
                                         verify=self._verify)[0]
            self._blocks[bi] = part
            self.load_stats.accumulate(part[1])
        self.assignment = plan.new
        artifact = pl.CompressedArtifact.from_parts(
            self.directory, self._ordered_parts())
        self.engine.params = artifact.params
        self._resume(requeued)
        ev = ReshardEvent(
            kind="host_loss", host=host, delta_bytes=plan.delta_bytes,
            full_reload_bytes=plan.full_reload_bytes,
            blocks_moved=len(plan.moves), requeued=len(requeued),
            recovery_s=time.time() - t0, note=plan.note)
        self.reshards.append(ev)
        return ev

    def join_host(self, host: Optional[int] = None) -> ReshardEvent:
        """Rebalance blocks onto a freshly joined host. Only the joiner
        streams (donors drop their moved blocks); serving continues
        uninterrupted — no drain, no params swap."""
        if not self.alive:
            raise RuntimeError(f"replica {self.replica_id} is dead")
        if host is None:
            host = max(self.assignment.hosts) + 1
        plan = elastic.plan_host_join(self.assignment, host)
        t0 = time.time()
        for mv in plan.moves:
            bi = self.assignment.blocks.index(mv.block)
            part = pl.load_expert_blocks(self.directory, [mv.block],
                                         verify=self._verify)[0]
            self._blocks[bi] = part
            self.load_stats.accumulate(part[1])
        self.assignment = plan.new
        ev = ReshardEvent(
            kind="host_join", host=host, delta_bytes=plan.delta_bytes,
            full_reload_bytes=plan.full_reload_bytes,
            blocks_moved=len(plan.moves), requeued=0,
            recovery_s=time.time() - t0, note=plan.note)
        self.reshards.append(ev)
        return ev


@dataclass
class _PendingResult:
    result: Result
    next_send: int               # next retransmit tick
    interval: int                # doubles per retransmit


class ReplicaNode:
    """The replica-side protocol endpoint over the fleet transport.

    Wraps anything with the replica surface (``replica_id`` / ``alive``
    / ``submit`` / ``pump`` / ``kill``) — a real
    :class:`ShardedReplica` or a test fake — and speaks the
    message protocol with the router:

    * **Idempotent dispatch dedup**: every DISPATCH is ACKed, but a uid
      already seen (a router retransmit after a lost ACK, a transport
      duplicate, a hedge landing twice) is **never** submitted to the
      engine again — a retry must never double-decode. Dedup hits are
      counted (``dedup_hits``) and, when the request already finished,
      answered with an immediate RESULT retransmit.
    * **Results retransmit until acked**: a finished request's RESULT is
      resent with doubling intervals until the router's RESULT_ACK
      arrives, so a dropped result message never strands a completion.
    * **Heartbeats** ride the same (faulty) transport — a partitioned
      replica genuinely looks dead to the router, and the retry/dedup
      machinery is what makes the resulting false positive harmless.
    * ``slowdown`` models a straggler host: the engine only advances
      every ``slowdown``-th tick, and the heartbeat reports the
      slowdown as its logical ``step_s`` so the supervisor's
      z-score detector can flag it (the router's hedging trigger).
    """

    def __init__(self, replica, transport: tp.Transport, *,
                 result_retry: int = 4):
        self.replica = replica
        self.replica_id = replica.replica_id
        self.endpoint = tp.replica_endpoint(replica.replica_id)
        self.transport = transport
        self.result_retry = result_retry
        self.slowdown = 1
        self.dedup_hits = 0
        self._seen: set = set()
        #: uid -> submissions that reached the engine (chaos harness
        #: asserts the max over all uids is 1: no duplicate decode work)
        self.decode_submissions: Dict[object, int] = {}
        self._unacked: Dict[object, _PendingResult] = {}
        self._step = 0

    @property
    def alive(self) -> bool:
        return self.replica.alive

    def _send(self, kind: str, uid=None, payload=None) -> None:
        self.transport.send(tp.Message(
            kind=kind, src=self.endpoint, dst=tp.ROUTER, seq=0,
            uid=uid, payload=payload))

    def _emit_result(self, res: Result, tick: int) -> None:
        pr = self._unacked.get(res.uid)
        if pr is None:
            pr = self._unacked[res.uid] = _PendingResult(
                result=res, next_send=0, interval=self.result_retry)
        self._send(tp.RESULT, uid=res.uid, payload=pr.result)
        pr.next_send = tick + pr.interval
        pr.interval *= 2

    def step(self, tick: int) -> None:
        """One replica scheduling round: drain the inbox (dedup +
        submit), advance the engine (unless straggling), emit finished
        results, retransmit unacked ones, heartbeat."""
        if not self.alive:
            return                     # a dead replica is silent
        self._step += 1
        fresh: List[Request] = []
        for m in self.transport.poll(self.endpoint):
            if m.kind == tp.DISPATCH:
                if m.uid in self._seen:
                    self.dedup_hits += 1
                    self._send(tp.ACK, uid=m.uid)
                    if m.uid in self._unacked:   # already finished here
                        self._emit_result(self._unacked[m.uid].result,
                                          tick)
                else:
                    self._seen.add(m.uid)
                    fresh.append(m.payload)
                    self._send(tp.ACK, uid=m.uid)
            elif m.kind == tp.RESULT_ACK:
                self._unacked.pop(m.uid, None)
        if fresh:
            self.replica.submit(fresh)
            for r in fresh:
                self.decode_submissions[r.uid] = \
                    self.decode_submissions.get(r.uid, 0) + 1
        if tick % max(self.slowdown, 1) == 0:
            for res in self.replica.pump():
                self._emit_result(res, tick)
        for uid, pr in list(self._unacked.items()):
            if tick >= pr.next_send:
                self._emit_result(pr.result, tick)
        self._send(tp.HEARTBEAT,
                   payload={"step": self._step,
                            "step_s": float(self.slowdown)})
