"""Paged KV-cache memory layer: page pool, free-list, refcounts, prefix reuse.

This module is the **host-side allocator** behind the continuous engine's
paged KV cache (``EngineConfig.kv_pool``). The device side — the physical
page arrays and the attention read-through — lives in
:mod:`repro.models.layers.attention` (:class:`PagedKVCache`); the engine
glue (admission, chunked prefill, on-demand growth) in
:mod:`repro.serve.engine`. Everything here is pure Python/numpy and fully
deterministic, which is what makes the hypothesis property harness in
``tests/test_kv_pool.py`` possible.

Model:

* The pool is ``num_pages`` physical pages of ``page_size`` token slots
  each. Page 0 is the reserved **trash page**: it is never allocated, and
  every unused page-table entry points at it, so idle/finished slots'
  decode writes land in storage nobody reads.
* A request owns a **growable page list** (:class:`SlotAlloc`): admission
  allocates just the pages covering the prompt (plus the first decode
  write); decode grows the list on demand, one page at a time, and the
  whole list is released when the request finishes.
* Every page carries a **refcount**. Owned pages have refcount 1 from
  their slot; pages of a shared prompt prefix are refcounted once per
  sharing slot plus once for the prefix cache itself. A page returns to
  the free list exactly when its refcount hits zero.
* The :class:`PrefixCache` remembers **full pages of prompt prefixes**
  (keyed by a hash chain over page-sized token chunks, salted with the
  per-request knobs that change KV content, e.g. the ODP threshold).
  Matching pages are handed to new requests read-only — decode never
  writes into a full prompt page — so system-prompt traffic shares
  storage. Cache-held pages are evicted LRU (deepest chain entries
  first) under pool pressure.

Invariants (the property suite's contract):

1. the free list and the live (refcount > 0) pages partition
   ``{1, ..., num_pages - 1}`` at every step;
2. a page is referenced by two slots only when both hold it as a shared
   prefix page (same content key);
3. refcounts hit zero exactly at release, never below;
4. allocation order is a pure function of the call sequence (no
   randomness, no iteration-order dependence).
"""
from __future__ import annotations

import dataclasses
import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

#: physical page id every unused page-table entry points at; never allocated
TRASH_PAGE = 0

#: storage bits per KV element for each quantization mode
KV_QUANT_BITS = {"off": 16, "int8": 8, "int4": 4}

#: pinned round-trip tolerance of the KV quantizer on *real captured KV*
#: (relative Frobenius error of dequantized vs original cache content).
#: ``tests/test_kv_quant.py`` asserts these bounds on KV captured from a
#: smoke decode, and the serving identity tests reuse them — the tolerance
#: used in serving is the tolerance tested.
KV_QUANT_REL_TOL = {"int8": 0.02, "int4": 0.15}

#: pinned relative logits drift of an int8-KV paged decode vs the bf16
#: contiguous reference (matches the seed ``test_decode_tracks_fp`` bound)
KV_DECODE_REL_TOL = 0.05


@dataclass(frozen=True)
class KVPoolConfig:
    """Configuration of the paged KV memory layer.

    num_pages: physical pages in the pool (page 0 is reserved as the
        trash page, so ``num_pages - 1`` are allocatable).
    page_size: token slots per page.
    quant: ``"off"`` (bf16/f32 storage), ``"int8"`` or ``"int4"`` —
        per-token-per-head absmax quantization (the seed quantizer from
        ``tests/test_kv_quant.py``), scales stored per page alongside the
        codes and folded into the attention math on read.
    prefix_sharing: share full prompt-prefix pages across requests.
    prefill_chunk: when set, prompts prefill in fixed-size chunks
        interleaved with decode steps (one chunk per scheduling round), so
        a long admission no longer stalls the pool. ``None`` = whole-prompt
        prefill (bucketed), the pre-paging behavior.
    """

    num_pages: int
    page_size: int = 16
    quant: str = "off"
    prefix_sharing: bool = True
    prefill_chunk: Optional[int] = None

    def __post_init__(self):
        if self.num_pages < 2:
            raise ValueError(
                f"num_pages must be >= 2 (page 0 is the reserved trash "
                f"page), got {self.num_pages}")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.quant not in KV_QUANT_BITS:
            raise ValueError(
                f"kv quant mode must be one of {sorted(KV_QUANT_BITS)}, "
                f"got {self.quant!r}")
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {self.prefill_chunk}")

    @property
    def bits(self) -> int:
        return KV_QUANT_BITS[self.quant]


@dataclass
class SlotAlloc:
    """One request's growable page list.

    ``pages[:n_shared]`` are read-only prefix pages borrowed from other
    requests / the prefix cache (refcounted, never written); the rest are
    exclusively owned. Logical token index ``t`` lives in
    ``pages[t // page_size]`` at offset ``t % page_size``.
    """

    pages: List[int]
    n_shared: int
    prompt_len: int
    total_tokens: int
    released: bool = False
    #: non-token positions preceding the prompt in this slot's KV layout
    #: (e.g. a VLM's image-prefix span); logical index t of the span is
    #: then ``prefix_tokens + t``
    prefix_tokens: int = 0


@dataclass
class PoolStats:
    allocated_pages: int = 0          # cumulative alloc_one() successes
    shared_pages: int = 0             # cumulative prefix-cache page hits
    evicted_pages: int = 0            # cache entries dropped under pressure
    failed_admits: int = 0            # admissions deferred for lack of pages
    grow_stalls: int = 0              # decode growth deferred


class PagePool:
    """Free-list page allocator with refcounts. Deterministic: the free
    list is LIFO over an initially ascending page order, so a fixed call
    sequence always yields the same page ids."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError(f"need >= 2 pages (one is trash), got "
                             f"{num_pages}")
        self.num_pages = num_pages
        # pop() takes from the end: initial allocation order is 1, 2, ...
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self.refcount = [0] * num_pages

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def usable_pages(self) -> int:
        return self.num_pages - 1

    def alloc_one(self) -> Optional[int]:
        if not self._free:
            return None
        p = self._free.pop()
        assert self.refcount[p] == 0, f"page {p} on free list with refs"
        self.refcount[p] = 1
        return p

    def retain(self, page: int) -> None:
        if page == TRASH_PAGE:
            raise ValueError("cannot retain the trash page")
        if self.refcount[page] <= 0:
            raise ValueError(f"retain of free page {page}")
        self.refcount[page] += 1

    def release(self, page: int) -> None:
        if self.refcount[page] <= 0:
            raise ValueError(f"release of already-free page {page}")
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self._free.append(page)       # LIFO reuse, deterministic

    def live_pages(self) -> List[int]:
        return [p for p in range(1, self.num_pages) if self.refcount[p] > 0]

    def free_pages(self) -> List[int]:
        return list(self._free)


@dataclass
class _CacheEntry:
    page: int
    depth: int                         # chain position (0 = first page)
    last_used: int


class PrefixCache:
    """Content-addressed cache of full prompt-prefix pages.

    Keys are a hash chain over page-sized token chunks (salted with
    ``thr_key``, the per-request knob that changes KV content), so two
    prompts share exactly the pages whose *entire* token prefix matches.
    Each entry holds one pool reference on its page; eviction (LRU,
    deepest-first among equals) drops that reference — the page is only
    actually freed once no slot shares it.

    Within a chain, ``last_used`` of a prefix entry is always >= that of
    its suffix entries (inserts stamp uniformly; matches touch a walked
    prefix), so deepest-first eviction can never orphan a reachable tail.
    """

    def __init__(self, pool: PagePool, page_size: int):
        self.pool = pool
        self.page_size = page_size
        self._entries: Dict[bytes, _CacheEntry] = {}
        self._clock = 0

    def __len__(self) -> int:
        return len(self._entries)

    def _chain_keys(self, prompt: np.ndarray, thr_key: float,
                    n_pages: int, salt: bytes = b"",
                    prefix_tokens: int = 0) -> List[bytes]:
        """Hash chain over the slot's page-sized spans. ``salt`` folds in
        non-prompt content the KV depends on (e.g. the encoder input of an
        enc-dec / VLM request — decoder KV depends on it through the
        residual stream, so pages may only be shared under identical
        encoder input). ``prefix_tokens`` shifts the prompt by a leading
        non-token span: its pages hash as empty chunks, so two requests
        share them exactly when their salt (= prefix content) matches."""
        ps = self.page_size
        h = hashlib.sha1(repr(float(thr_key)).encode() + salt
                         + int(prefix_tokens).to_bytes(4, "little")).digest()
        keys = []
        for i in range(n_pages):
            lo = max(0, i * ps - prefix_tokens)
            hi = max(0, (i + 1) * ps - prefix_tokens)
            chunk = np.ascontiguousarray(
                np.asarray(prompt[lo:hi], np.int32))
            h = hashlib.sha1(h + chunk.tobytes()).digest()
            keys.append(h)
        return keys

    def match(self, prompt: np.ndarray, thr_key: float,
              max_pages: int, salt: bytes = b"",
              prefix_tokens: int = 0) -> List[int]:
        """Longest chain of cached full-prefix pages (<= max_pages). Pure
        lookup plus LRU touch — the caller retains the returned pages."""
        self._clock += 1
        pages = []
        for key in self._chain_keys(prompt, thr_key, max_pages, salt,
                                    prefix_tokens):
            e = self._entries.get(key)
            if e is None:
                break
            e.last_used = self._clock
            pages.append(e.page)
        return pages

    def register(self, prompt: np.ndarray, thr_key: float,
                 pages: List[int], n_pages: int, salt: bytes = b"",
                 prefix_tokens: int = 0) -> None:
        """Insert the first ``n_pages`` full prompt pages of an admitted
        request. New entries take one pool reference; already-cached keys
        are only LRU-touched (their canonical page stays; the request's
        duplicate copy remains slot-owned and dies with the slot)."""
        self._clock += 1
        for depth, key in enumerate(
                self._chain_keys(prompt, thr_key, n_pages, salt,
                                 prefix_tokens)):
            e = self._entries.get(key)
            if e is not None:
                e.last_used = self._clock
                continue
            page = pages[depth]
            self.pool.retain(page)
            self._entries[key] = _CacheEntry(page=page, depth=depth,
                                             last_used=self._clock)

    def evict(self, n_pages: int) -> int:
        """Drop up to ``n_pages`` cache-only pages (refcount == 1, i.e. no
        slot shares them), oldest first and deepest-first among equals so
        a chain's tail always goes before its head. Returns pages freed."""
        victims = sorted(
            (e.last_used, -e.depth, key)
            for key, e in self._entries.items()
            if self.pool.refcount[e.page] == 1)
        freed = 0
        for _, _, key in victims:
            if freed >= n_pages:
                break
            e = self._entries.pop(key)
            self.pool.release(e.page)
            freed += 1
        return freed

    def cached_pages(self) -> List[int]:
        return [e.page for e in self._entries.values()]


class KVBlockManager:
    """Ties the pool and the prefix cache into the engine-facing API:
    ``admit`` / ``ensure`` (on-demand growth) / ``register_prefix`` /
    ``release`` / ``table_row``. All methods are atomic: a failed admit or
    grow leaves pool state unchanged (beyond LRU touches / evictions)."""

    def __init__(self, config: KVPoolConfig):
        self.config = config
        self.page_size = config.page_size
        self.pool = PagePool(config.num_pages)
        self.prefix = (PrefixCache(self.pool, config.page_size)
                       if config.prefix_sharing else None)
        self.stats = PoolStats()

    # ---- sizing ----
    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    @property
    def usable_pages(self) -> int:
        return self.pool.usable_pages

    @property
    def num_free(self) -> int:
        return self.pool.num_free

    def _free_up(self, n: int) -> bool:
        """Ensure >= n free pages, evicting cache-only pages if needed."""
        if self.pool.num_free >= n:
            return True
        if self.prefix is not None:
            self.stats.evicted_pages += self.prefix.evict(
                n - self.pool.num_free)
        return self.pool.num_free >= n

    # ---- request lifecycle ----
    def admit(self, prompt: np.ndarray, total_tokens: int,
              thr_key: float = 0.0, *, salt: bytes = b"",
              prefix_tokens: int = 0) -> Optional[SlotAlloc]:
        """Allocate the pages covering the prompt plus the first decode
        write (logical indices [0, prefix_tokens + len(prompt)]). Returns
        None when the pool cannot serve the request *right now* (queue
        until pages free); raises when the request can **never** fit the
        pool. ``total_tokens`` counts the whole span including any
        leading non-token prefix; ``salt``/``prefix_tokens`` thread into
        the prefix cache keys (see :meth:`PrefixCache._chain_keys`)."""
        ln = int(len(prompt))
        if ln < 1:
            raise ValueError("cannot admit an empty prompt")
        span = prefix_tokens + ln
        if total_tokens < span:
            raise ValueError(f"total_tokens {total_tokens} < prompt span "
                             f"{span}")
        total_pages = self.pages_for(total_tokens)
        if total_pages > self.usable_pages:
            raise ValueError(
                f"request needs {total_pages} KV pages "
                f"({total_tokens} tokens at page_size "
                f"{self.page_size}) but the whole pool holds only "
                f"{self.usable_pages} allocatable pages — enlarge "
                f"KVPoolConfig.num_pages or shorten the request")
        need_now = self.pages_for(span + 1)
        shared: List[int] = []
        if self.prefix is not None:
            # only pages strictly full of prompt tokens are shareable:
            # the page holding index `span` will be written by decode
            shared = self.prefix.match(prompt, thr_key,
                                       span // self.page_size, salt,
                                       prefix_tokens)
        n_new = need_now - len(shared)
        if not self._free_up(n_new):
            self.stats.failed_admits += 1
            return None
        for p in shared:
            self.pool.retain(p)
        pages = list(shared)
        for _ in range(n_new):
            p = self.pool.alloc_one()
            assert p is not None, "free count checked above"
            pages.append(p)
            self.stats.allocated_pages += 1
        self.stats.shared_pages += len(shared)
        return SlotAlloc(pages=pages, n_shared=len(shared), prompt_len=ln,
                         total_tokens=total_tokens,
                         prefix_tokens=prefix_tokens)

    def ensure(self, alloc: SlotAlloc, pos: int) -> bool:
        """Grow ``alloc`` to cover logical token index ``pos``. Returns
        False (and changes nothing but possible evictions) when the pool
        is exhausted — the caller stalls the slot and retries."""
        if pos >= alloc.total_tokens:
            raise ValueError(f"position {pos} outside the allocation's "
                             f"span {alloc.total_tokens}")
        idx = pos // self.page_size
        while len(alloc.pages) <= idx:
            if not self._free_up(1):
                self.stats.grow_stalls += 1
                return False
            p = self.pool.alloc_one()
            assert p is not None
            alloc.pages.append(p)
            self.stats.allocated_pages += 1
        return True

    def register_prefix(self, alloc: SlotAlloc, prompt: np.ndarray,
                        thr_key: float = 0.0, *,
                        salt: bytes = b"") -> None:
        """After prefill lands in the pool: publish the request's full
        prompt pages for sharing."""
        if self.prefix is None:
            return
        n_full = (alloc.prefix_tokens + alloc.prompt_len) // self.page_size
        self.prefix.register(prompt, thr_key, alloc.pages, n_full, salt,
                             alloc.prefix_tokens)

    def release(self, alloc: SlotAlloc) -> None:
        if alloc.released:
            raise ValueError("allocation already released")
        for p in alloc.pages:
            self.pool.release(p)
        alloc.released = True

    def table_row(self, alloc: Optional[SlotAlloc],
                  width: int) -> np.ndarray:
        """(width,) int32 page-table row; unallocated tail -> trash."""
        row = np.full(width, TRASH_PAGE, np.int32)
        if alloc is not None:
            row[:len(alloc.pages)] = alloc.pages
        return row

    # ---- introspection (tests / benchmarks) ----
    def check_invariants(self) -> None:
        """Free list + live pages must partition {1..num_pages-1}; trash
        never allocated; refcounts non-negative."""
        pool = self.pool
        free = pool.free_pages()
        live = pool.live_pages()
        assert TRASH_PAGE not in free and TRASH_PAGE not in live
        assert len(set(free)) == len(free), f"duplicate free pages: {free}"
        assert not (set(free) & set(live)), \
            f"pages both free and live: {set(free) & set(live)}"
        assert sorted(free + live) == list(range(1, pool.num_pages)), (
            f"free+live does not partition the pool: free={sorted(free)} "
            f"live={sorted(live)}")
        assert all(r >= 0 for r in pool.refcount)
        assert all(pool.refcount[p] == 0 for p in free)
        assert all(pool.refcount[p] > 0 for p in live)


# ---------------------------------------------------- shared (cross-KV) pool
@dataclass
class SharedStats:
    hits: int = 0                 # acquire() found a live/cached entry
    misses: int = 0               # acquire() had to compute
    evicted: int = 0              # idle entries dropped over capacity
    peak_refcount: int = 0        # max concurrent sharers of one entry


@dataclass
class _SharedEntry:
    value: object
    refcount: int
    last_used: int


class SharedStatePool:
    """Refcounted pool of admission-computed shared state (the engine's
    ``cross_kv`` kind: encoder-derived cross-attention KV). Entries are
    content-addressed by the request's encoder input, so requests with
    identical encoder input share ONE computed entry — the shared-state
    analogue of :class:`PrefixCache` page sharing. Released entries stay
    cached (refcount 0) up to ``capacity``, evicted LRU beyond it; a
    ``capacity`` of ``None`` never evicts. Pure host-side bookkeeping,
    deterministic like the page pool."""

    def __init__(self, capacity: Optional[int] = 8):
        self.capacity = capacity
        self._entries: Dict[bytes, _SharedEntry] = {}
        self._clock = 0
        self.stats = SharedStats()

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def key_of(array) -> bytes:
        """Content key of an encoder input: bytes + shape + dtype."""
        a = np.ascontiguousarray(np.asarray(array))
        meta = repr((a.shape, str(a.dtype))).encode()
        return hashlib.sha1(a.tobytes() + meta).digest()

    def acquire(self, key: bytes, compute):
        """Return the entry for ``key``, computing it via ``compute()`` on
        a miss, and take one reference. Every acquire must be paired with
        exactly one :meth:`release`."""
        self._clock += 1
        e = self._entries.get(key)
        if e is None:
            self.stats.misses += 1
            e = _SharedEntry(value=compute(), refcount=0,
                             last_used=self._clock)
            self._entries[key] = e
        else:
            self.stats.hits += 1
        e.refcount += 1
        e.last_used = self._clock
        self.stats.peak_refcount = max(self.stats.peak_refcount, e.refcount)
        return e.value

    def refcount(self, key: bytes) -> int:
        e = self._entries.get(key)
        return 0 if e is None else e.refcount

    def release(self, key: bytes) -> None:
        e = self._entries.get(key)
        if e is None or e.refcount <= 0:
            raise ValueError(
                "release of an unacquired shared-state entry")
        e.refcount -= 1
        if e.refcount == 0:
            self._evict_idle()

    def _evict_idle(self) -> None:
        """Keep at most ``capacity`` idle (refcount-0) entries, dropping
        the least recently used first."""
        if self.capacity is None:
            return
        idle = sorted(
            ((e.last_used, key) for key, e in self._entries.items()
             if e.refcount == 0))
        for _, key in idle[:max(0, len(idle) - self.capacity)]:
            del self._entries[key]
            self.stats.evicted += 1

    def check_invariants(self) -> None:
        assert all(e.refcount >= 0 for e in self._entries.values())
        if self.capacity is not None:
            idle = sum(1 for e in self._entries.values() if e.refcount == 0)
            assert idle <= self.capacity, \
                f"{idle} idle shared entries exceed capacity {self.capacity}"


# ------------------------------------------------------------------ sizing
def paged_kv_bytes_per_token(num_kv_heads: int, head_dim: int,
                             quant: str = "off") -> float:
    """Analytic paged KV bytes per token per attention layer (K + V codes
    plus quantization scales; the page table amortizes to ~0)."""
    bits = KV_QUANT_BITS[quant]
    payload = 2 * num_kv_heads * head_dim * bits / 8
    scales = 2 * num_kv_heads * 4 if quant != "off" else 0.0
    return payload + scales


def contiguous_kv_bytes_per_token(num_kv_heads: int, head_dim: int,
                                  dtype_bytes: int = 2) -> float:
    """Contiguous engine KV bytes per token per attention layer: bf16
    K + V rows plus the per-position int32 ``KVCache.pos`` bookkeeping."""
    return 2 * num_kv_heads * head_dim * dtype_bytes + 4
