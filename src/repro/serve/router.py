"""Fleet router: request queue, admission control, dispatch, failover.

The front door of fleet serving. Requests enter a bounded queue
(**admission control**: a full queue sheds the request immediately —
back-pressure beats unbounded latency) with an optional per-request SLA
deadline in ticks; a request whose deadline has already passed when it
reaches the head of the queue is shed rather than dispatched (it could
only waste a slot another request still inside its deadline needs).

Dispatch is least-outstanding-first over the live replicas. The router
drives everything on the **logical clock** (one tick = one scheduling
round = one decode step per replica): each tick it

1. fires due :class:`~repro.runtime.supervisor.FaultInjector` events
   (kill a replica / kill a host / join a host),
2. dispatches queued requests onto live replicas,
3. pumps every live replica one decode step and records completions,
4. beats the :class:`~repro.runtime.supervisor.FleetSupervisor` for the
   live replicas and asks it for newly-dead ones — a dead replica's
   outstanding requests are **requeued from their originals** (its memory
   died with it) and retried on the survivors, up to
   ``max_retries`` per request.

Host-level events are delegated to the replica
(:meth:`~repro.serve.fleet.ShardedReplica.lose_host` /
``join_host``) — the replica stays up, drains, delta-streams, resumes.
A host loss on a 1-host replica degenerates to replica death.

Greedy decode makes every recovery path token-identical to an
uninterrupted run: retried originals re-decode the same stream, drained
continuations resume it exactly (``tests/test_fleet_serving.py``).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional

from repro.runtime.supervisor import (FaultInjector, FleetSupervisor,
                                      JOIN_HOST, KILL_HOST, KILL_REPLICA)
from repro.serve.engine import Request, Result
from repro.serve.fleet import ReshardEvent, ShardedReplica


@dataclass(frozen=True)
class RouterConfig:
    """Admission/failover policy knobs (all times in logical ticks)."""

    max_queue: int = 64               # admission: shed submits beyond this
    default_sla: Optional[int] = None  # completion deadline; None = no SLA
    max_retries: int = 2              # per-request retries after deaths
    heartbeat_timeout: float = 3.0    # ticks of silence => replica dead
    replica_depth: int = 8            # max outstanding per replica; the
    #                                   rest wait in the router queue where
    #                                   deadline shedding still applies
    max_ticks: int = 100_000          # runaway guard for run()


@dataclass
class _Tracked:
    request: Request
    submit_tick: int
    deadline: Optional[int]           # absolute tick; None = no SLA
    retries: int = 0
    replica: Optional[int] = None     # replica id while dispatched


@dataclass
class FleetReport:
    """Everything run() observed, for tests/benchmarks/CLI."""

    submitted: int = 0
    admitted: int = 0
    completed: Dict[object, Result] = field(default_factory=dict)
    shed_queue_full: List[object] = field(default_factory=list)
    shed_deadline: List[object] = field(default_factory=list)
    failed: List[object] = field(default_factory=list)  # retries exhausted
    sla_misses: List[object] = field(default_factory=list)
    deaths: List[Dict] = field(default_factory=list)
    reshards: List[ReshardEvent] = field(default_factory=list)
    retries: int = 0
    ticks: int = 0

    @property
    def availability(self) -> float:
        """Completed fraction of admitted-and-not-shed requests."""
        served = self.admitted - len(self.shed_deadline)
        return len(self.completed) / max(served, 1)


class FleetRouter:
    """Dispatches requests over a pool of :class:`ShardedReplica`."""

    def __init__(self, replicas: List[ShardedReplica], directory, *,
                 config: Optional[RouterConfig] = None,
                 injector: Optional[FaultInjector] = None):
        if not replicas:
            raise ValueError("need at least one replica")
        ids = [r.replica_id for r in replicas]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate replica ids {ids}")
        self.replicas: Dict[int, ShardedReplica] = {
            r.replica_id: r for r in replicas}
        self.config = config or RouterConfig()
        self.injector = injector or FaultInjector([])
        self.supervisor = FleetSupervisor(
            directory=Path(directory),
            timeout=self.config.heartbeat_timeout)
        self.tick = 0
        self.queue: deque = deque()   # _Tracked awaiting dispatch
        self.tracked: Dict[object, _Tracked] = {}
        self.report = FleetReport()

    # ---- admission ----
    def submit(self, request: Request,
               sla: Optional[int] = None) -> bool:
        """Admit ``request`` (optionally overriding the config SLA).
        Returns False when the queue is full — the request is shed, not
        queued (load-shedding is the admission contract)."""
        self.report.submitted += 1
        if len(self.queue) >= self.config.max_queue:
            self.report.shed_queue_full.append(request.uid)
            return False
        sla = self.config.default_sla if sla is None else sla
        tr = _Tracked(request=request, submit_tick=self.tick,
                      deadline=None if sla is None else self.tick + sla)
        self.queue.append(tr)
        self.tracked[request.uid] = tr
        self.report.admitted += 1
        return True

    # ---- internals ----
    def _live(self) -> List[ShardedReplica]:
        return [r for r in self.replicas.values() if r.alive]

    def _outstanding(self, replica_id: int) -> List[_Tracked]:
        return [t for t in self.tracked.values()
                if t.replica == replica_id
                and t.request.uid not in self.report.completed]

    def _dispatch(self) -> None:
        depth = self.config.replica_depth
        while self.queue:
            cands = [r for r in self._live()
                     if len(self._outstanding(r.replica_id)) < depth]
            if not cands:
                return
            tr = self.queue.popleft()
            if tr.deadline is not None and self.tick > tr.deadline:
                # expired before ever reaching a replica: shed, don't burn
                # a slot a within-deadline request could use
                self.report.shed_deadline.append(tr.request.uid)
                del self.tracked[tr.request.uid]
                continue
            dst = min(cands, key=lambda r: (len(self._outstanding(
                r.replica_id)), r.replica_id))
            tr.replica = dst.replica_id
            dst.submit([tr.request])

    def _complete(self, res: Result) -> None:
        tr = self.tracked.get(res.uid)
        self.report.completed[res.uid] = res
        if tr is not None and tr.deadline is not None \
                and self.tick > tr.deadline:
            self.report.sla_misses.append(res.uid)

    def _requeue_from(self, replica_id: int, reason: str) -> None:
        """Retry a dead replica's outstanding requests from their
        originals (front of the queue — they have waited longest)."""
        # reverse order + appendleft => oldest request ends up frontmost
        for tr in sorted(self._outstanding(replica_id),
                         key=lambda t: t.submit_tick, reverse=True):
            if tr.retries >= self.config.max_retries:
                self.report.failed.append(tr.request.uid)
                del self.tracked[tr.request.uid]
                continue
            tr.retries += 1
            tr.replica = None
            self.report.retries += 1
            self.queue.appendleft(tr)
        self.report.deaths.append(
            {"tick": self.tick, "replica": replica_id, "reason": reason})

    def _kill_replica(self, replica_id: int, reason: str) -> None:
        rep = self.replicas.get(replica_id)
        if rep is None or not rep.alive:
            return
        rep.kill()
        # the dead replica stops beating; the supervisor will *detect* it
        # after `heartbeat_timeout` silent ticks and only then does the
        # router requeue — the detection latency is part of the measured
        # recovery, exactly as with a real crashed process

    def _apply_fault(self, ev) -> None:
        rep = self.replicas.get(ev.replica)
        if rep is None or not rep.alive:
            return
        if ev.kind == KILL_REPLICA:
            self._kill_replica(ev.replica, "injected kill")
        elif ev.kind == KILL_HOST:
            try:
                self.report.reshards.append(rep.lose_host(ev.host))
            except ValueError:
                # last host: the replica cannot re-shard, it dies
                self._kill_replica(ev.replica, f"lost last host {ev.host}")
        elif ev.kind == JOIN_HOST:
            try:
                self.report.reshards.append(
                    rep.join_host(None if ev.host in (None, -1)
                                  else ev.host))
            except ValueError:
                pass                  # no improving move: rebalance refused

    # ---- the clock ----
    def step(self) -> None:
        """One scheduling round (one logical tick)."""
        self.tick += 1
        self.report.ticks = self.tick
        for ev in self.injector.due(self.tick):
            self._apply_fault(ev)
        self._dispatch()
        for rep in self._live():
            for res in rep.pump():
                self._complete(res)
            self.supervisor.beat(rep.replica_id, step=self.tick,
                                 now=float(self.tick))
        for replica_id in self.supervisor.check(now=float(self.tick)):
            self._requeue_from(replica_id, "heartbeat timeout")

    @property
    def busy(self) -> bool:
        outstanding = [t for t in self.tracked.values()
                       if t.request.uid not in self.report.completed]
        return bool(self.queue) or bool(outstanding)

    def run(self, requests: List[Request],
            slas: Optional[List[Optional[int]]] = None) -> FleetReport:
        """Submit everything, crank the clock until the fleet is idle (or
        no replica survives), return the report."""
        slas = slas if slas is not None else [None] * len(requests)
        for req, sla in zip(requests, slas):
            self.submit(req, sla=sla)
        while self.busy:
            if not self._live():
                for tr in list(self.tracked.values()):
                    if tr.request.uid not in self.report.completed:
                        self.report.failed.append(tr.request.uid)
                self.tracked.clear()
                self.queue.clear()
                break
            if self.tick >= self.config.max_ticks:
                raise RuntimeError(
                    f"router made no progress in {self.tick} ticks; "
                    "check max_new_tokens vs max_ticks")
            self.step()
        for r in self._live():
            self.supervisor.retire(r.replica_id)
        return self.report
