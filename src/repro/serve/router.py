"""Fleet router: admission, dispatch over an unreliable transport,
retry/backoff, exactly-once completion, circuit breaking, hedging.

The front door of fleet serving. Requests enter a bounded queue
(**admission control**: a full queue sheds the request immediately —
back-pressure beats unbounded latency) with an optional per-request SLA
deadline in ticks; a request whose deadline has already passed when it
reaches the head of the queue is shed rather than dispatched (it could
only waste a slot another request still inside its deadline needs).

All router↔replica traffic is **messages** over a
:class:`~repro.serve.transport.Transport` (``serve.transport``): the
router sends DISPATCH, replicas answer ACK and later RESULT, heartbeats
ride the same channel. Because the transport may lose, delay, duplicate
or reorder anything (``FaultyTransport``), the router is hardened:

* **Per-call timeouts with exponential backoff + jitter** — a DISPATCH
  without an ACK within ``ack_timeout`` ticks is retransmitted with a
  doubling, jittered interval, up to ``dispatch_attempts`` tries.
* **Idempotent dispatch** — replicas dedup by request uid
  (:class:`~repro.serve.fleet.ReplicaNode`), so a retransmit after a
  lost ACK never double-decodes; greedy decode makes any genuine
  re-execution (on another replica) token-identical.
* **At-most-once result stitching** — the first RESULT per uid wins;
  duplicates (retransmits, hedge losers, resurrected replicas) are
  counted and discarded, results for already-shed requests likewise.
* **Circuit breaker per link** — ``breaker_threshold`` consecutive
  dispatch-attempt failures open the link (no traffic); after
  ``breaker_cooldown`` ticks it goes half-open and admits exactly one
  probe dispatch, which closes (success) or re-opens (failure) it.
* **Hedged stragglers** — the supervisor's straggler reports
  (:attr:`~repro.runtime.supervisor.FleetSupervisor.stragglers`, fed by
  the per-replica logical step time in heartbeats) trigger a hedge: the
  straggler's oldest outstanding request is *also* dispatched to the
  least-loaded healthy survivor, and the first completion wins.

Replica death is still detected by heartbeat silence — which a network
partition can now counterfeit. That false positive is deliberate and
harmless: the "dead" replica's requests are requeued from their
originals and retried elsewhere, and when the partition heals the
original's late results are discarded by the at-most-once rule. A beat
from a reported-dead replica resurrects it in the supervisor.

Every admitted request ends in exactly one bucket — completed, shed
(with a reason: ``sla_expired`` / ``retry_exhausted`` / ``link_open``),
or fatal (no replica survived) — and :meth:`FleetReport.check` asserts
that identity at the end of every ``run()``. ``tests/test_chaos.py``
and ``benchmarks/bench_chaos.py`` drive randomized fault schedules
against these invariants.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Set

import numpy as np

from repro.runtime.supervisor import (FaultInjector, FleetSupervisor,
                                      JOIN_HOST, KILL_HOST, KILL_REPLICA,
                                      NET_KINDS, SLOW_REPLICA)
from repro.serve import transport as tp
from repro.serve.engine import Request, Result
from repro.serve.fleet import ReplicaNode, ReshardEvent, ShardedReplica

#: shed reasons (FleetReport.shed keys)
SHED_QUEUE_FULL = "queue_full"      # admission: bounded queue overflow
SHED_SLA = "sla_expired"            # deadline passed before dispatch
SHED_RETRY = "retry_exhausted"      # death-requeue budget exhausted
SHED_LINK = "link_open"             # redispatch budget exhausted on
#                                     repeatedly failing links
SHED_REASONS = (SHED_QUEUE_FULL, SHED_SLA, SHED_RETRY, SHED_LINK)


@dataclass(frozen=True)
class RouterConfig:
    """Admission/failover/transport policy knobs (times in ticks)."""

    max_queue: int = 64               # admission: shed submits beyond this
    default_sla: Optional[int] = None  # completion deadline; None = no SLA
    max_retries: int = 2              # per-request retries after deaths
    heartbeat_timeout: float = 3.0    # ticks of silence => replica dead
    replica_depth: int = 8            # max outstanding per replica; the
    #                                   rest wait in the router queue where
    #                                   deadline shedding still applies
    max_ticks: int = 100_000          # runaway guard for run()
    # -- unreliable-transport hardening --
    ack_timeout: int = 4              # ticks to wait for a dispatch ACK
    dispatch_attempts: int = 3        # sends per dispatch attempt before
    #                                   the link is charged a failure
    retry_jitter: int = 2             # uniform 0..jitter ticks added to
    #                                   each backoff (decorrelates storms)
    seed: int = 0                     # jitter RNG seed (deterministic)
    max_redispatch: int = 16          # failed-link redispatches before the
    #                                   request is shed with 'link_open'
    breaker_threshold: int = 3        # consecutive attempt failures to
    #                                   open a link's circuit breaker
    breaker_cooldown: int = 8         # open ticks before half-open probe
    hedge: bool = True                # hedge straggler requests onto the
    #                                   least-loaded healthy survivor


@dataclass
class _Tracked:
    request: Request
    submit_tick: int
    deadline: Optional[int]           # absolute tick; None = no SLA
    retries: int = 0                  # death-requeue count
    redispatches: int = 0             # failed-link redispatch count
    assigned: Set[int] = field(default_factory=set)  # replicas working it
    hedged: bool = False
    hedge_target: Optional[int] = None


@dataclass
class _Attempt:
    """One outstanding DISPATCH awaiting its ACK."""

    uid: object
    replica: int
    tries: int
    next_retx: int


@dataclass
class _Breaker:
    """Per-link circuit breaker state."""

    state: str = "closed"             # closed | open | half_open
    failures: int = 0                 # consecutive attempt failures
    opened_at: int = 0
    probe_uid: Optional[object] = None  # the single half-open probe


@dataclass
class FleetReport:
    """Everything run() observed, for tests/benchmarks/CLI.

    Accounting contract (:meth:`check`): every admitted request lands in
    exactly one of ``completed``, ``shed[sla_expired]``,
    ``shed[retry_exhausted]``, ``shed[link_open]`` or ``fatal``; queue
    overflow sheds (``shed[queue_full]``) are counted in ``submitted``
    but never admitted."""

    submitted: int = 0
    admitted: int = 0
    completed: Dict[object, Result] = field(default_factory=dict)
    shed: Dict[str, List] = field(
        default_factory=lambda: {r: [] for r in SHED_REASONS})
    fatal: List[object] = field(default_factory=list)  # no replica left
    sla_misses: List[object] = field(default_factory=list)
    deaths: List[Dict] = field(default_factory=list)
    reshards: List[ReshardEvent] = field(default_factory=list)
    retries: int = 0                  # death requeues
    ticks: int = 0
    # -- transport-era accounting --
    redispatches: int = 0             # dispatch attempts that gave up
    dedup_hits: int = 0               # duplicate deliveries absorbed by
    #                                   replica-side dedup (no re-decode)
    duplicate_results: int = 0        # at-most-once discards
    ghost_results: int = 0            # results for already-shed requests
    hedges: int = 0
    hedge_wins: int = 0               # completions won by the hedge copy
    completion_ticks: Dict[object, int] = field(default_factory=dict)
    breaker_events: List[Dict] = field(default_factory=list)
    transport: Dict = field(default_factory=dict)   # TransportStats dump

    # -- legacy views (PR 7 field names) --
    @property
    def shed_queue_full(self) -> List[object]:
        return self.shed[SHED_QUEUE_FULL]

    @property
    def shed_deadline(self) -> List[object]:
        return self.shed[SHED_SLA]

    @property
    def failed(self) -> List[object]:
        """Terminally unserved admitted requests: retry/redispatch budget
        exhausted, or the whole fleet died."""
        return self.shed[SHED_RETRY] + self.shed[SHED_LINK] + \
            list(self.fatal)

    @property
    def availability(self) -> float:
        """Completed fraction of admitted-and-not-deadline-shed."""
        served = self.admitted - len(self.shed[SHED_SLA])
        return len(self.completed) / max(served, 1)

    def check(self) -> "FleetReport":
        """Assert the accounting identity — ``admitted == completed +
        shed(post-admission) + fatal``, ``submitted == admitted +
        shed[queue_full]``, all buckets disjoint. Raises ``ValueError``
        naming the imbalance; returns ``self`` for chaining."""
        buckets = {
            "completed": list(self.completed),
            f"shed[{SHED_SLA}]": self.shed[SHED_SLA],
            f"shed[{SHED_RETRY}]": self.shed[SHED_RETRY],
            f"shed[{SHED_LINK}]": self.shed[SHED_LINK],
            "fatal": self.fatal,
        }
        sizes = {k: len(v) for k, v in buckets.items()}
        seen: Dict[object, str] = {}
        for name, uids in buckets.items():
            for uid in uids:
                if uid in seen:
                    raise ValueError(
                        f"report accounting violated: request {uid!r} is "
                        f"in both {seen[uid]} and {name}")
                seen[uid] = name
        total = sum(sizes.values())
        if total != self.admitted:
            raise ValueError(
                "report accounting violated: admitted "
                f"({self.admitted}) != completed + shed + fatal "
                f"({total}: {sizes})")
        if self.admitted + len(self.shed[SHED_QUEUE_FULL]) != \
                self.submitted:
            raise ValueError(
                f"report accounting violated: submitted "
                f"({self.submitted}) != admitted ({self.admitted}) + "
                f"shed[{SHED_QUEUE_FULL}] "
                f"({len(self.shed[SHED_QUEUE_FULL])})")
        return self


class FleetRouter:
    """Dispatches requests over :class:`ShardedReplica`\\ s through a
    message :class:`~repro.serve.transport.Transport`."""

    def __init__(self, replicas: List[ShardedReplica], directory, *,
                 config: Optional[RouterConfig] = None,
                 injector: Optional[FaultInjector] = None,
                 transport: Optional[tp.Transport] = None):
        if not replicas:
            raise ValueError("need at least one replica")
        ids = [r.replica_id for r in replicas]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate replica ids {ids}")
        self.replicas: Dict[int, ShardedReplica] = {
            r.replica_id: r for r in replicas}
        self.config = config or RouterConfig()
        self.injector = injector or FaultInjector([])
        self.transport = transport if transport is not None \
            else tp.FaultyTransport()
        self.nodes: Dict[int, ReplicaNode] = {
            r.replica_id: ReplicaNode(r, self.transport)
            for r in replicas}
        self.supervisor = FleetSupervisor(
            directory=Path(directory),
            timeout=self.config.heartbeat_timeout)
        self.tick = 0
        self.queue: deque = deque()   # _Tracked awaiting dispatch
        self.tracked: Dict[object, _Tracked] = {}
        self.report = FleetReport()
        self._inflight: Dict[tuple, _Attempt] = {}  # (uid, rid) -> attempt
        self._breakers: Dict[int, _Breaker] = {
            rid: _Breaker() for rid in self.replicas}
        self._shed_uids: Set[object] = set()
        self._rng = np.random.RandomState(self.config.seed)
        self._straggler_cursor = 0

    # ---- admission ----
    def submit(self, request: Request,
               sla: Optional[int] = None) -> bool:
        """Admit ``request`` (optionally overriding the config SLA).
        Returns False when the queue is full — the request is shed, not
        queued (load-shedding is the admission contract)."""
        self.report.submitted += 1
        if len(self.queue) >= self.config.max_queue:
            self.report.shed[SHED_QUEUE_FULL].append(request.uid)
            return False
        sla = self.config.default_sla if sla is None else sla
        tr = _Tracked(request=request, submit_tick=self.tick,
                      deadline=None if sla is None else self.tick + sla)
        self.queue.append(tr)
        self.tracked[request.uid] = tr
        self.report.admitted += 1
        return True

    # ---- internals ----
    def _live(self) -> List[ReplicaNode]:
        return [n for n in self.nodes.values() if n.alive]

    def _load(self, replica_id: int) -> int:
        return sum(1 for t in self.tracked.values()
                   if replica_id in t.assigned
                   and t.request.uid not in self.report.completed)

    def _jitter(self) -> int:
        j = self.config.retry_jitter
        return int(self._rng.randint(0, j + 1)) if j > 0 else 0

    def _shed(self, tr: _Tracked, reason: str) -> None:
        uid = tr.request.uid
        self.report.shed[reason].append(uid)
        self._shed_uids.add(uid)
        self.tracked.pop(uid, None)
        try:
            self.queue.remove(tr)
        except ValueError:
            pass

    # ---- circuit breaker ----
    def _breaker_allows(self, replica_id: int) -> bool:
        b = self._breakers[replica_id]
        if b.state == "closed":
            return True
        if b.state == "open":
            if self.tick - b.opened_at >= self.config.breaker_cooldown:
                b.state = "half_open"
                b.probe_uid = None
                self.report.breaker_events.append(
                    {"tick": self.tick, "replica": replica_id,
                     "state": "half_open"})
                return True
            return False
        return b.probe_uid is None        # half_open: one probe at a time

    def _breaker_success(self, replica_id: int) -> None:
        b = self._breakers[replica_id]
        b.failures = 0
        if b.state != "closed":
            b.state = "closed"
            b.probe_uid = None
            self.report.breaker_events.append(
                {"tick": self.tick, "replica": replica_id,
                 "state": "closed"})

    def _breaker_failure(self, replica_id: int) -> None:
        b = self._breakers[replica_id]
        b.failures += 1
        reopen = b.state == "half_open"
        if reopen or (b.state == "closed"
                      and b.failures >= self.config.breaker_threshold):
            b.state = "open"
            b.opened_at = self.tick
            b.probe_uid = None
            self.report.breaker_events.append(
                {"tick": self.tick, "replica": replica_id,
                 "state": "open",
                 "reason": ("failed half-open probe" if reopen else
                            f"{b.failures} consecutive timeouts")})

    # ---- dispatch ----
    def _assign(self, tr: _Tracked, replica_id: int) -> None:
        uid = tr.request.uid
        tr.assigned.add(replica_id)
        b = self._breakers[replica_id]
        if b.state == "half_open":
            b.probe_uid = uid
        self._inflight[(uid, replica_id)] = _Attempt(
            uid=uid, replica=replica_id, tries=1,
            next_retx=self.tick + self.config.ack_timeout + self._jitter())
        self.transport.send(tp.Message(
            kind=tp.DISPATCH, src=tp.ROUTER,
            dst=tp.replica_endpoint(replica_id), seq=0, uid=uid,
            payload=tr.request))

    def _dispatch(self) -> None:
        depth = self.config.replica_depth
        while self.queue:
            cands = [n for n in self._live()
                     if self._breaker_allows(n.replica_id)
                     and self._load(n.replica_id) < depth]
            if not cands:
                return
            tr = self.queue.popleft()
            uid = tr.request.uid
            if uid in self.report.completed or uid in self._shed_uids:
                continue              # finished/given up while queued
            if tr.deadline is not None and self.tick > tr.deadline:
                # expired before ever reaching a replica: shed, don't burn
                # a slot a within-deadline request could use
                self._shed(tr, SHED_SLA)
                continue
            dst = min(cands, key=lambda n: (self._load(n.replica_id),
                                            n.replica_id))
            self._assign(tr, dst.replica_id)

    # ---- inbox ----
    def _on_ack(self, uid, replica_id: int) -> None:
        self._inflight.pop((uid, replica_id), None)
        self._breaker_success(replica_id)

    def _complete(self, res: Result, src_replica: int) -> None:
        uid = res.uid
        if uid in self.report.completed:
            self.report.duplicate_results += 1
            return
        if uid in self._shed_uids:
            self.report.ghost_results += 1   # we gave up on it already
            return
        tr = self.tracked.get(uid)
        self.report.completed[uid] = res
        self.report.completion_ticks[uid] = self.tick
        if tr is not None:
            if tr.deadline is not None and self.tick > tr.deadline:
                self.report.sla_misses.append(uid)
            if tr.hedged and src_replica == tr.hedge_target:
                self.report.hedge_wins += 1
        for key in [k for k in self._inflight if k[0] == uid]:
            del self._inflight[key]

    def _recv(self) -> None:
        for m in self.transport.poll(tp.ROUTER):
            rid = tp.endpoint_replica(m.src)
            if m.kind == tp.ACK:
                self._on_ack(m.uid, rid)
            elif m.kind == tp.RESULT:
                self._on_ack(m.uid, rid)     # a result implies receipt
                self._complete(m.payload, rid)
                self.transport.send(tp.Message(
                    kind=tp.RESULT_ACK, src=tp.ROUTER, dst=m.src,
                    seq=0, uid=m.uid))
            elif m.kind == tp.HEARTBEAT:
                hb = m.payload or {}
                self.supervisor.beat(
                    rid, step=int(hb.get("step", 0)),
                    now=float(self.tick), step_s=hb.get("step_s"))

    # ---- timeouts / retransmits ----
    def _retransmit(self) -> None:
        cfg = self.config
        for key, att in list(self._inflight.items()):
            uid, rid = key
            if uid in self.report.completed or uid in self._shed_uids:
                del self._inflight[key]
                continue
            if self.tick < att.next_retx:
                continue
            node = self.nodes.get(rid)
            tr = self.tracked.get(uid)
            if node is None or not node.alive or tr is None:
                del self._inflight[key]   # death path handles requeue
                continue
            if att.tries >= cfg.dispatch_attempts:
                # the whole attempt failed: no ACK after every try
                del self._inflight[key]
                self._breaker_failure(rid)
                tr.assigned.discard(rid)
                tr.redispatches += 1
                self.report.redispatches += 1
                if tr.redispatches > cfg.max_redispatch:
                    self._shed(tr, SHED_LINK)
                elif not tr.assigned and tr not in self.queue:
                    self.queue.appendleft(tr)
                continue
            att.tries += 1
            backoff = cfg.ack_timeout * (2 ** (att.tries - 1))
            att.next_retx = self.tick + backoff + self._jitter()
            self.transport.send(tp.Message(
                kind=tp.DISPATCH, src=tp.ROUTER,
                dst=tp.replica_endpoint(rid), seq=0, uid=uid,
                payload=tr.request))

    # ---- hedging ----
    def _hedge(self) -> None:
        if not self.config.hedge:
            self._straggler_cursor = len(self.supervisor.stragglers)
            return
        entries = self.supervisor.stragglers
        while self._straggler_cursor < len(entries):
            e = entries[self._straggler_cursor]
            self._straggler_cursor += 1
            rid = e["replica"]
            node = self.nodes.get(rid)
            if node is None or not node.alive:
                continue
            cands = [t for t in self.tracked.values()
                     if t.assigned == {rid} and not t.hedged
                     and t.request.uid not in self.report.completed]
            if not cands:
                continue
            tr = min(cands, key=lambda t: t.submit_tick)
            targets = [n for n in self._live()
                       if n.replica_id not in tr.assigned
                       and self._breakers[n.replica_id].state == "closed"]
            if not targets:
                continue
            dst = min(targets, key=lambda n: (self._load(n.replica_id),
                                              n.replica_id))
            tr.hedged = True
            tr.hedge_target = dst.replica_id
            self.report.hedges += 1
            self._assign(tr, dst.replica_id)

    # ---- failure handling ----
    def _requeue_from(self, replica_id: int, reason: str) -> None:
        """Retry a dead replica's outstanding requests from their
        originals (front of the queue — they have waited longest). A
        request hedged onto a surviving replica is left with the hedge;
        one out of death-retries is shed with ``retry_exhausted``."""
        victims = [t for t in self.tracked.values()
                   if replica_id in t.assigned
                   and t.request.uid not in self.report.completed]
        # reverse order + appendleft => oldest request ends up frontmost
        for tr in sorted(victims, key=lambda t: t.submit_tick,
                         reverse=True):
            tr.assigned.discard(replica_id)
            self._inflight.pop((tr.request.uid, replica_id), None)
            if tr.assigned:
                continue              # the hedge copy is still running
            if tr.retries >= self.config.max_retries:
                self._shed(tr, SHED_RETRY)
                continue
            tr.retries += 1
            self.report.retries += 1
            self.queue.appendleft(tr)
        self.report.deaths.append(
            {"tick": self.tick, "replica": replica_id, "reason": reason})

    def _kill_replica(self, replica_id: int, reason: str) -> None:
        rep = self.replicas.get(replica_id)
        if rep is None or not rep.alive:
            return
        rep.kill()
        # the dead replica stops beating; the supervisor will *detect* it
        # after `heartbeat_timeout` silent ticks and only then does the
        # router requeue — the detection latency is part of the measured
        # recovery, exactly as with a real crashed process

    def _apply_fault(self, ev) -> None:
        if ev.kind in NET_KINDS:
            if not hasattr(self.transport, "inject"):
                raise ValueError(
                    f"fault {ev.kind!r} needs a fault-injectable "
                    f"transport (FaultyTransport); got "
                    f"{type(self.transport).__name__}")
            self.transport.inject(ev)
            return
        if ev.kind == SLOW_REPLICA:
            node = self.nodes.get(ev.replica)
            if node is not None and node.alive:
                node.slowdown = int(ev.factor)
            return
        rep = self.replicas.get(ev.replica)
        if rep is None or not rep.alive:
            return
        if ev.kind == KILL_REPLICA:
            self._kill_replica(ev.replica, "injected kill")
        elif ev.kind == KILL_HOST:
            try:
                self.report.reshards.append(rep.lose_host(ev.host))
            except ValueError:
                # last host: the replica cannot re-shard, it dies
                self._kill_replica(ev.replica, f"lost last host {ev.host}")
        elif ev.kind == JOIN_HOST:
            try:
                self.report.reshards.append(
                    rep.join_host(None if ev.host in (None, -1)
                                  else ev.host))
            except ValueError:
                pass                  # no improving move: rebalance refused

    # ---- the clock ----
    def step(self) -> None:
        """One scheduling round (one logical tick): faults fire, the
        transport clock advances, the router drains its inbox, handles
        timeouts/hedges/dispatches, every live replica endpoint steps,
        and heartbeat silence is checked last."""
        self.tick += 1
        self.report.ticks = self.tick
        for ev in self.injector.due(self.tick):
            self._apply_fault(ev)
        self.transport.advance(self.tick)
        self._recv()
        self._retransmit()
        self._hedge()
        self._dispatch()
        for node in self._live():
            node.step(self.tick)
        for replica_id in self.supervisor.check(now=float(self.tick)):
            self._requeue_from(replica_id, "heartbeat timeout")
        self.report.dedup_hits = sum(n.dedup_hits
                                     for n in self.nodes.values())

    @property
    def busy(self) -> bool:
        outstanding = [t for t in self.tracked.values()
                       if t.request.uid not in self.report.completed]
        return bool(self.queue) or bool(outstanding)

    def run(self, requests: List[Request],
            slas: Optional[List[Optional[int]]] = None) -> FleetReport:
        """Submit everything, crank the clock until the fleet is idle (or
        no replica survives), validate the accounting identity, return
        the report."""
        slas = slas if slas is not None else [None] * len(requests)
        for req, sla in zip(requests, slas):
            self.submit(req, sla=sla)
        while self.busy:
            if not self._live():
                for tr in list(self.tracked.values()):
                    if tr.request.uid not in self.report.completed:
                        self.report.fatal.append(tr.request.uid)
                self.tracked.clear()
                self.queue.clear()
                break
            if self.tick >= self.config.max_ticks:
                raise RuntimeError(
                    f"router made no progress in {self.tick} ticks; "
                    "check max_new_tokens vs max_ticks")
            self.step()
        for n in self._live():
            self.supervisor.retire(n.replica_id)
        stats = getattr(self.transport, "stats", None)
        if stats is not None:
            self.report.transport = stats.to_dict()
        return self.report.check()
