"""Family-agnostic per-slot state layer for the serving engines.

Every model family carries some per-request ("per-slot") state across
decode steps; what differs between families is the *kind* of state, not
the engine logic around it. This module names the kinds, derives each
family's bundle from its :class:`~repro.config.ModelConfig`, and provides
the generic tree operations the continuous engine programs against — so
adding a family means adding a descriptor row here, not forking the
engine's admit/insert/drain/collect paths.

State kinds:

============  ==========================================  ============
kind          what it is                                  capability
============  ==========================================  ============
``attn_kv``   attention K/V rows, one per position        pageable
``ssm``       Mamba recurrent state (conv window + h)     recurrent
``cross_kv``  encoder-derived cross-attention K/V,        shared
              computed once at admission
============  ==========================================  ============

* **pageable** state grows with the sequence, so it can live in paged
  block pools behind a page table (:mod:`repro.serve.kv_pool`).
* **recurrent** state is fixed-size per slot and rewritten every token;
  it rides the slot pool as a dense batch-axis entry with per-row
  lifetimes, and is **zero-reset** (not position-voided) between
  requests — there is no position index to invalidate.
* **shared** state is a pure function of the request's encoder input:
  computed once at admission and refcount-shared across requests with
  identical input (:class:`repro.serve.kv_pool.SharedStatePool`).

Per-family bundles (``state_kinds``):

=========  ==========================  =====================================
family     kinds                       per-slot layout in the engine
=========  ==========================  =====================================
dense/moe  attn_kv                     contiguous rows or paged pools
vlm        attn_kv                     ditto; image prefix occupies the
                                       leading ``num_prefix_tokens`` slots
ssm        ssm                         dense state pool, per-row lifetimes
hybrid     ssm + attn_kv               dense SSM pool + (paged) shared-block
                                       KV, one pool per attention group
encdec     attn_kv + cross_kv          (paged) decoder self-attn KV +
                                       refcounted cross-KV pool entries
=========  ==========================  =====================================
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import attention as attn_lib
from repro.models.layers import ssm as ssm_lib

KNOWN_FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclass(frozen=True)
class StateKind:
    """One kind of per-slot state and its engine-facing capabilities."""

    name: str
    pageable: bool = False      # can live in paged block pools
    recurrent: bool = False     # fixed-size, rewritten every token
    shared: bool = False        # admission-computed, refcount-shared

    def capabilities(self) -> str:
        caps = [c for c in ("pageable", "recurrent", "shared")
                if getattr(self, c)]
        return ", ".join(caps) or "plain"


ATTN_KV = StateKind("attn_kv", pageable=True)
SSM = StateKind("ssm", recurrent=True)
CROSS_KV = StateKind("cross_kv", shared=True)


def state_kinds(cfg) -> Tuple[StateKind, ...]:
    """The per-slot state bundle of a model family, from its config."""
    fam = cfg.family
    if fam == "ssm":
        return (SSM,)
    if fam == "hybrid":
        # the weight-shared attention block runs between Mamba groups even
        # when shared_attn_period is 0 (one trailing block)
        return (SSM, ATTN_KV)
    if fam == "encdec":
        return (ATTN_KV, CROSS_KV)
    if fam in ("dense", "moe", "vlm"):
        return (ATTN_KV,)
    raise ValueError(
        f"unknown model family {fam!r}; known families: "
        f"{', '.join(KNOWN_FAMILIES)}")


@dataclass(frozen=True)
class SlotStateSpec:
    """The engine's view of one model family's slot state: which kinds it
    carries and therefore which engine capabilities apply. Built once at
    engine construction; the admit/insert/drain paths branch on the
    capability flags instead of on family names."""

    family: str
    kinds: Tuple[StateKind, ...]

    @classmethod
    def from_config(cls, cfg) -> "SlotStateSpec":
        return cls(family=cfg.family, kinds=state_kinds(cfg))

    @property
    def has_pageable(self) -> bool:
        return any(k.pageable for k in self.kinds)

    @property
    def has_recurrent(self) -> bool:
        return any(k.recurrent for k in self.kinds)

    @property
    def has_shared(self) -> bool:
        return any(k.shared for k in self.kinds)

    def describe(self) -> str:
        """Human-readable kind list for error messages: e.g.
        ``"ssm (recurrent), attn_kv (pageable)"``."""
        return ", ".join(f"{k.name} ({k.capabilities()})"
                         for k in self.kinds)


# ------------------------------------------------------- generic tree ops
#: pytree leaf types holding per-slot state (CrossKV is a plain NamedTuple
#: of arrays and needs no special-casing in any of the ops below)
STATE_LEAF_TYPES = (attn_lib.KVCache, attn_lib.PagedKVCache,
                    ssm_lib.SSMState)


def is_state_leaf(x) -> bool:
    return isinstance(x, STATE_LEAF_TYPES)


def insert_row(pool, one, slot):
    """Scatter row 0 of a batch-1 state bundle into row ``slot`` of the
    pool bundle. Every pool leaf carries batch at axis 1 (axis 0 is the
    model's layer/step/group stacking) for all state kinds alike, so one
    ``dynamic_update_slice`` shape covers KV rows, SSM state and cross-KV
    entries. The engines jit this with donation so the pool updates in
    place on accelerators."""
    return jax.tree.map(
        lambda pl, on: jax.lax.dynamic_update_slice(
            pl, on.astype(pl.dtype),
            (0, slot) + (0,) * (pl.ndim - 2)),
        pool, one)


def reset_recurrent(caches):
    """Zero every recurrent (``SSMState``) leaf, leaving other kinds
    untouched — the per-kind reset that makes the batch-1 admission
    scratch reusable for SSM/hybrid families: attention entries are
    position-voided by :func:`void_attention_tail`, recurrent entries are
    zero-filled here. Jitted with donation this is an in-place fill."""
    def fix(c):
        if isinstance(c, ssm_lib.SSMState):
            return ssm_lib.SSMState(jnp.zeros_like(c.conv),
                                    jnp.zeros_like(c.h))
        return c
    return jax.tree.map(
        fix, caches, is_leaf=lambda c: isinstance(c, ssm_lib.SSMState))


def void_attention_tail(caches, length):
    """Invalidate attention KV entries at positions ``>= length`` (the
    padded prefill tail, or a reused scratch's stale entries): a voided
    entry (``pos = -1``) is never attended. Recurrent and paged leaves
    pass through — recurrent state has no positions to void, and paged
    pools are written through the page table, never via padding."""
    def fix(c):
        if isinstance(c, attn_lib.KVCache):
            return dataclasses.replace(
                c, pos=jnp.where(c.pos >= length, -1, c.pos))
        return c
    return jax.tree.map(
        fix, caches, is_leaf=lambda c: isinstance(c, attn_lib.KVCache))


# --------------------------------------------------------------- sizing
def _attention_layer_count(cfg) -> int:
    if cfg.family == "hybrid":
        period = cfg.shared_attn_period or cfg.num_layers
        return cfg.num_layers // period      # one shared block per group
    if cfg.family == "ssm":
        return 0
    return cfg.num_layers


def state_bytes_per_slot(cfg, capacity: int,
                         kv_cfg=None) -> Dict[str, float]:
    """Analytic bytes of per-slot state, keyed by state-kind name — the
    serving benchmark's family-sweep metric. ``capacity`` is the slot's
    logical token span; a :class:`~repro.serve.kv_pool.KVPoolConfig`
    switches ``attn_kv`` to paged accounting (quantized storage, scales
    included)."""
    from repro.serve import kv_pool as kvp

    dtype_bytes = 4 if cfg.dtype == "float32" else 2
    out: Dict[str, float] = {}
    for kind in state_kinds(cfg):
        if kind is ATTN_KV:
            n_layers = _attention_layer_count(cfg)
            if kv_cfg is not None:
                per_tok = kvp.paged_kv_bytes_per_token(
                    cfg.num_kv_heads, cfg.head_dim, kv_cfg.quant)
            else:
                per_tok = kvp.contiguous_kv_bytes_per_token(
                    cfg.num_kv_heads, cfg.head_dim, dtype_bytes)
            out[kind.name] = per_tok * capacity * n_layers
        elif kind is SSM:
            inner = cfg.d_model * cfg.ssm_expand
            # the conv window matches the activation dtype (see
            # ssm.init_ssm_state); h is always f32
            conv = (cfg.ssm_conv - 1) * inner * dtype_bytes
            if cfg.ssm_type == "mamba1":
                h = inner * cfg.ssm_state * 4              # f32
            else:
                nh = inner // cfg.ssm_head_dim
                h = nh * cfg.ssm_head_dim * cfg.ssm_state * 4
            out[kind.name] = float((conv + h) * cfg.num_layers)
        elif kind is CROSS_KV:
            out[kind.name] = float(
                2 * cfg.encoder_seq * cfg.num_kv_heads * cfg.head_dim
                * dtype_bytes * cfg.num_layers)
    return out
