"""Message transport between the fleet router and its replicas.

PR 7's router called replicas as in-process Python objects — correct,
but silent about every failure mode a real deployment sees first: lost,
delayed, duplicated and reordered messages. This module makes all
router↔replica traffic explicit :class:`Message`\\ s over a
:class:`Transport`:

* :class:`LocalTransport` — the in-process reference transport:
  reliable, in-order, delivered at the receiver's next poll. The
  router's scheduling tick drives delivery (``advance(tick)``), so the
  whole protocol runs on the fleet's deterministic logical clock.
* :class:`FaultyTransport` — the same queues with **message-level fault
  injection** on top: per-link drops, fixed/variable delays, duplicates,
  reorders and full partitions, from a scripted schedule
  (:meth:`~FaultyTransport.inject`, fed by the router's
  :class:`~repro.runtime.supervisor.FaultInjector`) and/or a
  seeded-random :class:`ChaosConfig`. Every decision comes from one
  ``numpy.random.RandomState``, so a chaos schedule is exactly
  reproducible from its seed — the property ``tests/test_chaos.py`` and
  ``benchmarks/bench_chaos.py`` build on.

The protocol the router/replica endpoints speak over this channel
(DISPATCH/ACK retransmits with backoff, request dedup, RESULT
retransmit-until-acked, heartbeats) lives in ``serve.router`` and
``serve.fleet.ReplicaNode``; this module only moves messages. Any
future real-network transport (TCP, RPC mesh) plugs in by implementing
``send``/``poll``/``advance`` — the router code does not change.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

#: message kinds (router -> replica)
DISPATCH = "dispatch"            # payload: serve.engine.Request
RESULT_ACK = "result_ack"        # uid: acknowledged result
#: message kinds (replica -> router)
ACK = "ack"                      # uid: dispatch received (idempotent)
RESULT = "result"                # payload: serve.engine.Result
HEARTBEAT = "heartbeat"          # payload: {"step": int, "step_s": float}

ROUTER = "router"


def replica_endpoint(replica_id: int) -> str:
    return f"replica:{replica_id}"


def endpoint_replica(endpoint: str) -> Optional[int]:
    """The replica id a link touches (None for the router endpoint)."""
    if endpoint.startswith("replica:"):
        return int(endpoint.split(":", 1)[1])
    return None


@dataclass(frozen=True)
class Message:
    """One protocol message on a router↔replica link."""

    kind: str
    src: str
    dst: str
    seq: int                     # transport-assigned send order
    uid: Any = None              # request uid (all kinds but HEARTBEAT)
    payload: Any = None          # Request / Result / heartbeat dict

    def link(self) -> Optional[int]:
        """The replica id of the link this message travels on."""
        r = endpoint_replica(self.src)
        return r if r is not None else endpoint_replica(self.dst)


@dataclass
class TransportStats:
    """What the transport did to the traffic (reported per run)."""

    sent: int = 0
    delivered: int = 0
    dropped: int = 0             # random drops + scripted one-tick drops
    partition_dropped: int = 0   # dropped inside a partition window
    duplicated: int = 0
    delayed: int = 0             # messages given a non-zero extra delay
    reordered_polls: int = 0     # polls whose batch was shuffled
    by_kind: Dict[str, int] = field(default_factory=dict)

    def to_dict(self) -> Dict:
        return {
            "sent": self.sent, "delivered": self.delivered,
            "dropped": self.dropped,
            "partition_dropped": self.partition_dropped,
            "duplicated": self.duplicated, "delayed": self.delayed,
            "reordered_polls": self.reordered_polls,
            "by_kind": dict(self.by_kind),
        }


class Transport:
    """Interface: ``send`` a message, ``poll`` an endpoint's due inbox,
    ``advance`` the logical clock. Implementations must deliver each
    *kept* message exactly once per enqueued copy and never invent
    messages — loss/duplication semantics live in the implementation,
    correctness under them lives in the protocol above."""

    def send(self, msg: Message) -> None:
        raise NotImplementedError

    def poll(self, endpoint: str) -> List[Message]:
        raise NotImplementedError

    def advance(self, tick: int) -> None:
        raise NotImplementedError


class LocalTransport(Transport):
    """Reliable in-process transport on the router's logical clock.

    A message sent at tick ``t`` is deliverable at any ``poll`` at tick
    ``>= t`` — within the router's fixed phase order that means the
    router's sends reach a replica the same tick, and a replica's
    replies reach the router next tick (the router polls first). FIFO
    per link; delivery order is the global send order."""

    def __init__(self):
        self._now = 0
        self._seq = 0
        self._boxes: Dict[str, List[Tuple[int, int, Message]]] = {}
        self.stats = TransportStats()

    # -- clock --
    def advance(self, tick: int) -> None:
        self._now = tick

    @property
    def now(self) -> int:
        return self._now

    # -- send path (hooks for FaultyTransport) --
    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def _enqueue(self, msg: Message, due: int) -> None:
        self._boxes.setdefault(msg.dst, []).append((due, msg.seq, msg))

    def send(self, msg: Message) -> None:
        if msg.seq == 0:
            msg = Message(kind=msg.kind, src=msg.src, dst=msg.dst,
                          seq=self._next_seq(), uid=msg.uid,
                          payload=msg.payload)
        self.stats.sent += 1
        self.stats.by_kind[msg.kind] = \
            self.stats.by_kind.get(msg.kind, 0) + 1
        self._enqueue(msg, self._now)

    # -- receive path --
    def _shuffle_hook(self, batch: List[Message]) -> List[Message]:
        return batch

    def poll(self, endpoint: str) -> List[Message]:
        box = self._boxes.get(endpoint, [])
        due = sorted((e for e in box if e[0] <= self._now),
                     key=lambda e: (e[0], e[1]))
        self._boxes[endpoint] = [e for e in box if e[0] > self._now]
        out = self._shuffle_hook([m for _, _, m in due])
        self.stats.delivered += len(out)
        return out

    @property
    def in_flight(self) -> int:
        return sum(len(v) for v in self._boxes.values())


@dataclass(frozen=True)
class ChaosConfig:
    """Seeded-random message faults, applied per send (and per poll for
    reorders) until tick ``until`` — after that the network **heals**,
    which is what lets chaos runs terminate with every admitted request
    completed. All probabilities are independent per message."""

    seed: int = 0
    p_drop: float = 0.0          # message silently lost
    p_dup: float = 0.0           # a second copy arrives (extra-delayed)
    p_delay: float = 0.0         # message held back 1..max_delay ticks
    max_delay: int = 3
    p_reorder: float = 0.0       # a poll's due batch is shuffled
    until: Optional[int] = None  # faults stop strictly after this tick

    def __post_init__(self):
        for name in ("p_drop", "p_dup", "p_delay", "p_reorder"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"ChaosConfig.{name} must be a "
                                 f"probability in [0, 1]; got {v!r}")
        if self.max_delay < 1:
            raise ValueError("ChaosConfig.max_delay must be >= 1; got "
                             f"{self.max_delay}")


class FaultyTransport(LocalTransport):
    """LocalTransport plus deterministic message-level fault injection.

    Faults come from two composable sources, both on the logical clock:

    * **Scripted link events** via :meth:`inject` — the parsed
      ``drop:<r>@<t>`` / ``delay:<r>@<t>+<d>`` /
      ``partition:<r>@<t>..<t2>`` grammar of
      :func:`repro.runtime.supervisor.parse_fault_spec`. ``drop`` loses
      every message sent on replica ``r``'s link at tick ``t``;
      ``delay`` holds them back ``d`` ticks; ``partition`` loses all
      traffic both directions for the whole window.
    * **Seeded-random chaos** via :class:`ChaosConfig` — per-message
      drop/duplicate/delay draws and per-poll reorders from one
      ``RandomState(seed)``, healed after ``until``.

    With neither configured it behaves exactly like
    :class:`LocalTransport` (the router's default)."""

    def __init__(self, chaos: Optional[ChaosConfig] = None):
        super().__init__()
        self.chaos = chaos
        self._rng = np.random.RandomState(
            chaos.seed if chaos is not None else 0)
        self._drops: set = set()              # (replica, tick)
        self._delays: Dict[Tuple[int, int], int] = {}
        self._partitions: List[Tuple[int, int, int]] = []  # (r, t0, t1)

    # -- scripted schedule --
    def inject(self, event) -> None:
        """Apply one parsed net-fault :class:`FaultEvent` (kinds
        ``drop_link`` / ``delay_link`` / ``partition``)."""
        from repro.runtime.supervisor import (DELAY_LINK, DROP_LINK,
                                              PARTITION)
        if event.kind == DROP_LINK:
            self._drops.add((event.replica, event.tick))
        elif event.kind == DELAY_LINK:
            self._delays[(event.replica, event.tick)] = int(event.delay)
        elif event.kind == PARTITION:
            self._partitions.append(
                (event.replica, event.tick, int(event.until)))
        else:
            raise ValueError(
                f"FaultyTransport cannot inject event kind "
                f"{event.kind!r}; expected a message fault "
                "(drop_link/delay_link/partition)")

    def partitioned(self, replica: int, tick: Optional[int] = None) -> bool:
        t = self._now if tick is None else tick
        return any(r == replica and t0 <= t <= t1
                   for r, t0, t1 in self._partitions)

    # -- chaos --
    def _chaos_active(self) -> bool:
        c = self.chaos
        return c is not None and (c.until is None or self._now <= c.until)

    def send(self, msg: Message) -> None:
        msg = Message(kind=msg.kind, src=msg.src, dst=msg.dst,
                      seq=self._next_seq(), uid=msg.uid,
                      payload=msg.payload)
        self.stats.sent += 1
        self.stats.by_kind[msg.kind] = \
            self.stats.by_kind.get(msg.kind, 0) + 1
        link = msg.link()
        if link is not None:
            if self.partitioned(link):
                self.stats.partition_dropped += 1
                return
            if (link, self._now) in self._drops:
                self.stats.dropped += 1
                return
        extra = self._delays.get((link, self._now), 0)
        if self._chaos_active():
            c = self.chaos
            if c.p_drop and self._rng.random_sample() < c.p_drop:
                self.stats.dropped += 1
                return
            if c.p_delay and self._rng.random_sample() < c.p_delay:
                extra += 1 + int(self._rng.randint(c.max_delay))
            if c.p_dup and self._rng.random_sample() < c.p_dup:
                dup_extra = extra + 1 + int(self._rng.randint(c.max_delay))
                self.stats.duplicated += 1
                self._enqueue(msg, self._now + dup_extra)
        if extra:
            self.stats.delayed += 1
        self._enqueue(msg, self._now + extra)

    def _shuffle_hook(self, batch: List[Message]) -> List[Message]:
        if (len(batch) > 1 and self._chaos_active()
                and self.chaos.p_reorder
                and self._rng.random_sample() < self.chaos.p_reorder):
            idx = self._rng.permutation(len(batch))
            self.stats.reordered_polls += 1
            return [batch[i] for i in idx]
        return batch
