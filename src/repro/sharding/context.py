"""Mesh-axis context: lets model code emit sharding constraints without
threading mesh objects through every layer.

Axis conventions (DESIGN.md §5):
* ``model`` — tensor parallelism (attention heads, FFN width, vocab);
* ``data``  — batch data parallelism AND FSDP parameter sharding AND MoE
  expert parallelism;
* ``pod``   — multi-pod data parallelism (gradient all-reduce over DCN).

Single-process CPU tests run with no mesh: constraints become no-ops.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def set_mesh_axes(axes: Optional[Tuple[str, ...]],
                  sizes: Optional[Tuple[int, ...]] = None) -> None:
    _state.axes = tuple(axes) if axes else None
    _state.sizes = dict(zip(axes, sizes)) if (axes and sizes) else {}


def mesh_axes() -> Optional[Tuple[str, ...]]:
    return getattr(_state, "axes", None)


def axis_size(name: str) -> int:
    """Size of a mesh axis if known via set_mesh_axes (else 0 = unknown)."""
    return getattr(_state, "sizes", {}).get(name, 0)


@contextlib.contextmanager
def use_mesh_axes(axes: Optional[Tuple[str, ...]],
                  sizes: Optional[Tuple[int, ...]] = None):
    prev, prev_sizes = mesh_axes(), getattr(_state, "sizes", {})
    set_mesh_axes(axes, sizes)
    try:
        yield
    finally:
        set_mesh_axes(prev)
        _state.sizes = prev_sizes


def set_ep_mesh(mesh) -> None:
    """Install a mesh for explicit expert-parallel MoE dispatch: while set,
    dense-expert MoE layers route through
    ``sharding.moe_parallel.apply_moe_shard_map`` instead of the GSPMD
    gather path (see ``transformer._apply_ffn``)."""
    _state.ep_mesh = mesh


def ep_mesh():
    """The active expert-parallel dispatch mesh, or None (gather path)."""
    return getattr(_state, "ep_mesh", None)


@contextlib.contextmanager
def use_ep_mesh(mesh):
    prev = ep_mesh()
    set_ep_mesh(mesh)
    try:
        yield
    finally:
        set_ep_mesh(prev)


def batch_axes():
    """Axes the global batch is sharded over ('pod','data' when present)."""
    axes = mesh_axes()
    if not axes:
        return None
    return tuple(a for a in axes if a in ("pod", "data")) or None


def fsdp_axis() -> Optional[str]:
    axes = mesh_axes()
    return "data" if axes and "data" in axes else None


def tp_axis() -> Optional[str]:
    axes = mesh_axes()
    return "model" if axes and "model" in axes else None


def activate_mesh(mesh):
    """Version-portable mesh-activation context manager.

    ``jax.set_mesh`` (newest) -> ``jax.sharding.use_mesh`` -> the mesh's own
    context manager (the only spelling on the pinned 0.4.x line).
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh


def shard_map(body, mesh, in_specs, out_specs, check: bool = False):
    """Version-portable ``shard_map`` (top-level ``jax.shard_map`` with
    ``check_vma`` on new JAX, or ``check_rep`` on the intermediate 0.5/0.6
    line; ``jax.experimental.shard_map`` on the pinned 0.4.x line)."""
    import inspect
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    kw = {"check_vma": check} if "check_vma" in \
        inspect.signature(sm).parameters else {"check_rep": check}
    return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint that degrades to a no-op without a mesh."""
    if mesh_axes() is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def activation_spec(*trailing) -> P:
    """P(batch_axes, *trailing) — standard activation layout."""
    return P(batch_axes(), *trailing)


def constrain_batch(x: jax.Array) -> jax.Array:
    """Shard leading (batch) dim over the data axes, rest replicated."""
    if mesh_axes() is None:
        return x
    return constrain(x, P(batch_axes(), *([None] * (x.ndim - 1))))
