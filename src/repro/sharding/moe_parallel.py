"""Explicit expert-parallel MoE dispatch (shard_map + all_to_all).

The GSPMD gather path (`models/layers/moe.py`) lets XLA choose collectives;
this module is the deterministic-collective alternative for large expert
counts (DESIGN.md §5): experts sharded over ``data`` (EP), expert FFN width
over ``model`` (TP), tokens exchanged with exactly

    2 x all_to_all(data)  +  1 x psum(model)        per MoE layer

— the textbook DP x EP x TP schedule, and the layout the §Roofline
collective terms can be read off directly.

Two expert bodies share the routing/dispatch/combine machinery:

* **dense** — bf16 expert stacks, FFN width TP-sharded over ``model``;
* **quantized** — the packed per-class PMQ planes of a compressed
  artifact, each class's plane stack sharded along its expert axis over
  ``data`` and the local FFN running the fused grouped kernel
  (`kernels.moe_ffn`, one ``pallas_call`` per layer per shard).  Because
  experts are class-sorted globally but sharded per class, a static
  lookup table remaps global expert ids to **shard-major EP slots**
  (shard ``r`` owns the ``r``-th block of every class); the table is the
  only difference between the two dispatch paths.  Requires every class
  count to divide the ``data`` axis — otherwise a class would straddle
  shards with unequal plane shapes; use GSPMD placement (``mesh=``
  without ``ep_dispatch``) for such layouts.

Capacity semantics: each source shard may send up to
``cap = ceil(k * T_local * cf * capacity_scale / E)`` tokens to each global
expert; overflow drops (GShard). ODP integrates as in the gather path —
pruned slots never enter the send buffers, and the calibrated
``capacity_scale`` shrinks them statically.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.core import odp as odp_lib
from repro.kernels.moe_ffn.ops import moe_ffn_quant
from repro.sharding import context as shctx
from repro.models.layers.core import mlp_activation
from repro.models.layers.moe import (MoEQuantMeta, OdpRuntime,
                                     expert_capacity)


# ------------------------------------------------------- EP layout helpers
def validate_ep_quant_meta(meta: MoEQuantMeta, dp: int) -> None:
    """Quantized EP shards every bit class over ``dp`` expert shards."""
    if any(c % dp for c in meta.class_counts):
        raise ValueError(
            f"quantized ep_dispatch needs every bit-class expert count to "
            f"divide the mesh 'data' axis ({dp}); got class_counts="
            f"{tuple(meta.class_counts)} for bit_classes="
            f"{tuple(meta.bit_classes)} — re-plan with divisible class "
            "sizes or serve with GSPMD placement (mesh= without ep)")


def ep_class_segments(spec) -> Tuple[Tuple[int, int], ...]:
    """Normalize to ``((start, count), ...)`` class segments: a
    :class:`MoEQuantMeta` yields its bit-class segmentation, a plain
    expert count the single dense segment ``((0, E),)``, and an already
    segment-shaped sequence passes through."""
    if isinstance(spec, MoEQuantMeta):
        return spec.class_segments()
    if isinstance(spec, (int, np.integer)):
        return ((0, int(spec)),)
    return tuple((int(a), int(b)) for a, b in spec)


def ep_owned_ranges(meta_or_experts, dp: int,
                    shard: int) -> Tuple[Tuple[int, int], ...]:
    """Global expert ranges EP shard ``shard`` owns under the standard
    placement: every class's plane stack (or the dense expert stack) is
    split evenly over the ``dp`` shards of the EP axis, shard ``r``
    taking the ``r``-th block of each class. Adjacent per-class blocks
    are merged, so the result is the minimal sorted disjoint cover.

    This is the contract between per-host artifact streams and the
    distributed engine: a host whose addressable devices sit in EP shard
    ``r`` must hold exactly these experts (and no others) to serve as
    one process of a multi-process mesh (`core.pipeline`).
    """
    segments = ep_class_segments(meta_or_experts)
    if not 0 <= shard < dp:
        raise ValueError(f"shard {shard} out of range for dp={dp}")
    out: list = []
    for e0, cnt in segments:
        if cnt % dp:
            raise ValueError(
                f"expert-parallel placement needs every class expert "
                f"count to divide the EP axis ({dp}); got a class of "
                f"{cnt} experts (segments={segments})")
        per = cnt // dp
        r = (e0 + shard * per, e0 + (shard + 1) * per)
        if out and out[-1][1] == r[0]:
            out[-1] = (out[-1][0], r[1])
        else:
            out.append(r)
    return tuple(out)


def merge_ranges(ranges) -> Tuple[Tuple[int, int], ...]:
    """Canonicalize ``(start, stop)`` ranges: sort and merge adjacent or
    overlapping runs (the form :func:`ep_owned_ranges` emits)."""
    rs = sorted((int(a), int(b)) for a, b in ranges)
    out: list = []
    for a, b in rs:
        if out and a <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], b))
        else:
            out.append((a, b))
    return tuple(out)


def ep_shard_for_ranges(meta_or_experts, dp: int, ranges) -> int:
    """Inverse of :func:`ep_owned_ranges`: which EP shard owns exactly
    ``ranges``? Raises ``ValueError`` naming the overlap / gap /
    misalignment when the ranges match no shard — the loud-failure path
    for booting a host from a mismatched per-host artifact stream."""
    norm = merge_ranges(ranges)
    for r in range(dp):
        if ep_owned_ranges(meta_or_experts, dp, r) == norm:
            return r
    got = _range_set(norm)
    best = min(range(dp), key=lambda r: len(got.symmetric_difference(
        _range_set(ep_owned_ranges(meta_or_experts, dp, r)))))
    want = _range_set(ep_owned_ranges(meta_or_experts, dp, best))
    extra, missing = sorted(got - want), sorted(want - got)
    detail = "; ".join(
        ([f"foreign experts {extra} overlap other shards"] if extra
         else [])
        + ([f"gap — experts {missing} are missing"] if missing else []))
    raise ValueError(
        f"expert ranges {norm} match no EP shard of a {dp}-way axis "
        f"(class segments {ep_class_segments(meta_or_experts)}); closest "
        f"is shard {best}: {detail or 'same experts, split differently'}")


def _range_set(ranges) -> set:
    out: set = set()
    for a, b in ranges:
        out.update(range(a, b))
    return out


def local_quant_meta(meta: MoEQuantMeta, dp: int) -> MoEQuantMeta:
    """The per-shard class layout: same classes, counts / dp."""
    return MoEQuantMeta(
        bit_classes=meta.bit_classes,
        class_counts=tuple(c // dp for c in meta.class_counts),
        group_size=meta.group_size, pack_block=meta.pack_block,
        plane_suffixes=meta.plane_suffixes)


def ep_slot_table(meta: MoEQuantMeta, dp: int) -> np.ndarray:
    """Global class-sorted expert index -> shard-major EP slot.

    Sharding each class's plane stack over ``dp`` gives shard ``r`` rows
    ``[r*cnt/dp, (r+1)*cnt/dp)`` of every class; the shard's local expert
    order is therefore the class order with per-class blocks. The EP slot
    of global expert ``e0 + o`` (class offset ``o``) is
    ``shard * E_l + local_class_start + o % (cnt/dp)``.

    Only the *global* class layout enters the table, so a process whose
    planes are local (a per-host partial artifact) still derives the
    full remap from the plan's meta; :func:`ep_owned_ranges` /
    :func:`ep_shard_for_ranges` map its ``expert_range`` to the shard
    whose rows those planes are.
    """
    e = meta.num_experts
    e_l = e // dp
    table = np.zeros(e, np.int64)
    local_start = 0
    for bits, e0, cnt in meta.class_slices():
        per = cnt // dp
        for o in range(cnt):
            table[e0 + o] = (o // per) * e_l + local_start + o % per
        local_start += per
    return table


# ------------------------------------------- shared routing/dispatch bodies
def _protect_local(token_importance, token_mask, odp, t_l, shape, data_axis):
    """Gather-path-equivalent token-protection quotas on a data shard.

    :func:`~repro.core.odp.protect_tokens` budgets ``ceil(ratio * L)``
    tokens per last-axis row. The gather path applies that per (b, s)
    sequence row, and regroups decode (s == 1) into a single (1, b) pool
    over all batch slots. Batch rows are shard-local under data
    parallelism, so prefill protection stays local; the decode pool spans
    shards, so it takes one (b_l,)-sized all_gather of importance/mask
    before slicing the local verdicts back out. Keeping the grouping
    identical makes the per-request ODP knob deployment-path-independent:
    gather and EP dispatch prune the same tokens.
    """
    if shape is None:
        return odp_lib.protect_tokens(
            token_importance.reshape(t_l), odp.protect_ratio,
            valid=(token_mask.reshape(t_l)
                   if token_mask is not None else None))
    b_l, s = shape
    if s > 1 or data_axis is None:
        prot = odp_lib.protect_tokens(
            token_importance.reshape(b_l, s), odp.protect_ratio,
            valid=(token_mask.reshape(b_l, s)
                   if token_mask is not None else None))
        return prot.reshape(t_l)
    imp_g = jax.lax.all_gather(token_importance.reshape(b_l), data_axis,
                               tiled=True)
    val_g = (jax.lax.all_gather(token_mask.reshape(b_l), data_axis,
                                tiled=True)
             if token_mask is not None else None)
    prot_g = odp_lib.protect_tokens(
        imp_g[None, :], odp.protect_ratio,
        valid=(val_g[None, :] if val_g is not None else None))[0]
    start = jax.lax.axis_index(data_axis) * b_l
    return jax.lax.dynamic_slice_in_dim(prot_g, start, b_l)


def _route_local(x_flat, router, cfg: ModelConfig, odp: Optional[OdpRuntime],
                 capacity_scale: float, token_importance, token_mask, t_l,
                 odp_threshold=None, shape=None, data_axis=None):
    """Per-shard routing with ODP pruning/protection; returns (topw, topi,
    cap) — identical math to the gather path's router block.

    odp_threshold: optional (t_l,) traced per-token threshold (the
    engines' per-request knob); overrides ``odp.threshold`` and suppresses
    the static capacity shrink, exactly as in the gather path.
    shape: the local (b_l, s) layout; with ``data_axis`` it makes token
    protection grouping-equivalent to the gather path (see below)."""
    logits = x_flat.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, cfg.top_k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    if token_mask is not None:
        topw = topw * token_mask.reshape(t_l, 1).astype(topw.dtype)

    eff_scale = capacity_scale
    if odp is not None and odp.enabled and cfg.top_k >= 2:
        protected = None
        if token_importance is not None and odp.protect_ratio > 0:
            # masked (pad / idle-slot) tokens must not steal protection
            # quota from live tokens — same rule as the gather path
            protected = _protect_local(token_importance, token_mask, odp,
                                       t_l, shape, data_axis)
        thr = (odp_threshold if odp_threshold is not None
               else odp.threshold)
        keep = odp_lib.prune_mask(topw, thr, protected)
        topw = odp_lib.apply_pruning(topw, keep)
        if odp_threshold is None:
            eff_scale = eff_scale * odp.capacity_scale

    cap = expert_capacity(cfg, t_l, eff_scale)
    return topw, topi, cap


def _fill_send(x_flat, topi, topw, e: int, cap: int, t_l: int, k: int,
               remap=None):
    """Scatter assignments into per-(EP-slot, quota-position) send rows.

    ``remap``: optional (E,) global-expert -> EP-slot table (quantized
    layout); identity for the dense contiguous sharding. Returns
    ``(send (e*cap, D), slot, flat_w, tok_ids, sent)`` — ``slot`` indexes
    both the send buffer and the returned expert outputs; ``sent`` is the
    (e,) count of live rows this shard occupies in each destination
    expert's quota (the per-source live-prefix lengths the quantized body's
    row compaction consumes).
    """
    d = x_flat.shape[-1]
    flat_e = topi.reshape(-1)                                  # (T_l*k,)
    if remap is not None:
        flat_e = remap[flat_e]
    flat_w = topw.reshape(-1)
    # position of each assignment within its destination expert's quota;
    # dead assignments (ODP-pruned or token_mask'd: weight 0) must not
    # occupy quota positions — only live ones enter the cumsum
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32) \
        * (flat_w > 0).astype(jnp.int32)[:, None]
    pos = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - 1, flat_e[:, None],
                              axis=1)[:, 0]
    live = (pos < cap) & (flat_w > 0)
    slot = jnp.where(live, flat_e * cap + pos, e * cap)        # sentinel
    sent = jax.ops.segment_sum(live.astype(jnp.int32), flat_e,
                               num_segments=e)                 # (e,)

    send = jnp.zeros((e * cap + 1, d), x_flat.dtype)
    tok_ids = jnp.repeat(jnp.arange(t_l), k)
    send = send.at[slot].set(x_flat[tok_ids], mode="drop")
    return send[:-1], slot, flat_w, tok_ids, sent


def _combine_local(ret, slot, flat_w, tok_ids, e: int, cap: int, t_l: int):
    d = ret.shape[-1]
    y_slots = jnp.concatenate(
        [ret.reshape(e * cap, d), jnp.zeros((1, d), ret.dtype)], axis=0)
    y_assign = y_slots[slot] * flat_w[:, None].astype(ret.dtype)
    return jax.ops.segment_sum(y_assign, tok_ids, num_segments=t_l)


def _local_moe(x_loc, router, w_in, w_gate, w_out, cfg: ModelConfig,
               odp: Optional[OdpRuntime], capacity_scale: float,
               data_axis: str, model_axis: str,
               token_importance: Optional[jax.Array],
               token_mask: Optional[jax.Array] = None,
               odp_threshold: Optional[jax.Array] = None):
    """Per-shard dense body. x_loc: (B_l, S, D); experts (E_l, D, F_l).

    token_mask: optional (B_l, S) bool — masked tokens (padding, inactive
    decode slots) get zero routing weight, so they never enter the send
    buffers or consume expert capacity; their output rows are zero.
    """
    b_l, s, d = x_loc.shape
    e = cfg.num_experts
    e_l = w_in.shape[0]
    dp = e // e_l
    t_l = b_l * s

    x_flat = x_loc.reshape(t_l, d)
    thr = _flat_threshold(odp_threshold, b_l, s)
    topw, topi, cap = _route_local(x_flat, router, cfg, odp, capacity_scale,
                                   token_importance, token_mask, t_l,
                                   odp_threshold=thr, shape=(b_l, s),
                                   data_axis=data_axis)
    send, slot, flat_w, tok_ids, _ = _fill_send(
        x_flat, topi, topw, e, cap, t_l, cfg.top_k)
    send = send.reshape(dp, e_l, cap, d)

    # dispatch: destination-major -> expert-major
    recv = jax.lax.all_to_all(send, data_axis, split_axis=0, concat_axis=0,
                              tiled=False)
    # recv: (dp, E_l, cap, D): recv[src] = tokens from shard `src`
    xe = recv.transpose(1, 0, 2, 3).reshape(e_l, dp * cap, d)

    act = mlp_activation(cfg)
    dt = x_loc.dtype
    h = jnp.einsum("etd,edf->etf", xe, w_in.astype(dt))
    g = jnp.einsum("etd,edf->etf", xe, w_gate.astype(dt))
    ye = jnp.einsum("etf,efd->etd", act(g) * h, w_out.astype(dt))
    # TP: expert FFN width is model-sharded -> reduce the partial outputs
    ye = jax.lax.psum(ye, model_axis)

    back = ye.reshape(e_l, dp, cap, d).transpose(1, 0, 2, 3)
    ret = jax.lax.all_to_all(back, data_axis, split_axis=0, concat_axis=0,
                             tiled=False)
    y = _combine_local(ret, slot, flat_w, tok_ids, e, cap, t_l)
    return y.reshape(b_l, s, d).astype(x_loc.dtype)


def _local_moe_quant(x_loc, router, experts_q, cfg: ModelConfig,
                     local_meta: MoEQuantMeta, remap,
                     odp: Optional[OdpRuntime], capacity_scale: float,
                     data_axis: str,
                     token_importance: Optional[jax.Array],
                     token_mask: Optional[jax.Array] = None,
                     odp_threshold: Optional[jax.Array] = None):
    """Per-shard quantized body: packed per-class planes, fused FFN.

    ``experts_q`` holds this shard's slice of every class's plane stack
    (``local_meta`` class layout); ``remap`` is the static shard-major EP
    slot table. The FFN width is not TP-sharded — planes replicate over
    ``model`` and no psum is needed (every model shard computes the full,
    identical output).

    Received rows arrive (source, quota-slot)-ordered — each source fills
    its own quota prefix, so live rows are NOT one contiguous prefix. A
    static-shape compaction (exclusive-cumsum offsets over the per-source
    live counts, exchanged alongside the tokens) packs them into one, so
    the fused kernel's per-expert ``counts`` skip every dead capacity tile
    — this is where ODP-pruned / idle-slot rows turn into saved FLOPs on
    the expert-parallel path.
    """
    b_l, s, d = x_loc.shape
    e = cfg.num_experts
    e_l = local_meta.num_experts
    dp = e // e_l
    t_l = b_l * s

    x_flat = x_loc.reshape(t_l, d)
    thr = _flat_threshold(odp_threshold, b_l, s)
    topw, topi, cap = _route_local(x_flat, router, cfg, odp, capacity_scale,
                                   token_importance, token_mask, t_l,
                                   odp_threshold=thr, shape=(b_l, s),
                                   data_axis=data_axis)
    send, slot, flat_w, tok_ids, sent = _fill_send(
        x_flat, topi, topw, e, cap, t_l, cfg.top_k, remap=remap)
    send = send.reshape(dp, e_l, cap, d)

    recv = jax.lax.all_to_all(send, data_axis, split_axis=0, concat_axis=0,
                              tiled=False)
    # cnt[src, e] = live rows source shard `src` sent local expert `e`
    cnt = jax.lax.all_to_all(sent.reshape(dp, e_l), data_axis,
                             split_axis=0, concat_axis=0, tiled=False)
    xe = recv.transpose(1, 0, 2, 3).reshape(e_l, dp * cap, d)

    # compact each expert's rows to a live prefix: source `src`'s rows
    # [0, cnt[src]) move to [off[src], off[src] + cnt[src]) — disjoint by
    # construction; dead rows scatter to a sentinel row that is sliced off
    cnt_e = cnt.T                                               # (e_l, dp)
    off = jnp.cumsum(cnt_e, axis=1) - cnt_e                     # exclusive
    jrow = jnp.arange(cap)[None, None, :]
    live_rows = jrow < cnt_e[:, :, None]                        # (e_l,dp,cap)
    dest = jnp.where(live_rows, off[:, :, None] + jrow,
                     dp * cap).reshape(e_l, dp * cap)
    comp = jax.vmap(
        lambda rows, dd: jnp.zeros((dp * cap + 1, d), rows.dtype)
        .at[dd].set(rows, mode="drop"))(xe, dest)[:, :-1]
    counts = cnt_e.sum(1).astype(jnp.int32)         # (e_l,) live prefixes
    ye = moe_ffn_quant(comp, experts_q, counts, meta=local_meta,
                       act=cfg.mlp_act,
                       out_dtype=jnp.float32).astype(x_loc.dtype)
    # un-compact: gather each (source, quota-slot) row's output back; the
    # appended zero row serves the dead slots
    ye = jnp.concatenate([ye, jnp.zeros((e_l, 1, d), ye.dtype)], axis=1)
    ye = jax.vmap(lambda rows, dd: rows[dd])(ye, dest)

    back = ye.reshape(e_l, dp, cap, d).transpose(1, 0, 2, 3)
    ret = jax.lax.all_to_all(back, data_axis, split_axis=0, concat_axis=0,
                             tiled=False)
    y = _combine_local(ret, slot, flat_w, tok_ids, e, cap, t_l)
    return y.reshape(b_l, s, d).astype(x_loc.dtype)


def _flat_threshold(odp_threshold, b_l: int, s: int):
    """(B_l,) per-row dynamic threshold -> (B_l*S,) per-token, or None."""
    if odp_threshold is None:
        return None
    return jnp.broadcast_to(odp_threshold.reshape(b_l, -1),
                            (b_l, s)).reshape(b_l * s)


def apply_moe_shard_map(p: Dict, x: jax.Array, cfg: ModelConfig, mesh, *,
                        quant_meta: Optional[MoEQuantMeta] = None,
                        odp: Optional[OdpRuntime] = None,
                        capacity_scale: float = 1.0,
                        token_importance: Optional[jax.Array] = None,
                        token_mask: Optional[jax.Array] = None,
                        odp_threshold: Optional[jax.Array] = None,
                        data_axis: str = "data",
                        model_axis: str = "model") -> jax.Array:
    """shard_map-wrapped MoE layer (dense or PMQ-quantized experts).

    x sharded P(data, None, None). Dense experts P(data, None, model);
    with ``quant_meta``, ``p['experts_q']`` packed planes are sharded
    along their expert axis over ``data`` (every class count must divide
    the axis) and the local FFN runs the fused grouped quantized kernel.
    token_importance / token_mask are optional (B, S) arrays sharded with
    the batch (ODP protection scores / live-token mask — the serving
    engines thread the latter so idle decode slots never send tokens).
    odp_threshold is the optional (B,) per-row dynamic ODP threshold
    (traced — the per-request knob), sharded with the batch too.
    """
    extras, extra_specs, have = [], [], []
    for extra, spec in ((token_importance, P(data_axis, None)),
                        (token_mask, P(data_axis, None)),
                        (odp_threshold, P(data_axis))):
        if extra is not None:
            extra_specs.append(spec)
            extras.append(extra)
        have.append(extra is not None)

    def unpack_extras(rest):
        it = iter(rest)
        ti = next(it) if have[0] else None
        tm = next(it) if have[1] else None
        thr = next(it) if have[2] else None
        return ti, tm, thr

    if quant_meta is not None:
        dp = dict(mesh.shape)[data_axis]
        validate_ep_quant_meta(quant_meta, dp)
        lmeta = local_quant_meta(quant_meta, dp)
        remap = jnp.asarray(ep_slot_table(quant_meta, dp))
        fn = functools.partial(
            _local_moe_quant, cfg=cfg, local_meta=lmeta, remap=remap,
            odp=odp, capacity_scale=capacity_scale, data_axis=data_axis)

        in_specs = [P(data_axis, None, None), P(None, None),
                    P(data_axis)] + extra_specs
        args = [x, p["router"], p["experts_q"]] + extras

        def body(xl, r, eq, *rest):
            ti, tm, thr = unpack_extras(rest)
            return fn(xl, r, eq, token_importance=ti, token_mask=tm,
                      odp_threshold=thr)

        return shctx.shard_map(
            body, mesh, tuple(in_specs), P(data_axis, None, None))(*args)

    fn = functools.partial(
        _local_moe, cfg=cfg, odp=odp, capacity_scale=capacity_scale,
        data_axis=data_axis, model_axis=model_axis)

    in_specs = [P(data_axis, None, None), P(None, None),
                P(data_axis, None, model_axis),
                P(data_axis, None, model_axis),
                P(data_axis, model_axis, None)] + extra_specs
    args = [x, p["router"], p["w_in"], p["w_gate"], p["w_out"]] + extras

    def body(xl, r, wi, wg, wo, *rest):
        ti, tm, thr = unpack_extras(rest)
        return fn(xl, r, wi, wg, wo, token_importance=ti, token_mask=tm,
                  odp_threshold=thr)

    return shctx.shard_map(
        body, mesh, tuple(in_specs), P(data_axis, None, None))(*args)
