"""Explicit expert-parallel MoE dispatch (shard_map + all_to_all).

The GSPMD gather path (`models/layers/moe.py`) lets XLA choose collectives;
this module is the deterministic-collective alternative for large expert
counts (DESIGN.md §5): experts sharded over ``data`` (EP), expert FFN width
over ``model`` (TP), tokens exchanged with exactly

    2 x all_to_all(data)  +  1 x psum(model)        per MoE layer

— the textbook DP x EP x TP schedule, and the layout the §Roofline
collective terms can be read off directly.

Capacity semantics: each source shard may send up to
``cap = ceil(k * T_local * cf * capacity_scale / E)`` tokens to each global
expert; overflow drops (GShard). ODP integrates as in the gather path —
pruned slots never enter the send buffers, and the calibrated
``capacity_scale`` shrinks them statically.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.core import odp as odp_lib
from repro.sharding import context as shctx
from repro.models.layers.core import mlp_activation
from repro.models.layers.moe import OdpRuntime, expert_capacity


def _local_moe(x_loc, router, w_in, w_gate, w_out, cfg: ModelConfig,
               odp: Optional[OdpRuntime], capacity_scale: float,
               data_axis: str, model_axis: str,
               token_importance: Optional[jax.Array],
               token_mask: Optional[jax.Array] = None):
    """Per-shard body. x_loc: (B_l, S, D); experts local (E_l, D, F_l).

    token_mask: optional (B_l, S) bool — masked tokens (padding, inactive
    decode slots) get zero routing weight, so they never enter the send
    buffers or consume expert capacity; their output rows are zero.
    """
    b_l, s, d = x_loc.shape
    e = cfg.num_experts
    e_l = w_in.shape[0]
    dp = e // e_l
    k = cfg.top_k
    t_l = b_l * s

    x_flat = x_loc.reshape(t_l, d)
    logits = x_flat.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    if token_mask is not None:
        topw = topw * token_mask.reshape(t_l, 1).astype(topw.dtype)

    eff_scale = capacity_scale
    if odp is not None and odp.enabled and k >= 2:
        protected = None
        if token_importance is not None and odp.protect_ratio > 0:
            # masked (pad / idle-slot) tokens must not steal protection
            # quota from live tokens — same rule as the gather path
            protected = odp_lib.protect_tokens(
                token_importance.reshape(t_l), odp.protect_ratio,
                valid=(token_mask.reshape(t_l)
                       if token_mask is not None else None))
        keep = odp_lib.prune_mask(topw, odp.threshold, protected)
        topw = odp_lib.apply_pruning(topw, keep)
        eff_scale = eff_scale * odp.capacity_scale

    cap = expert_capacity(cfg, t_l, eff_scale)

    # position of each assignment within its destination expert's quota;
    # dead assignments (ODP-pruned or token_mask'd: weight 0) must not
    # occupy quota positions — only live ones enter the cumsum
    flat_e = topi.reshape(-1)                                  # (T_l*k,)
    flat_w = topw.reshape(-1)
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32) \
        * (flat_w > 0).astype(jnp.int32)[:, None]
    pos = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - 1, flat_e[:, None],
                              axis=1)[:, 0]
    live = (pos < cap) & (flat_w > 0)
    slot = jnp.where(live, flat_e * cap + pos, e * cap)        # sentinel

    send = jnp.zeros((e * cap + 1, d), x_loc.dtype)
    tok_ids = jnp.repeat(jnp.arange(t_l), k)
    send = send.at[slot].set(x_flat[tok_ids], mode="drop")
    send = send[:-1].reshape(dp, e_l, cap, d)

    # dispatch: destination-major -> expert-major
    recv = jax.lax.all_to_all(send, data_axis, split_axis=0, concat_axis=0,
                              tiled=False)
    # recv: (dp, E_l, cap, D): recv[src] = tokens from shard `src`
    xe = recv.transpose(1, 0, 2, 3).reshape(e_l, dp * cap, d)

    act = mlp_activation(cfg)
    dt = x_loc.dtype
    h = jnp.einsum("etd,edf->etf", xe, w_in.astype(dt))
    g = jnp.einsum("etd,edf->etf", xe, w_gate.astype(dt))
    ye = jnp.einsum("etf,efd->etd", act(g) * h, w_out.astype(dt))
    # TP: expert FFN width is model-sharded -> reduce the partial outputs
    ye = jax.lax.psum(ye, model_axis)

    back = ye.reshape(e_l, dp, cap, d).transpose(1, 0, 2, 3)
    ret = jax.lax.all_to_all(back, data_axis, split_axis=0, concat_axis=0,
                             tiled=False)
    y_slots = jnp.concatenate(
        [ret.reshape(e * cap, d),
         jnp.zeros((1, d), ret.dtype)], axis=0)

    y_assign = y_slots[slot] * flat_w[:, None].astype(ret.dtype)
    y = jax.ops.segment_sum(y_assign, tok_ids, num_segments=t_l)
    return y.reshape(b_l, s, d).astype(x_loc.dtype)


def apply_moe_shard_map(p: Dict, x: jax.Array, cfg: ModelConfig, mesh, *,
                        odp: Optional[OdpRuntime] = None,
                        capacity_scale: float = 1.0,
                        token_importance: Optional[jax.Array] = None,
                        token_mask: Optional[jax.Array] = None,
                        data_axis: str = "data",
                        model_axis: str = "model") -> jax.Array:
    """shard_map-wrapped MoE layer (dense experts).

    x sharded P(data, None, None); experts P(data, None, model).
    token_importance / token_mask are optional (B, S) arrays sharded with
    the batch (ODP protection scores / live-token mask — the serving
    engines thread the latter so idle decode slots never send tokens).
    """
    fn = functools.partial(
        _local_moe, cfg=cfg, odp=odp, capacity_scale=capacity_scale,
        data_axis=data_axis, model_axis=model_axis)

    in_specs = [P(data_axis, None, None), P(None, None),
                P(data_axis, None, model_axis),
                P(data_axis, None, model_axis),
                P(data_axis, model_axis, None)]
    args = [x, p["router"], p["w_in"], p["w_gate"], p["w_out"]]
    have = []
    for extra in (token_importance, token_mask):
        if extra is not None:
            in_specs.append(P(data_axis, None))
            args.append(extra)
        have.append(extra is not None)

    def body(xl, r, wi, wg, wo, *rest):
        it = iter(rest)
        ti = next(it) if have[0] else None
        tm = next(it) if have[1] else None
        return fn(xl, r, wi, wg, wo, token_importance=ti, token_mask=tm)

    return shctx.shard_map(
        body, mesh, tuple(in_specs), P(data_axis, None, None))(*args)
