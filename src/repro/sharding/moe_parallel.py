"""Explicit expert-parallel MoE dispatch (shard_map + all_to_all).

The GSPMD gather path (`models/layers/moe.py`) lets XLA choose collectives;
this module is the deterministic-collective alternative for large expert
counts (DESIGN.md §5): experts sharded over ``data`` (EP), expert FFN width
over ``model`` (TP), tokens exchanged with exactly

    2 x all_to_all(data)  +  1 x psum(model)        per MoE layer

— the textbook DP x EP x TP schedule, and the layout the §Roofline
collective terms can be read off directly.

Two expert bodies share the routing/dispatch/combine machinery:

* **dense** — bf16 expert stacks, FFN width TP-sharded over ``model``;
* **quantized** — the packed per-class PMQ planes of a compressed
  artifact, each class's plane stack sharded along its expert axis over
  ``data`` and the local FFN running the fused grouped kernel
  (`kernels.moe_ffn`, one ``pallas_call`` per layer per shard).  Because
  experts are class-sorted globally but sharded per class, a static
  lookup table remaps global expert ids to **shard-major EP slots**
  (shard ``r`` owns the ``r``-th block of every class); the table is the
  only difference between the two dispatch paths.  Requires every class
  count to divide the ``data`` axis — otherwise a class would straddle
  shards with unequal plane shapes; use GSPMD placement (``mesh=``
  without ``ep_dispatch``) for such layouts.

Capacity semantics: each source shard may send up to
``cap = ceil(k * T_local * cf * capacity_scale / E)`` tokens to each global
expert; overflow drops (GShard). ODP integrates as in the gather path —
pruned slots never enter the send buffers, and the calibrated
``capacity_scale`` shrinks them statically.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.core import odp as odp_lib
from repro.kernels.moe_ffn.ops import moe_ffn_quant
from repro.sharding import context as shctx
from repro.models.layers.core import mlp_activation
from repro.models.layers.moe import (MoEQuantMeta, OdpRuntime,
                                     expert_capacity)


# ------------------------------------------------------- EP layout helpers
def validate_ep_quant_meta(meta: MoEQuantMeta, dp: int) -> None:
    """Quantized EP shards every bit class over ``dp`` expert shards."""
    if any(c % dp for c in meta.class_counts):
        raise ValueError(
            f"quantized ep_dispatch needs every bit-class expert count to "
            f"divide the mesh 'data' axis ({dp}); got class_counts="
            f"{tuple(meta.class_counts)} for bit_classes="
            f"{tuple(meta.bit_classes)} — re-plan with divisible class "
            "sizes or serve with GSPMD placement (mesh= without ep)")


def ep_class_segments(spec) -> Tuple[Tuple[int, int], ...]:
    """Normalize to ``((start, count), ...)`` class segments: a
    :class:`MoEQuantMeta` yields its bit-class segmentation, a plain
    expert count the single dense segment ``((0, E),)``, and an already
    segment-shaped sequence passes through."""
    if isinstance(spec, MoEQuantMeta):
        return spec.class_segments()
    if isinstance(spec, (int, np.integer)):
        return ((0, int(spec)),)
    return tuple((int(a), int(b)) for a, b in spec)


def ep_owned_ranges(meta_or_experts, dp: int,
                    shard: int) -> Tuple[Tuple[int, int], ...]:
    """Global expert ranges EP shard ``shard`` owns under the standard
    placement: every class's plane stack (or the dense expert stack) is
    split evenly over the ``dp`` shards of the EP axis, shard ``r``
    taking the ``r``-th block of each class. Adjacent per-class blocks
    are merged, so the result is the minimal sorted disjoint cover.

    This is the contract between per-host artifact streams and the
    distributed engine: a host whose addressable devices sit in EP shard
    ``r`` must hold exactly these experts (and no others) to serve as
    one process of a multi-process mesh (`core.pipeline`).
    """
    segments = ep_class_segments(meta_or_experts)
    if not 0 <= shard < dp:
        raise ValueError(f"shard {shard} out of range for dp={dp}")
    out: list = []
    for e0, cnt in segments:
        if cnt % dp:
            raise ValueError(
                f"expert-parallel placement needs every class expert "
                f"count to divide the EP axis ({dp}); got a class of "
                f"{cnt} experts (segments={segments})")
        per = cnt // dp
        r = (e0 + shard * per, e0 + (shard + 1) * per)
        if out and out[-1][1] == r[0]:
            out[-1] = (out[-1][0], r[1])
        else:
            out.append(r)
    return tuple(out)


def merge_ranges(ranges) -> Tuple[Tuple[int, int], ...]:
    """Canonicalize ``(start, stop)`` ranges: sort and merge adjacent or
    overlapping runs (the form :func:`ep_owned_ranges` emits)."""
    rs = sorted((int(a), int(b)) for a, b in ranges)
    out: list = []
    for a, b in rs:
        if out and a <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], b))
        else:
            out.append((a, b))
    return tuple(out)


def ep_shard_for_ranges(meta_or_experts, dp: int, ranges) -> int:
    """Inverse of :func:`ep_owned_ranges`: which EP shard owns exactly
    ``ranges``? Raises ``ValueError`` naming the overlap / gap /
    misalignment when the ranges match no shard — the loud-failure path
    for booting a host from a mismatched per-host artifact stream."""
    norm = merge_ranges(ranges)
    for r in range(dp):
        if ep_owned_ranges(meta_or_experts, dp, r) == norm:
            return r
    got = _range_set(norm)
    best = min(range(dp), key=lambda r: len(got.symmetric_difference(
        _range_set(ep_owned_ranges(meta_or_experts, dp, r)))))
    want = _range_set(ep_owned_ranges(meta_or_experts, dp, best))
    extra, missing = sorted(got - want), sorted(want - got)
    detail = "; ".join(
        ([f"foreign experts {extra} overlap other shards"] if extra
         else [])
        + ([f"gap — experts {missing} are missing"] if missing else []))
    raise ValueError(
        f"expert ranges {norm} match no EP shard of a {dp}-way axis "
        f"(class segments {ep_class_segments(meta_or_experts)}); closest "
        f"is shard {best}: {detail or 'same experts, split differently'}")


def _range_set(ranges) -> set:
    out: set = set()
    for a, b in ranges:
        out.update(range(a, b))
    return out


def local_quant_meta(meta: MoEQuantMeta, dp: int) -> MoEQuantMeta:
    """The per-shard class layout: same classes, counts / dp."""
    return MoEQuantMeta(
        bit_classes=meta.bit_classes,
        class_counts=tuple(c // dp for c in meta.class_counts),
        group_size=meta.group_size, pack_block=meta.pack_block,
        plane_suffixes=meta.plane_suffixes)


def ep_slot_table(meta: MoEQuantMeta, dp: int) -> np.ndarray:
    """Global class-sorted expert index -> shard-major EP slot.

    Sharding each class's plane stack over ``dp`` gives shard ``r`` rows
    ``[r*cnt/dp, (r+1)*cnt/dp)`` of every class; the shard's local expert
    order is therefore the class order with per-class blocks. The EP slot
    of global expert ``e0 + o`` (class offset ``o``) is
    ``shard * E_l + local_class_start + o % (cnt/dp)``.

    Only the *global* class layout enters the table, so a process whose
    planes are local (a per-host partial artifact) still derives the
    full remap from the plan's meta; :func:`ep_owned_ranges` /
    :func:`ep_shard_for_ranges` map its ``expert_range`` to the shard
    whose rows those planes are.
    """
    e = meta.num_experts
    e_l = e // dp
    table = np.zeros(e, np.int64)
    local_start = 0
    for bits, e0, cnt in meta.class_slices():
        per = cnt // dp
        for o in range(cnt):
            table[e0 + o] = (o // per) * e_l + local_start + o % per
        local_start += per
    return table


# ------------------------------------------- shared routing/dispatch bodies
def _route_local(x_flat, router, cfg: ModelConfig, odp: Optional[OdpRuntime],
                 capacity_scale: float, token_importance, token_mask, t_l):
    """Per-shard routing with ODP pruning/protection; returns (topw, topi,
    cap) — identical math to the gather path's router block."""
    logits = x_flat.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, cfg.top_k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    if token_mask is not None:
        topw = topw * token_mask.reshape(t_l, 1).astype(topw.dtype)

    eff_scale = capacity_scale
    if odp is not None and odp.enabled and cfg.top_k >= 2:
        protected = None
        if token_importance is not None and odp.protect_ratio > 0:
            # masked (pad / idle-slot) tokens must not steal protection
            # quota from live tokens — same rule as the gather path
            protected = odp_lib.protect_tokens(
                token_importance.reshape(t_l), odp.protect_ratio,
                valid=(token_mask.reshape(t_l)
                       if token_mask is not None else None))
        keep = odp_lib.prune_mask(topw, odp.threshold, protected)
        topw = odp_lib.apply_pruning(topw, keep)
        eff_scale = eff_scale * odp.capacity_scale

    cap = expert_capacity(cfg, t_l, eff_scale)
    return topw, topi, cap


def _fill_send(x_flat, topi, topw, e: int, cap: int, t_l: int, k: int,
               remap=None):
    """Scatter assignments into per-(EP-slot, quota-position) send rows.

    ``remap``: optional (E,) global-expert -> EP-slot table (quantized
    layout); identity for the dense contiguous sharding. Returns
    ``(send (e*cap, D), slot, flat_w, tok_ids)`` — ``slot`` indexes both
    the send buffer and the returned expert outputs.
    """
    d = x_flat.shape[-1]
    flat_e = topi.reshape(-1)                                  # (T_l*k,)
    if remap is not None:
        flat_e = remap[flat_e]
    flat_w = topw.reshape(-1)
    # position of each assignment within its destination expert's quota;
    # dead assignments (ODP-pruned or token_mask'd: weight 0) must not
    # occupy quota positions — only live ones enter the cumsum
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32) \
        * (flat_w > 0).astype(jnp.int32)[:, None]
    pos = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - 1, flat_e[:, None],
                              axis=1)[:, 0]
    live = (pos < cap) & (flat_w > 0)
    slot = jnp.where(live, flat_e * cap + pos, e * cap)        # sentinel

    send = jnp.zeros((e * cap + 1, d), x_flat.dtype)
    tok_ids = jnp.repeat(jnp.arange(t_l), k)
    send = send.at[slot].set(x_flat[tok_ids], mode="drop")
    return send[:-1], slot, flat_w, tok_ids


def _combine_local(ret, slot, flat_w, tok_ids, e: int, cap: int, t_l: int):
    d = ret.shape[-1]
    y_slots = jnp.concatenate(
        [ret.reshape(e * cap, d), jnp.zeros((1, d), ret.dtype)], axis=0)
    y_assign = y_slots[slot] * flat_w[:, None].astype(ret.dtype)
    return jax.ops.segment_sum(y_assign, tok_ids, num_segments=t_l)


def _local_moe(x_loc, router, w_in, w_gate, w_out, cfg: ModelConfig,
               odp: Optional[OdpRuntime], capacity_scale: float,
               data_axis: str, model_axis: str,
               token_importance: Optional[jax.Array],
               token_mask: Optional[jax.Array] = None):
    """Per-shard dense body. x_loc: (B_l, S, D); experts (E_l, D, F_l).

    token_mask: optional (B_l, S) bool — masked tokens (padding, inactive
    decode slots) get zero routing weight, so they never enter the send
    buffers or consume expert capacity; their output rows are zero.
    """
    b_l, s, d = x_loc.shape
    e = cfg.num_experts
    e_l = w_in.shape[0]
    dp = e // e_l
    t_l = b_l * s

    x_flat = x_loc.reshape(t_l, d)
    topw, topi, cap = _route_local(x_flat, router, cfg, odp, capacity_scale,
                                   token_importance, token_mask, t_l)
    send, slot, flat_w, tok_ids = _fill_send(
        x_flat, topi, topw, e, cap, t_l, cfg.top_k)
    send = send.reshape(dp, e_l, cap, d)

    # dispatch: destination-major -> expert-major
    recv = jax.lax.all_to_all(send, data_axis, split_axis=0, concat_axis=0,
                              tiled=False)
    # recv: (dp, E_l, cap, D): recv[src] = tokens from shard `src`
    xe = recv.transpose(1, 0, 2, 3).reshape(e_l, dp * cap, d)

    act = mlp_activation(cfg)
    dt = x_loc.dtype
    h = jnp.einsum("etd,edf->etf", xe, w_in.astype(dt))
    g = jnp.einsum("etd,edf->etf", xe, w_gate.astype(dt))
    ye = jnp.einsum("etf,efd->etd", act(g) * h, w_out.astype(dt))
    # TP: expert FFN width is model-sharded -> reduce the partial outputs
    ye = jax.lax.psum(ye, model_axis)

    back = ye.reshape(e_l, dp, cap, d).transpose(1, 0, 2, 3)
    ret = jax.lax.all_to_all(back, data_axis, split_axis=0, concat_axis=0,
                             tiled=False)
    y = _combine_local(ret, slot, flat_w, tok_ids, e, cap, t_l)
    return y.reshape(b_l, s, d).astype(x_loc.dtype)


def _local_moe_quant(x_loc, router, experts_q, cfg: ModelConfig,
                     local_meta: MoEQuantMeta, remap,
                     odp: Optional[OdpRuntime], capacity_scale: float,
                     data_axis: str,
                     token_importance: Optional[jax.Array],
                     token_mask: Optional[jax.Array] = None):
    """Per-shard quantized body: packed per-class planes, fused FFN.

    ``experts_q`` holds this shard's slice of every class's plane stack
    (``local_meta`` class layout); ``remap`` is the static shard-major EP
    slot table. The FFN width is not TP-sharded — planes replicate over
    ``model`` and no psum is needed (every model shard computes the full,
    identical output).
    """
    b_l, s, d = x_loc.shape
    e = cfg.num_experts
    e_l = local_meta.num_experts
    dp = e // e_l
    t_l = b_l * s

    x_flat = x_loc.reshape(t_l, d)
    topw, topi, cap = _route_local(x_flat, router, cfg, odp, capacity_scale,
                                   token_importance, token_mask, t_l)
    send, slot, flat_w, tok_ids = _fill_send(
        x_flat, topi, topw, e, cap, t_l, cfg.top_k, remap=remap)
    send = send.reshape(dp, e_l, cap, d)

    recv = jax.lax.all_to_all(send, data_axis, split_axis=0, concat_axis=0,
                              tiled=False)
    xe = recv.transpose(1, 0, 2, 3).reshape(e_l, dp * cap, d)

    # EP slots are not count-prefix-ordered (each source shard fills its
    # own quota prefix), so no dead-row skipping here: all dp*cap rows run.
    # Empty slots are zero vectors and the gated FFN maps 0 -> 0.
    counts = jnp.full((e_l,), dp * cap, jnp.int32)
    ye = moe_ffn_quant(xe, experts_q, counts, meta=local_meta,
                       act=cfg.mlp_act,
                       out_dtype=jnp.float32).astype(x_loc.dtype)

    back = ye.reshape(e_l, dp, cap, d).transpose(1, 0, 2, 3)
    ret = jax.lax.all_to_all(back, data_axis, split_axis=0, concat_axis=0,
                             tiled=False)
    y = _combine_local(ret, slot, flat_w, tok_ids, e, cap, t_l)
    return y.reshape(b_l, s, d).astype(x_loc.dtype)


def apply_moe_shard_map(p: Dict, x: jax.Array, cfg: ModelConfig, mesh, *,
                        quant_meta: Optional[MoEQuantMeta] = None,
                        odp: Optional[OdpRuntime] = None,
                        capacity_scale: float = 1.0,
                        token_importance: Optional[jax.Array] = None,
                        token_mask: Optional[jax.Array] = None,
                        data_axis: str = "data",
                        model_axis: str = "model") -> jax.Array:
    """shard_map-wrapped MoE layer (dense or PMQ-quantized experts).

    x sharded P(data, None, None). Dense experts P(data, None, model);
    with ``quant_meta``, ``p['experts_q']`` packed planes are sharded
    along their expert axis over ``data`` (every class count must divide
    the axis) and the local FFN runs the fused grouped quantized kernel.
    token_importance / token_mask are optional (B, S) arrays sharded with
    the batch (ODP protection scores / live-token mask — the serving
    engines thread the latter so idle decode slots never send tokens).
    """
    extras, extra_specs, have = [], [], []
    for extra in (token_importance, token_mask):
        if extra is not None:
            extra_specs.append(P(data_axis, None))
            extras.append(extra)
        have.append(extra is not None)

    def unpack_extras(rest):
        it = iter(rest)
        ti = next(it) if have[0] else None
        tm = next(it) if have[1] else None
        return ti, tm

    if quant_meta is not None:
        dp = dict(mesh.shape)[data_axis]
        validate_ep_quant_meta(quant_meta, dp)
        lmeta = local_quant_meta(quant_meta, dp)
        remap = jnp.asarray(ep_slot_table(quant_meta, dp))
        fn = functools.partial(
            _local_moe_quant, cfg=cfg, local_meta=lmeta, remap=remap,
            odp=odp, capacity_scale=capacity_scale, data_axis=data_axis)

        in_specs = [P(data_axis, None, None), P(None, None),
                    P(data_axis)] + extra_specs
        args = [x, p["router"], p["experts_q"]] + extras

        def body(xl, r, eq, *rest):
            ti, tm = unpack_extras(rest)
            return fn(xl, r, eq, token_importance=ti, token_mask=tm)

        return shctx.shard_map(
            body, mesh, tuple(in_specs), P(data_axis, None, None))(*args)

    fn = functools.partial(
        _local_moe, cfg=cfg, odp=odp, capacity_scale=capacity_scale,
        data_axis=data_axis, model_axis=model_axis)

    in_specs = [P(data_axis, None, None), P(None, None),
                P(data_axis, None, model_axis),
                P(data_axis, None, model_axis),
                P(data_axis, model_axis, None)] + extra_specs
    args = [x, p["router"], p["w_in"], p["w_gate"], p["w_out"]] + extras

    def body(xl, r, wi, wg, wo, *rest):
        ti, tm = unpack_extras(rest)
        return fn(xl, r, wi, wg, wo, token_importance=ti, token_mask=tm)

    return shctx.shard_map(
        body, mesh, tuple(in_specs), P(data_axis, None, None))(*args)
