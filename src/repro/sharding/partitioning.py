"""Partitioning utilities: PartitionSpec trees -> NamedSharding trees,
batch specs, divisibility-safe demotion.

Parameter layout (DESIGN.md §5): FSDP over ``data`` + TP over ``model``;
``pod`` carries only batch DP (params replicated across pods — cross-pod
traffic is the gradient all-reduce, DCN-friendly). Any spec axis that does
not divide its dimension is demoted to replicated rather than relying on
GSPMD padding — keeps memory_analysis honest.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def sanitize_spec(mesh: Mesh, spec: P, shape: Tuple[int, ...]) -> P:
    """Drop axes that don't exist in the mesh or don't divide the dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axis in zip(shape, entries):
        if axis is None:
            out.append(None)
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        axes = tuple(a for a in axes if a in mesh.shape)
        if not axes:
            out.append(None)
            continue
        size = _axis_size(mesh, axes)
        if dim % size != 0:
            # try a prefix of the axes that divides
            while axes and dim % _axis_size(mesh, axes) != 0:
                axes = axes[:-1]
            out.append(axes if axes else None)
            continue
        out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def shardings_for(mesh: Mesh, specs, shapes) -> Any:
    """tree of (spec, ShapeDtypeStruct) -> tree of NamedSharding."""
    def one(spec, arr):
        spec = spec if isinstance(spec, P) else P()
        return NamedSharding(mesh, sanitize_spec(mesh, spec, arr.shape))
    return jax.tree.map(one, specs, shapes,
                        is_leaf=lambda v: isinstance(v, P))


def batch_spec(mesh: Mesh, global_batch: int, ndim: int = 2) -> P:
    """Shard the batch dim over (pod, data) when divisible, else demote."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if not axes:
        return P(*([None] * ndim))
    if global_batch % _axis_size(mesh, axes) != 0:
        while axes and global_batch % _axis_size(mesh, axes) != 0:
            axes = axes[:-1]
    lead = axes if axes else None
    return P(lead, *([None] * (ndim - 1)))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def meshes_equal(a: Optional[Mesh], b: Optional[Mesh]) -> bool:
    """True when two meshes describe the same device layout: same axis
    names, same shape, same devices in the same order. Identity is *not*
    required — a mesh rebuilt over the same devices places arrays
    identically, so callers deciding whether to re-place params must use
    this, never ``is`` (`serve.engine.from_artifact`)."""
    if a is None or b is None:
        return False                 # "no mesh" never equals a mesh
    if a is b:
        return True
    if a.axis_names != b.axis_names or a.devices.shape != b.devices.shape:
        return False
    return all(da is db or da.id == db.id for da, db in
               zip(a.devices.flat, b.devices.flat))


def mesh_process_indices(mesh: Mesh) -> Tuple[int, ...]:
    """Sorted process indices owning at least one device of the mesh."""
    return tuple(sorted({d.process_index for d in mesh.devices.flat}))


def mesh_spans_processes(mesh: Optional[Mesh]) -> bool:
    """True when the mesh's devices belong to more than one process —
    the regime where each process holds only its addressable shards and
    engines must boot from per-host partial artifacts."""
    return mesh is not None and len(mesh_process_indices(mesh)) > 1


def expert_placement_shardings(mesh: Mesh, params, expert_axes,
                               axis: str = "data"):
    """NamedSharding tree for an artifact param tree under expert parallelism.

    ``expert_axes`` maps key paths (``jax.tree_util.keystr``) of packed
    expert planes to their expert axis; those leaves get that axis sharded
    over mesh axis ``axis`` — subject to the module's divisibility rule
    (:func:`sanitize_spec` demotes a class slice whose expert count does
    not divide the axis to replicated rather than relying on GSPMD
    padding). Every other leaf (router, attention, norms, embeddings) is
    replicated, matching the serving layout where routing is computed
    everywhere and only expert FFNs are distributed.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for kp, leaf in flat:
        ax = expert_axes.get(jax.tree_util.keystr(kp))
        if ax is None:
            out.append(NamedSharding(mesh, P()))
            continue
        spec = [None] * np.ndim(leaf)
        spec[ax] = axis
        out.append(NamedSharding(
            mesh, sanitize_spec(mesh, P(*spec), np.shape(leaf))))
    return jax.tree_util.tree_unflatten(treedef, out)


def spec_tree_to_shardings(mesh: Mesh, spec_tree, shape_tree):
    """Like shardings_for but tolerates structure mismatches by walking
    the shape tree and looking specs up positionally."""
    flat_specs = jax.tree.flatten(
        spec_tree, is_leaf=lambda v: isinstance(v, P))[0]
    flat_shapes, treedef = jax.tree.flatten(shape_tree)
    assert len(flat_specs) == len(flat_shapes), \
        (len(flat_specs), len(flat_shapes))
    out = [NamedSharding(mesh, sanitize_spec(mesh, sp, sh.shape))
           for sp, sh in zip(flat_specs, flat_shapes)]
    return jax.tree.unflatten(treedef, out)
