"""Int8 gradient compression with error feedback (1-bit-Adam-family trick).

``compress_decompress_ef`` models the lossy channel the DP all-reduce would
traverse at int8: the gradient plus the carried error buffer is quantized
per-row to int8, the quantization residual becomes the next step's error
feedback. Convergence-wise this is exactly what a compressed all-reduce
does; on the wire it cuts DP gradient bytes 4x (bf16 -> int8 + scale row).

Integration note (DESIGN.md §5): under pjit the backward all-reduce is
emitted by XLA, so the compression runs around it (error feedback keeps the
*optimizer trajectory* faithful to a compressed collective). The shard_map
EP/DP path in `sharding/moe_parallel.py` is where a hand-rolled int8
``psum`` would slot in; the EF library here is collective-agnostic.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _q8_roundtrip(x: jax.Array) -> jax.Array:
    if x.ndim == 0 or x.shape[-1] < 16:
        return x
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q * scale


def compress_decompress_ef(grads: Any, error_buf: Any) -> Tuple[Any, Any]:
    """Returns (decompressed grads, new error buffers)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        gq = _q8_roundtrip(g32)
        return gq.astype(g.dtype), g32 - gq
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.flatten(error_buf)[0]
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))
