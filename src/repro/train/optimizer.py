"""Optimizers: AdamW with optional 8-bit block-quantized moments.

The 8-bit state (per-row absmax int8, dynamic dequant in the update) is the
distributed-optimization trick that makes the 480B-class archs fit v5e HBM:
moment memory drops 4x (8+8 bytes -> 1+1 + scale row), see DESIGN.md §5.
State sharding mirrors the parameter specs (FSDP over `data`).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import TrainConfig


class Quant8(NamedTuple):
    """Per-row absmax-quantized tensor (last dim is the block)."""

    q: jax.Array       # int8, same shape as the dense tensor
    scale: jax.Array   # f32, shape = tensor.shape[:-1]


class Quant8Sq(NamedTuple):
    """Sqrt-domain uint8 coding for non-negative tensors (2nd moments).

    ``v = scale * (code/255)^2`` — quadratic spacing gives small elements
    ~4x finer resolution, and the decoded quantization step defines the
    Adam eps floor (under-resolved elements must not rsqrt-explode).
    """

    q: jax.Array       # uint8
    scale: jax.Array   # f32 row max


def q8_encode(x: jax.Array) -> Quant8:
    absmax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
    return Quant8(q, scale)


def q8_decode(t: Quant8) -> jax.Array:
    return t.q.astype(jnp.float32) * t.scale[..., None]


def q8sq_encode(v: jax.Array) -> Quant8Sq:
    scale = jnp.maximum(jnp.max(v, axis=-1), 1e-20)
    code = jnp.round(255.0 * jnp.sqrt(v / scale[..., None]))
    return Quant8Sq(jnp.clip(code, 0, 255).astype(jnp.uint8), scale)


def q8sq_decode(t: Quant8Sq) -> jax.Array:
    c = t.q.astype(jnp.float32) / 255.0
    return t.scale[..., None] * c * c


def q8sq_eps(t_scale: jax.Array) -> jax.Array:
    """rsqrt floor: half an LSB of the sqrt-domain code."""
    return jnp.sqrt(t_scale)[..., None] / 255.0


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any      # tree of f32 or Quant8
    v: Any


def _zeros_like_moment(p, quantized: bool, second: bool = False):
    if quantized and p.ndim >= 1 and p.shape[-1] >= 16:
        if second:
            return Quant8Sq(jnp.zeros(p.shape, jnp.uint8),
                            jnp.zeros(p.shape[:-1], jnp.float32))
        return Quant8(jnp.zeros(p.shape, jnp.int8),
                      jnp.zeros(p.shape[:-1], jnp.float32))
    return jnp.zeros(p.shape, jnp.float32)


def adamw_init(params, tcfg: TrainConfig) -> AdamWState:
    quantized = tcfg.optimizer == "adamw8bit"
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(lambda p: _zeros_like_moment(p, quantized), params),
        v=jax.tree.map(lambda p: _zeros_like_moment(p, quantized, True),
                       params))


def _read(t):
    if isinstance(t, Quant8):
        return q8_decode(t)
    if isinstance(t, Quant8Sq):
        return q8sq_decode(t)
    return t


def _write(val, like):
    if isinstance(like, Quant8):
        return q8_encode(val)
    if isinstance(like, Quant8Sq):
        return q8sq_encode(val)
    return val


def adamw_update(grads, state: AdamWState, params, lr: jax.Array,
                 tcfg: TrainConfig) -> Tuple[Any, AdamWState]:
    b1, b2, eps, wd = tcfg.beta1, tcfg.beta2, tcfg.eps, tcfg.weight_decay
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * _read(m) + (1 - b1) * g32
        v_new = b2 * _read(v) + (1 - b2) * g32 ** 2
        mh = m_new / c1
        vh = v_new / c2
        eps_eff = eps
        if isinstance(v, Quant8Sq):
            # under-resolved v elements must not rsqrt-explode: floor the
            # denominator at the decoded quantization step
            row = jnp.max(v_new, axis=-1)
            eps_eff = q8sq_eps(row / c2) + eps
        delta = mh / (jnp.sqrt(vh) + eps_eff) + wd * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, _write(m_new, m), _write(v_new, v)

    is_q = lambda t: isinstance(t, (Quant8, Quant8Sq))
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.flatten(grads)[0]
    flat_m = jax.tree.flatten(state.m, is_leaf=is_q)[0]
    flat_v = jax.tree.flatten(state.v, is_leaf=is_q)[0]
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def moment_specs(param_specs, params_shape, quantized: bool,
                 second: bool = False):
    """Sharding specs for moments mirroring the parameter specs."""
    def one(spec, p):
        spec = spec if isinstance(spec, P) else P()
        if quantized and p.ndim >= 1 and p.shape[-1] >= 16:
            entries = list(spec)[:max(p.ndim - 1, 0)]
            cls = Quant8Sq if second else Quant8
            return cls(q=spec, scale=P(*entries))
        return spec
    return jax.tree.map(one, param_specs, params_shape,
                        is_leaf=lambda v: isinstance(v, P))


# ------------------------------------------------------------- lr schedule
def lr_schedule(tcfg: TrainConfig):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, s / max(tcfg.warmup_steps, 1))
        prog = jnp.clip((s - tcfg.warmup_steps)
                        / max(tcfg.total_steps - tcfg.warmup_steps, 1),
                        0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(np.pi * prog))
        return tcfg.learning_rate * warm * (0.1 + 0.9 * cos)
    return fn


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    factor = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * factor
                                   ).astype(x.dtype), tree), norm
