"""Training step: loss (CE + z-loss + MoE load-balance) + AdamW update.

Built as a closure over the model so ``jax.jit(step).lower()`` works for the
multi-pod dry-run. Gradients are clipped by global norm; optional int8
gradient compression with error feedback runs on the DP gradient path.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.train import optimizer as opt_lib
from repro.train.grad_compression import compress_decompress_ef


class TrainState(NamedTuple):
    params: Any
    opt: opt_lib.AdamWState
    ef: Any                 # error-feedback buffers (or None)


def init_train_state(model, key, tcfg: TrainConfig) -> TrainState:
    params = model.init(key)
    opt = opt_lib.adamw_init(params, tcfg)
    ef = None
    if tcfg.grad_compression == "int8_ef":
        ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(params=params, opt=opt, ef=ef)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       z_loss: float = 0.0) -> jax.Array:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    nll = (lse - gold).mean()
    if z_loss > 0:
        nll = nll + z_loss * jnp.mean(lse ** 2)
    return nll


def make_loss_fn(model, cfg: ModelConfig, tcfg: TrainConfig):
    def loss_fn(params, batch):
        kwargs = {}
        if "enc_frames" in batch:
            kwargs["enc_frames"] = batch["enc_frames"]
        if "prefix_embeds" in batch:
            kwargs["prefix_embeds"] = batch["prefix_embeds"]
        logits, _, aux = model.forward(params, batch["tokens"], **kwargs)
        labels = batch["labels"]
        if logits.shape[1] != labels.shape[1]:   # vlm prefix offset
            logits = logits[:, -labels.shape[1]:]
        loss = cross_entropy_loss(logits, labels, tcfg.z_loss)
        metrics = {"ce_loss": loss}
        lb = sum(v for k, v in aux.items() if k.startswith("load_balance"))
        if cfg.is_moe and not isinstance(lb, int):
            loss = loss + tcfg.aux_loss_weight * lb
            metrics["load_balance"] = lb
        metrics["loss"] = loss
        return loss, metrics
    return loss_fn


def make_train_step(model, cfg: ModelConfig, tcfg: TrainConfig):
    loss_fn = make_loss_fn(model, cfg, tcfg)
    sched = opt_lib.lr_schedule(tcfg)

    def train_step(state: TrainState, batch: Dict
                   ) -> Tuple[TrainState, Dict]:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        new_ef = state.ef
        if state.ef is not None:
            grads, new_ef = compress_decompress_ef(grads, state.ef)
        grads, gnorm = opt_lib.clip_by_global_norm(grads, tcfg.grad_clip)
        lr = sched(state.opt.step)
        new_params, new_opt = opt_lib.adamw_update(
            grads, state.opt, state.params, lr, tcfg)
        metrics.update(grad_norm=gnorm, lr=lr,
                       step=new_opt.step.astype(jnp.float32))
        return TrainState(new_params, new_opt, new_ef), metrics

    return train_step
