"""Property-test shim: real `hypothesis` when installed, else a small
deterministic fallback so collection never errors in offline environments.

The fallback runs each `@given` test over a fixed, seeded sample grid
(`max_examples` draws from a `RandomState(0)`), which keeps the property
coverage meaningful while being dependency-free. Only the strategy surface
the test suite uses is implemented: `sampled_from`, `integers`, `floats`.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda rng: opts[rng.randint(len(opts))])

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.randint(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

    st = _Strategies()

    def settings(max_examples: int = 10, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # read off the wrapper: @settings may sit above OR below
                # @given (wraps() copies a below-@settings attr here, and
                # an above-@settings sets it here directly)
                n = getattr(wrapper, "_max_examples", 10)
                rng = np.random.RandomState(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            # hide the drawn parameters from pytest's fixture resolution
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=params)
            return wrapper
        return deco
