"""IP bit-allocation tests — incl. optimality cross-check vs scipy MILP."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.allocation import (
    AllocationResult, allocate_greedy_metric, allocate_layer,
    allocate_random, allocate_uniform, build_costs, solve_allocation,
)


def _rand_costs(rng, n, decreasing=True):
    base = rng.rand(n, 3) + 0.01
    if decreasing:  # eps falls with more bits, as in reality
        base = np.sort(base, axis=1)[:, ::-1]
    return base


class TestSolver:
    def test_budget_respected(self):
        rng = np.random.RandomState(0)
        for k in (1.57, 2.05, 2.54):
            costs = _rand_costs(rng, 8)
            res = solve_allocation(costs, k)
            assert res.bits.sum() <= int(np.floor(8 * k))
            assert res.achieved_bits <= k + 1e-9

    def test_presence_constraints(self):
        rng = np.random.RandomState(1)
        costs = _rand_costs(rng, 8)
        res = solve_allocation(costs, 2.0)
        assert (res.bits == 3).sum() >= 1
        assert (res.bits == 2).sum() >= 1

    def test_all_max_bits_when_budget_allows(self):
        costs = _rand_costs(np.random.RandomState(2), 8)
        # presence constraint pins one expert at 2-bit even at k = 3.0
        res = solve_allocation(costs, 3.0)
        assert (res.bits == 3).sum() == 7 and (res.bits == 2).sum() == 1
        # without presence constraints, saturate to all-3-bit
        res2 = solve_allocation(costs, 3.0, require_presence=False)
        assert np.all(res2.bits == 3)

    def test_important_experts_get_more_bits(self):
        """An expert with huge cost-at-low-bits must receive 3 bits."""
        costs = np.ones((8, 3)) * 0.1
        costs[3, 0] = 100.0  # expert 3 catastrophic at 1 bit
        costs[3, 1] = 50.0   # bad at 2 bits
        costs[3, 2] = 0.01
        res = solve_allocation(costs, 2.0)
        assert res.bits[3] == 3

    def test_objective_matches_allocation(self):
        rng = np.random.RandomState(3)
        costs = _rand_costs(rng, 16)
        res = solve_allocation(costs, 2.2)
        obj = sum(costs[i, res.bits[i] - 1] for i in range(16))
        assert obj == pytest.approx(res.objective, rel=1e-9)

    @given(n=st.sampled_from([4, 8, 16]),
           k=st.floats(1.3, 2.9),
           seed=st.integers(0, 10 ** 6))
    @settings(max_examples=20, deadline=None)
    def test_matches_scipy_milp(self, n, k, seed):
        """DP optimum == MILP optimum (same constraints) on random instances."""
        from scipy.optimize import LinearConstraint, Bounds, milp
        rng = np.random.RandomState(seed)
        costs = _rand_costs(rng, n)
        budget = int(np.floor(n * k))
        if budget < n + 3:
            return  # presence-infeasible corner: DP degrades gracefully
        res = solve_allocation(costs, k)
        c = costs.reshape(-1)
        a_rows, lb, ub = [], [], []
        # one width per expert
        for i in range(n):
            row = np.zeros(3 * n); row[3 * i: 3 * i + 3] = 1
            a_rows.append(row); lb.append(1); ub.append(1)
        # total bits == res budget (exact; DP relaxes downward only when
        # infeasible, so feed the budget DP actually achieved)
        row = np.zeros(3 * n)
        for i in range(n):
            row[3 * i: 3 * i + 3] = [1, 2, 3]
        a_rows.append(row); lb.append(int(res.bits.sum())); ub.append(int(res.bits.sum()))
        # presence
        row3 = np.zeros(3 * n); row3[2::3] = 1
        a_rows.append(row3); lb.append(1); ub.append(n)
        row2 = np.zeros(3 * n); row2[1::3] = 1
        a_rows.append(row2); lb.append(1); ub.append(n)

        lc = LinearConstraint(np.array(a_rows), lb, ub)
        sol = milp(c, constraints=lc, integrality=np.ones(3 * n),
                   bounds=Bounds(0, 1))
        assert sol.success
        assert res.objective == pytest.approx(sol.fun, rel=1e-6, abs=1e-9)

    def test_layer_convenience(self):
        rng = np.random.RandomState(4)
        freq = rng.rand(8); w = rng.rand(8); eps = _rand_costs(rng, 8)
        res = allocate_layer(freq, w, eps, target_bits=2.54)
        assert isinstance(res, AllocationResult)
        assert res.bits.shape == (8,)

    def test_cost_weighting_direction(self):
        """Higher significance -> bigger penalty for low bits."""
        freq = np.array([0.9, 0.01]); w = np.array([0.5, 0.01])
        eps = np.array([[1.0, 0.5, 0.1], [1.0, 0.5, 0.1]])
        costs = build_costs(freq, w, eps)
        assert costs[0, 0] > costs[1, 0]


class TestBaselines:
    def test_uniform(self):
        assert np.all(allocate_uniform(8, 2) == 2)

    def test_random_budget(self):
        rng = np.random.RandomState(0)
        for _ in range(10):
            a = allocate_random(8, 2.5, rng)
            assert a.sum() <= int(8 * 2.5)
            assert np.all((a >= 1) & (a <= 3))

    def test_greedy_prefers_high_metric(self):
        metric = np.array([10.0, 1.0, 0.1, 0.01])
        a = allocate_greedy_metric(metric, 2.0)
        assert a[0] >= a[1] >= a[2] >= a[3]
        assert a.sum() <= 8
