"""Expert-major artifact sharding: streaming subset loads + EP serving.

Fast-slice guarantees (PR-gating):

* a per-host subset load reads strictly fewer bytes than the full load —
  and < 60% of total artifact bytes at 2 hosts (the acceptance headline);
* the union of per-host subsets reconstructs the full pytree exactly;
* a corrupted shard group fails its fingerprint check loudly (and only
  when a load actually touches that group);
* missing payload leaves error with the offending key path; v1 manifests
  still load; newer manifest/artifact versions fail with an upgrade
  message;
* mesh-placed serving from ``load_sharded`` is token-identical to the
  single-host ``from_artifact`` path.

The multi-device (2-way expert-parallel) equivalence runs as a slow
subprocess test, same pattern as ``test_moe_parallel``.
"""
import json
import re
import shutil
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from benchmarks.bench_artifact_loading import build_artifact, _tree_equal
from repro.checkpoint import checkpointer as ckpt_lib
from repro.configs import get_config
from repro.core import pipeline
from repro.launch.mesh import single_device_mesh
from repro.models.layers.moe import MoEQuantMeta
from repro.models.transformer import DecoderModel
from repro.serve.engine import Request, ServeEngine
from repro.sharding import moe_parallel as mp

ROOT = Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def saved(tmp_path_factory):
    """A small but expert-heavy artifact saved in the expert-major layout
    (16 experts so 2-host byte-balanced splits have granularity)."""
    d = tmp_path_factory.mktemp("artifact")
    model, artifact, step_dir = build_artifact(
        d, num_experts=16, d_model=32, moe_d_ff=384, vocab_size=64,
        group_size=32)
    return model, artifact, d, step_dir


def _gen(model, artifact, mesh=None, n_req=3, max_new=4):
    eng = ServeEngine.from_artifact(model, artifact, mesh=mesh,
                                    batch_size=2)
    reqs = [Request(uid=i, prompt=np.arange(1 + i, 9 + i, dtype=np.int32),
                    max_new_tokens=max_new) for i in range(n_req)]
    return [r.tokens for r in eng.run(reqs)]


# ------------------------------------------------------------ byte accounting
class TestShardedLoading:
    def test_two_host_subsets_read_under_60_percent(self, saved):
        _, _, d, _ = saved
        full = pipeline.CompressedArtifact.load(d)
        total = full.load_stats.total_bytes
        assert full.load_stats.bytes_read == total

        parts = []
        for h in range(2):
            art = pipeline.CompressedArtifact.load_sharded(
                d, num_hosts=2, host=h)
            st = art.load_stats
            assert st.bytes_read < total, "subset must read fewer bytes"
            assert st.read_fraction < 0.60, (
                f"host {h} read {st.read_fraction:.0%} of the artifact")
            assert st.groups_read < st.total_groups
            parts.append((art.params, st))

        merged = ckpt_lib.merge_subset_trees(parts)
        assert _tree_equal(merged, full.params), \
            "union of host subsets must reconstruct the full tree exactly"

    def test_host_ranges_tile_and_balance(self, saved):
        _, artifact, d, _ = saved
        e = artifact.num_experts
        arts = [pipeline.CompressedArtifact.load_sharded(
                    d, num_hosts=2, host=h) for h in range(2)]
        (a0, a1), (b0, b1) = arts[0].expert_range, arts[1].expert_range
        assert (a0, b1) == (0, e) and a1 == b0, "ranges must tile [0, E)"
        assert all(a.is_partial for a in arts)
        # byte-balanced: a count-skewed split (e.g. [0:15)/[15:16)) would
        # blow one host's read fraction well past 60%
        for a in arts:
            assert a.load_stats.read_fraction < 0.60, a.expert_range

    def test_explicit_range_and_partial_flag(self, saved):
        model, artifact, d, _ = saved
        art = pipeline.CompressedArtifact.load_sharded(
            d, expert_range=(0, 4))
        assert art.expert_range == (0, 4) and art.is_partial
        with pytest.raises(ValueError, match="experts \\[0:4\\)"):
            ServeEngine.from_artifact(model, art)

    def test_byte_balanced_ranges(self):
        assert pipeline.byte_balanced_ranges([1, 1, 1, 1], 2) == \
            [(0, 2), (2, 4)]
        assert pipeline.byte_balanced_ranges([1, 1, 1, 10], 2) == \
            [(0, 3), (3, 4)]
        assert pipeline.byte_balanced_ranges([5, 1, 1, 1, 1], 2) == \
            [(0, 1), (1, 5)]
        # 1 host: everything; H == E: exactly one expert per host
        assert pipeline.byte_balanced_ranges([3, 1, 2], 1) == [(0, 3)]
        assert pipeline.byte_balanced_ranges([3, 1, 2], 3) == \
            [(0, 1), (1, 2), (2, 3)]
        with pytest.raises(ValueError, match="cannot split"):
            pipeline.byte_balanced_ranges([1], 2)

    def test_single_host_load_is_full(self, saved):
        _, _, d, _ = saved
        art = pipeline.CompressedArtifact.load_sharded(d, num_hosts=1,
                                                       host=0)
        assert art.expert_range == (0, art.num_experts)
        assert not art.is_partial
        st = art.load_stats
        assert st.bytes_read == st.total_bytes

    def test_num_hosts_equals_num_experts(self, saved):
        _, artifact, d, _ = saved
        e = artifact.num_experts
        arts = [pipeline.CompressedArtifact.load_sharded(
            d, num_hosts=e, host=h) for h in range(e)]
        ranges = [a.expert_range for a in arts]
        assert ranges[0][0] == 0 and ranges[-1][1] == e
        for (_, a1), (b0, _) in zip(ranges, ranges[1:]):
            assert a1 == b0, "one-expert blocks must tile [0, E)"
        assert all(k1 - k0 == 1 for k0, k1 in ranges)
        assert all(a.is_partial for a in arts)

    def test_host_out_of_range(self, saved):
        _, _, d, _ = saved
        with pytest.raises(ValueError, match="out of range"):
            pipeline.CompressedArtifact.load_sharded(d, num_hosts=2,
                                                     host=2)
        with pytest.raises(ValueError, match="out of range"):
            pipeline.CompressedArtifact.load_sharded(d, num_hosts=2,
                                                     host=-1)

    def test_partial_rejection_message_on_meshless_engine(self, saved):
        model, _, d, _ = saved
        art = pipeline.CompressedArtifact.load_sharded(
            d, num_hosts=2, host=1)
        k0, k1 = art.expert_range
        with pytest.raises(ValueError) as exc:
            ServeEngine.from_artifact(model, art)
        msg = str(exc.value)
        assert f"[{k0}:{k1})" in msg
        assert "per-host stream" in msg
        assert "full expert layout" in msg

    def test_mesh_serving_token_identical(self, saved):
        model, _, d, _ = saved
        base = _gen(model, pipeline.CompressedArtifact.load(d))
        mesh = single_device_mesh()
        sharded = pipeline.CompressedArtifact.load_sharded(d, mesh)
        assert not sharded.is_partial
        for a, b in zip(base, _gen(model, sharded, mesh=mesh)):
            np.testing.assert_array_equal(a, b)


# ------------------------------------------------------- integrity + versions
class TestIntegrity:
    def _copy(self, step_dir, tmp_path):
        dst = tmp_path / "artifact"
        shutil.copytree(step_dir.parent, dst)
        return dst

    def test_fingerprint_mismatch_fails_loudly(self, saved, tmp_path):
        _, _, _, step_dir = saved
        d = self._copy(step_dir, tmp_path)
        mpath = d / step_dir.name / "manifest.json"
        man = json.loads(mpath.read_text())
        group = next(g for g in man["groups"]
                     if pipeline.expert_of_group(g) == 0)
        # tamper: recorded fingerprint no longer matches the file bytes
        man["groups"][group]["files"][0]["sha256"] = "0" * 64
        mpath.write_text(json.dumps(man))

        with pytest.raises(ValueError, match="fingerprint"):
            pipeline.CompressedArtifact.load(d)
        # a subset that avoids the corrupt group still loads
        art = pipeline.CompressedArtifact.load_sharded(
            d, expert_range=(1, 3))
        assert art.expert_range == (1, 3)
        # verify=False is the explicit escape hatch
        pipeline.CompressedArtifact.load(d, verify=False)

    def test_missing_leaf_errors_with_key_path(self, saved, tmp_path):
        _, _, _, step_dir = saved
        d = self._copy(step_dir, tmp_path)
        mpath = d / step_dir.name / "manifest.json"
        man = json.loads(mpath.read_text())
        rec = man["leaves"][0]
        rec["key"] = "leaf_999999"
        mpath.write_text(json.dumps(man))
        # the offending key path must be named (KeyError str-escapes the
        # quotes, so match on the bare dict keys)
        inner = ".*".join(re.findall(r"\w+", rec["path"]))
        with pytest.raises(KeyError, match=f"missing leaf.*{inner}"):
            pipeline.CompressedArtifact.load(d)

    def test_future_manifest_version_rejected(self, saved, tmp_path):
        _, _, _, step_dir = saved
        d = self._copy(step_dir, tmp_path)
        mpath = d / step_dir.name / "manifest.json"
        man = json.loads(mpath.read_text())
        man["format_version"] = ckpt_lib.FORMAT_VERSION + 1
        mpath.write_text(json.dumps(man))
        with pytest.raises(ValueError, match="upgrade repro"):
            ckpt_lib.load_pytree(d)

    def test_future_artifact_version_rejected(self, saved, tmp_path):
        _, _, _, step_dir = saved
        d = self._copy(step_dir, tmp_path)
        mpath = d / step_dir.name / "manifest.json"
        man = json.loads(mpath.read_text())
        man["meta"]["artifact"]["version"] = pipeline.ARTIFACT_VERSION + 1
        mpath.write_text(json.dumps(man))
        with pytest.raises(ValueError, match="upgrade repro"):
            pipeline.CompressedArtifact.load(d)

    def test_v1_manifest_back_compat(self, tmp_path):
        """Checkpoints written before the group format (per-leaf ``shard``
        index, no ``format_version``) must keep loading."""
        ckpt = tmp_path / "ck" / "step_00000000"
        ckpt.mkdir(parents=True)
        a = np.arange(6, dtype=np.float32).reshape(2, 3)
        b = np.arange(4, dtype=np.int32)
        np.savez(ckpt / "shard_00000.npz", leaf_000000=a, leaf_000001=b)
        manifest = {"step": 0, "meta": {}, "time": 0.0, "leaves": [
            {"path": "['a']", "key": "leaf_000000", "shard": 0,
             "shape": [2, 3], "dtype": "float32"},
            {"path": "['b']", "key": "leaf_000001", "shard": 0,
             "shape": [4], "dtype": "int32"},
        ]}
        (ckpt / "manifest.json").write_text(json.dumps(manifest))
        (tmp_path / "ck" / "LATEST").write_text(ckpt.name)

        tree, man = ckpt_lib.load_pytree(tmp_path / "ck")
        np.testing.assert_array_equal(np.asarray(tree["a"]), a)
        np.testing.assert_array_equal(np.asarray(tree["b"]), b)
        restored, step = ckpt_lib.restore_pytree(
            tmp_path / "ck", {"a": a, "b": b})
        assert step == 0
        np.testing.assert_array_equal(np.asarray(restored["a"]), a)


# ------------------------------------------------- checkpointer split leaves
class TestSplitLeaves:
    def _save(self, tmp_path, arr):
        def split(path, a):
            if path == "['w']":
                return 0, [f"g.expert{j:04d}" for j in range(a.shape[0])]
            return None
        return ckpt_lib.save_pytree(tmp_path / "ck", 0,
                                    {"w": arr, "d": np.ones(3, np.float32)},
                                    split_fn=split)

    def test_split_roundtrip_and_partial(self, tmp_path):
        arr = np.arange(4 * 6, dtype=np.float32).reshape(4, 6)
        self._save(tmp_path, arr)
        tree, _ = ckpt_lib.load_pytree(tmp_path / "ck")
        np.testing.assert_array_equal(np.asarray(tree["w"]), arr)

        keep = lambda p, g: pipeline.expert_of_group(g) in (None, 1, 2)
        sub, _, stats = ckpt_lib.load_pytree_subset(tmp_path / "ck", keep)
        np.testing.assert_array_equal(np.asarray(sub["w"]), arr[1:3])
        assert stats.partial["['w']"] == (1, 3, 4)
        assert stats.split_axes["['w']"] == 0
        assert stats.bytes_read < stats.total_bytes

    def test_noncontiguous_subset_rejected(self, tmp_path):
        arr = np.arange(4 * 6, dtype=np.float32).reshape(4, 6)
        self._save(tmp_path, arr)
        keep = lambda p, g: pipeline.expert_of_group(g) in (None, 0, 2)
        with pytest.raises(ValueError, match="non-contiguous"):
            ckpt_lib.load_pytree_subset(tmp_path / "ck", keep)

    def test_merge_rejects_gaps(self, tmp_path):
        arr = np.arange(4 * 6, dtype=np.float32).reshape(4, 6)
        self._save(tmp_path, arr)
        keep0 = lambda p, g: pipeline.expert_of_group(g) in (None, 0)
        keep2 = lambda p, g: pipeline.expert_of_group(g) in (None, 2, 3)
        t0, _, s0 = ckpt_lib.load_pytree_subset(tmp_path / "ck", keep0)
        t2, _, s2 = ckpt_lib.load_pytree_subset(tmp_path / "ck", keep2)
        with pytest.raises(ValueError, match="do not tile"):
            ckpt_lib.merge_subset_trees([(t0, s0), (t2, s2)])
        # a missing *trailing* host must not yield a silently truncated
        # array either
        with pytest.raises(ValueError, match="do not tile"):
            ckpt_lib.merge_subset_trees([(t0, s0)])


# ------------------------------------------- distributed placement (fast)
class TestDistributedPlacement:
    """Pure range/expectation algebra plus the single-process behavior of
    the multi-process assembly path; the real 2-process run lives in
    ``tests/test_distributed_serving.py``."""

    def test_ep_owned_ranges_per_class_blocks(self):
        meta = MoEQuantMeta(bit_classes=(1, 2, 3), class_counts=(2, 4, 2),
                            group_size=32, pack_block=32)
        assert mp.ep_owned_ranges(meta, 2, 0) == ((0, 1), (2, 4), (6, 7))
        assert mp.ep_owned_ranges(meta, 2, 1) == ((1, 2), (4, 6), (7, 8))
        # dense experts: one segment, contiguous equal blocks
        assert mp.ep_owned_ranges(8, 2, 0) == ((0, 4),)
        assert mp.ep_owned_ranges(8, 4, 3) == ((6, 8),)
        # adjacent per-class blocks merge (single class == dense)
        assert mp.ep_owned_ranges(((0, 4),), 2, 1) == ((2, 4),)
        with pytest.raises(ValueError, match="divide"):
            mp.ep_owned_ranges(((0, 3), (3, 5)), 2, 0)
        with pytest.raises(ValueError, match="out of range"):
            mp.ep_owned_ranges(8, 2, 2)

    def test_ep_shard_for_ranges_inverse_and_loud(self):
        meta = MoEQuantMeta(bit_classes=(1, 2, 3), class_counts=(2, 4, 2),
                            group_size=32, pack_block=32)
        for r in range(2):
            assert mp.ep_shard_for_ranges(
                meta, 2, mp.ep_owned_ranges(meta, 2, r)) == r
        with pytest.raises(ValueError, match="gap"):
            mp.ep_shard_for_ranges(meta, 2, ((0, 1),))
        with pytest.raises(ValueError, match="overlap"):
            mp.ep_shard_for_ranges(meta, 2, ((0, 2), (2, 4), (6, 7)))

    def test_expectation_on_single_process_mesh_is_everything(self):
        mesh = single_device_mesh()
        # segments are (start, count); dp=1 owns every class block, and
        # adjacent blocks merge into the full range
        assert pipeline.expert_shard_expectation(
            mesh, ((0, 3), (3, 5)), process_index=0) == ((0, 8),)
        with pytest.raises(ValueError, match="owns no devices"):
            pipeline.expert_shard_expectation(mesh, ((0, 8),),
                                              process_index=1)

    def test_partial_boot_on_wrong_mesh_is_loud(self, saved):
        model, _, d, _ = saved
        art = pipeline.CompressedArtifact.load_sharded(
            d, expert_range=(0, 4))
        with pytest.raises(ValueError, match="expects exactly"):
            ServeEngine.from_artifact(model, art,
                                      mesh=single_device_mesh())

    def test_distributed_params_single_process_matches_tree(self, saved):
        _, _, d, _ = saved
        full = pipeline.CompressedArtifact.load(d)
        placed = pipeline.distributed_params(
            full.params, single_device_mesh(), full.load_stats)
        assert _tree_equal(placed, full.params)

    def test_merge_reconstructs_full_artifact(self, saved):
        model, _, d, _ = saved
        full = pipeline.CompressedArtifact.load(d)
        parts = [pipeline.CompressedArtifact.load_sharded(
            d, num_hosts=2, host=h) for h in range(2)]
        merged = pipeline.CompressedArtifact.merge(parts)
        assert not merged.is_partial
        assert _tree_equal(merged.params, full.params)
        # a merged artifact boots where its parts could not
        ServeEngine.from_artifact(model, merged, batch_size=2)


class TestDenseExpertCheckpoints:
    def _model(self):
        cfg = get_config("mixtral-8x7b", smoke=True).replace(
            dtype="float32", num_layers=2, d_model=32, d_ff=32,
            moe_d_ff=64, num_experts=4, vocab_size=64, scan_layers=False)
        model = DecoderModel(cfg)
        return model, model.init(jax.random.PRNGKey(0))

    def test_roundtrip_and_partial_stream(self, tmp_path):
        _, params = self._model()
        pipeline.save_dense_expert_params(tmp_path / "ck", params)
        full, st, ranges = pipeline.load_dense_expert_params(
            tmp_path / "ck")
        assert ranges == ((0, 4),)
        assert _tree_equal(full, params)

        part, st2, r2 = pipeline.load_dense_expert_params(
            tmp_path / "ck", num_hosts=2, host=0)
        assert r2 == ((0, 2),)
        assert st2.bytes_read < st.bytes_read
        # a partial dense stream cannot land on a single-process mesh
        with pytest.raises(ValueError, match="single-process mesh"):
            pipeline.load_dense_expert_params(
                tmp_path / "ck", single_device_mesh(), num_hosts=2,
                host=0)

    def test_placed_full_load_on_mesh(self, tmp_path):
        _, params = self._model()
        pipeline.save_dense_expert_params(tmp_path / "ck", params)
        placed, _, _ = pipeline.load_dense_expert_params(
            tmp_path / "ck", single_device_mesh())
        assert _tree_equal(placed, params)

    def test_wrong_checkpoint_kinds_are_loud(self, saved, tmp_path):
        _, artifact, d, _ = saved
        with pytest.raises(ValueError, match="dense_moe"):
            pipeline.load_dense_expert_params(d)
        with pytest.raises(ValueError, match="no dense expert stacks"):
            pipeline.save_dense_expert_params(tmp_path / "bad",
                                              artifact.params)


# ----------------------------------------------------- multi-device (slow)
_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import sys; sys.path.insert(0, {src!r}); sys.path.insert(0, {root!r})
    import jax, numpy as np
    from benchmarks.bench_artifact_loading import build_artifact
    from repro.core import pipeline
    from repro.serve.engine import Request, ServeEngine

    d = {tmp!r}
    model, art, _ = build_artifact(
        d, num_experts=4, d_model=32, moe_d_ff=64, vocab_size=64,
        group_size=32)

    def gen(artifact, mesh=None, ep=False, params=None):
        if params is not None:
            eng = ServeEngine(model, params, batch_size=2, mesh=mesh,
                              ep_dispatch=ep)
        else:
            eng = ServeEngine.from_artifact(model, artifact, mesh=mesh,
                                            batch_size=2)
        reqs = [Request(uid=i, prompt=np.arange(1 + i, 9 + i,
                                                dtype=np.int32),
                        max_new_tokens=4) for i in range(3)]
        return [r.tokens for r in eng.run(reqs)]

    base = gen(pipeline.CompressedArtifact.load(d))
    mesh = jax.make_mesh((2, 1), ("data", "model"))
    sharded = pipeline.CompressedArtifact.load_sharded(d, mesh)
    for a, b in zip(base, gen(sharded, mesh=mesh)):
        np.testing.assert_array_equal(a, b)
    print("MESH_TOKENS_OK")

    # dense EP dispatch (shard_map schedule) on the 2-device mesh decodes
    # the same tokens as the single-device gather path (capacity_factor
    # is high enough that neither path drops; dead assignments must not
    # consume quota on either)
    params = model.init(jax.random.PRNGKey(0))
    base_dense = gen(None, params=params)
    toks = gen(None, mesh=mesh, ep=True, params=params)
    for a, b in zip(base_dense, toks):
        np.testing.assert_array_equal(a, b)
    print("EP_SERVE_OK")
""")


@pytest.mark.slow
def test_two_device_sharded_serving(tmp_path):
    out = subprocess.run(
        [sys.executable, "-c", _PROG.format(
            src=str(ROOT / "src"), root=str(ROOT),
            tmp=str(tmp_path / "artifact"))],
        capture_output=True, text=True, timeout=900)
    assert "MESH_TOKENS_OK" in out.stdout, out.stderr[-3000:]
    assert "EP_SERVE_OK" in out.stdout, out.stderr[-3000:]
