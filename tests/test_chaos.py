"""Chaos harness: the fleet under an unreliable transport.

PR 9's tentpole turned all router↔replica traffic into messages over
``serve.transport`` and hardened the router against the failure modes a
real network delivers: lost, delayed, duplicated and reordered messages,
full partitions, and straggling replicas. This suite drives every
hardening mechanism, then composes them under seeded-random chaos
schedules and asserts the core invariants:

* **exactly-once**: every admitted request completes exactly once —
  retransmits after lost ACKs are absorbed by replica-side dedup (never
  re-decoded on the same replica), duplicate/late results are discarded
  by at-most-once stitching;
* **token identity**: under greedy decode, results equal the fault-free
  run's token-for-token (faults may change *where* and *when* a request
  decodes, never *what* it decodes);
* **accounting**: ``FleetReport.check`` balances — admitted ==
  completed + shed(post-admission) + fatal, submitted == admitted +
  shed[queue_full], buckets disjoint;
* **determinism**: a chaos schedule is a pure function of its seed.

``benchmarks/bench_chaos.py`` runs the same invariants at benchmark
scale (CI's tier1-slow gate) plus the hedging A/B.
"""
import numpy as np
import pytest

from benchmarks.bench_artifact_loading import build_artifact
from repro.runtime.supervisor import (DELAY_LINK, DROP_LINK, KILL_REPLICA,
                                      PARTITION, SLOW_REPLICA, FaultEvent,
                                      FaultInjector, parse_fault_spec)
from repro.serve.engine import (EngineConfig, GenerationOptions, Request,
                                Result, ServeEngine)
from repro.serve.fleet import ShardedReplica
from repro.serve.kv_pool import KVPoolConfig
from repro.serve.router import (SHED_LINK, SHED_RETRY, FleetRouter,
                                RouterConfig)
from repro.serve.transport import (ACK, DISPATCH, ROUTER, ChaosConfig,
                                   FaultyTransport, LocalTransport,
                                   Message, replica_endpoint)


def _reqs(n=4, max_new=6):
    return [Request(uid=i, prompt=np.arange(1 + i, 9 + i, dtype=np.int32),
                    options=GenerationOptions(max_new_tokens=max_new,
                                              odp="off"))
            for i in range(n)]


def _msg(kind=DISPATCH, dst=replica_endpoint(0), src=ROUTER, uid=0):
    return Message(kind=kind, src=src, dst=dst, seq=0, uid=uid)


class _FakeReplica:
    """Engine-free replica: completes each request after ``steps`` pumps."""

    def __init__(self, replica_id, steps=3):
        self.replica_id = replica_id
        self.alive = True
        self.steps = steps
        self._work = {}

    @property
    def busy(self):
        return self.alive and bool(self._work)

    def submit(self, requests):
        for r in requests:
            self._work[r.uid] = self.steps

    def pump(self):
        done = []
        for uid in list(self._work):
            self._work[uid] -= 1
            if self._work[uid] <= 0:
                del self._work[uid]
                done.append(Result(
                    uid=uid, tokens=np.zeros(1, np.int32), prefill_s=0.0,
                    decode_s=0.0, new_tokens=1, finish_reason="length"))
        return done

    def kill(self):
        self.alive = False
        self._work.clear()


# ------------------------------------------------------------- transport
class TestTransport:
    def test_local_delivers_once_in_order(self):
        t = LocalTransport()
        t.advance(1)
        for uid in (7, 8, 9):
            t.send(_msg(uid=uid))
        got = t.poll(replica_endpoint(0))
        assert [m.uid for m in got] == [7, 8, 9]
        assert t.poll(replica_endpoint(0)) == []     # consumed
        assert t.in_flight == 0
        assert t.stats.sent == 3 and t.stats.delivered == 3

    def test_local_routes_by_endpoint(self):
        t = LocalTransport()
        t.advance(1)
        t.send(_msg(dst=replica_endpoint(0), uid=1))
        t.send(_msg(dst=replica_endpoint(1), uid=2))
        t.send(_msg(kind=ACK, dst=ROUTER, src=replica_endpoint(1), uid=2))
        assert [m.uid for m in t.poll(replica_endpoint(1))] == [2]
        assert [m.uid for m in t.poll(ROUTER)] == [2]
        assert [m.uid for m in t.poll(replica_endpoint(0))] == [1]

    def test_scripted_drop_hits_one_tick_only(self):
        t = FaultyTransport()
        t.inject(FaultEvent(tick=2, kind=DROP_LINK, replica=0))
        t.advance(2)
        t.send(_msg(uid=1))                          # dropped
        t.advance(3)
        t.send(_msg(uid=2))                          # delivered
        assert [m.uid for m in t.poll(replica_endpoint(0))] == [2]
        assert t.stats.dropped == 1

    def test_scripted_delay_holds_messages(self):
        t = FaultyTransport()
        t.inject(FaultEvent(tick=1, kind=DELAY_LINK, replica=0, delay=2))
        t.advance(1)
        t.send(_msg(uid=1))
        assert t.poll(replica_endpoint(0)) == []
        t.advance(2)
        assert t.poll(replica_endpoint(0)) == []
        t.advance(3)
        assert [m.uid for m in t.poll(replica_endpoint(0))] == [1]
        assert t.stats.delayed == 1

    def test_partition_cuts_both_directions_for_window(self):
        t = FaultyTransport()
        t.inject(FaultEvent(tick=2, kind=PARTITION, replica=0, until=4))
        for tick, lost in [(1, False), (2, True), (4, True), (5, False)]:
            t.advance(tick)
            t.send(_msg(uid=tick))                             # to replica
            t.send(_msg(kind=ACK, dst=ROUTER,
                        src=replica_endpoint(0), uid=tick))    # to router
        assert [m.uid for m in t.poll(replica_endpoint(0))] == [1, 5]
        assert [m.uid for m in t.poll(ROUTER)] == [1, 5]
        assert t.stats.partition_dropped == 4

    def test_partition_spares_other_links(self):
        t = FaultyTransport()
        t.inject(FaultEvent(tick=1, kind=PARTITION, replica=0, until=9))
        t.advance(2)
        t.send(_msg(dst=replica_endpoint(0), uid=1))
        t.send(_msg(dst=replica_endpoint(1), uid=2))
        assert t.poll(replica_endpoint(0)) == []
        assert [m.uid for m in t.poll(replica_endpoint(1))] == [2]

    def test_inject_rejects_non_network_kinds(self):
        t = FaultyTransport()
        with pytest.raises(ValueError, match="cannot inject"):
            t.inject(FaultEvent(tick=1, kind=KILL_REPLICA, replica=0))

    def test_chaos_duplicates_and_heals(self):
        t = FaultyTransport(ChaosConfig(seed=0, p_dup=1.0, max_delay=1,
                                        until=1))
        t.advance(1)
        t.send(_msg(uid=1))                          # duplicated
        t.advance(5)
        t.send(_msg(uid=2))                          # healed: single copy
        got = [m.uid for m in t.poll(replica_endpoint(0))]
        assert sorted(got) == [1, 1, 2]
        assert t.stats.duplicated == 1

    def test_chaos_is_seed_deterministic(self):
        def run(seed):
            t = FaultyTransport(ChaosConfig(seed=seed, p_drop=0.3,
                                            p_delay=0.3, p_dup=0.3))
            log = []
            for tick in range(1, 20):
                t.advance(tick)
                t.send(_msg(uid=tick))
                log.append(tuple(m.uid for m in
                                 t.poll(replica_endpoint(0))))
            return log, t.stats.to_dict()
        assert run(7) == run(7)
        assert run(7) != run(8)


# ----------------------------------------------------- fault-spec grammar
class TestFaultSpecGrammar:
    def test_new_message_fault_kinds_parse(self):
        ev = parse_fault_spec("drop:2@5")
        assert (ev.kind, ev.replica, ev.tick) == (DROP_LINK, 2, 5)
        ev = parse_fault_spec("delay:0@3+4")
        assert (ev.kind, ev.tick, ev.delay) == (DELAY_LINK, 3, 4)
        ev = parse_fault_spec("partition:1@4..9")
        assert (ev.kind, ev.tick, ev.until) == (PARTITION, 4, 9)
        ev = parse_fault_spec("slow:1@10x6")
        assert (ev.kind, ev.tick, ev.factor) == (SLOW_REPLICA, 10, 6)

    @pytest.mark.parametrize("spec,needle", [
        ("replica0@3", "missing ':'"),
        ("vaporize:0@3", "unknown fault kind 'vaporize'"),
        ("replica:0", "missing '@<tick>'"),
        ("replica:zero@3", "'zero' is not an integer"),
        ("replica:0@soon", "'soon' is not an integer"),
        ("host:0@3", "must be '<replica>.<host>'"),
        ("delay:0@3", "delay needs"),
        ("delay:0@3+x", "'x' is not an integer"),
        ("partition:0@3", "partition needs"),
        ("partition:0@9..3", "end tick 3 is before its start tick 9"),
        ("slow:0@3", "needs '@<tick>x<factor>'"),
    ])
    def test_malformed_specs_name_the_bad_token(self, spec, needle):
        with pytest.raises(ValueError, match=needle):
            parse_fault_spec(spec)

    def test_event_validation(self):
        with pytest.raises(ValueError, match="delay >= 1"):
            FaultEvent(tick=1, kind=DELAY_LINK, replica=0, delay=0)
        with pytest.raises(ValueError, match="end tick"):
            FaultEvent(tick=5, kind=PARTITION, replica=0, until=3)
        with pytest.raises(ValueError, match="factor >= 1"):
            FaultEvent(tick=1, kind=SLOW_REPLICA, replica=0, factor=0)


# ------------------------------------------------------ protocol (fakes)
class TestProtocolHardening:
    def test_dropped_dispatch_is_retransmitted(self, tmp_path):
        inj = FaultInjector([FaultEvent(tick=1, kind=DROP_LINK,
                                        replica=0)])
        router = FleetRouter(
            [_FakeReplica(0)], tmp_path / "hb",
            config=RouterConfig(retry_jitter=0), injector=inj,
            transport=FaultyTransport())
        rpt = router.run(_reqs(n=1))
        assert list(rpt.completed) == [0]
        # the tick-1 dispatch AND that tick's heartbeat were both lost
        assert rpt.transport["dropped"] == 2
        assert rpt.transport["by_kind"][DISPATCH] >= 2  # original + retx

    def test_lost_ack_dedups_not_double_decodes(self, tmp_path):
        # delay the tick-1 dispatch by 1, then drop the tick-2 replica
        # traffic — the ACK is lost but the request IS decoding; the
        # router's retransmit must be absorbed by dedup
        inj = FaultInjector([
            FaultEvent(tick=1, kind=DELAY_LINK, replica=0, delay=1),
            FaultEvent(tick=2, kind=DROP_LINK, replica=0)])
        router = FleetRouter(
            [_FakeReplica(0, steps=8)], tmp_path / "hb",
            config=RouterConfig(retry_jitter=0, heartbeat_timeout=6.0),
            injector=inj, transport=FaultyTransport())
        rpt = router.run(_reqs(n=1))
        assert list(rpt.completed) == [0]
        assert rpt.dedup_hits >= 1
        node = router.nodes[0]
        assert node.decode_submissions == {0: 1}     # decoded exactly once

    def test_chaos_duplicates_never_double_decode(self, tmp_path):
        router = FleetRouter(
            [_FakeReplica(0), _FakeReplica(1)], tmp_path / "hb",
            config=RouterConfig(max_retries=10),
            transport=FaultyTransport(
                ChaosConfig(seed=3, p_dup=1.0, max_delay=2, until=10)))
        rpt = router.run(_reqs(n=6))
        assert sorted(rpt.completed) == list(range(6))
        assert rpt.dedup_hits > 0
        for node in router.nodes.values():
            assert all(n == 1 for n in node.decode_submissions.values())

    def test_partition_false_death_recovers_exactly_once(self, tmp_path):
        """A partitioned replica looks dead (heartbeat silence); its
        requests retry elsewhere, and its late results are discarded by
        the at-most-once rule. Every request completes exactly once."""
        inj = FaultInjector([FaultEvent(tick=2, kind=PARTITION,
                                        replica=0, until=30)])
        router = FleetRouter(
            [_FakeReplica(0, steps=3), _FakeReplica(1, steps=3)],
            tmp_path / "hb",
            config=RouterConfig(retry_jitter=0, max_retries=5),
            injector=inj, transport=FaultyTransport())
        rpt = router.run(_reqs(n=4))
        assert sorted(rpt.completed) == [0, 1, 2, 3]
        assert any(d["replica"] == 0 for d in rpt.deaths)  # false positive
        assert router.replicas[0].alive                    # ...but alive
        # per-replica dedup held: nothing decoded twice on one node
        for node in router.nodes.values():
            assert all(n == 1 for n in node.decode_submissions.values())

    def test_breaker_opens_on_dead_link(self, tmp_path):
        inj = FaultInjector([FaultEvent(tick=1, kind=PARTITION,
                                        replica=0, until=60)])
        router = FleetRouter(
            [_FakeReplica(0), _FakeReplica(1)], tmp_path / "hb",
            config=RouterConfig(ack_timeout=1, dispatch_attempts=1,
                                breaker_threshold=2, retry_jitter=0,
                                max_retries=10, heartbeat_timeout=50.0),
            injector=inj, transport=FaultyTransport())
        rpt = router.run(_reqs(n=4))
        assert sorted(rpt.completed) == [0, 1, 2, 3]
        opens = [e for e in rpt.breaker_events
                 if e["replica"] == 0 and e["state"] == "open"]
        assert opens and rpt.redispatches >= 2

    def test_breaker_half_open_probe_closes_after_heal(self, tmp_path):
        inj = FaultInjector([FaultEvent(tick=1, kind=PARTITION,
                                        replica=0, until=6)])
        router = FleetRouter(
            [_FakeReplica(0)], tmp_path / "hb",
            config=RouterConfig(ack_timeout=1, dispatch_attempts=1,
                                breaker_threshold=1, breaker_cooldown=3,
                                retry_jitter=0, max_retries=10,
                                max_redispatch=50,
                                heartbeat_timeout=50.0),
            injector=inj, transport=FaultyTransport())
        rpt = router.run(_reqs(n=1))
        assert list(rpt.completed) == [0]
        states = [e["state"] for e in rpt.breaker_events
                  if e["replica"] == 0]
        assert "half_open" in states and states[-1] == "closed"
        # the mid-partition probe failed and re-opened before the heal
        assert states.count("open") >= 2

    def test_unreachable_fleet_sheds_link_open(self, tmp_path):
        """A permanent partition with no survivor: the redispatch budget
        runs out and the request is shed with reason ``link_open`` —
        bounded, loudly accounted, identity still balanced."""
        inj = FaultInjector([FaultEvent(tick=1, kind=PARTITION,
                                        replica=0, until=10_000)])
        router = FleetRouter(
            [_FakeReplica(0)], tmp_path / "hb",
            config=RouterConfig(ack_timeout=1, dispatch_attempts=1,
                                breaker_threshold=2, breaker_cooldown=2,
                                max_redispatch=3, retry_jitter=0,
                                heartbeat_timeout=50.0),
            injector=inj, transport=FaultyTransport())
        rpt = router.run(_reqs(n=1))
        assert rpt.shed[SHED_LINK] == [0] and not rpt.completed
        assert rpt.failed == [0]                     # legacy view agrees

    def test_hedging_beats_straggler(self, tmp_path):
        """Replica 0 slows 8x mid-run; the supervisor's z-score flags it
        and the router hedges its outstanding work onto replica 1. First
        completion wins — the run finishes far earlier than unhedged."""
        def run(hedge):
            inj = FaultInjector([FaultEvent(tick=14, kind=SLOW_REPLICA,
                                            replica=0, factor=8)])
            router = FleetRouter(
                [_FakeReplica(0, steps=40), _FakeReplica(1, steps=40)],
                tmp_path / f"hb{hedge}",
                config=RouterConfig(hedge=hedge, retry_jitter=0,
                                    heartbeat_timeout=10.0),
                injector=inj, transport=FaultyTransport())
            return router.run(_reqs(n=4))
        hedged, unhedged = run(True), run(False)
        assert sorted(hedged.completed) == [0, 1, 2, 3]
        assert hedged.hedges >= 1 and hedged.hedge_wins >= 1
        assert unhedged.hedges == 0
        assert max(hedged.completion_ticks.values()) < \
            max(unhedged.completion_ticks.values())

    @pytest.mark.parametrize("seed", [1, 2, 3, 4, 5])
    def test_seeded_chaos_exactly_once_and_balanced(self, tmp_path, seed):
        chaos = ChaosConfig(seed=seed, p_drop=0.15, p_dup=0.15,
                            p_delay=0.2, p_reorder=0.2, max_delay=3,
                            until=60)
        router = FleetRouter(
            [_FakeReplica(i) for i in range(3)], tmp_path / "hb",
            config=RouterConfig(seed=seed, max_retries=20,
                                max_redispatch=100),
            transport=FaultyTransport(chaos))
        rpt = router.run(_reqs(n=12))
        # run() already called rpt.check(); re-assert the headline
        assert sorted(rpt.completed) == list(range(12))
        assert rpt.admitted == 12 and not rpt.fatal
        for node in router.nodes.values():
            assert all(n == 1 for n in node.decode_submissions.values())

    def test_same_seed_same_story(self, tmp_path):
        def run(tag):
            chaos = ChaosConfig(seed=11, p_drop=0.2, p_dup=0.2,
                                p_delay=0.2, p_reorder=0.2, until=50)
            router = FleetRouter(
                [_FakeReplica(i) for i in range(2)], tmp_path / tag,
                config=RouterConfig(seed=11, max_retries=20,
                                    max_redispatch=100),
                transport=FaultyTransport(chaos))
            rpt = router.run(_reqs(n=8))
            return rpt.completion_ticks, rpt.transport
        assert run("a") == run("b")

    def test_report_check_catches_imbalance(self, tmp_path):
        router = FleetRouter([_FakeReplica(0)], tmp_path / "hb")
        rpt = router.run(_reqs(n=2))
        rpt.admitted += 1
        with pytest.raises(ValueError, match="accounting violated"):
            rpt.check()
        rpt.admitted -= 1
        rpt.shed[SHED_RETRY].append(0)               # also in completed
        with pytest.raises(ValueError, match="in both"):
            rpt.check()


# ------------------------------------------------- real engines, chaos
@pytest.fixture(scope="module")
def saved(tmp_path_factory):
    d = tmp_path_factory.mktemp("chaos_artifact")
    model, artifact, _ = build_artifact(
        d, num_experts=16, d_model=32, moe_d_ff=384, vocab_size=64,
        group_size=32, capacity_factor=32.0)
    return model, artifact, d


@pytest.fixture(scope="module")
def ref(saved):
    model, artifact, _ = saved
    eng = ServeEngine.from_artifact(model, artifact, batch_size=2,
                                    odp="off")
    return {r.uid: [int(t) for t in r.tokens] for r in eng.run(_reqs())}


def _pool(model, d, n=2, config=None):
    return [ShardedReplica(model, d, replica_id=i, num_hosts=2,
                           blocks_per_host=2, batch_size=2, odp="off",
                           config=config)
            for i in range(n)]


class TestChaosRealEngine:
    def _chaos_run(self, saved, tmp_path, seed, kill_tick=None):
        model, _, d = saved
        events = [] if kill_tick is None else \
            [FaultEvent(tick=kill_tick, kind=KILL_REPLICA, replica=0)]
        chaos = ChaosConfig(seed=seed, p_drop=0.1, p_dup=0.1,
                            p_delay=0.15, p_reorder=0.15, max_delay=2,
                            until=40)
        router = FleetRouter(
            _pool(model, d), tmp_path / f"hb{seed}",
            config=RouterConfig(seed=seed, max_retries=20,
                                max_redispatch=100),
            injector=FaultInjector(events),
            transport=FaultyTransport(chaos))
        return router.run(_reqs()), router

    def test_chaos_token_identical(self, saved, ref, tmp_path):
        """Message chaos over real engines: every request completes
        exactly once, token-identical to the fault-free run."""
        rpt, router = self._chaos_run(saved, tmp_path, seed=1)
        got = {r.uid: [int(t) for t in r.tokens]
               for r in rpt.completed.values()}
        assert got == ref
        for node in router.nodes.values():
            assert all(n == 1 for n in node.decode_submissions.values())

    @pytest.mark.slow
    @pytest.mark.parametrize("seed", [2, 3])
    def test_chaos_more_seeds(self, saved, ref, tmp_path, seed):
        rpt, _ = self._chaos_run(saved, tmp_path, seed=seed)
        got = {r.uid: [int(t) for t in r.tokens]
               for r in rpt.completed.values()}
        assert got == ref

    @pytest.mark.slow
    def test_chaos_with_replica_kill(self, saved, ref, tmp_path):
        """Chaos composed with a real mid-decode replica death: the
        survivor serves everything, still token-identical."""
        rpt, router = self._chaos_run(saved, tmp_path, seed=4,
                                      kill_tick=6)
        got = {r.uid: [int(t) for t in r.tokens]
               for r in rpt.completed.values()}
        assert got == ref
        assert not router.replicas[0].alive


# --------------------------------------------- fleet retry × paged KV
class TestRetryPagedKV:
    @pytest.mark.slow
    def test_death_mid_chunked_prefill_leaks_no_pages(self, saved, ref,
                                                      tmp_path):
        """Replica 0 dies while still chunk-prefilling its share; the
        requests requeue onto the paged survivor, whose pool must end
        the run with every page back on the free list and invariants
        clean (no leak from the requeue/re-admit cycle)."""
        model, _, d = saved
        cfg = EngineConfig(max_seq_len=32, kv_pool=KVPoolConfig(
            num_pages=24, page_size=4, prefill_chunk=4,
            prefix_sharing=False))
        inj = FaultInjector([FaultEvent(tick=2, kind=KILL_REPLICA,
                                        replica=0)])
        router = FleetRouter(
            _pool(model, d, config=cfg), tmp_path / "hb",
            config=RouterConfig(heartbeat_timeout=2.0, max_retries=5),
            injector=inj)
        rpt = router.run(_reqs())
        got = {r.uid: [int(t) for t in r.tokens]
               for r in rpt.completed.values()}
        assert got == ref                            # paged == contiguous
        assert rpt.retries > 0
        survivor = router.replicas[1].engine
        mgr = survivor._kv_mgr
        mgr.check_invariants()
        assert mgr.pool.live_pages() == []           # all pages released


# ------------------------------------- checkpoint torn-read robustness
class TestFingerprintRetry:
    def _save(self, tmp_path):
        from repro.checkpoint.checkpointer import save_pytree
        tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                "b": np.ones(4, np.float32)}
        save_pytree(tmp_path, 0, tree)
        return tree

    def test_transient_mismatch_retries_once(self, tmp_path, monkeypatch):
        from repro.checkpoint import checkpointer as ck
        self._save(tmp_path)
        real = ck._sha256_file
        flips = {"n": 0}

        def torn_once(path):
            flips["n"] += 1
            return "0" * 64 if flips["n"] == 1 else real(path)

        monkeypatch.setattr(ck, "_sha256_file", torn_once)
        tree, _, stats = ck.load_pytree_subset(tmp_path, None, step=0)
        assert stats.fingerprint_retries == 1
        np.testing.assert_array_equal(tree["w"],
                                      np.arange(12).reshape(3, 4))

    def test_persistent_mismatch_still_raises(self, tmp_path, monkeypatch):
        from repro.checkpoint import checkpointer as ck
        self._save(tmp_path)
        monkeypatch.setattr(ck, "_sha256_file", lambda p: "0" * 64)
        with pytest.raises(ValueError, match="twice"):
            ck.load_pytree(tmp_path, 0)

    def test_retry_counts_accumulate(self):
        from repro.checkpoint.checkpointer import LoadStats
        a = LoadStats(fingerprint_retries=1)
        a.accumulate(LoadStats(fingerprint_retries=2))
        assert a.fingerprint_retries == 3
