"""Tests for expert significance stats and ODP pruning logic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.odp import (
    OdpConfig, apply_pruning, calibrate, capacity_scale_from_prune_rate,
    protect_tokens, prune_mask, pruned_fraction,
)
from repro.core.significance import ExpertStats


class TestExpertStats:
    def test_frequency_and_weight(self):
        s = ExpertStats(num_experts=4)
        idx = jnp.array([[0, 1], [0, 2], [0, 1]])       # 3 tokens, top-2
        w = jnp.array([[0.9, 0.1], [0.6, 0.4], [0.7, 0.3]])
        s.update(idx, w)
        assert s.tokens_seen == 3
        np.testing.assert_allclose(s.frequency, [1.0, 2 / 3, 1 / 3, 0.0])
        np.testing.assert_allclose(s.mean_weight,
                                   [(0.9 + 0.6 + 0.7) / 3, 0.4 / 3 + 0.0,
                                    0.4 / 3, 0.0], atol=1e-7)

    def test_ratio_median(self):
        s = ExpertStats(num_experts=2)
        w = jnp.array([[0.8, 0.2], [0.5, 0.5], [0.6, 0.3]])
        s.update(jnp.zeros((3, 2), jnp.int32), w)
        assert s.ratio_median() == pytest.approx(0.5)

    def test_significance_monotone(self):
        s = ExpertStats(num_experts=3)
        s.update(jnp.array([[0, 1], [0, 1], [0, 2]]),
                 jnp.array([[0.9, 0.1], [0.8, 0.2], [0.9, 0.1]]))
        sig = s.significance(1.0, 1.0)
        assert sig[0] > sig[1] > sig[2]


class TestPruning:
    def test_low_ratio_pruned(self):
        w = jnp.array([[0.9, 0.1], [0.6, 0.4]])
        keep = prune_mask(w, threshold=0.5)
        np.testing.assert_array_equal(np.asarray(keep),
                                      [[True, False], [True, True]])

    def test_primary_never_pruned(self):
        w = jnp.array([[0.99, 0.001], [0.5, 0.0]])
        keep = prune_mask(w, threshold=0.9)
        assert bool(keep[..., 0].all())

    def test_protection_overrides(self):
        w = jnp.array([[0.9, 0.1], [0.9, 0.1]])
        prot = jnp.array([True, False])
        keep = prune_mask(w, 0.5, protected=prot)
        np.testing.assert_array_equal(np.asarray(keep),
                                      [[True, True], [True, False]])

    def test_top1_noop(self):
        w = jnp.ones((4, 1))
        assert bool(prune_mask(w, 0.9).all())

    def test_renormalize(self):
        w = jnp.array([[0.8, 0.2]])
        keep = jnp.array([[True, False]])
        out = apply_pruning(w, keep)
        np.testing.assert_allclose(np.asarray(out), [[1.0, 0.0]], atol=1e-6)

    @given(mu=st.floats(0.0, 1.0), seed=st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_prune_rate_monotone_in_threshold(self, mu, seed):
        key = jax.random.PRNGKey(seed)
        w = jnp.sort(jax.random.uniform(key, (64, 2)), axis=-1)[:, ::-1]
        f_lo = float(pruned_fraction(prune_mask(w, mu * 0.5), 2))
        f_hi = float(pruned_fraction(prune_mask(w, mu), 2))
        assert f_lo <= f_hi + 1e-9


class TestProtection:
    def test_topk_selected(self):
        imp = jnp.array([0.1, 5.0, 0.2, 3.0, 0.05, 0.0, 1.0, 0.3])
        mask = protect_tokens(imp, 0.25)  # 2 of 8
        np.testing.assert_array_equal(
            np.asarray(mask),
            [False, True, False, True, False, False, False, False])

    def test_ratio_zero(self):
        assert not bool(protect_tokens(jnp.arange(8.0), 0.0).any())

    def test_valid_mask_respected(self):
        imp = jnp.array([9.0, 8.0, 1.0, 0.5])
        valid = jnp.array([False, True, True, True])
        mask = protect_tokens(imp, 0.25, valid=valid)
        assert not bool(mask[0])
        assert bool(mask[1])

    def test_batched(self):
        imp = jnp.stack([jnp.arange(8.0), jnp.arange(8.0)[::-1]])
        mask = protect_tokens(imp, 2 / 8)
        assert int(mask.sum()) == 4
        assert bool(mask[0, 7]) and bool(mask[1, 0])


class TestCalibration:
    def test_median_threshold_and_rate(self):
        rng = np.random.RandomState(0)
        ratios = rng.uniform(0, 1, 10_000)
        cfg, rate = calibrate(ratios)
        assert cfg.threshold == pytest.approx(0.5, abs=0.02)
        # half the tokens prune their secondary slot -> 1/4 of all slots
        assert rate == pytest.approx(0.25, abs=0.01)

    def test_capacity_scale(self):
        s = capacity_scale_from_prune_rate(0.25, top_k=2, protect_ratio=0.02)
        assert s == pytest.approx(1 - 0.25 * 0.98)
        assert capacity_scale_from_prune_rate(0.25, 1, 0.02) == 1.0
