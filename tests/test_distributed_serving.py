"""True multi-process serving: per-host partial artifacts drive a
process-local distributed engine.

The slow acceptance test launches **two real ``jax.distributed``
processes** (gloo CPU collectives) sharing a (data=2, model=1) mesh.
Each process streams only its own slice of the saved weights —
``CompressedArtifact.load_sharded(dir, mesh)`` for the quantized model,
``load_dense_expert_params(dir, mesh)`` for the dense one — asserts via
``LoadStats`` that it read < 60% of the artifact bytes, boots the
expert-parallel engine from that partial stream alone, and decodes.
The driver asserts both processes' tokens equal the single-process
full-artifact engine's, for both the dense-EP shard_map body and the
quantized-EP fused ``moe_ffn`` body. A per-host stream whose experts
mismatch the mesh's placement expectation must fail loudly inside the
distributed process.

Fast-slice tests cover the pure range/expectation algebra
(`moe_parallel.ep_owned_ranges` / `ep_shard_for_ranges`,
`pipeline.expert_shard_expectation`), the single-process behavior of
`distributed_params`, dense expert checkpoints, and artifact merging.
"""
import json
import socket
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

ROOT = Path(__file__).resolve().parents[1]

# one reduced expert-heavy Mixtral shared by driver and children — the
# config must match exactly or the artifact fingerprint check trips
_CFG = """
cfg = get_config("mixtral-8x7b", smoke=True).replace(
    dtype="float32", num_layers=2, d_model=32, d_ff=32, moe_d_ff=384,
    num_experts=16, vocab_size=64, capacity_factor=8.0,
    scan_layers=False)
"""

_BITS = "[1] * 4 + [2] * 8 + [3] * 4"          # class counts (4, 8, 4)

_CHILD = textwrap.dedent("""
    import sys, json
    proc, port, art_dir, dense_dir = (int(sys.argv[1]), sys.argv[2],
                                      sys.argv[3], sys.argv[4])
    sys.path.insert(0, {src!r}); sys.path.insert(0, {root!r})
    import jax
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(coordinator_address=f"localhost:{{port}}",
                               num_processes=2, process_id=proc)
    import numpy as np
    from repro.configs import get_config
    from repro.core import pipeline
    from repro.models.transformer import DecoderModel
    from repro.serve.engine import Request, ServeEngine
    {cfg}
    model = DecoderModel(cfg)
    mesh = jax.make_mesh((2, 1), ("data", "model"))

    def reqs():
        return [Request(uid=i,
                        prompt=np.arange(1 + i, 9 + i, dtype=np.int32),
                        max_new_tokens=4) for i in range(3)]

    # ---- quantized-EP: partial artifact -> local shard of the engine
    art = pipeline.CompressedArtifact.load_sharded(art_dir, mesh)
    st = art.load_stats
    assert art.is_partial, "multi-process stream must be partial"
    assert st.read_fraction < 0.60, st.read_fraction
    assert st.bytes_read < st.total_bytes
    eng = ServeEngine.from_artifact(model, art, mesh=mesh,
                                    ep_dispatch=True, batch_size=2)
    toks = [r.tokens.tolist() for r in eng.run(reqs())]
    print(f"QUANT_TOKENS {{json.dumps(toks)}}", flush=True)

    # ---- a stream that mismatches the placement expectation fails
    # loudly (byte-balanced contiguous blocks != per-class blocks here)
    try:
        pipeline.CompressedArtifact.load_sharded(
            art_dir, mesh, num_hosts=2, host=proc)
    except ValueError as e:
        assert "expectation" in str(e), e
        print("MISMATCH_LOUD_OK", flush=True)

    # ---- dense-EP: partial dense checkpoint -> shard_map dense body
    params, st, ranges = pipeline.load_dense_expert_params(dense_dir, mesh)
    assert st.read_fraction < 0.60, st.read_fraction
    eng_d = ServeEngine(model, params, batch_size=2, mesh=mesh,
                        ep_dispatch=True)
    toks = [r.tokens.tolist() for r in eng_d.run(reqs())]
    print(f"DENSE_TOKENS {{json.dumps(toks)}}", flush=True)
    print("CHILD_OK", flush=True)
""")

_DRIVER = textwrap.dedent("""
    import sys, json
    tmp = sys.argv[1]
    sys.path.insert(0, {src!r}); sys.path.insert(0, {root!r})
    import jax, numpy as np
    from benchmarks.bench_artifact_loading import build_artifact
    from repro.configs import get_config
    from repro.core import pipeline
    from repro.models.transformer import DecoderModel
    from repro.serve.engine import Request, ServeEngine

    model, art, _ = build_artifact(
        tmp + "/artifact", num_experts=16, d_model=32, moe_d_ff=384,
        vocab_size=64, group_size=32, capacity_factor=8.0,
        bits_override={bits})
    params = model.init(jax.random.PRNGKey(0))
    pipeline.save_dense_expert_params(tmp + "/dense", params)

    def reqs():
        return [Request(uid=i,
                        prompt=np.arange(1 + i, 9 + i, dtype=np.int32),
                        max_new_tokens=4) for i in range(3)]

    full = pipeline.CompressedArtifact.load(tmp + "/artifact")
    eng = ServeEngine.from_artifact(model, full, batch_size=2)
    ref_q = [r.tokens.tolist() for r in eng.run(reqs())]
    eng_d = ServeEngine(model, params, batch_size=2)
    ref_d = [r.tokens.tolist() for r in eng_d.run(reqs())]
    print(f"REF {{json.dumps({{'quant': ref_q, 'dense': ref_d}})}}",
          flush=True)
""")


def _free_port() -> int:
    s = socket.socket()
    s.bind(("localhost", 0))
    port = s.getsockname()[1]
    s.close()
    return port


@pytest.mark.slow
def test_two_process_distributed_serving(tmp_path):
    """Acceptance: each jax.distributed process boots from only its own
    partial stream (< 60% of artifact bytes) and decodes token-identically
    to the single-process full-artifact engine — dense-EP and
    quantized-EP (fused moe_ffn)."""
    fmt = dict(src=str(ROOT / "src"), root=str(ROOT), cfg=_CFG, bits=_BITS)
    drv = subprocess.run(
        [sys.executable, "-c", _DRIVER.format(**fmt), str(tmp_path)],
        capture_output=True, text=True, timeout=900)
    ref_line = [ln for ln in drv.stdout.splitlines()
                if ln.startswith("REF ")]
    assert ref_line, drv.stderr[-3000:]
    ref = json.loads(ref_line[0][4:])

    port = _free_port()
    children = [subprocess.Popen(
        [sys.executable, "-c", _CHILD.format(**fmt), str(i), str(port),
         str(tmp_path / "artifact"), str(tmp_path / "dense")],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        for i in range(2)]
    outs = [p.communicate(timeout=900) for p in children]
    for i, (out, err) in enumerate(outs):
        assert "CHILD_OK" in out, f"process {i}:\n{err[-4000:]}"
        assert "MISMATCH_LOUD_OK" in out, f"process {i}:\n{out}"
        for tag, want in (("QUANT_TOKENS", ref["quant"]),
                          ("DENSE_TOKENS", ref["dense"])):
            line = [ln for ln in out.splitlines() if ln.startswith(tag)]
            assert line, f"process {i} printed no {tag}:\n{out}"
            got = json.loads(line[0].split(" ", 1)[1])
            assert got == want, (tag, i, got, want)
