"""Docs stay navigable: the CI ``docs-check`` invariants, fast-slice.

Same checks the ``docs-check`` CI job runs — kept in the tier-1 fast
slice so a broken link or an unparsable example fails locally before CI.
"""
import compileall
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

import check_docs  # noqa: E402


def test_docs_exist_and_linked():
    names = {p.name for p in check_docs.doc_files(ROOT)}
    assert {"README.md", "architecture.md", "artifact_format.md",
            "serving.md"} <= names
    readme = (ROOT / "README.md").read_text()
    for doc in ("docs/architecture.md", "docs/artifact_format.md",
                "docs/serving.md"):
        assert doc in readme, f"README must link {doc}"


def test_no_broken_relative_links():
    bad = check_docs.broken_links(ROOT)
    assert not bad, f"broken doc links: {bad}"


def test_examples_compile():
    assert compileall.compile_dir(str(ROOT / "examples"), quiet=1,
                                  force=True), \
        "examples/ must at least parse (CI docs-check runs compileall)"
