"""Elastic fault-tolerant fleet serving: router, re-sharding, recovery.

Fast-slice guarantees (PR-gating):

* the engine's stepwise session API (`begin`/`pump`/`drain`/`collect`)
  is behaviorally identical to ``run()``, and a drain/requeue cycle
  resumes decode **token-identically** via generated-prefix
  continuations;
* block ownership planning tiles the expert axis exactly, re-homes only
  a dead host's blocks (delta < full reload), and join traffic is
  bounded by the joiner's share;
* ``load_expert_blocks`` parts reassemble the artifact bit-for-bit and
  their byte accounting composes (``LoadStats.accumulate``);
* the router sheds at admission (queue bound) and at dispatch (expired
  SLA deadline), detects replica death by heartbeat silence, and retries
  the dead replica's requests on survivors — availability 1.0 for every
  admitted-and-served request;
* a mid-decode host loss on a live replica streams strictly fewer bytes
  than a reload and the resumed streams match an uninterrupted run.

The full two-replica kill-mid-decode integration runs as a slow test
(same scenario the CI fleet smoke gates via ``benchmarks/bench_fleet``).
"""
import numpy as np
import pytest

from benchmarks.bench_artifact_loading import _tree_equal, build_artifact
from repro.checkpoint.checkpointer import LoadStats, merge_subset_trees
from repro.core import pipeline
from repro.runtime import elastic
from repro.runtime.supervisor import (KILL_HOST, KILL_REPLICA, JOIN_HOST,
                                      FaultEvent, FaultInjector,
                                      FleetSupervisor, parse_fault_spec)
from repro.serve.engine import (GenerationOptions, Request, Result,
                                ServeEngine)
from repro.serve.fleet import ShardedReplica
from repro.serve.router import FleetRouter, RouterConfig


# --------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def saved(tmp_path_factory):
    """Expert-heavy artifact with capacity high enough that co-resident
    requests never overflow expert capacity — decode is then independent
    of batch composition, so *any* recovery path must be token-identical
    to the uninterrupted run."""
    d = tmp_path_factory.mktemp("fleet_artifact")
    model, artifact, _ = build_artifact(
        d, num_experts=16, d_model=32, moe_d_ff=384, vocab_size=64,
        group_size=32, capacity_factor=32.0)
    return model, artifact, d


def _reqs(n=4, max_new=6):
    return [Request(uid=i, prompt=np.arange(1 + i, 9 + i, dtype=np.int32),
                    options=GenerationOptions(max_new_tokens=max_new,
                                              odp="off"))
            for i in range(n)]


@pytest.fixture(scope="module")
def ref(saved):
    """Uninterrupted single-engine reference streams (and the engine,
    reusable for session-API tests)."""
    model, artifact, _ = saved
    eng = ServeEngine.from_artifact(model, artifact, batch_size=2,
                                    odp="off")
    tokens = {r.uid: [int(t) for t in r.tokens] for r in eng.run(_reqs())}
    return eng, tokens


# ------------------------------------------------------- engine sessions
class TestEngineSession:
    def test_stepwise_equals_run(self, ref):
        eng, want = ref
        eng.begin(_reqs())
        while eng.busy:
            eng.pump()
        got = {r.uid: [int(t) for t in r.tokens] for r in eng.collect()}
        assert got == want

    def test_drain_resume_token_identical(self, ref):
        eng, want = ref
        eng.begin(_reqs())
        for _ in range(3):
            eng.pump()
        requeued = eng.drain()
        assert not eng.busy
        early = {r.uid: [int(t) for t in r.tokens] for r in eng.collect()}
        # in-flight slots carry their generated prefix; pending carry none
        uids = [rq.request.uid for rq in requeued]
        assert sorted(uids + list(early)) == [0, 1, 2, 3]
        prior = {rq.request.uid: [int(t) for t in rq.prior_tokens]
                 for rq in requeued}
        assert any(len(p) > 0 for p in prior.values())

        eng.begin([rq.continuation() for rq in requeued])
        while eng.busy:
            eng.pump()
        done = {r.uid: [int(t) for t in r.tokens] for r in eng.collect()}
        got = dict(early)
        got.update({u: prior[u] + toks for u, toks in done.items()})
        assert got == want

    def test_continuation_budget_and_prompt(self):
        from repro.serve.engine import Requeued
        req = Request(uid="a", prompt=np.arange(4, dtype=np.int32),
                      options=GenerationOptions(max_new_tokens=8))
        rq = Requeued(request=req,
                      prior_tokens=np.asarray([9, 7], np.int32))
        cont = rq.continuation()
        assert cont.uid == "a"
        assert [int(t) for t in cont.prompt] == [0, 1, 2, 3, 9, 7]
        assert cont.opts.max_new_tokens == 6
        empty = Requeued(request=req, prior_tokens=np.zeros(0, np.int32))
        assert empty.continuation() is req

    def test_session_misuse_raises(self, ref):
        eng, _ = ref
        with pytest.raises(RuntimeError, match="no active session"):
            eng.pump()
        with pytest.raises(RuntimeError, match="no active session"):
            eng.collect()
        assert eng.take_finished() == []
        eng.begin(_reqs(n=1, max_new=4))
        with pytest.raises(RuntimeError, match="already active"):
            eng.begin(_reqs(n=1))
        with pytest.raises(RuntimeError, match="in-flight"):
            eng.collect()
        while eng.busy:
            eng.pump()
        assert len(eng.collect()) == 1

    def test_submit_into_open_session(self, ref):
        eng, want = ref
        first, later = _reqs()[:2], _reqs()[2:]
        eng.begin(first)
        eng.pump()
        eng.submit(later)
        with pytest.raises(ValueError, match="capacity"):
            eng.submit([Request(uid="big",
                                prompt=np.zeros(500, np.int32),
                                options=GenerationOptions(
                                    max_new_tokens=500))])
        seen = {}
        while eng.busy:
            eng.pump()
            for r in eng.take_finished():
                seen[r.uid] = [int(t) for t in r.tokens]
        assert eng.collect() == []     # take_finished drained everything
        assert seen == want


# ------------------------------------------------------- block ownership
class TestBlockPlanning:
    def test_initial_assignment_tiles_and_balances(self):
        a = elastic.initial_assignment([10] * 16, [0, 1],
                                       blocks_per_host=2)
        assert a.blocks[0][0] == 0 and a.blocks[-1][1] == 16
        assert [b[1] for b in a.blocks[:-1]] == \
            [b[0] for b in a.blocks[1:]]
        assert a.hosts == (0, 1)
        assert a.bytes_of(0) == a.bytes_of(1) == 80

    def test_bad_blocks_rejected(self):
        with pytest.raises(ValueError, match="tile"):
            elastic.BlockAssignment(blocks=((0, 4), (5, 8)),
                                    block_bytes=(1, 1), owner=(0, 0))
        with pytest.raises(ValueError, match="mismatch"):
            elastic.BlockAssignment(blocks=((0, 8),), block_bytes=(1, 1),
                                    owner=(0,))

    def test_host_loss_moves_only_orphans(self):
        a = elastic.initial_assignment(list(range(1, 17)), [0, 1, 2],
                                       blocks_per_host=2)
        plan = elastic.plan_host_loss(a, 1)
        assert all(m.src == 1 for m in plan.moves)
        assert all(m.dst in (0, 2) for m in plan.moves)
        assert plan.delta_bytes == a.bytes_of(1)
        assert 0 < plan.delta_bytes < plan.full_reload_bytes
        assert 1 not in plan.new.hosts
        # resident blocks never moved
        for blk, old_o, new_o in zip(a.blocks, a.owner, plan.new.owner):
            if old_o != 1:
                assert new_o == old_o

    def test_last_host_loss_raises(self):
        a = elastic.initial_assignment([1] * 8, [5], blocks_per_host=2)
        with pytest.raises(ValueError, match="last host"):
            elastic.plan_host_loss(a, 5)
        with pytest.raises(ValueError, match="owns no blocks"):
            elastic.plan_host_loss(a, 99)

    def test_join_streams_only_joiner(self):
        a = elastic.initial_assignment([10] * 16, [0, 1],
                                       blocks_per_host=2)
        plan = elastic.plan_host_join(a, 2)
        assert all(m.dst == 2 for m in plan.moves)
        assert plan.delta_bytes == plan.new.bytes_of(2)
        assert plan.new.max_host_bytes <= a.max_host_bytes
        with pytest.raises(ValueError, match="already owns"):
            elastic.plan_host_join(plan.new, 2)

    def test_join_without_granularity_refused(self):
        a = elastic.initial_assignment([10] * 2, [0, 1],
                                       blocks_per_host=1)
        with pytest.raises(ValueError, match="more"):
            elastic.plan_host_join(a, 2)

    def test_expert_range_delta(self):
        d = pipeline.expert_range_delta
        assert d(((0, 8),), ((0, 12),)) == ((8, 12),)
        assert d(((4, 8),), ((0, 12),)) == ((0, 4), (8, 12))
        assert d(((0, 8),), ((0, 8),)) == ()
        assert d((), ((2, 4),)) == ((2, 4),)
        assert d(((0, 16),), ()) == ()
        assert d(((0, 2), (6, 8)), ((0, 8),)) == ((2, 6),)


# --------------------------------------------------------- byte accounting
class TestDeltaAccounting:
    def test_loadstats_accumulate(self):
        a = LoadStats(bytes_read=10, total_bytes=100, files_read=1,
                      total_files=5, groups_read=1, total_groups=5)
        b = LoadStats(bytes_read=20, total_bytes=100, files_read=2,
                      total_files=5, groups_read=2, total_groups=5)
        out = a.accumulate(b)
        assert out is a
        assert a.bytes_read == 30 and a.files_read == 3
        assert a.groups_read == 3 and a.reads == 2
        assert a.total_bytes == 100 and a.total_files == 5

    def test_expert_blocks_reassemble_and_account(self, saved):
        _, _, d = saved
        full = pipeline.CompressedArtifact.load(d)
        parts = pipeline.load_expert_blocks(d, [(0, 5), (5, 16)],
                                            include_dense=True)
        assert len(parts) == 3
        merged = merge_subset_trees(parts)
        assert _tree_equal(merged, full.params)
        total = sum(st.bytes_read for _, st in parts)
        assert total == full.load_stats.bytes_read
        # a single block is a strict subset of the artifact
        blk = parts[1][1]
        assert 0 < blk.bytes_read < full.load_stats.bytes_read
        with pytest.raises(ValueError, match="empty expert block"):
            pipeline.load_expert_blocks(d, [(3, 3)])

    def test_artifact_expert_bytes(self, saved):
        _, _, d = saved
        n, ebytes = pipeline.artifact_expert_bytes(d)
        assert n == 16 and len(ebytes) == 16
        assert all(b > 0 for b in ebytes)


# ------------------------------------------------------------- supervision
class TestSupervision:
    def test_parse_fault_spec(self):
        ev = parse_fault_spec("replica:1@5")
        assert (ev.kind, ev.replica, ev.tick) == (KILL_REPLICA, 1, 5)
        ev = parse_fault_spec("host:0.2@7")
        assert (ev.kind, ev.replica, ev.host, ev.tick) == \
            (KILL_HOST, 0, 2, 7)
        ev = parse_fault_spec("join:3@2")
        assert (ev.kind, ev.replica) == (JOIN_HOST, 3)
        for bad in ("replica:1", "host:0@3", "nope:1@2", "replica:x@2"):
            with pytest.raises(ValueError):
                parse_fault_spec(bad)

    def test_injector_fires_once_in_order(self):
        inj = FaultInjector([
            FaultEvent(tick=5, kind=KILL_REPLICA, replica=1),
            FaultEvent(tick=2, kind=KILL_HOST, replica=0, host=1)])
        assert inj.pending == 2
        assert inj.due(1) == []
        ev = inj.due(3)
        assert len(ev) == 1 and ev[0].kind == KILL_HOST
        assert [e.kind for e in inj.due(99)] == [KILL_REPLICA]
        assert inj.due(99) == [] and inj.pending == 0
        assert len(inj.fired) == 2

    def test_bad_event_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultEvent(tick=1, kind="explode", replica=0)
        with pytest.raises(ValueError, match="host index"):
            FaultEvent(tick=1, kind=KILL_HOST, replica=0)

    def test_supervisor_detects_silence_once(self, tmp_path):
        sup = FleetSupervisor(directory=tmp_path / "hb", timeout=3.0)
        for t in range(1, 4):
            sup.beat(0, step=t, now=float(t))
            sup.beat(1, step=t, now=float(t))
        for t in range(4, 7):       # replica 1 goes silent after tick 3
            sup.beat(0, step=t, now=float(t))
            assert sup.check(now=float(t)) == []
        assert sup.check(now=7.0) == [1]
        assert sup.check(now=8.0) == []     # reported exactly once

    def test_supervisor_retire_is_not_death(self, tmp_path):
        sup = FleetSupervisor(directory=tmp_path / "hb", timeout=2.0)
        sup.beat(0, step=1, now=1.0)
        sup.retire(0)
        assert sup.check(now=50.0) == []

    def test_supervisor_stragglers(self, tmp_path):
        sup = FleetSupervisor(directory=tmp_path / "hb", timeout=3.0,
                              straggler_z=3.0)
        for t in range(30):
            sup.beat(0, step=t, now=float(t),
                     step_s=0.1 + 0.001 * (t % 3))
        assert not sup.stragglers
        sup.beat(0, step=30, now=30.0, step_s=1.5)
        assert sup.stragglers and sup.stragglers[-1]["replica"] == 0


# ------------------------------------------------- router (fake replicas)
class _FakeReplica:
    """Engine-free replica: completes each request after ``steps`` pumps."""

    def __init__(self, replica_id, steps=3):
        self.replica_id = replica_id
        self.alive = True
        self.steps = steps
        self._work = {}

    @property
    def busy(self):
        return self.alive and bool(self._work)

    def submit(self, requests):
        for r in requests:
            self._work[r.uid] = self.steps

    def pump(self):
        done = []
        for uid in list(self._work):
            self._work[uid] -= 1
            if self._work[uid] <= 0:
                del self._work[uid]
                done.append(Result(
                    uid=uid, tokens=np.zeros(1, np.int32), prefill_s=0.0,
                    decode_s=0.0, new_tokens=1, finish_reason="length"))
        return done

    def kill(self):
        self.alive = False
        self._work.clear()


class TestRouterPolicy:
    def test_admission_sheds_on_full_queue(self, tmp_path):
        router = FleetRouter([_FakeReplica(0)], tmp_path / "hb",
                             config=RouterConfig(max_queue=2))
        rpt = router.run(_reqs(n=5))
        assert rpt.submitted == 5 and rpt.admitted == 2
        assert len(rpt.shed_queue_full) == 3
        assert len(rpt.completed) == 2
        assert rpt.availability == 1.0

    def test_deadline_sheds_stale_queue(self, tmp_path):
        router = FleetRouter(
            [_FakeReplica(0, steps=5)], tmp_path / "hb",
            config=RouterConfig(replica_depth=1, default_sla=3))
        rpt = router.run(_reqs(n=3))
        assert len(rpt.completed) == 1
        assert len(rpt.shed_deadline) == 2
        assert rpt.availability == 1.0
        # the one that did run finished late — recorded, not shed
        assert rpt.sla_misses == [0]

    def test_replica_death_retries_on_survivor(self, tmp_path):
        inj = FaultInjector([FaultEvent(tick=2, kind=KILL_REPLICA,
                                        replica=0)])
        router = FleetRouter(
            [_FakeReplica(0, steps=4), _FakeReplica(1, steps=4)],
            tmp_path / "hb",
            config=RouterConfig(heartbeat_timeout=2.0), injector=inj)
        rpt = router.run(_reqs(n=4))
        assert len(rpt.completed) == 4
        assert rpt.deaths and rpt.deaths[0]["replica"] == 0
        assert rpt.retries > 0
        assert rpt.availability == 1.0

    def test_all_replicas_dead_fails_outstanding(self, tmp_path):
        inj = FaultInjector([FaultEvent(tick=1, kind=KILL_REPLICA,
                                        replica=0)])
        router = FleetRouter([_FakeReplica(0, steps=10)], tmp_path / "hb",
                             config=RouterConfig(), injector=inj)
        rpt = router.run(_reqs(n=3))
        assert not rpt.completed
        assert sorted(rpt.failed) == [0, 1, 2]

    def test_retries_exhausted_fails_request(self, tmp_path):
        inj = FaultInjector([FaultEvent(tick=2, kind=KILL_REPLICA,
                                        replica=0)])
        router = FleetRouter(
            [_FakeReplica(0, steps=6), _FakeReplica(1, steps=6)],
            tmp_path / "hb",
            config=RouterConfig(max_retries=0, heartbeat_timeout=2.0,
                                replica_depth=2),
            injector=inj)
        rpt = router.run(_reqs(n=4))
        assert rpt.failed                      # replica 0's share gave up
        assert len(rpt.completed) + len(rpt.failed) == 4
        assert rpt.retries == 0


# ---------------------------------------------- fleet integration (real)
class TestFleetIntegration:
    def test_host_loss_then_join(self, saved, ref, tmp_path):
        """Mid-decode host loss: drain, delta-stream, resume — every
        admitted request completes token-identically to the
        uninterrupted run, and strictly fewer bytes stream than a full
        reload. Then a host joins with zero interruption."""
        model, _, d = saved
        _, want = ref
        rep = ShardedReplica(model, d, replica_id=0, num_hosts=2,
                             blocks_per_host=2, batch_size=2, odp="off")
        boot_bytes = rep.load_stats.bytes_read
        assert rep.load_stats.reads == 5       # dense + 4 blocks
        inj = FaultInjector([FaultEvent(tick=4, kind=KILL_HOST,
                                        replica=0, host=0)])
        router = FleetRouter([rep], tmp_path / "hb", injector=inj)
        rpt = router.run(_reqs())

        got = {r.uid: [int(t) for t in r.tokens]
               for r in rpt.completed.values()}
        assert got == want                     # token-identical recovery
        assert rpt.availability == 1.0
        ev = rpt.reshards[0]
        assert ev.kind == "host_loss" and ev.requeued > 0
        assert 0 < ev.delta_bytes < ev.full_reload_bytes
        assert rep.load_stats.bytes_read == boot_bytes + ev.delta_bytes
        assert rep.hosts == (1,)

        ev2 = rep.join_host()
        assert ev2.kind == "host_join" and len(rep.hosts) == 2
        assert 0 < ev2.delta_bytes < ev2.full_reload_bytes
        assert rep.load_stats.bytes_read == \
            boot_bytes + ev.delta_bytes + ev2.delta_bytes

    def test_lost_last_host_is_replica_death(self, saved, tmp_path):
        model, _, d = saved
        rep = ShardedReplica(model, d, replica_id=0, num_hosts=1,
                             blocks_per_host=2, batch_size=2, odp="off")
        inj = FaultInjector([FaultEvent(tick=2, kind=KILL_HOST,
                                        replica=0, host=0)])
        router = FleetRouter([rep], tmp_path / "hb", injector=inj)
        rpt = router.run(_reqs(n=2))
        assert not rep.alive
        assert sorted(rpt.failed) == [0, 1]    # no survivor to retry on

    @pytest.mark.slow
    def test_replica_kill_mid_decode(self, saved, ref, tmp_path):
        """Two real replicas; one dies mid-decode. Heartbeat silence is
        detected, its requests retry from originals on the survivor, and
        every admitted request completes token-identically."""
        model, _, d = saved
        _, want = ref
        pool = [ShardedReplica(model, d, replica_id=i, num_hosts=2,
                               blocks_per_host=2, batch_size=2, odp="off")
                for i in range(2)]
        inj = FaultInjector([FaultEvent(tick=3, kind=KILL_REPLICA,
                                        replica=0)])
        router = FleetRouter(pool, tmp_path / "hb", injector=inj)
        rpt = router.run(_reqs())
        got = {r.uid: [int(t) for t in r.tokens]
               for r in rpt.completed.values()}
        assert got == want
        assert rpt.availability == 1.0
        assert rpt.deaths and rpt.deaths[0]["replica"] == 0
        assert rpt.retries > 0

    def test_mesh_reshard_delta_same_mesh_is_empty(self, saved):
        import jax
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        segs = ((0, 16),)
        assert elastic.mesh_reshard_delta(mesh, mesh, segs) == ()
