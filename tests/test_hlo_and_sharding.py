"""HLO cost-analysis + partitioning-rule tests.

The multi-device probes run in a subprocess so the main test process keeps
its single CPU device (the dry-run owns the 512-device configuration).
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import analyze, parse_computations

ROOT = Path(__file__).resolve().parents[1]


class TestSanitize:
    def test_sanitize_spec(self):
        from repro.sharding.partitioning import sanitize_spec
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        s = jax.ShapeDtypeStruct((8, 6), jnp_f32())
        # axes exist and divide
        assert tuple(sanitize_spec(mesh, P("data", "model"), (8, 16))) == \
            ("data", "model")
        # unknown axis dropped
        assert tuple(sanitize_spec(mesh, P("pod", None), (8, 6))) == \
            (None, None)

    def test_sanitize_divisibility(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        from repro.sharding.partitioning import sanitize_spec
        # size-1 axes always divide on a 1x1 mesh
        assert tuple(sanitize_spec(mesh, P("model"), (7,))) == ("model",)


def jnp_f32():
    import jax.numpy as jnp
    return jnp.float32


_PROBE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys; sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.hlo_analysis import analyze
    from repro.sharding import context as shctx
    mesh = jax.make_mesh((4,), ("d",))
    sh = NamedSharding(mesh, P("d", None))
    N = 256
    def g(a):
        def body(c, _):
            return c @ jnp.ones((N, N), jnp.float32), None
        out, _ = jax.lax.scan(body, a, None, length=8)
        return out
    with shctx.activate_mesh(mesh):
        c = jax.jit(g, in_shardings=sh).lower(
            jax.ShapeDtypeStruct((N, N), jnp.float32)).compile()
    res = analyze(c.as_text())
    expect = 8 * 2 * 64 * 256 * 256
    assert abs(res.flops - expect) / expect < 1e-6, (res.flops, expect)
    print("PROBE_OK", res.flops)
""")


class TestHloAnalysis:
    @pytest.mark.slow
    def test_scan_trip_counts_exact(self):
        """Loop bodies must be counted trip-count times (XLA counts once)."""
        out = subprocess.run(
            [sys.executable, "-c", _PROBE.format(src=str(ROOT / "src"))],
            capture_output=True, text=True, timeout=300)
        assert "PROBE_OK" in out.stdout, out.stderr[-2000:]

    def test_parse_computations_structure(self):
        hlo = textwrap.dedent("""\
        HloModule test

        %fused_computation (param_0: f32[8,8]) -> f32[8,8] {
          %param_0 = f32[8,8]{1,0} parameter(0)
          ROOT %c = f32[8,8]{1,0} convert(%param_0)
        }

        ENTRY %main (p: f32[8,8]) -> f32[8,8] {
          %p = f32[8,8]{1,0} parameter(0)
          %dot = f32[8,8]{1,0} dot(%p, %p), lhs_contracting_dims={1}, rhs_contracting_dims={0}
          ROOT %f = f32[8,8]{1,0} fusion(%dot), kind=kLoop, calls=%fused_computation
        }
        """)
        comps, entry = parse_computations(hlo)
        assert entry == "main"
        assert "fused_computation" in comps
        cost = analyze(hlo)
        assert cost.flops == 2 * 8 * 8 * 8
        assert cost.dot_count == 1

    def test_collective_bytes(self):
        hlo = textwrap.dedent("""\
        HloModule test

        ENTRY %main (p: f32[128,128]) -> f32[128,128] {
          %p = f32[128,128]{1,0} parameter(0)
          ROOT %ar = f32[128,128]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add
        }
        """)
        cost = analyze(hlo)
        assert cost.collective_bytes == 128 * 128 * 4
        assert cost.collective_counts.get("all-reduce") == 1
