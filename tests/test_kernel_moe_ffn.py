"""Fused grouped quantized-MoE FFN kernel: oracle equivalence + wiring.

Slow slice: interpret-mode Pallas kernel vs the jnp oracle across bit
mixes, ragged per-expert counts (incl. zero-token experts) and multi-tile
grids. Fast slice: the staged-vs-fused equivalence through ``apply_moe``
(CPU ref path), the decode-regroup path, the launch-count probe (one
``pallas_call`` per MoE layer vs 3 x num_classes), the quantized
shard_map EP body vs the gather path on a single-device mesh, and the
``quant_matmul`` block auto-shrink/pad satellite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import pack_random_experts as _pack_experts
from repro.config import CompressionConfig
from repro.configs import get_config
from repro.core import pmq as pmq_lib
from repro.kernels import common as kcommon
from repro.kernels.common import pack_kernel_layout
from repro.kernels.moe_ffn.ops import moe_ffn_quant
from repro.kernels.moe_ffn.ref import moe_ffn_ref
from repro.kernels.quant_matmul.ops import quant_matmul
from repro.kernels.quant_matmul.ref import quant_matmul_ref
from repro.models.layers import moe as moe_lib
from repro.models.layers.moe import MoEQuantMeta
from repro.quant import rtn_quantize


def _ref(x, experts_q, counts, meta, act="silu"):
    classes = [experts_q[f"cls{ci}"]
               for ci in range(len(meta.bit_classes))]
    return moe_ffn_ref(x, classes, counts, meta=meta, act=act)


def _quant_moe_layer(cfg, bits_per_expert, seed=0):
    """A quantized MoE layer (params + meta) at forced per-expert widths."""
    p = moe_lib.init_moe(jax.random.PRNGKey(seed), cfg)
    ccfg = CompressionConfig(enabled=True, target_bits=2.5, group_size=32)
    rng = np.random.RandomState(seed)
    calib_x = jnp.asarray(
        rng.randn(64, cfg.d_model).astype(np.float32))
    idx = np.stack([rng.permutation(cfg.num_experts)[:cfg.top_k]
                    for _ in range(64)])
    bits = np.asarray(bits_per_expert, np.int64)
    order = np.argsort(bits, kind="stable")
    classes, counts = np.unique(bits[order], return_counts=True)
    pack_block = 128 if (cfg.d_model % 128 == 0
                         and cfg.moe_d_ff % 128 == 0) else ccfg.group_size
    meta = MoEQuantMeta(bit_classes=tuple(int(b) for b in classes),
                        class_counts=tuple(int(c) for c in counts),
                        group_size=ccfg.group_size, pack_block=pack_block)
    qp = pmq_lib.quantize_moe_layer(cfg, ccfg, p, calib_x, idx,
                                    bits_per_expert=bits, order=order,
                                    meta=meta)
    return qp, meta


@pytest.mark.slow
class TestFusedKernelVsOracle:
    @pytest.mark.parametrize("bits", [1, 2, 3, 4])
    def test_single_class(self, bits):
        experts_q, meta = _pack_experts((bits,), (3,))
        x = jax.random.normal(jax.random.PRNGKey(bits), (3, 16, 128))
        counts = jnp.asarray([16, 5, 0], jnp.int32)   # full/ragged/empty
        ref = _ref(x, experts_q, counts, meta)
        out = moe_ffn_quant(x, experts_q, counts, meta=meta, act="silu",
                            impl="interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("mix,counts", [
        ((1, 2, 3), (2, 1, 2)), ((2, 4), (2, 2)), ((1, 4), (1, 3)),
    ])
    def test_grouped_classes_ragged(self, mix, counts):
        e = sum(counts)
        experts_q, meta = _pack_experts(mix, counts)
        x = jax.random.normal(jax.random.PRNGKey(7), (e, 24, 128))
        cnts = jnp.asarray([(3 * i) % 25 for i in range(e)], jnp.int32)
        ref = _ref(x, experts_q, cnts, meta)
        out = moe_ffn_quant(x, experts_q, cnts, meta=meta, act="silu",
                            impl="interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_zero_token_expert_is_exact_zero(self):
        experts_q, meta = _pack_experts((2, 3), (1, 1))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 128))
        counts = jnp.asarray([0, 8], jnp.int32)
        out = moe_ffn_quant(x, experts_q, counts, meta=meta, act="silu",
                            impl="interpret")
        assert float(jnp.abs(out[0]).max()) == 0.0
        assert float(jnp.abs(out[1]).max()) > 0.0

    def test_multi_tile_grid(self):
        # force NM > 1, NF > 1: M=32 @ bm=8, F=256 @ bf=128
        experts_q, meta = _pack_experts((2,), (2,), f=256, pb=128)
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 128))
        counts = jnp.asarray([9, 32], jnp.int32)
        ref = _ref(x, experts_q, counts, meta)
        out = moe_ffn_quant(x, experts_q, counts, meta=meta, act="silu",
                            impl="interpret", block_m=8, block_f=128)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_activation_variants(self):
        experts_q, meta = _pack_experts((3,), (2,))
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 128))
        counts = jnp.asarray([8, 8], jnp.int32)
        for act in ("silu", "gelu", "relu"):
            ref = _ref(x, experts_q, counts, meta, act=act)
            out = moe_ffn_quant(x, experts_q, counts, meta=meta, act=act,
                                impl="interpret")
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-5, atol=1e-5)


class TestRefVsStagedComposition:
    """The fused oracle is token-identical to the staged per-expert
    quant_matmul_ref composition on live rows (the pre-fusion math)."""

    @pytest.mark.parametrize("mix,counts", [((1, 2, 3, 4), (1, 1, 1, 1)),
                                            ((2,), (3,))])
    def test_matches(self, mix, counts):
        e = sum(counts)
        gs, pb, d, f = 32, 128, 128, 256
        experts_q, meta = _pack_experts(mix, counts, d=d, f=f, gs=gs, pb=pb)
        m = 8
        x = jax.random.normal(jax.random.PRNGKey(9), (e, m, d))
        cnts = jnp.asarray([m] * e, jnp.int32)
        fused = _ref(x, experts_q, cnts, meta)
        for ci, (bits, e0, cnt) in enumerate(meta.class_slices()):
            w = experts_q[f"cls{ci}"]
            for j in range(cnt):
                def one(tag, xin, j=j, w=w, bits=bits, ci=ci):
                    planes = tuple(w[f"{tag}_{s}"][j]
                                   for s in meta.plane_suffixes[ci])
                    z = w.get(f"{tag}_z")
                    return quant_matmul_ref(
                        xin, planes, w[f"{tag}_s"][j],
                        z[j] if z is not None else None, bits=bits,
                        group_size=gs, pack_block=pb)
                h = one("in", x[e0 + j])
                g = one("gate", x[e0 + j])
                y = one("out", jax.nn.silu(g) * h)
                np.testing.assert_allclose(np.asarray(fused[e0 + j]),
                                           np.asarray(y),
                                           rtol=1e-5, atol=1e-5)


class TestApplyMoeFusedPath:
    def _cfg(self):
        return get_config("mixtral-8x7b", smoke=True).replace(
            dtype="float32", d_model=128, moe_d_ff=256, num_experts=8,
            capacity_factor=8.0)

    def test_prefill_fused_equals_staged(self):
        cfg = self._cfg()
        qp, meta = _quant_moe_layer(cfg, [1, 1, 2, 2, 2, 3, 3, 3])
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 128))
        yf, _ = moe_lib.apply_moe(qp, x, cfg, quant_meta=meta,
                                  quant_path="fused")
        ys, _ = moe_lib.apply_moe(qp, x, cfg, quant_meta=meta,
                                  quant_path="staged")
        np.testing.assert_allclose(np.asarray(yf), np.asarray(ys),
                                   rtol=1e-5, atol=1e-6)

    def test_decode_regroup_fused_equals_staged(self):
        cfg = self._cfg()
        qp, meta = _quant_moe_layer(cfg, [1, 2, 2, 2, 3, 3, 4, 4])
        xd = jax.random.normal(jax.random.PRNGKey(3), (6, 1, 128))
        yf, auxf = moe_lib.apply_moe(qp, xd, cfg, quant_meta=meta,
                                     quant_path="fused")
        ys, auxs = moe_lib.apply_moe(qp, xd, cfg, quant_meta=meta,
                                     quant_path="staged")
        assert yf.shape == (6, 1, 128)
        np.testing.assert_allclose(np.asarray(yf), np.asarray(ys),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(auxf["topk_idx"]),
                                      np.asarray(auxs["topk_idx"]))

    def test_token_mask_zeroes_inactive_slots(self):
        cfg = self._cfg()
        qp, meta = _quant_moe_layer(cfg, [2] * 8)
        xd = jax.random.normal(jax.random.PRNGKey(5), (4, 1, 128))
        mask = jnp.asarray([[True], [False], [True], [False]])
        y, _ = moe_lib.apply_moe(qp, xd, cfg, quant_meta=meta,
                                 token_mask=mask)
        assert float(jnp.abs(y[1]).max()) == 0.0
        assert float(jnp.abs(y[0]).max()) > 0.0

    def test_launch_count_probe(self):
        """Acceptance: ONE pallas_call per MoE layer on the fused quant
        path, replacing 3 x num_classes on the staged baseline."""
        cfg = self._cfg()
        qp, meta = _quant_moe_layer(cfg, [1, 1, 2, 2, 2, 3, 3, 3])
        n_classes = len(meta.bit_classes)
        assert n_classes == 3
        xd = jax.random.normal(jax.random.PRNGKey(4), (4, 1, 128))
        with kcommon.override_impl("pallas"):
            fused = kcommon.count_pallas_calls(
                lambda xx: moe_lib.apply_moe(
                    qp, xx, cfg, quant_meta=meta, quant_path="fused")[0],
                xd)
            staged = kcommon.count_pallas_calls(
                lambda xx: moe_lib.apply_moe(
                    qp, xx, cfg, quant_meta=meta, quant_path="staged")[0],
                xd)
        assert fused == 1, fused
        assert staged == 3 * n_classes, staged

    def test_plane_suffixes_precomputed(self):
        meta = MoEQuantMeta(bit_classes=(1, 3), class_counts=(2, 2))
        assert meta.plane_suffixes == (("p0",), ("p0", "p1"))
        # explicit construction (pipeline.apply) round-trips unchanged
        meta2 = MoEQuantMeta(bit_classes=(1, 3), class_counts=(2, 2),
                             plane_suffixes=(("p0",), ("p0", "p1")))
        assert meta == meta2


class TestQuantizedShardMapEP:
    """Quantized ep_dispatch vs the gather path (single-device mesh; the
    simulated 2-device engine equivalence lives in test_moe_parallel)."""

    def test_ep_matches_gather(self):
        from repro.sharding.moe_parallel import apply_moe_shard_map
        from repro.sharding import context as shctx
        cfg = get_config("mixtral-8x7b", smoke=True).replace(
            dtype="float32", d_model=128, moe_d_ff=256, num_experts=8,
            capacity_factor=8.0)
        qp, meta = _quant_moe_layer(cfg, [1, 1, 2, 2, 3, 3, 3, 3])
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 8, 128))
        y_ref, _ = moe_lib.apply_moe(qp, x, cfg, quant_meta=meta)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with shctx.use_mesh_axes(("data", "model"), (1, 1)), \
                shctx.activate_mesh(mesh):
            y_ep = jax.jit(lambda p_, x_: apply_moe_shard_map(
                p_, x_, cfg, mesh, quant_meta=meta))(qp, x)
        rel = float(jnp.linalg.norm(y_ep - y_ref)
                    / jnp.linalg.norm(y_ref))
        assert rel < 2e-3, rel

    def test_ep_slot_table_shard_major(self):
        from repro.sharding.moe_parallel import (ep_slot_table,
                                                 local_quant_meta,
                                                 validate_ep_quant_meta)
        meta = MoEQuantMeta(bit_classes=(1, 2, 3), class_counts=(2, 4, 2))
        table = ep_slot_table(meta, 2)
        # shard 0: cls0[0], cls1[0:2], cls2[0]; shard 1: the second halves
        np.testing.assert_array_equal(table, [0, 4, 1, 2, 5, 6, 3, 7])
        lm = local_quant_meta(meta, 2)
        assert lm.class_counts == (1, 2, 1)
        with pytest.raises(ValueError, match="divide"):
            validate_ep_quant_meta(
                MoEQuantMeta(bit_classes=(1, 2), class_counts=(3, 5)), 2)


class TestQuantMatmulBlockFit:
    """Satellite: non-multiple N auto-shrinks/pads; bad K errors clearly."""

    def _mk(self, k, n, bits=2, gs=32):
        w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.1
        res = rtn_quantize(w, bits=bits, group_size=gs)
        planes = pack_kernel_layout(res.codes, bits, 128)
        return planes, res

    def test_block_n_shrinks_to_divisor(self):
        k, n = 128, 96          # 96 % 128 != 0 -> shrink block_n to 96
        planes, res = self._mk(k, n)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, k))
        ref = quant_matmul_ref(x, planes, res.scales, res.zeros, bits=2,
                               group_size=32, pack_block=128)
        out = quant_matmul(x, planes, res.scales, res.zeros, bits=2,
                           group_size=32, impl="interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    def test_block_n_pads_when_unaligned(self):
        k, n = 128, 100         # no aligned divisor -> pad N to 104
        planes, res = self._mk(k, n)
        x = jax.random.normal(jax.random.PRNGKey(2), (4, k))
        ref = quant_matmul_ref(x, planes, res.scales, res.zeros, bits=2,
                               group_size=32, pack_block=128)
        out = quant_matmul(x, planes, res.scales, res.zeros, bits=2,
                           group_size=32, impl="interpret")
        assert out.shape == (4, n)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    def test_bad_k_raises_named_error(self):
        planes, res = self._mk(128, 128)
        x = jax.random.normal(jax.random.PRNGKey(3), (4, 192))
        with pytest.raises(ValueError, match="K=192.*pack_block=128"):
            quant_matmul(x, planes, res.scales, res.zeros, bits=2,
                         group_size=32, impl="interpret")

    def test_bad_group_size_raises(self):
        planes, res = self._mk(128, 128, gs=32)
        x = jax.random.normal(jax.random.PRNGKey(4), (4, 128))
        with pytest.raises(ValueError, match="group_size"):
            quant_matmul(x, planes, res.scales, res.zeros, bits=2,
                         group_size=48, impl="interpret")
