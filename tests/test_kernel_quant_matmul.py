"""Kernel-vs-oracle validation for the fused dequant GEMM (interpret mode).

Sweeps shapes, bit-widths and dtypes per the deliverable: every Pallas kernel
is checked against its pure-jnp ref and against a float matmul with
dequantized weights.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytestmark = pytest.mark.slow


from repro.kernels.common import pack_kernel_layout, unpack_kernel_layout
from repro.kernels.quant_matmul.ops import quant_matmul
from repro.kernels.quant_matmul.ref import dequant_ref, quant_matmul_ref
from repro.quant import rtn_quantize


def _make(bits, k=256, n=256, group=128, pack_block=128, seed=0, e=None):
    key = jax.random.PRNGKey(seed)
    kw, kx = jax.random.split(key)
    shape = (k, n) if e is None else (e, k, n)
    w = jax.random.normal(kw, shape) * 0.1
    if e is None:
        res = rtn_quantize(w, bits=bits, group_size=group)
        planes = pack_kernel_layout(res.codes, bits, pack_block)
        return w, planes, res.scales, res.zeros
    rs = [rtn_quantize(w[i], bits=bits, group_size=group) for i in range(e)]
    planes = [pack_kernel_layout(r.codes, bits, pack_block) for r in rs]
    planes = tuple(jnp.stack([p[i] for p in planes])
                   for i in range(len(planes[0])))
    scales = jnp.stack([r.scales for r in rs])
    zeros = jnp.stack([r.zeros for r in rs])
    return w, planes, scales, zeros


class TestKernelLayout:
    @pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
    def test_layout_roundtrip(self, bits):
        codes = jax.random.randint(jax.random.PRNGKey(bits), (256, 128), 0,
                                   2 ** bits).astype(jnp.uint8)
        planes = pack_kernel_layout(codes, bits, 128)
        out = unpack_kernel_layout(planes, bits, 256, 128)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))

    @pytest.mark.parametrize("bits", [2, 3])
    def test_dequant_ref_matches_dense(self, bits):
        w, planes, scales, zeros = _make(bits)
        res = rtn_quantize(w, bits=bits, group_size=128)
        from repro.quant import gptq_dequantize
        dense = gptq_dequantize(res)
        wref = dequant_ref(planes, scales, zeros, bits=bits, group_size=128,
                           d_in=256, pack_block=128)
        np.testing.assert_allclose(np.asarray(wref), np.asarray(dense),
                                   rtol=1e-5, atol=1e-6)


class TestQuantMatmulKernel:
    @pytest.mark.parametrize("bits", [1, 2, 3, 4])
    @pytest.mark.parametrize("m", [1, 7, 128])
    def test_matches_ref(self, bits, m):
        w, planes, scales, zeros = _make(bits)
        x = jax.random.normal(jax.random.PRNGKey(m), (m, 256))
        ref = quant_matmul_ref(x, planes, scales, zeros, bits=bits,
                               group_size=128, pack_block=128)
        out = quant_matmul(x, planes, scales, zeros, bits=bits,
                           group_size=128, impl="interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("bits,k,n,group", [
        (2, 128, 128, 128), (2, 512, 256, 128), (3, 256, 384, 64),
        (4, 384, 128, 128), (1, 256, 128, 64),
    ])
    def test_shape_sweep(self, bits, k, n, group):
        w, planes, scales, zeros = _make(bits, k=k, n=n, group=group)
        x = jax.random.normal(jax.random.PRNGKey(1), (16, k))
        ref = quant_matmul_ref(x, planes, scales, zeros, bits=bits,
                               group_size=group, pack_block=128)
        out = quant_matmul(x, planes, scales, zeros, bits=bits,
                           group_size=group, impl="interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, xdtype):
        w, planes, scales, zeros = _make(2)
        x = jax.random.normal(jax.random.PRNGKey(2), (8, 256)).astype(xdtype)
        ref = quant_matmul_ref(x, planes, scales, zeros, bits=2,
                               group_size=128, pack_block=128)
        out = quant_matmul(x, planes, scales, zeros, bits=2, group_size=128,
                           impl="interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-2, atol=2e-2)

    def test_against_true_dense_matmul(self):
        """End-to-end: kernel(x, pack(quantize(w))) ~= x @ quant_dequant(w)."""
        from repro.quant import gptq_dequantize
        w, planes, scales, zeros = _make(4, k=256, n=128)
        res = rtn_quantize(w, bits=4, group_size=128)
        x = jax.random.normal(jax.random.PRNGKey(3), (4, 256))
        exact = x @ gptq_dequantize(res)
        out = quant_matmul(x, planes, scales, zeros, bits=4, group_size=128,
                           impl="interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(exact),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("bits", [2, 3])
    def test_expert_batched(self, bits):
        e = 4
        w, planes, scales, zeros = _make(bits, e=e)
        x = jax.random.normal(jax.random.PRNGKey(5), (e, 8, 256))
        ref = quant_matmul_ref(x, planes, scales, zeros, bits=bits,
                               group_size=128, pack_block=128)
        out = quant_matmul(x, planes, scales, zeros, bits=bits,
                           group_size=128, impl="interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    def test_cpu_fallback_path(self):
        w, planes, scales, zeros = _make(2)
        x = jax.random.normal(jax.random.PRNGKey(6), (4, 256))
        out = quant_matmul(x, planes, scales, zeros, bits=2, group_size=128,
                           impl="auto")   # CPU -> XLA ref
        ref = quant_matmul_ref(x, planes, scales, zeros, bits=2,
                               group_size=128, pack_block=128)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5)
