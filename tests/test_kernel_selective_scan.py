"""Selective-scan kernel vs oracle (interpret mode), shape/chunk sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytestmark = pytest.mark.slow


from repro.kernels.selective_scan.ops import selective_scan
from repro.kernels.selective_scan.ref import selective_scan_ref


def _inputs(key, b=2, s=256, i=128, n=16):
    ks = jax.random.split(jax.random.PRNGKey(key), 6)
    delta = jax.nn.softplus(jax.random.normal(ks[0], (b, s, i)) - 1)
    x = jax.random.normal(ks[1], (b, s, i))
    bm = jax.random.normal(ks[2], (b, s, n)) * 0.5
    cm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    a = -jnp.exp(jax.random.normal(ks[4], (i, n)) * 0.3)
    h0 = jax.random.normal(ks[5], (b, i, n)) * 0.1
    return delta, x, bm, cm, a, h0


class TestSelectiveScanKernel:
    @pytest.mark.parametrize("s,i,bs,bi", [
        (256, 128, 128, 128), (128, 256, 64, 128), (512, 128, 128, 64)])
    def test_matches_ref(self, s, i, bs, bi):
        args = _inputs(0, s=s, i=i)
        ref_y, ref_h = selective_scan_ref(*args)
        y, h = selective_scan(*args, impl="interpret", block_i=bi,
                              block_s=bs)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref_y),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(h), np.asarray(ref_h),
                                   rtol=2e-4, atol=2e-4)

    def test_state_carry_across_time_blocks(self):
        """h must persist across the sequential S grid dimension."""
        args = _inputs(1, s=512, i=128)
        y, h = selective_scan(*args, impl="interpret", block_s=128)
        ref_y, ref_h = selective_scan_ref(*args)
        np.testing.assert_allclose(np.asarray(y[:, -1]),
                                   np.asarray(ref_y[:, -1]),
                                   rtol=2e-4, atol=2e-4)

    def test_scan_schedules_agree(self):
        """Model-level: assoc and fused_seq schedules are identical."""
        from repro.configs import get_config
        from repro.models.layers import ssm as ssm_lib
        cfg = get_config("falcon-mamba-7b", smoke=True).replace(
            dtype="float32", d_model=64)
        p = ssm_lib.init_mamba1(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
        out_assoc, _ = ssm_lib.apply_mamba1(p, x, cfg)
        cfg2 = cfg.replace(ssm_scan="fused_seq")
        out_seq, _ = ssm_lib.apply_mamba1(p, x, cfg2)
        np.testing.assert_allclose(np.asarray(out_assoc),
                                   np.asarray(out_seq), rtol=2e-4, atol=2e-4)
