"""Kernel-vs-oracle tests for the fused token-importance reduction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytestmark = pytest.mark.slow


from repro.kernels.binary_matmul.ops import binary_matmul
from repro.kernels.binary_matmul.ref import binary_matmul_ref
from repro.kernels.common import pack_kernel_layout
from repro.kernels.token_importance.ops import token_importance
from repro.kernels.token_importance.ref import token_importance_ref
from repro.quant import rtn_quantize


def _probs(key, h, l):
    logits = jax.random.normal(jax.random.PRNGKey(key), (h, l, l))
    mask = jnp.tril(jnp.ones((l, l), bool))
    logits = jnp.where(mask[None], logits, -1e9)
    return jax.nn.softmax(logits, axis=-1)


class TestTokenImportance:
    @pytest.mark.parametrize("h,l", [(2, 128), (4, 256), (8, 128)])
    def test_matches_ref(self, h, l):
        probs = _probs(0, h, l)
        t = jax.random.normal(jax.random.PRNGKey(1), (l, 64))
        ref = token_importance_ref(probs, t)
        out = token_importance(probs, t, impl="interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)

    def test_batched(self):
        probs = jnp.stack([_probs(2, 2, 128), _probs(3, 2, 128)])
        t = jax.random.normal(jax.random.PRNGKey(4), (2, 128, 32))
        out = token_importance(probs, t, impl="interpret")
        ref = jnp.stack([token_importance_ref(probs[i], t[i])
                         for i in range(2)])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)

    def test_high_attention_token_ranks_high(self):
        """A token every query attends to must get top importance."""
        l, h = 128, 2
        logits = jnp.full((h, l, l), -1e9)
        # all causal mass on token 7
        causal = jnp.tril(jnp.ones((l, l), bool))
        logits = jnp.where(causal[None], 0.0, -1e9)
        logits = logits.at[:, :, 7].set(jnp.where(jnp.arange(l) >= 7, 50.0,
                                                  -1e9)[None, :])
        probs = jax.nn.softmax(logits, axis=-1)
        t = jnp.ones((l, 16))
        imp = token_importance(probs, t, impl="interpret")
        assert int(jnp.argmax(imp)) == 7

    def test_magnitude_scales_importance(self):
        probs = _probs(5, 2, 128)
        t = jnp.ones((128, 16))
        t = t.at[11].mul(100.0)
        imp = np.asarray(token_importance(probs, t, impl="interpret"))
        base = np.asarray(token_importance(probs, jnp.ones((128, 16)),
                                           impl="interpret"))
        assert imp[11] / base[11] == pytest.approx(100.0, rel=1e-3)

    def test_non_divisible_length_falls_back(self):
        probs = _probs(6, 2, 96)
        t = jax.random.normal(jax.random.PRNGKey(7), (96, 8))
        out = token_importance(probs, t, impl="interpret")  # falls to ref
        ref = token_importance_ref(probs, t)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5)


class TestBinaryMatmul:
    @pytest.mark.parametrize("k,n,group", [(128, 128, 128), (256, 128, 64)])
    def test_matches_ref_and_dense(self, k, n, group):
        w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.1
        res = rtn_quantize(w, bits=1, group_size=group)
        plane = pack_kernel_layout(res.codes, 1, 128)[0]
        x = jax.random.normal(jax.random.PRNGKey(1), (8, k))
        ref = binary_matmul_ref(x, plane, res.scales, group_size=group,
                                pack_block=128)
        out = binary_matmul(x, plane, res.scales, group_size=group,
                            impl="interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        # dense check: matches x @ dequant(sign(w))
        from repro.quant import gptq_dequantize
        dense = x @ gptq_dequantize(res)
        np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                                   rtol=1e-4, atol=1e-4)
